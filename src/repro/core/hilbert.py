"""d-dimensional Hilbert curve ranks (for the Hilbert-packing baseline).

Vectorized iterative transpose algorithm (Skilling, AIP 2004): converts
integer grid coordinates to the Hilbert index, for arbitrary dimensionality.
``bits`` per dimension is capped so the interleaved rank fits in uint64,
which keeps everything fully vectorized.
"""
from __future__ import annotations

import numpy as np


def hilbert_rank(points: np.ndarray, bits: int | None = None) -> np.ndarray:
    """Hilbert indices for float points (any bounding box) as uint64.

    Points are normalized to the [0, 2^bits) integer grid per dimension;
    ``bits`` defaults to the largest precision with d*bits <= 63.
    """
    pts = np.asarray(points, dtype=np.float64)
    n, d = pts.shape
    if bits is None:
        bits = 63 // d
    bits = min(bits, 63 // d)
    lo = pts.min(axis=0)
    hi = pts.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    grid = ((pts - lo) / span * (2**bits - 1)).astype(np.uint64)
    x = grid.T.copy()  # (d, n)
    one = np.uint64(1)

    m = one << np.uint64(bits - 1)
    # Inverse undo excess work (Skilling transform)
    q = m
    while q > one:
        p = q - one
        for i in range(d):
            hit = (x[i] & q) != 0
            x[0][hit] ^= p  # invert
            t = (x[0] ^ x[i]) & p  # exchange
            x[0][~hit] ^= t[~hit]
            x[i][~hit] ^= t[~hit]
        q >>= one
    # Gray encode
    for i in range(1, d):
        x[i] ^= x[i - 1]
    t = np.zeros(n, dtype=np.uint64)
    q = m
    while q > one:
        mask = (x[d - 1] & q) != 0
        t[mask] ^= q - one
        q >>= one
    for i in range(d):
        x[i] ^= t

    # interleave bits (MSB of dim 0 first)
    ranks = np.zeros(n, dtype=np.uint64)
    for b in range(bits - 1, -1, -1):
        for i in range(d):
            ranks = (ranks << one) | ((x[i] >> np.uint64(b)) & one)
    return ranks


def hilbert_sort(points: np.ndarray, bits: int | None = None) -> np.ndarray:
    """Row order that sorts ``points`` along the Hilbert curve."""
    return np.argsort(hilbert_rank(points, bits=bits), kind="stable")
