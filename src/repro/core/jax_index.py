"""JAX-native FMBI: vectorized balanced median-split index build + queries.

This is the accelerator reformulation of the paper's bulk loader (DESIGN.md
section 2, level 2).  FMBI's structure — recursive median splits on the
highest-spread dimension, at page granularity — is built here as a fully
data-parallel computation with static shapes:

  * ``build``: ``levels`` rounds of segment-wise (per-group) spread
    computation, rank-median split, and group re-assignment.  After L rounds
    the points are partitioned into 2^L equal-size leaves ("pages"), each
    with a tight MBB — exactly the leaf level FMBI produces, computed with
    sorts over *tiles in fast memory* instead of external sorts (the paper's
    core insight, mapped onto the HBM->VMEM hierarchy).
  * ``route``: point -> leaf traversal through the recorded (dim, value)
    split tables; the Pallas kernel ``kernels/partition_assign`` implements
    the same loop with explicit VMEM tiling.
  * ``window_count`` / ``knn``: batched query execution, leaf-granular
    pruning followed by exact per-candidate-leaf scans (consuming
    ``kernels/knn_topk`` on TPU).

Everything is jit-able and shard_map-compatible (see ``distributed.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class JaxIndex:
    """Array-encoded balanced KD index: 2^levels equal leaves."""

    points_sorted: jnp.ndarray  # (n_pad, d) leaf-contiguous layout
    row_ids: jnp.ndarray        # (n_pad,) original row ids (-1 = padding)
    split_dim: jnp.ndarray      # (levels, n_groups_max) int32
    split_val: jnp.ndarray      # (levels, n_groups_max) float32
    leaf_lo: jnp.ndarray        # (n_leaves, d)
    leaf_hi: jnp.ndarray        # (n_leaves, d)
    levels: int
    leaf_size: int

    def tree_flatten(self):
        arrays = (
            self.points_sorted,
            self.row_ids,
            self.split_dim,
            self.split_val,
            self.leaf_lo,
            self.leaf_hi,
        )
        return arrays, (self.levels, self.leaf_size)

    @classmethod
    def tree_unflatten(cls, aux, arrays):
        return cls(*arrays, levels=aux[0], leaf_size=aux[1])

    @property
    def n_leaves(self) -> int:
        return 1 << self.levels


@partial(jax.jit, static_argnames=("levels",))
def build(points: jnp.ndarray, levels: int, row_ids=None) -> JaxIndex:
    """Build the balanced median-split index over ``points`` (n, d).

    n must be a multiple of 2^levels (callers pad; see ``pad_points``).
    ``row_ids`` carries original row identities (-1 for padding sentinels).
    """
    n, d = points.shape
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    n_groups_max = 1 << levels
    assert n % n_groups_max == 0, "pad points to a multiple of 2^levels"
    g = jnp.zeros(n, dtype=jnp.int32)
    pts = points
    split_dim = jnp.zeros((levels, n_groups_max), dtype=jnp.int32)
    split_val = jnp.full((levels, n_groups_max), jnp.inf, dtype=points.dtype)

    for level in range(levels):
        n_groups = 1 << level
        size = n // n_groups
        # spread per (group, dim) -> split dimension per group
        gmax = jax.ops.segment_max(pts, g, num_segments=n_groups)
        gmin = jax.ops.segment_min(pts, g, num_segments=n_groups)
        dim_g = jnp.argmax(gmax - gmin, axis=1).astype(jnp.int32)  # (G,)
        key = pts[jnp.arange(n), dim_g[g]]
        order = jnp.lexsort((key, g))
        pts = pts[order]
        g = g[order]
        row_ids = row_ids[order]
        half = size // 2
        rank = jnp.arange(n) % size
        child = (rank >= half).astype(jnp.int32)
        # record split value = key of last left point per group
        key_sorted = key[order]
        med = key_sorted[jnp.arange(n_groups) * size + (half - 1)]
        split_dim = split_dim.at[level, :n_groups].set(dim_g)
        split_val = split_val.at[level, :n_groups].set(med)
        g = g * 2 + child

    # leaf boxes
    leaf_lo = jax.ops.segment_min(pts, g, num_segments=n_groups_max)
    leaf_hi = jax.ops.segment_max(pts, g, num_segments=n_groups_max)
    # leaf-contiguous layout (g is already sorted into leaf order)
    return JaxIndex(
        points_sorted=pts,
        row_ids=row_ids.astype(jnp.int32),
        split_dim=split_dim,
        split_val=split_val,
        leaf_lo=leaf_lo,
        leaf_hi=leaf_hi,
        levels=levels,
        leaf_size=n // n_groups_max,
    )


def pad_points(points: np.ndarray, levels: int) -> tuple[np.ndarray, np.ndarray]:
    """Pad to a multiple of 2^levels with +inf sentinels (routed to the last
    leaf; queries mask them via row_ids == -1)."""
    n, d = points.shape
    unit = 1 << levels
    n_pad = -(-n // unit) * unit
    if n_pad == n:
        return points, np.arange(n)
    pad = np.full((n_pad - n, d), np.finfo(points.dtype).max, dtype=points.dtype)
    ids = np.concatenate([np.arange(n), np.full(n_pad - n, -1)])
    return np.concatenate([points, pad]), ids


@jax.jit
def nearest_leaf(index: JaxIndex, queries: jnp.ndarray) -> jnp.ndarray:
    """Leaf id with the smallest box mindist per query (0 for the containing
    leaf).  Works on any index — including tables bridged through
    ``NodeTable.to_jax_index``, which carry no split tables for ``route``."""
    gap = jnp.maximum(index.leaf_lo[None] - queries[:, None, :], 0.0) + jnp.maximum(
        queries[:, None, :] - index.leaf_hi[None], 0.0
    )
    return jnp.argmin(jnp.sum(gap * gap, axis=2), axis=1).astype(jnp.int32)


@jax.jit
def route(index: JaxIndex, queries: jnp.ndarray) -> jnp.ndarray:
    """Leaf id for each query point — the Step-2 routing loop."""
    q = queries
    g = jnp.zeros(q.shape[0], dtype=jnp.int32)
    for level in range(index.levels):
        dim = index.split_dim[level, g]
        val = index.split_val[level, g]
        coord = q[jnp.arange(q.shape[0]), dim]
        g = g * 2 + (coord > val).astype(jnp.int32)
    return g


@jax.jit
def _leaf_window_masks(index: JaxIndex, lo: jnp.ndarray, hi: jnp.ndarray):
    """(Q, L) masks: leaves intersecting each window, leaves fully inside."""
    inter = jnp.all(index.leaf_lo[None] <= hi[:, None, :], axis=2) & jnp.all(
        index.leaf_hi[None] >= lo[:, None, :], axis=2
    )
    contained = jnp.all(
        index.leaf_lo[None] >= lo[:, None, :], axis=2
    ) & jnp.all(index.leaf_hi[None] <= hi[:, None, :], axis=2)
    return inter, contained


# compiled-variant accounting: ``_window_count_core`` retraces once per
# (shape bucket, candidate budget, use_kernel) combination; budgets are
# always rounded to powers of two so the variant count stays O(log L) no
# matter how straddle widths drift across calls.  The counter increments at
# trace time (the body only runs when XLA compiles a new variant), which is
# what tests pin.
_TRACE_COUNTS = {"window_count_core": 0}


def window_count_traces() -> int:
    """How many times the counting core has been (re)compiled."""
    return _TRACE_COUNTS["window_count_core"]


def _pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


@partial(jax.jit, static_argnames=("n_candidate_leaves", "use_kernel"))
def _window_count_core(
    index: JaxIndex,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    contained: jnp.ndarray,
    straddle: jnp.ndarray,
    n_candidate_leaves: int,
    use_kernel: bool = False,
):
    """Counting pass over precomputed (Q, L) leaf masks."""
    _TRACE_COUNTS["window_count_core"] += 1
    pts = index.points_sorted.reshape(index.n_leaves, index.leaf_size, -1)
    valid = (index.row_ids >= 0).reshape(index.n_leaves, index.leaf_size)
    base = jnp.sum(jnp.where(contained, jnp.sum(valid, axis=1)[None], 0), axis=1)

    c = min(n_candidate_leaves, index.n_leaves)
    score, cand = jax.lax.top_k(straddle.astype(jnp.int32), c)  # (Q, C)
    cand_pts = pts[cand]                        # (Q, C, leaf, d)
    cand_valid = valid[cand] & (score > 0)[..., None]
    q = lo.shape[0]
    if use_kernel:
        from repro.kernels import ops as _kops

        scan = _kops.window_count_gathered(
            lo,
            hi,
            cand_pts.reshape(q, c * index.leaf_size, -1),
            cand_valid.reshape(q, c * index.leaf_size),
        )
    else:
        inside = jnp.all(
            (cand_pts >= lo[:, None, None, :])
            & (cand_pts <= hi[:, None, None, :]),
            axis=3,
        ) & cand_valid
        scan = jnp.sum(inside, axis=(1, 2))
    exact = jnp.sum(straddle, axis=1) <= c
    return base + scan.astype(base.dtype), exact


def window_count_candidates(
    index: JaxIndex,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    n_candidate_leaves: int,
    use_kernel: bool = False,
):
    """Candidate-leaf window counting: cost scales with the leaves a window
    actually touches, not with the dataset.

    Fully *contained* leaves contribute their (precomputable) valid-point
    counts without touching a single coordinate; only the leaves straddling
    the window boundary — the top ``n_candidate_leaves`` by intersection —
    are gathered and scanned (through the ``kernels/window_filter`` Pallas
    kernel when ``use_kernel``).  Returns (counts, exact) where ``exact``
    certifies that no straddling leaf was left unscanned; where ``exact``
    is False the count is a lower bound, NOT the window cardinality.  Use
    :func:`window_count` for guaranteed-exact answers.

    ``n_candidate_leaves`` is rounded *up* to a power of two (the compiled
    variant actually scans that many leaves) so repeated calls with
    drifting budgets reuse a bounded set of compilations; certificates and
    counts reflect the rounded budget.
    """
    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi)
    inter, contained = _leaf_window_masks(index, lo, hi)
    c = max(1, min(_pow2(n_candidate_leaves), index.n_leaves))
    return _window_count_core(
        index, lo, hi, contained, inter & ~contained, c, use_kernel,
    )


def window_count(
    index: JaxIndex,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    use_kernel: bool = False,
    n_candidate_leaves: int | None = None,
) -> jnp.ndarray:
    """Exact result counts for a batch of window queries (Q, d) x 2.

    The candidate budget defaults to the batch's true maximum number of
    boundary-straddling leaves, rounded up to a power of two so repeated
    batches reuse a handful of compiled shapes.  Work therefore scales with
    the candidate leaves (plus an O(L) per-query box test), never with the
    total point count — the same pruning ``knn`` already does.  An explicit
    ``n_candidate_leaves`` is taken as a starting budget (rounded up to a
    power of two to bound recompiles): if the exactness certificate fails
    it is doubled until every query is certified, so the result is exact
    either way (pin budgets via :func:`window_count_candidates` if a lower
    bound is acceptable).
    """
    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi)
    inter, contained = _leaf_window_masks(index, lo, hi)
    straddle = inter & ~contained
    if n_candidate_leaves is None:
        need = int(jnp.max(jnp.sum(straddle, axis=1)))
        c = _pow2(max(need, 1))
    else:
        c = _pow2(n_candidate_leaves)  # pow2 buckets bound recompiles
    c = max(1, min(c, index.n_leaves))
    while True:
        counts, exact = _window_count_core(
            index, lo, hi, contained, straddle, c, use_kernel
        )
        if c >= index.n_leaves or bool(jnp.all(exact)):
            return counts
        c = min(c * 2, index.n_leaves)


@partial(jax.jit, static_argnames=("k", "n_candidate_leaves"))
def knn(
    index: JaxIndex, queries: jnp.ndarray, k: int, n_candidate_leaves: int = 8
):
    """Batched k-NN: take the C closest leaves per query (by box mindist),
    scan them exactly, and merge top-k.  Returns (row_ids, dists_sq,
    exact_flag) where exact_flag certifies that the k-th distance does not
    exceed the mindist of the first unscanned leaf (best-first guarantee).
    """
    pts = index.points_sorted.reshape(index.n_leaves, index.leaf_size, -1)
    valid = (index.row_ids >= 0).reshape(index.n_leaves, index.leaf_size)
    rows = index.row_ids.reshape(index.n_leaves, index.leaf_size)

    n_c = min(n_candidate_leaves, index.n_leaves)

    def one(q):
        gap = jnp.maximum(index.leaf_lo - q, 0.0) + jnp.maximum(
            q - index.leaf_hi, 0.0
        )
        mind = jnp.sum(gap * gap, axis=1)  # (L,)
        neg, cand_all = jax.lax.top_k(-mind, min(n_c + 1, index.n_leaves))
        cand = cand_all[:n_c]
        cand_pts = pts[cand]  # (C, leaf, d)
        d2 = jnp.sum((cand_pts - q) ** 2, axis=2)
        d2 = jnp.where(valid[cand], d2, jnp.inf)
        flat_d2 = d2.reshape(-1)
        flat_rows = rows[cand].reshape(-1)
        topv, topi = jax.lax.top_k(-flat_d2, k)
        kth = -topv[-1]
        # exactness certificate: kth dist <= mindist of the closest leaf we
        # did NOT scan (then no unscanned leaf can hold a closer neighbor)
        if n_c == index.n_leaves:
            exact = jnp.bool_(True)
        else:
            exact = kth <= -neg[n_c]
        return flat_rows[topi], -topv, exact

    return jax.vmap(one)(queries)
