"""Crash-safe file output: the tmp + fsync + atomic-replace idiom.

``NodeTable.save`` grew this pattern in PR 6 because a snapshot is often
the only durable copy of the adaptive state; the bench writers
(``BENCH_CORE.json``, ``BENCH_SERVE.json``) need the same guarantee — a
kill mid-write must never leave a torn baseline that silently corrupts
the CI regression gate.  This module is the one shared implementation.

``atomic_output`` yields a binary file handle open on ``<path>.tmp`` in
the destination directory (same filesystem, so the final ``os.replace``
is atomic); on clean exit the data is flushed, fsynced, and swapped into
place.  On an exception the temp file is removed and nothing at ``path``
changes.  A stale ``.tmp`` left by a kill between open and replace is
harmless — the next save overwrites it.
"""
from __future__ import annotations

import contextlib
import json
import os


@contextlib.contextmanager
def atomic_output(path):
    """Binary file handle whose contents land at ``path`` atomically."""
    path = os.fspath(path)
    tmp = path + ".tmp"
    f = open(tmp, "wb")
    try:
        yield f
        f.flush()
        os.fsync(f.fileno())
    except BaseException:
        f.close()
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
    f.close()
    os.replace(tmp, path)


def atomic_write_json(path, obj, *, indent: int = 2,
                      sort_keys: bool = True) -> None:
    """Serialize ``obj`` as JSON and atomically replace ``path`` with it."""
    data = (json.dumps(obj, indent=indent, sort_keys=sort_keys) + "\n").encode()
    with atomic_output(path) as f:
        f.write(data)
