"""Competitor bulk loaders (paper Section 2.1), in the unified framework.

Every loader physically builds the same ``Node`` tree (so query processing
and the Table-1 leaf statistics are measured on the real structure) while
charging construction I/O to the shared ``PageStore`` according to each
method's disk access pattern:

  * hilbert  — Kamel & Faloutsos packing: ONE external sort by Hilbert rank,
               pack leaves, build upper levels bottom-up.
  * str      — Leutenegger et al.: sort-and-tile, one sorting round per
               dimension (later rounds run per-slice, usually in-buffer).
  * omt      — Lee & Lee: top-down STR variant driven by the height formula;
               re-sorts at every tree level -> more expensive than STR.
  * kdb      — Spread KDB-tree bulk load (top-down median splits at *entry*
               granularity: leaves are not packed, ~1.4x the leaf count).
  * waffle   — bottom-up median splits at page boundaries to single pages,
               then upper levels reuse the splits (query-optimal structure,
               but one sorting pass per recursion level -> slow build).

Sorting subsets larger than the buffer is charged as textbook external merge
sort; subsets that fit in the buffer are read once and processed in memory —
the same accounting the paper applies in its Rust framework.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from .fmbi import Index, Node
from .hilbert import hilbert_sort
from .pagestore import PageStore, branch_capacity, leaf_capacity
from .splittree import longest_dimension, mbb_of


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------
def _leaf(points, idx, store) -> Node:
    page = store.alloc()
    store.write(page)
    return Node(mbb=mbb_of(points[idx]), page_id=page, point_idx=idx)


def _branch(children, store) -> Node:
    page = store.alloc()
    store.write(page)
    mbb = np.stack(
        [
            np.min([c.mbb[0] for c in children], axis=0),
            np.max([c.mbb[1] for c in children], axis=0),
        ]
    )
    return Node(mbb=mbb, page_id=page, children=children)


def _pack_leaves(points, idx_sorted, leaf_cap, store) -> list[Node]:
    return [
        _leaf(points, idx_sorted[i : i + leaf_cap], store)
        for i in range(0, len(idx_sorted), leaf_cap)
    ]


def _group_upper(nodes, branch_cap, store, order=None) -> Node:
    """Build upper levels by grouping ``branch_cap`` consecutive nodes."""
    while len(nodes) > 1:
        if order is not None:
            centers = np.stack([(n.mbb[0] + n.mbb[1]) / 2 for n in nodes])
            nodes = [nodes[i] for i in order(centers)]
        nodes = [
            _branch(nodes[i : i + branch_cap], store)
            for i in range(0, len(nodes), branch_cap)
        ]
    return nodes[0]


def _charge_sort(store: PageStore, pages: int, buffer_pages: int) -> None:
    store.charge(store.external_sort_cost(pages, buffer_pages))


# --------------------------------------------------------------------------
# Hilbert packing
# --------------------------------------------------------------------------
def bulk_load_hilbert(
    points: np.ndarray, buffer_pages: int, store: Optional[PageStore] = None
) -> Index:
    store = store or PageStore(buffer_pages)
    n, d = points.shape
    c_l, c_b = leaf_capacity(d), branch_capacity(d)
    p = -(-n // c_l)
    # one external sort of the whole file by Hilbert rank
    _charge_sort(store, p, buffer_pages)
    order = hilbert_sort(points)
    leaves = _pack_leaves(points, order, c_l, store)

    def center_order(centers):
        return hilbert_sort(centers)

    root = _group_upper(leaves, c_b, store, order=center_order)
    return Index(root, d, c_l, c_b, store, points)


# --------------------------------------------------------------------------
# STR
# --------------------------------------------------------------------------
def bulk_load_str(
    points: np.ndarray, buffer_pages: int, store: Optional[PageStore] = None
) -> Index:
    store = store or PageStore(buffer_pages)
    n, d = points.shape
    c_l, c_b = leaf_capacity(d), branch_capacity(d)

    def tile(idx: np.ndarray, dims: list[int], unit: int, in_memory: bool):
        """Recursive sort-and-tile; ``unit`` = points per packed unit."""
        pages = -(-len(idx) // c_l)
        if not in_memory:
            if pages <= buffer_pages:
                store.read_run(pages)
                in_memory = True
            else:
                _charge_sort(store, pages, buffer_pages)
        if len(dims) == 1 or len(idx) <= unit:
            order = np.argsort(points[idx, dims[0]], kind="stable")
            si = idx[order]
            return [si[i : i + unit] for i in range(0, len(si), unit)]
        n_units = -(-len(idx) // unit)
        slices = math.ceil(n_units ** (1.0 / len(dims)))
        per_slice = -(-n_units // slices) * unit
        order = np.argsort(points[idx, dims[0]], kind="stable")
        si = idx[order]
        out = []
        for i in range(0, len(si), per_slice):
            out.extend(tile(si[i : i + per_slice], dims[1:], unit, in_memory))
        return out

    chunks = tile(np.arange(n), list(range(d)), c_l, in_memory=False)
    leaves = [_leaf(points, c, store) for c in chunks]

    # upper levels: STR over node centers (fits in memory at these scales)
    nodes = leaves
    while len(nodes) > 1:
        centers = np.stack([(nd.mbb[0] + nd.mbb[1]) / 2 for nd in nodes])
        groups = _str_tile_centers(centers, list(range(d)), c_b)
        nodes = [_branch([nodes[i] for i in g], store) for g in groups]
    return Index(nodes[0], d, c_l, c_b, store, points)


def _str_tile_centers(centers, dims, unit) -> list[list[int]]:
    def rec(idx, dims):
        if len(dims) == 1 or len(idx) <= unit:
            order = np.argsort(centers[idx, dims[0]], kind="stable")
            si = idx[order]
            return [list(si[i : i + unit]) for i in range(0, len(si), unit)]
        n_units = -(-len(idx) // unit)
        slices = math.ceil(n_units ** (1.0 / len(dims)))
        per_slice = -(-n_units // slices) * unit
        order = np.argsort(centers[idx, dims[0]], kind="stable")
        si = idx[order]
        out = []
        for i in range(0, len(si), per_slice):
            out.extend(rec(si[i : i + per_slice], dims[1:]))
        return out

    return rec(np.arange(len(centers)), dims)


# --------------------------------------------------------------------------
# OMT
# --------------------------------------------------------------------------
def bulk_load_omt(
    points: np.ndarray, buffer_pages: int, store: Optional[PageStore] = None
) -> Index:
    store = store or PageStore(buffer_pages)
    n, d = points.shape
    c_l, c_b = leaf_capacity(d), branch_capacity(d)

    def rec(idx: np.ndarray, in_memory: bool) -> Node:
        pages = -(-len(idx) // c_l)
        if not in_memory:
            if pages <= buffer_pages:
                store.read_run(pages)
                in_memory = True
        if pages <= 1:
            return _leaf(points, idx, store)
        h = max(1, math.ceil(math.log(pages, c_b)))
        p_child = c_b ** (h - 1)
        n_child = -(-pages // p_child)

        def tile(sub: np.ndarray, dims: list[int], want: int) -> list[np.ndarray]:
            if want <= 1 or len(dims) == 0:
                return [sub]
            sub_pages = -(-len(sub) // c_l)
            if not in_memory and sub_pages > buffer_pages:
                _charge_sort(store, sub_pages, buffer_pages)
            t = max(1, math.floor(want ** (1.0 / len(dims))))
            if t <= 1:
                t = min(want, 2)
            order = np.argsort(points[sub, dims[0]], kind="stable")
            ss = sub[order]
            unit = -(-sub_pages // t) * c_l
            out = []
            for i in range(0, len(ss), unit):
                out.extend(tile(ss[i : i + unit], dims[1:], -(-want // t)))
            return out

        parts = tile(idx, list(range(d)), n_child)
        children = [rec(p, in_memory) for p in parts if len(p)]
        if len(children) == 1:
            return children[0]
        return _branch(children, store)

    return Index(rec(np.arange(n), False), d, c_l, c_b, store, points)


# --------------------------------------------------------------------------
# Spread KDB-tree (bulk load of [24], spread split dimension)
# --------------------------------------------------------------------------
def bulk_load_kdb(
    points: np.ndarray, buffer_pages: int, store: Optional[PageStore] = None
) -> Index:
    store = store or PageStore(buffer_pages)
    n, d = points.shape
    c_l, c_b = leaf_capacity(d), branch_capacity(d)

    def rec(idx: np.ndarray, in_memory: bool) -> list[Node]:
        pages = -(-len(idx) // c_l)
        if not in_memory:
            if pages <= buffer_pages:
                store.read_run(pages)
                in_memory = True
            else:
                _charge_sort(store, pages, buffer_pages)
        if len(idx) <= c_l:
            return [_leaf(points, idx, store)]
        dim = longest_dimension(points[idx])
        order = np.argsort(points[idx, dim], kind="stable")
        half = len(idx) // 2  # median *entry* split: leaves end up ~3/4 full
        left = rec(idx[order[:half]], in_memory)
        right = rec(idx[order[half:]], in_memory)
        both = left + right
        if len(both) <= c_b:
            return both
        return [_branch(left, store), _branch(right, store)]

    entries = rec(np.arange(n), False)
    root = entries[0] if len(entries) == 1 else _branch(entries, store)
    return Index(root, d, c_l, c_b, store, points)


# --------------------------------------------------------------------------
# Waffle bulk loading (bottom-up, page-boundary median splits)
# --------------------------------------------------------------------------
def bulk_load_waffle(
    points: np.ndarray, buffer_pages: int, store: Optional[PageStore] = None
) -> Index:
    store = store or PageStore(buffer_pages)
    n, d = points.shape
    c_l, c_b = leaf_capacity(d), branch_capacity(d)

    def rec(idx: np.ndarray, in_memory: bool) -> list[Node]:
        pages = -(-len(idx) // c_l)
        if not in_memory:
            if pages <= buffer_pages:
                store.read_run(pages)
                in_memory = True
            else:
                # Waffle sorts the subset to find the page-boundary median
                _charge_sort(store, pages, buffer_pages)
        if pages <= 1:
            return [_leaf(points, idx, store)]
        dim = longest_dimension(points[idx])
        order = np.argsort(points[idx, dim], kind="stable")
        # split entry ranked C_L * ⌊⌈N/C_L⌉ / 2⌋  (paper Section 2.1)
        cut = c_l * (pages // 2)
        left = rec(idx[order[:cut]], in_memory)
        right = rec(idx[order[cut:]], in_memory)
        both = left + right
        if len(both) <= c_b:
            return both
        return [_branch(left, store), _branch(right, store)]

    entries = rec(np.arange(n), False)
    root = entries[0] if len(entries) == 1 else _branch(entries, store)
    return Index(root, d, c_l, c_b, store, points)


LOADERS = {
    "hilbert": bulk_load_hilbert,
    "str": bulk_load_str,
    "omt": bulk_load_omt,
    "kdb": bulk_load_kdb,
    "waffle": bulk_load_waffle,
}
