"""Flat index core: one array-backed node table for FMBI/AMBI.

The paper's indexes are defined by arrays-of-pages semantics — near-full,
zero-overlap nodes — yet the seed reproduction traversed a Python ``Node``
object graph one node at a time.  This module is the structure-of-arrays
representation every layer now shares (the move skd-tree and Flood make:
commit to an array encoding so traversal becomes vectorized arithmetic):

  * ``mbb_lo`` / ``mbb_hi``  (N, d)  node bounding boxes, split columns so
    whole-frontier intersection tests are two broadcast comparisons;
  * ``first_child`` / ``child_count``  CSR child ranges: the children of row
    ``i`` are rows ``first_child[i] : first_child[i] + child_count[i]``
    (rows are laid out level-by-level, so sibling blocks are contiguous and
    a frontier expands with one ragged-range gather);
  * ``page_id``  the disk page backing each node (merged Step-4 nodes share
    a page, exactly as in the object graph);
  * ``leaf_start`` / ``leaf_count``  point ranges into ``perm``, a
    leaf-contiguous permutation of dataset row ids (−1 start for branches);
  * ``unrefined`` / ``raw_pages``  AMBI's deferred nodes: an unrefined row
    owns raw disk pages and a ``perm`` range not yet formed into a subtree.

The table is the *query-time* representation.  Construction (FMBI Steps 1–5,
AMBI's adaptive build, the sort-based baselines) still assembles transient
``Node`` objects — that machinery is what charges paper-faithful I/O — and
flattens them here once; ``NodeView`` is the thin read-only object view kept
for tests, metrics, and examples that walk ``index.root``.

Because the table is plain arrays it is also the serialization and
accelerator boundary: ``save``/``load`` snapshot an index (optionally with
its points) into a single ``.npz``, ``merged`` combines per-server tables
into one global index for distributed snapshot shipping, and
``to_jax_index`` re-lays the leaf level into the ``JaxIndex`` grid so the
serving path can boot from a snapshot without rebuilding.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from .ioutil import atomic_output
from ..analysis import runtime as _san


# --------------------------------------------------------------------------
# bf16 compressed-MBB export (outward rounding; shared with queries_jax.py)
# --------------------------------------------------------------------------
def _bf16_outward(x: np.ndarray, up: bool) -> np.ndarray:
    """Round float32 values to bfloat16 toward +inf (``up``) or -inf.

    bfloat16 is float32 with the low 16 mantissa bits dropped, so rounding
    is pure bit arithmetic: truncation moves every value toward zero; when
    that is the wrong direction for the requested rounding, step one bf16
    ulp outward by incrementing the truncated magnitude (saturating into
    +/-inf is fine — an infinite bound is still conservative)."""
    import ml_dtypes

    f = np.ascontiguousarray(np.asarray(x, dtype=np.float32))
    u = f.view(np.uint32)
    frac = u & np.uint32(0xFFFF)
    trunc = u & ~np.uint32(0xFFFF)
    neg = (u >> 31) != 0
    step = (frac != 0) & (neg != up)
    out = np.where(step, trunc + (np.uint32(1) << 16), trunc)
    return out.view(np.float32).astype(ml_dtypes.bfloat16)


def compress_boxes_bf16(
    lo: np.ndarray, hi: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Outward-rounded bfloat16 copies of f32 box columns.

    ``lo`` rounds toward -inf and ``hi`` toward +inf, so every compressed
    box *contains* its f32 box: any query intersecting the f32 box also
    intersects the compressed one (no false negatives, ever), and the
    squared mindist to the compressed box never exceeds the f32 mindist
    (a superset-safe lower bound for k-NN pruning).  The device engine
    re-checks borderline boxes against the exact f32 columns, so results
    stay id-identical — compression only adds candidates, never drops one.
    """
    return _bf16_outward(lo, up=False), _bf16_outward(hi, up=True)


# --------------------------------------------------------------------------
# ragged-range helper (shared with queries.py)
# --------------------------------------------------------------------------
def ragged_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenate ``[starts[i], starts[i]+counts[i])`` into one index array
    without a Python loop (the standard repeat/cumsum trick)."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    offs = np.cumsum(counts) - counts
    return np.repeat(np.asarray(starts, dtype=np.int64) - offs, counts) + np.arange(
        total, dtype=np.int64
    )


class NodeTable:
    """Structure-of-arrays index representation (see module docstring).

    Rows are appended through an amortized-doubling growth policy so AMBI
    refinement — which grafts freshly built subtrees under unrefined rows —
    costs O(rows added), not O(table) per refinement.  Public accessors
    return views trimmed to the live row/perm counts.
    """

    __slots__ = (
        "dim",
        "_n",
        "_np",
        "_mbb_lo",
        "_mbb_hi",
        "_page_id",
        "_first_child",
        "_child_count",
        "_leaf_start",
        "_leaf_count",
        "_raw_pages",
        "_unrefined",
        "_perm",
        "_dfs",
        "node_reallocs",
        "perm_reallocs",
        "node_rows_copied",
        "perm_elems_copied",
        "_san_lock",  # REPRO_SANITIZE: writer lock this table is bound to
    )

    def __init__(self, dim: int, node_capacity: int = 8, perm_capacity: int = 8):
        self.dim = int(dim)
        self._n = 0
        self._np = 0
        self._san_lock = None
        # Reallocation accounting: how many times the backing arrays were
        # reallocated and how many live elements those reallocations copied.
        # Under amortized doubling total copies stay O(final size); a
        # regression here means some path reintroduced O(n^2) append cost.
        self.node_reallocs = 0
        self.perm_reallocs = 0
        self.node_rows_copied = 0
        self.perm_elems_copied = 0
        self._mbb_lo = np.zeros((node_capacity, dim))
        self._mbb_hi = np.zeros((node_capacity, dim))
        self._page_id = np.zeros(node_capacity, dtype=np.int64)
        self._first_child = np.zeros(node_capacity, dtype=np.int64)
        self._child_count = np.zeros(node_capacity, dtype=np.int64)
        self._leaf_start = np.full(node_capacity, -1, dtype=np.int64)
        self._leaf_count = np.zeros(node_capacity, dtype=np.int64)
        self._raw_pages = np.zeros(node_capacity, dtype=np.int64)
        self._unrefined = np.zeros(node_capacity, dtype=bool)
        self._perm = np.zeros(perm_capacity, dtype=np.int64)
        self._dfs: Optional[np.ndarray] = None

    # -- trimmed views -----------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return self._n

    @property
    def n_perm(self) -> int:
        return self._np

    @property
    def mbb_lo(self) -> np.ndarray:
        return self._mbb_lo[: self._n]

    @property
    def mbb_hi(self) -> np.ndarray:
        return self._mbb_hi[: self._n]

    @property
    def page_id(self) -> np.ndarray:
        return self._page_id[: self._n]

    @property
    def first_child(self) -> np.ndarray:
        return self._first_child[: self._n]

    @property
    def child_count(self) -> np.ndarray:
        return self._child_count[: self._n]

    @property
    def leaf_start(self) -> np.ndarray:
        return self._leaf_start[: self._n]

    @property
    def leaf_count(self) -> np.ndarray:
        return self._leaf_count[: self._n]

    @property
    def raw_pages(self) -> np.ndarray:
        return self._raw_pages[: self._n]

    @property
    def unrefined(self) -> np.ndarray:
        return self._unrefined[: self._n]

    @property
    def perm(self) -> np.ndarray:
        return self._perm[: self._np]

    # -- row classification ------------------------------------------------
    def is_leaf_row(self, rows) -> np.ndarray:
        return (self.leaf_start[rows] >= 0) & ~self.unrefined[rows]

    def leaf_rows(self) -> np.ndarray:
        return np.flatnonzero((self.leaf_start >= 0) & ~self.unrefined)

    def point_rows(self, row: int) -> np.ndarray:
        """Dataset row ids of a leaf/unrefined row (view into ``perm``)."""
        s = int(self._leaf_start[row])
        if s < 0:
            return np.zeros(0, dtype=np.int64)
        return self._perm[s : s + int(self._leaf_count[row])]

    def children_of(self, row: int) -> range:
        f = int(self._first_child[row])
        return range(f, f + int(self._child_count[row]))

    # -- growth ------------------------------------------------------------
    def _grow_nodes(self, k: int) -> int:
        """Reserve ``k`` rows; returns the first new row id."""
        need = self._n + k
        cap = len(self._page_id)
        if need > cap:
            # Always at least double: growing to the exact ``need`` would
            # make a run of large-then-small appends reallocate (and copy
            # the whole table) on every small append — the O(n^2) pattern
            # sustained ingest streams hit.  Doubling keeps total copy work
            # O(final size) regardless of append sizing.
            new = max(need, 2 * cap)
            self.node_reallocs += 1
            self.node_rows_copied += self._n
            grow2 = lambda a: np.concatenate(
                [a, np.zeros((new - cap, self.dim), a.dtype)]
            )
            grow1 = lambda a, fill=0: np.concatenate(
                [a, np.full(new - cap, fill, a.dtype)]
            )
            self._mbb_lo = grow2(self._mbb_lo)
            self._mbb_hi = grow2(self._mbb_hi)
            self._page_id = grow1(self._page_id)
            self._first_child = grow1(self._first_child)
            self._child_count = grow1(self._child_count)
            self._leaf_start = grow1(self._leaf_start, -1)
            self._leaf_count = grow1(self._leaf_count)
            self._raw_pages = grow1(self._raw_pages)
            self._unrefined = grow1(self._unrefined)
        first = self._n
        self._n = need
        return first

    def _append_perm(self, rows: np.ndarray) -> int:
        """Append dataset row ids to ``perm``; returns their start offset."""
        k = len(rows)
        need = self._np + k
        cap = len(self._perm)
        if need > cap:
            new = max(need, 2 * cap)
            self.perm_reallocs += 1
            self.perm_elems_copied += self._np
            self._perm = np.concatenate(
                [self._perm, np.zeros(new - cap, np.int64)]
            )
        start = self._np
        self._perm[start:need] = rows
        self._np = need
        return start

    # -- construction from a Node tree ------------------------------------
    def _set_row(self, row: int, node) -> None:
        """Write one construction ``Node``'s scalar fields into ``row``
        (children, if any, are linked by the caller)."""
        self._mbb_lo[row] = node.mbb[0]
        self._mbb_hi[row] = node.mbb[1]
        self._page_id[row] = node.page_id
        self._first_child[row] = 0
        self._child_count[row] = 0
        self._raw_pages[row] = 0
        self._unrefined[row] = False
        if node.point_idx is not None:  # leaf
            self._leaf_start[row] = self._append_perm(
                np.asarray(node.point_idx, dtype=np.int64)
            )
            self._leaf_count[row] = len(node.point_idx)
        elif node.raw_points is not None:  # AMBI unrefined
            self._leaf_start[row] = self._append_perm(
                np.asarray(node.raw_points, dtype=np.int64)
            )
            self._leaf_count[row] = len(node.raw_points)
            self._raw_pages[row] = node.raw_pages
            self._unrefined[row] = True
        else:
            self._leaf_start[row] = -1
            self._leaf_count[row] = 0

    def _append_level_order(self, queue: list, rows: list[int]) -> None:
        """Flatten ``queue[i]``'s subtrees below already-written ``rows[i]``,
        level by level, so every sibling block is contiguous."""
        head = 0
        while head < len(queue):
            node, row = queue[head], rows[head]
            head += 1
            kids = node.children
            if not kids:
                continue
            first = self._grow_nodes(len(kids))
            self._first_child[row] = first
            self._child_count[row] = len(kids)
            for j, kid in enumerate(kids):
                self._set_row(first + j, kid)
                queue.append(kid)
                rows.append(first + j)
        self._dfs = None

    @classmethod
    def from_tree(cls, root, dim: int, n_points_hint: int = 0) -> "NodeTable":
        """Flatten a construction ``Node`` tree (level order, root = row 0)."""
        t = cls(dim, node_capacity=16, perm_capacity=max(n_points_hint, 16))
        t._grow_nodes(1)
        t._set_row(0, root)
        t._append_level_order([root], [0])
        return t

    @classmethod
    def single_unrefined(
        cls, mbb: np.ndarray, page_id: int, raw_pages: int, rows: np.ndarray
    ) -> "NodeTable":
        """AMBI's starting state: the whole dataset as one unrefined root."""
        t = cls(mbb.shape[1], node_capacity=16, perm_capacity=max(len(rows), 16))
        t._grow_nodes(1)
        t._mbb_lo[0] = mbb[0]
        t._mbb_hi[0] = mbb[1]
        t._page_id[0] = page_id
        t._leaf_start[0] = t._append_perm(np.asarray(rows, dtype=np.int64))
        t._leaf_count[0] = len(rows)
        t._raw_pages[0] = raw_pages
        t._unrefined[0] = True
        return t

    # -- AMBI refinement: graft a freshly built subtree ---------------------
    def graft(self, row: int, entries: list) -> None:
        """Replace unrefined ``row`` by the subtree ``entries`` (a root entry
        list from ``refine_subspace`` / the adaptive build).

        Mirrors the object-graph ``_become`` semantics: a single entry is
        adopted in place (the row takes its MBB, page and payload), multiple
        entries turn the row into a branch whose MBB tightens to their union.
        New rows and perm segments are *appended* (amortized growth); the
        row's previous raw-point segment simply goes dead.
        """
        _san.check_write(self, "graft")
        row = int(row)
        if len(entries) == 1:
            e = entries[0]
            self._set_row(row, e)
            if e.children:
                self._append_level_order([e], [row])
            return
        lo = np.min([e.mbb[0] for e in entries], axis=0)
        hi = np.max([e.mbb[1] for e in entries], axis=0)
        self._mbb_lo[row] = lo
        self._mbb_hi[row] = hi
        self._leaf_start[row] = -1
        self._leaf_count[row] = 0
        self._raw_pages[row] = 0
        self._unrefined[row] = False
        first = self._grow_nodes(len(entries))
        self._first_child[row] = first
        self._child_count[row] = len(entries)
        queue, rows = [], []
        for j, e in enumerate(entries):
            self._set_row(first + j, e)
            queue.append(e)
            rows.append(first + j)
        self._append_level_order(queue, rows)

    # -- streaming-mirror surgery -------------------------------------------
    # The streaming device mirror (core/streaming.py) is one append-only
    # table whose synthetic root spans the live LSM tiers.  These helpers
    # are its whole mutation surface: append a tier subtree, re-point the
    # root's CSR child block at the live tier roots (as freshly appended
    # row copies, keeping the block contiguous), and neutralize retired
    # rows.  Rows are never removed — ``DeviceTable.apply_delta`` requires
    # previously exported leaf rows to persist — so retirement inverts the
    # MBB and zeroes the fill count instead: traversal never reaches a
    # detached row, and the recomputed device metadata makes its leaf block
    # unmatchable (inverted box) and empty (count 0) for the global
    # leaf-table pruning paths.
    def append_subtree(self, src: "NodeTable") -> int:
        """Append every row of ``src`` (root first); returns the base row.

        ``src.perm`` is appended wholesale, so its ids must already be in
        this table's id namespace (streaming tiers index the global point
        buffer directly).  Page ids are taken verbatim — the tiers share
        one ``PageStore`` namespace with the mirror.
        """
        _san.check_write(self, "append_subtree")
        k = src.n_nodes
        base = self._grow_nodes(k)
        pbase = self._np
        self._append_perm(src.perm)
        sl = slice(base, base + k)
        self._mbb_lo[sl] = src.mbb_lo
        self._mbb_hi[sl] = src.mbb_hi
        self._page_id[sl] = src.page_id
        self._child_count[sl] = src.child_count
        self._leaf_count[sl] = src.leaf_count
        self._raw_pages[sl] = src.raw_pages
        self._unrefined[sl] = src.unrefined
        self._first_child[sl] = np.where(
            src.child_count > 0, src.first_child + base, 0
        )
        self._leaf_start[sl] = np.where(
            src.leaf_start >= 0, src.leaf_start + pbase, -1
        )
        self._dfs = None
        return base

    def append_row_copies(self, rows) -> int:
        """Append verbatim copies of ``rows`` (pointers preserved, so a copy
        of a branch adopts the original's children); returns the base row."""
        _san.check_write(self, "append_row_copies")
        rows = np.asarray(rows, dtype=np.int64)
        base = self._grow_nodes(len(rows))
        sl = slice(base, base + len(rows))
        self._mbb_lo[sl] = self._mbb_lo[rows]
        self._mbb_hi[sl] = self._mbb_hi[rows]
        self._page_id[sl] = self._page_id[rows]
        self._first_child[sl] = self._first_child[rows]
        self._child_count[sl] = self._child_count[rows]
        self._leaf_start[sl] = self._leaf_start[rows]
        self._leaf_count[sl] = self._leaf_count[rows]
        self._raw_pages[sl] = self._raw_pages[rows]
        self._unrefined[sl] = self._unrefined[rows]
        self._dfs = None
        return base

    def set_root_children(self, first: int, count: int) -> None:
        """Re-point row 0's CSR child block and tighten its MBB."""
        _san.check_write(self, "set_root_children")
        self._first_child[0] = first
        self._child_count[0] = count
        self._mbb_lo[0] = self._mbb_lo[first : first + count].min(axis=0)
        self._mbb_hi[0] = self._mbb_hi[first : first + count].max(axis=0)
        self._leaf_start[0] = -1
        self._leaf_count[0] = 0
        self._dfs = None

    def append_branch(self, first: int, count: int, page_id: int) -> int:
        """Append a branch row adopting the existing contiguous row block
        ``[first, first + count)`` as its children; returns the new row."""
        _san.check_write(self, "append_branch")
        r = self._grow_nodes(1)
        self._mbb_lo[r] = self._mbb_lo[first : first + count].min(axis=0)
        self._mbb_hi[r] = self._mbb_hi[first : first + count].max(axis=0)
        self._page_id[r] = page_id
        self._first_child[r] = first
        self._child_count[r] = count
        self._leaf_start[r] = -1
        self._leaf_count[r] = 0
        self._raw_pages[r] = 0
        self._unrefined[r] = False
        self._dfs = None
        return r

    def neutralize_rows(self, rows) -> None:
        """Mark detached rows dead for every engine: inverted MBB (matches
        no window, +inf k-NN mindist) and zero fill count."""
        _san.check_write(self, "neutralize_rows")
        rows = np.asarray(rows, dtype=np.int64)
        # 1e17: beyond any data yet small enough that f32 mindist math on
        # the inverted box (sums and squares of ~2e17) stays finite
        big = 1e17
        self._mbb_lo[rows] = big
        self._mbb_hi[rows] = -big
        self._leaf_count[rows] = 0
        self._dfs = None

    # -- vacuum --------------------------------------------------------------
    def compact(self) -> np.ndarray:
        """Vacuum the dead ``perm`` segments (and any unreachable rows)
        that grafting accumulates.

        Grafting never rewrites in place: refining an unrefined row appends
        a fresh perm segment for every new leaf and the row's old raw
        segment simply goes dead, so a long refinement workload leaves
        ``n_perm`` far above the live point count (and the next snapshot or
        device export correspondingly padded).  ``compact`` rebuilds the
        table in BFS level order — rows renumber, sibling blocks stay
        contiguous, children keep higher ids than their parent — and
        rewrites ``perm`` to exactly the live segments in that row order,
        so afterwards ``n_perm`` equals the live point count.  Page ids,
        tree shape, and therefore traversal I/O are unchanged.

        Returns the old-row -> new-row map (``-1`` for dropped rows) so
        host-side scaffolding (device-table slot maps, shard root lists)
        can be rebased instead of rebuilt.
        """
        _san.check_write(self, "compact")
        blocks = []
        cur = np.zeros(1, dtype=np.int64)
        while cur.size:
            blocks.append(cur)
            cur = ragged_ranges(self.first_child[cur], self.child_count[cur])
        order = np.concatenate(blocks)
        n_new = len(order)
        remap = np.full(self._n, -1, dtype=np.int64)
        remap[order] = np.arange(n_new)
        mbb_lo = self.mbb_lo[order].copy()
        mbb_hi = self.mbb_hi[order].copy()
        page_id = self.page_id[order].copy()
        child_count = self.child_count[order].copy()
        first_child = np.where(
            child_count > 0, remap[self.first_child[order]], 0
        )
        leaf_count = self.leaf_count[order].copy()
        raw_pages = self.raw_pages[order].copy()
        unrefined = self.unrefined[order].copy()
        payload = self.leaf_start[order] >= 0
        starts = self.leaf_start[order]
        sel = ragged_ranges(starts[payload], leaf_count[payload])
        perm = self.perm[sel].copy()
        leaf_start = np.full(n_new, -1, dtype=np.int64)
        leaf_start[payload] = (
            np.cumsum(leaf_count[payload]) - leaf_count[payload]
        )
        self._n = n_new
        self._np = len(perm)
        # Rebuild with capacity headroom: exact-fit arrays would force the
        # very next graft — however small — to copy the whole table again,
        # so a compact-then-trickle-grafts serving loop goes quadratic.
        cap = n_new + n_new // 8 + 16
        pcap = len(perm) + len(perm) // 8 + 16
        self._mbb_lo = self._pad_cap(mbb_lo, cap)
        self._mbb_hi = self._pad_cap(mbb_hi, cap)
        self._page_id = self._pad_cap(page_id, cap)
        self._first_child = self._pad_cap(first_child, cap)
        self._child_count = self._pad_cap(child_count, cap)
        self._leaf_start = self._pad_cap(leaf_start, cap, -1)
        self._leaf_count = self._pad_cap(leaf_count, cap)
        self._raw_pages = self._pad_cap(raw_pages, cap)
        self._unrefined = self._pad_cap(unrefined, cap)
        self._perm = self._pad_cap(perm, pcap)
        self._dfs = None
        return remap

    @staticmethod
    def _pad_cap(a: np.ndarray, cap: int, fill=0) -> np.ndarray:
        """Copy ``a`` into a ``cap``-capacity array (headroom for appends)."""
        shape = (cap, a.shape[1]) if a.ndim == 2 else cap
        out = np.full(shape, fill, a.dtype)
        out[: len(a)] = a
        return out

    # -- traversal orders ---------------------------------------------------
    def parent_rows(self) -> np.ndarray:
        """Parent row of every row (−1 for the root); one ragged gather."""
        par = np.full(self._n, -1, dtype=np.int64)
        branches = np.flatnonzero(self.child_count > 0)
        if len(branches):
            kids = ragged_ranges(
                self.first_child[branches], self.child_count[branches]
            )
            par[kids] = np.repeat(branches, self.child_count[branches])
        return par

    def dfs_order(self) -> np.ndarray:
        """Rows in the depth-first pop order of the object-graph traversal
        (children expanded onto a stack, so visited in reverse); cached until
        the next graft.  This is the order the query layer replays page reads
        in, which pins IOStats to the PR-1 engine bit for bit."""
        if self._dfs is None:
            fc, cc = self._first_child, self._child_count
            order = np.empty(self._n, dtype=np.int64)
            stack = [0]
            i = 0
            while stack:
                r = stack.pop()
                order[i] = r
                i += 1
                k = int(cc[r])
                if k:
                    stack.extend(range(int(fc[r]), int(fc[r]) + k))
            self._dfs = order[:i]
        return self._dfs

    def subtree_points(self) -> np.ndarray:
        """Points under each row (leaves count their range, unrefined rows
        their raw range), accumulated bottom-up over the BFS levels reached
        from the root.  Level-wise accumulation (rather than a reverse row
        sweep) keeps this correct for append-only tables — the streaming
        mirror's root child block is appended *after* the subtrees it
        points at, so children may live at lower row ids than their parent.
        Unreachable (detached) rows keep their own leaf count."""
        sizes = np.where(self.leaf_start >= 0, self.leaf_count, 0).astype(np.int64)
        blocks = []
        cur = np.zeros(min(1, self._n), dtype=np.int64)
        while cur.size:
            blocks.append(cur)
            cur = ragged_ranges(self.first_child[cur], self.child_count[cur])
        for blk in reversed(blocks):
            cc = self.child_count[blk]
            parents = blk[cc > 0]
            if len(parents) == 0:
                continue
            kids = ragged_ranges(self.first_child[parents], cc[cc > 0])
            np.add.at(sizes, np.repeat(parents, cc[cc > 0]), sizes[kids])
        return sizes

    # -- serialization ------------------------------------------------------
    def save(
        self,
        path,
        points: Optional[np.ndarray] = None,
        extra: Optional[dict] = None,
    ) -> None:
        """Snapshot the table (and optionally the dataset) into one ``.npz``."""
        payload = {
            "mbb_lo": self.mbb_lo,
            "mbb_hi": self.mbb_hi,
            "page_id": self.page_id,
            "first_child": self.first_child,
            "child_count": self.child_count,
            "leaf_start": self.leaf_start,
            "leaf_count": self.leaf_count,
            "raw_pages": self.raw_pages,
            "unrefined": self.unrefined,
            "perm": self.perm,
            "dim": np.int64(self.dim),
        }
        if points is not None:
            payload["points"] = points
        for k, v in (extra or {}).items():
            payload[f"meta_{k}"] = np.asarray(v)
        # Crash-safe write: a kill mid-save must never leave a torn .npz at
        # ``path`` — the snapshot is often the only durable copy.  The
        # shared tmp+fsync+replace helper writes into the destination
        # directory and atomically swaps (np.savez appends ".npz" to bare
        # string paths, so hand it the open handle).
        path = os.fspath(path)
        if not path.endswith(".npz"):
            path = path + ".npz"
        with atomic_output(path) as f:
            np.savez(f, **payload)

    def equals(self, other: "NodeTable") -> bool:
        """Bit-identical structural equality (the crash-recovery invariant:
        snapshot + journal replay must land exactly here)."""
        if self.dim != other.dim or self._n != other._n or self._np != other._np:
            return False
        return all(
            np.array_equal(getattr(self, name), getattr(other, name))
            for name in (
                "mbb_lo", "mbb_hi", "page_id", "first_child", "child_count",
                "leaf_start", "leaf_count", "raw_pages", "unrefined", "perm",
            )
        )

    @classmethod
    def load(cls, path) -> tuple["NodeTable", dict, Optional[np.ndarray]]:
        """Load a snapshot; returns (table, meta, points-or-None)."""
        with np.load(path) as z:
            dim = int(z["dim"])
            n = len(z["page_id"])
            np_ = len(z["perm"])
            # capacity headroom: a loaded snapshot that immediately starts
            # grafting must not pay a full-table copy on the first append
            t = cls(dim, node_capacity=n + n // 8 + 16,
                    perm_capacity=np_ + np_ // 8 + 16)
            t._n = n
            t._np = len(z["perm"])
            t._mbb_lo[:n] = z["mbb_lo"]
            t._mbb_hi[:n] = z["mbb_hi"]
            t._page_id[:n] = z["page_id"]
            t._first_child[:n] = z["first_child"]
            t._child_count[:n] = z["child_count"]
            t._leaf_start[:n] = z["leaf_start"]
            t._leaf_count[:n] = z["leaf_count"]
            t._raw_pages[:n] = z["raw_pages"]
            t._unrefined[:n] = z["unrefined"]
            t._perm[: t._np] = z["perm"]
            meta = {
                k[len("meta_") :]: z[k][()] for k in z.files if k.startswith("meta_")
            }
            points = z["points"] if "points" in z.files else None
        return t, meta, points

    # -- distributed merge ---------------------------------------------------
    @classmethod
    def merged(
        cls,
        tables: list["NodeTable"],
        perm_maps: list[np.ndarray],
        page_offsets: list[int],
        root_page: int,
    ) -> "NodeTable":
        """Merge per-server tables into one global table.

        A synthetic root (row 0) takes the server roots as children; server
        ``s``'s local dataset rows are mapped to global ids through
        ``perm_maps[s]`` and its page ids shifted by ``page_offsets[s]`` so
        the merged snapshot has one flat page namespace.  Server-root rows
        are relocated to rows ``1..m`` (keeping the root's CSR child block
        contiguous); every other row shifts by a per-server base offset.
        """
        if not (len(tables) == len(perm_maps) == len(page_offsets)):
            raise ValueError(
                f"merge inputs misaligned: {len(tables)} tables, "
                f"{len(perm_maps)} perm maps, {len(page_offsets)} page offsets"
            )
        live = [t for t in tables if t.n_nodes > 0]
        live_maps = [m for t, m in zip(tables, perm_maps) if t.n_nodes > 0]
        live_offs = [o for t, o in zip(tables, page_offsets) if t.n_nodes > 0]
        m = len(live)
        if m == 0:
            raise ValueError("nothing to merge")
        dim = live[0].dim
        total_nodes = 1 + sum(t.n_nodes for t in live)
        total_perm = sum(t.n_perm for t in live)
        out = cls(dim, node_capacity=total_nodes + total_nodes // 8 + 16,
                  perm_capacity=total_perm + total_perm // 8 + 16)
        out._grow_nodes(total_nodes)
        # row mapping: server root -> 1 + s; row r > 0 -> base_s + r - 1
        bases = []
        base = 1 + m
        for t in live:
            bases.append(base)
            base += t.n_nodes - 1
        perm_off = 0
        for s, t in enumerate(live):
            n = t.n_nodes
            root_dst = slice(1 + s, 2 + s)
            rest_dst = slice(bases[s], bases[s] + n - 1)
            for dst, src in ((root_dst, slice(0, 1)), (rest_dst, slice(1, n))):
                out._mbb_lo[dst] = t.mbb_lo[src]
                out._mbb_hi[dst] = t.mbb_hi[src]
                out._page_id[dst] = t.page_id[src] + live_offs[s]
                out._child_count[dst] = t.child_count[src]
                out._leaf_count[dst] = t.leaf_count[src]
                out._raw_pages[dst] = t.raw_pages[src]
                out._unrefined[dst] = t.unrefined[src]
                # child pointers: children are never the server root (row 0)
                out._first_child[dst] = np.where(
                    t.child_count[src] > 0, t.first_child[src] + bases[s] - 1, 0
                )
                out._leaf_start[dst] = np.where(
                    t.leaf_start[src] >= 0, t.leaf_start[src] + perm_off, -1
                )
            out._perm[perm_off : perm_off + t.n_perm] = live_maps[s][t.perm]
            perm_off += t.n_perm
        out._np = perm_off
        out._mbb_lo[0] = out._mbb_lo[1 : 1 + m].min(axis=0)
        out._mbb_hi[0] = out._mbb_hi[1 : 1 + m].max(axis=0)
        out._page_id[0] = root_page
        out._first_child[0] = 1
        out._child_count[0] = m
        out._leaf_start[0] = -1
        return out

    # -- sharding ------------------------------------------------------------
    def subtable(self, roots, sizes: Optional[np.ndarray] = None) -> "NodeTable":
        """Extract the subtrees rooted at ``roots`` into a standalone table.

        A single root is adopted in place; multiple roots hang under a
        synthetic root whose MBB tightens to their union (the same shape
        :meth:`merged` produces).  ``perm`` values are copied verbatim, so
        the sub-table keeps addressing the *parent's* dataset rows — the
        property the sharded query engine relies on: every shard answers
        with global ids and results merge by concatenation.  ``sizes`` is
        an optional precomputed :meth:`subtree_points` array (callers that
        extract several sub-tables pass it once instead of re-sweeping).
        """
        from .fmbi import Node  # function-local: fmbi imports this module

        roots = [int(r) for r in roots]
        if not roots:
            raise ValueError("subtable needs at least one root row")
        if len(roots) == 1:
            src = NodeView(self, roots[0])
        else:
            src = Node(
                mbb=np.stack(
                    [
                        self.mbb_lo[roots].min(axis=0),
                        self.mbb_hi[roots].max(axis=0),
                    ]
                ),
                page_id=int(self._page_id[0]),
                children=[NodeView(self, r) for r in roots],
            )
        if sizes is None:
            sizes = self.subtree_points()
        hint = int(sizes[roots].sum())
        return NodeTable.from_tree(src, self.dim, n_points_hint=hint)

    def shard_plan(
        self, m: int, sizes: Optional[np.ndarray] = None
    ) -> list[list[int]]:
        """The root-row lists :meth:`shard` extracts its sub-tables from
        (exposed so callers that later need to *re-extract* a shard — the
        adaptive refresh path — can record which subspaces each shard
        owns).  Row lists are sorted; empty bins are dropped.  ``sizes``
        is an optional precomputed :meth:`subtree_points` array.
        """
        if m < 1:
            raise ValueError(f"shard count must be >= 1, got {m}")
        if m == 1 or self._child_count[0] == 0:
            return [[0]]
        if sizes is None:
            sizes = self.subtree_points()
        frontier = list(self.children_of(0))
        while len(frontier) < m:
            branches = [r for r in frontier if self._child_count[r] > 0]
            if not branches:
                break
            big = max(branches, key=lambda r: (sizes[r], -r))
            frontier.remove(big)
            frontier.extend(self.children_of(big))
        bins: list[list[int]] = [[] for _ in range(m)]
        loads = [0] * m
        for r in sorted(frontier, key=lambda r: (-sizes[r], r)):
            i = min(range(m), key=lambda j: (loads[j], j))
            bins[i].append(r)
            loads[i] += int(sizes[r])
        return [sorted(b) for b in bins if b]

    def shard(self, m: int) -> list["NodeTable"]:
        """Partition the table into at most ``m`` sub-tables of balanced
        point count (the distributed engine's per-shard tables).

        The root's child subtrees form the starting units — for a
        :meth:`merged` table these are exactly the per-server subspaces, so
        the central SplitTree's partition is recovered verbatim when ``m``
        matches the server count.  While there are fewer units than shards
        the largest unit is split into its children, then units are packed
        into ``m`` bins by greedy longest-processing-time assignment.  Fewer
        than ``m`` shards come back when the tree cannot be cut that finely
        (e.g. a single-leaf table).  Deterministic for a given table.
        """
        if m == 1:
            return [self]
        sizes = self.subtree_points()
        return [
            self.subtable(b, sizes=sizes) for b in self.shard_plan(m, sizes)
        ]

    # -- accelerator bridge --------------------------------------------------
    def to_jax_index(self, points: np.ndarray, dtype=np.float32):
        """Re-lay the leaf level into the ``JaxIndex`` grid (serving layout).

        The table's leaf-contiguous ``perm`` *is* the sorted point order the
        JAX side wants; leaves are padded to a uniform slot count with
        sentinel rows (``row_id = -1``, coords at dtype-max) and the leaf
        count to a power of two with empty boxes, which the batched
        ``knn`` / ``window_count`` kernels already mask out.  Only the leaf
        gather runs here — no rebuild, no re-sort.  The balanced split
        tables do not exist for an FMBI tree, so ``jax_index.route`` is not
        meaningful on a bridged index; use ``jax_index.nearest_leaf``.
        """
        import jax.numpy as jnp

        from .jax_index import JaxIndex

        if bool(self.unrefined.any()):
            raise ValueError("bridge requires a fully refined table")
        rows = self.leaf_rows()
        counts = self.leaf_count[rows]
        l_real = len(rows)
        leaf_size = int(counts.max()) if l_real else 1
        n_leaves = 1
        while n_leaves < l_real:
            n_leaves *= 2
        levels = n_leaves.bit_length() - 1
        d = points.shape[1]
        big = np.finfo(dtype).max
        grid = np.full((n_leaves * leaf_size, d), big, dtype=dtype)
        ids = np.full(n_leaves * leaf_size, -1, dtype=np.int32)
        sel = ragged_ranges(self.leaf_start[rows], counts)
        within = np.arange(len(sel), dtype=np.int64) - np.repeat(
            np.cumsum(counts) - counts, counts
        )
        slot = np.repeat(np.arange(l_real, dtype=np.int64) * leaf_size, counts) + within
        data_rows = self.perm[sel]
        grid[slot] = points[data_rows].astype(dtype)
        ids[slot] = data_rows
        leaf_lo = np.full((n_leaves, d), big, dtype=dtype)
        leaf_hi = np.full((n_leaves, d), -big, dtype=dtype)
        leaf_lo[:l_real] = self.mbb_lo[rows]
        leaf_hi[:l_real] = self.mbb_hi[rows]
        lv = max(levels, 1)
        return JaxIndex(
            points_sorted=jnp.asarray(grid),
            row_ids=jnp.asarray(ids),
            split_dim=jnp.zeros((lv, n_leaves), jnp.int32),
            split_val=jnp.full((lv, n_leaves), np.inf, dtype=dtype),
            leaf_lo=jnp.asarray(leaf_lo),
            leaf_hi=jnp.asarray(leaf_hi),
            levels=levels,
            leaf_size=leaf_size,
        )

    # -- device layout --------------------------------------------------------
    def pack_leaf_blocks(
        self, rows: np.ndarray, points: np.ndarray, S: int, dtype=np.float32
    ) -> tuple[np.ndarray, np.ndarray]:
        """Uniform ``S``-slot point/id blocks for the given payload rows
        (padding slots carry ``id = -1`` and dtype-max coordinates).  The
        device export and the incremental delta refresh share this packing.
        """
        d = self.dim
        big = np.finfo(dtype).max
        k = len(rows)
        counts = self.leaf_count[rows]
        leaf_pts = np.full((k, S, d), big, dtype=dtype)
        leaf_ids = np.full((k, S), -1, dtype=np.int32)
        if k:
            sel = ragged_ranges(self.leaf_start[rows], counts)
            within = np.arange(len(sel), dtype=np.int64) - np.repeat(
                np.cumsum(counts) - counts, counts
            )
            slot_l = np.repeat(np.arange(k, dtype=np.int64), counts)
            data_rows = self.perm[sel]
            leaf_pts[slot_l, within] = points[data_rows].astype(dtype)
            leaf_ids[slot_l, within] = data_rows
        return leaf_pts, leaf_ids

    def slot_map(
        self, leaf_rows: np.ndarray, cold_rows: np.ndarray
    ) -> np.ndarray:
        """Per-row frontier slots: leaves take ``[0, L)`` in ``leaf_rows``
        order, cold (unrefined) rows ``[L, L + U)``, branches the dropped
        sentinel ``L + U``.  One encoding shared by the full export and
        the incremental delta refresh."""
        L, U = len(leaf_rows), len(cold_rows)
        slot_of = np.full(self._n, L + U, dtype=np.int64)
        slot_of[leaf_rows] = np.arange(L)
        slot_of[cold_rows] = L + np.arange(U)
        return slot_of

    def level_blocks(self, slot_of: np.ndarray, dtype=np.float32) -> list:
        """BFS level blocks for the frontier descent: per depth, row MBBs,
        each row's parent *position* within the previous level's block, and
        the row's slot from ``slot_of`` (leaf slot, cold slot, or the
        dropped sentinel for branches)."""
        pos = np.zeros(self._n, dtype=np.int64)
        levels: list[dict] = []
        cur = np.zeros(1, dtype=np.int64)
        parent_pos = np.zeros(1, dtype=np.int64)
        while cur.size:
            pos[cur] = np.arange(cur.size)
            levels.append(
                {
                    "lo": self.mbb_lo[cur].astype(dtype),
                    "hi": self.mbb_hi[cur].astype(dtype),
                    "parent": parent_pos.astype(np.int32),
                    "slot": slot_of[cur].astype(np.int32),
                }
            )
            cc = self.child_count[cur]
            nxt = ragged_ranges(self.first_child[cur], cc)
            parent_pos = pos[np.repeat(cur, cc)]
            cur = nxt
        return levels

    def device_layout(
        self, points: np.ndarray, dtype=np.float32, *,
        partial: bool = False, compressed: bool = False,
    ) -> dict:
        """Fixed-shape arrays for the compiled query engine (numpy side).

        The ragged table is re-blocked so every shape is static and every
        query-time access is a dense gather (see ``core/queries_jax.py``,
        which wraps these arrays in a jit-able ``DeviceTable`` pytree):

          * ``leaf_pts``/``leaf_ids``  (L, S, d)/(L, S): each leaf's points
            gathered once through ``perm`` into uniform ``S``-slot blocks
            (S = max leaf fullness; padding slots carry ``id = -1`` and
            dtype-max coordinates so containment and distance tests mask
            them for free);
          * ``leaf_lo``/``leaf_hi``  (L, d): leaf MBBs, slot-aligned;
          * ``levels``: one block per tree depth — row MBBs, each row's
            parent *position* within the previous level's block, and the
            row's slot: leaf slot, ``L + cold slot`` for unrefined rows,
            or the dropped sentinel ``L + U`` for branches.  Level blocks
            drive the masked level-synchronous frontier descent; BFS order
            is computed here so grafted (AMBI-refined) tables, whose rows
            are not level-contiguous, lay out identically to freshly built
            ones.

        With ``partial=False`` (default) the table must be fully refined:
        an unrefined row has no subtree to descend and its raw pages live
        host-side only.  With ``partial=True`` unrefined rows are exported
        as *cold* entries — their MBBs land in ``cold_lo``/``cold_hi`` and
        their slots in the level blocks address the cold range, so the
        frontier traversal surfaces "this query reaches unindexed space"
        as a mask the serving layer answers host-side (refining on
        demand).  ``leaf_rows``/``cold_rows`` map slots back to table rows
        (the scaffolding the incremental delta refresh rebases).

        With ``compressed=True`` the layout also carries outward-rounded
        bfloat16 copies of every bound column (:func:`compress_boxes_bf16`):
        ``leaf_lo_c``/``leaf_hi_c`` beside the leaf MBBs and ``lo_c``/
        ``hi_c`` inside each level block.  The compressed boxes contain
        their f32 originals, so traversal against them can only *add*
        candidates; the f32 columns stay alongside for the engine's
        certified re-check, keeping results id-identical at half the
        bound-column bandwidth.
        """
        if not partial and bool(self.unrefined.any()):
            raise ValueError(
                "device layout requires a fully refined table "
                "(pass partial=True to export unrefined rows as cold)"
            )
        rows = self.leaf_rows()
        cold = np.flatnonzero(self.unrefined)
        counts = self.leaf_count[rows]
        L = len(rows)
        S = max(int(counts.max()) if L and counts.size else 1, 1)
        leaf_pts, leaf_ids = self.pack_leaf_blocks(rows, points, S, dtype)
        slot_of = self.slot_map(rows, cold)
        levels = self.level_blocks(slot_of, dtype)
        layout = {
            "leaf_pts": leaf_pts,
            "leaf_ids": leaf_ids,
            "leaf_counts": counts.astype(np.int32),
            "leaf_lo": self.mbb_lo[rows].astype(dtype),
            "leaf_hi": self.mbb_hi[rows].astype(dtype),
            "cold_lo": self.mbb_lo[cold].astype(dtype),
            "cold_hi": self.mbb_hi[cold].astype(dtype),
            "levels": levels,
            "leaf_rows": rows,
            "cold_rows": cold,
        }
        if compressed:
            layout["leaf_lo_c"], layout["leaf_hi_c"] = compress_boxes_bf16(
                layout["leaf_lo"], layout["leaf_hi"]
            )
            for lv in levels:
                lv["lo_c"], lv["hi_c"] = compress_boxes_bf16(
                    lv["lo"], lv["hi"]
                )
        return layout

    def to_device(self, points: np.ndarray, dtype=np.float32, *,
                  compressed: bool = False):
        """Wrap :meth:`device_layout` into the jit-able ``DeviceTable``."""
        from .queries_jax import DeviceTable

        return DeviceTable.from_table(
            self, points, dtype=dtype, compressed=compressed
        )

    # -- invariants ----------------------------------------------------------
    def check_invariants(self, n_points: Optional[int] = None) -> None:
        """Assert the structural invariants every layer relies on."""
        n = self._n
        assert n >= 1, "empty table"
        fc, cc = self.first_child, self.child_count
        branches = np.flatnonzero(cc > 0)
        # CSR ranges stay inside the table and cover every non-root row once
        assert np.all(fc[branches] >= 1)
        assert np.all(fc[branches] + cc[branches] <= n)
        seen = np.zeros(n, dtype=np.int64)
        for r in branches:
            seen[fc[r] : fc[r] + cc[r]] += 1
        assert np.all(seen[1:] == 1), "child ranges must partition rows 1..N"
        assert seen[0] == 0, "root must not be a child"
        # leaf/unrefined perm ranges: in bounds, disjoint, and together a
        # permutation of the dataset rows (dead segments from grafts allowed)
        payload = np.flatnonzero(self.leaf_start >= 0)
        ls, lcnt = self.leaf_start[payload], self.leaf_count[payload]
        assert np.all(ls + lcnt <= self._np)
        sel = ragged_ranges(ls, lcnt)
        assert len(np.unique(sel)) == len(sel), "live perm segments overlap"
        vals = self.perm[sel]
        assert len(np.unique(vals)) == len(vals), "duplicate dataset rows"
        if n_points is not None:
            assert len(vals) == n_points
            assert vals.min(initial=0) >= 0
            if len(vals):
                assert vals.max() < n_points
        # parent MBBs contain child MBBs
        if len(branches):
            kids = ragged_ranges(fc[branches], cc[branches])
            par = np.repeat(branches, cc[branches])
            assert np.all(self.mbb_lo[par] <= self.mbb_lo[kids] + 1e-12)
            assert np.all(self.mbb_hi[par] >= self.mbb_hi[kids] - 1e-12)


# --------------------------------------------------------------------------
# thin read-only object view (tests / metrics / examples walk this)
# --------------------------------------------------------------------------
class NodeView:
    """Read-only ``Node``-shaped view over one table row."""

    __slots__ = ("_t", "row")

    def __init__(self, table: NodeTable, row: int):
        self._t = table
        self.row = int(row)

    @property
    def mbb(self) -> np.ndarray:
        return np.stack([self._t.mbb_lo[self.row], self._t.mbb_hi[self.row]])

    @property
    def page_id(self) -> int:
        return int(self._t.page_id[self.row])

    @property
    def is_leaf(self) -> bool:
        return bool(
            self._t.leaf_start[self.row] >= 0 and not self._t.unrefined[self.row]
        )

    @property
    def is_unrefined(self) -> bool:
        return bool(self._t.unrefined[self.row])

    @property
    def point_idx(self) -> Optional[np.ndarray]:
        return self._t.point_rows(self.row) if self.is_leaf else None

    @property
    def raw_points(self) -> Optional[np.ndarray]:
        return self._t.point_rows(self.row) if self.is_unrefined else None

    @property
    def raw_pages(self) -> int:
        return int(self._t.raw_pages[self.row])

    @property
    def children(self) -> Optional[list["NodeView"]]:
        if self._t.leaf_start[self.row] >= 0:
            return None
        return [NodeView(self._t, r) for r in self._t.children_of(self.row)]

    def n_entries(self) -> int:
        if self.is_leaf:
            return int(self._t.leaf_count[self.row])
        if self.is_unrefined:
            return int(self._t.raw_pages[self.row])
        return int(self._t.child_count[self.row])

    def iter_leaves(self):
        t = self._t
        stack = [self.row]
        while stack:
            r = stack.pop()
            if t.leaf_start[r] >= 0:
                if not t.unrefined[r]:
                    yield NodeView(t, r)
            else:
                stack.extend(t.children_of(r))
