"""AMBI: Adaptive Multidimensional Bulkloaded Index (paper Section 4).

The index is built on demand while queries are processed.  The whole dataset
starts as a single *unrefined* root node; refining an unrefined node runs the
adaptive analogue of FMBI's Steps 1-4 (Section 4.1):

  * Step 2 keeps a max-heap of active subspaces ordered by their distance to
    the current query and flushes the farthest first, so qualified subspaces
    stay in memory;
  * a qualified subspace holding >= C_B pages is *split* (minor SplitTree of
    its in-memory pages) instead of flushed — its children join the heap;
  * after distribution only the active subspaces are refined (Algorithm 1,
    free: their pages are in memory); inactive subspaces become unrefined
    nodes that later queries refine on demand (sparse -> Algorithm 1 after
    re-reading their pages, dense -> recursive adaptive build);
  * Algorithm 2 merging includes unrefined subspaces — a sparse subspace of
    P pages always yields exactly P leaf entries, so its entry count is known
    before refinement (paper Section 4.1).

The node set AMBI converges to is independent of the query order; with
queries covering the whole space it coincides with FMBI.

Scan engine
-----------
The adaptive distribution is chunk-batched: each streamed page is grouped
with one stable argsort, per-subspace counts and bounding boxes are updated
with ``reduceat`` segment reductions, and the grow/flush/split bookkeeping
runs only for the few subspaces whose in-memory point count actually crosses
a page boundary.  Subspace MBBs (the max-heap keys) are maintained
incrementally instead of being recomputed from every buffered point at each
victim selection, and the final per-subspace row lists come from one global
stable argsort rather than per-page list appends.  One deliberate
difference from the strictly sequential formulation: all of a page's counts
and MBB updates are applied before that page's flush decisions run, so a
decision sees the page's full contents even for subspaces later in the
page's group order — the flush policy itself is unchanged.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .fmbi import Index, Node, merge_branches, refine_subspace
from .geometry import mindist_box_sq, mindist_sq
from .nodetable import NodeTable, NodeView
from .pagestore import PageStore, branch_capacity, leaf_capacity
from .queries import knn_query, window_query
from .splittree import build_group_median_tree, mbb_of


class AMBI:
    def __init__(
        self,
        points: np.ndarray,
        buffer_pages: int,
        store: Optional[PageStore] = None,
        rng: Optional[np.random.Generator] = None,
    ):
        self.points = points
        self.M = buffer_pages
        self.store = store or PageStore(buffer_pages)
        self.rng = rng or np.random.default_rng(0)
        n, d = points.shape
        self.d = d
        self.c_l = leaf_capacity(d)
        self.c_b = branch_capacity(d)
        root_page = self.store.alloc()
        self.table = NodeTable.single_unrefined(
            mbb=mbb_of(points) if n else np.zeros((2, d)),
            page_id=root_page,
            raw_pages=-(-n // self.c_l),
            rows=np.arange(n),
        )
        self.index = Index(self.table, d, self.c_l, self.c_b, self.store, points)

    @property
    def root(self) -> NodeView:
        return self.index.root

    # -- durable adaptive state --------------------------------------------
    # Grafting is deterministic given (points, M, rng state, store state):
    # ``_adaptive_build`` draws only from ``self.rng`` and page ids only
    # from ``self.store``.  Capturing both alongside the table snapshot is
    # what lets crash recovery *replay* the journaled cold queries and land
    # on the bit-identical table.
    def state_meta(self) -> str:
        """JSON blob of everything beyond the table that refinement
        consumes: the buffer size, the rng bit-generator state, and the
        page store (allocator + IOStats + LRU residency)."""
        import json

        return json.dumps(
            {
                "M": int(self.M),
                "rng": self.rng.bit_generator.state,
                "store": self.store.state_dict(),
            }
        )

    @classmethod
    def from_table_state(
        cls, points: np.ndarray, table: NodeTable, meta: str
    ) -> "AMBI":
        """Rebuild a live AMBI around an existing (snapshot-loaded) table
        and a :meth:`state_meta` blob — the recovery boot path."""
        import json

        state = json.loads(meta)
        self = cls.__new__(cls)
        self.points = points
        self.M = int(state["M"])
        self.store = PageStore(self.M)
        self.store.load_state(state["store"])
        self.rng = np.random.default_rng(0)
        self.rng.bit_generator.state = state["rng"]
        n, d = points.shape
        self.d = d
        self.c_l = leaf_capacity(d)
        self.c_b = branch_capacity(d)
        self.table = table
        self.index = Index(table, d, self.c_l, self.c_b, self.store, points)
        return self

    # -- public query API --------------------------------------------------
    def window(self, lo, hi):
        lo = np.asarray(lo, dtype=np.float64)
        hi = np.asarray(hi, dtype=np.float64)
        return window_query(
            self.index, lo, hi, refiner=self.window_refiner(lo, hi)
        )

    def knn(self, q, k: int):
        q = np.asarray(q, dtype=np.float64)
        return knn_query(self.index, q, k, refiner=self.knn_refiner(q))

    # -- refiners: the query context is bound explicitly, never held as
    # instance state (a refinement triggered outside a query — the serving
    # loop's case — must flush against *that* query, not the last one)
    def window_refiner(self, lo, hi) -> Callable[[int], bool]:
        """Row refiner whose flush policy keys on distance to [lo, hi]."""
        lo = np.asarray(lo, dtype=np.float64)
        hi = np.asarray(hi, dtype=np.float64)
        return lambda row: self._refine(
            row, lambda mbb: mindist_box_sq(mbb, lo, hi)
        )

    def knn_refiner(self, q) -> Callable[[int], bool]:
        """Row refiner whose flush policy keys on distance to point ``q``."""
        q = np.asarray(q, dtype=np.float64)
        return lambda row: self._refine(row, lambda mbb: mindist_sq(mbb, q))

    def is_fully_refined(self) -> bool:
        return not bool(self.table.unrefined.any())

    # -- refinement --------------------------------------------------------
    def _refine(
        self, row: int, query_dist: Callable[[np.ndarray], float]
    ) -> bool:
        """Refine unrefined table ``row`` in place (the construction
        machinery assembles a transient ``Node`` subtree which is grafted
        into the table); returns False when the row holds no points.
        ``query_dist`` maps a subspace MBB to its distance from the query
        that triggered refinement (the adaptive build's max-heap key)."""
        idx = self.table.point_rows(row)
        if len(idx) == 0:
            return False
        idx = idx.copy()  # graft appends to perm; detach the live view
        pages = -(-len(idx) // self.c_l)
        if pages <= self.M:
            # sparse: reload its pages and refine with Algorithm 1
            self.store.read_run(int(self.table.raw_pages[row]))
            entries = refine_subspace(
                self.points, idx, self.c_l, self.c_b, self.store
            )
        else:
            entries = self._adaptive_build(idx, query_dist)
        self.table.graft(row, entries)
        return True

    def _adaptive_build(
        self, idx: np.ndarray, query_dist: Callable[[np.ndarray], float]
    ) -> list[Node]:
        """Adaptive Steps 1-4 scoped to a dense unrefined row; returns its
        root entry list."""
        points, store, c_l, c_b, M = (
            self.points,
            self.store,
            self.c_l,
            self.c_b,
            self.M,
        )
        n = len(idx)
        p_total = -(-n // c_l)
        alpha = max(M // c_b, 1)

        # Step 1: sample alpha*C_B pages, build the Major SplitTree
        sample_pages = min(alpha * c_b, p_total)
        store.read_run(sample_pages)
        need = min(sample_pages * c_l, n)
        perm = self.rng.permutation(n)
        samp_local = np.sort(perm[:need])
        rest_local = np.sort(perm[need:])
        n_groups = max(need // (alpha * c_l), 1)
        trim = n_groups * alpha * c_l
        samp_use, samp_extra = samp_local[:trim], samp_local[trim:]
        mst, _, samp_assign = build_group_median_tree(
            points[idx[samp_use]], n_groups, alpha, c_l
        )

        # live routing forest state, array-form.  Subspace i: point count,
        # disk/memory pages, active flag, and an incrementally maintained MBB
        # (identical to the min/max over its buffered points, which the
        # scalar formulation recomputed at every victim selection).
        count = np.zeros(n_groups, dtype=np.int64)
        disk = np.zeros(n_groups, dtype=np.int64)
        mem = np.full(n_groups, alpha, dtype=np.int64)
        active = np.ones(n_groups, dtype=bool)
        mbb_lo = np.full((n_groups, self.d), np.inf)
        mbb_hi = np.full((n_groups, self.d), -np.inf)
        refine_map: dict[int, tuple] = {}  # sub id -> (tree, child sub ids)

        # arrival log: per streamed page, the rows (group-sorted) and their
        # subspace assignment; the Step-3 row lists fall out of one global
        # stable argsort at the end
        all_rows: list[np.ndarray] = []
        all_assign: list[np.ndarray] = []

        def grow_subs(k: int) -> list[int]:
            nonlocal count, disk, mem, active, mbb_lo, mbb_hi
            first = len(count)
            count = np.concatenate([count, np.zeros(k, np.int64)])
            disk = np.concatenate([disk, np.zeros(k, np.int64)])
            mem = np.concatenate([mem, np.zeros(k, np.int64)])
            active = np.concatenate([active, np.ones(k, bool)])
            mbb_lo = np.vstack([mbb_lo, np.full((k, self.d), np.inf)])
            mbb_hi = np.vstack([mbb_hi, np.full((k, self.d), -np.inf)])
            return list(range(first, first + k))

        def route(rows: np.ndarray) -> np.ndarray:
            out = mst.route(points[rows])
            pending = {s for s in np.unique(out) if int(s) in refine_map}
            while pending:
                s = pending.pop()
                tree, kids = refine_map[int(s)]
                sel = out == s
                sub_assign = tree.route(points[rows[sel]])
                out[sel] = np.asarray(kids, dtype=np.int32)[sub_assign]
                pending |= {
                    t for t in np.unique(out[sel]) if int(t) in refine_map
                }
            return out

        def ingest(rows: np.ndarray, a: np.ndarray):
            """Group-by + segment min/max updates for one streamed page.
            Returns the page's (sorted) group ids."""
            order = np.argsort(a, kind="stable")
            ra, aa = rows[order], a[order]
            uniq, starts = np.unique(aa, return_index=True)
            seg = points[ra]
            mbb_lo[uniq] = np.minimum(
                mbb_lo[uniq], np.minimum.reduceat(seg, starts, axis=0)
            )
            mbb_hi[uniq] = np.maximum(
                mbb_hi[uniq], np.maximum.reduceat(seg, starts, axis=0)
            )
            count[uniq] += np.diff(np.append(starts, len(aa)))
            all_rows.append(ra)
            all_assign.append(aa.astype(np.int32))
            return uniq

        def qdist(i: int) -> float:
            if count[i] == 0:
                return np.inf
            return query_dist(np.stack([mbb_lo[i], mbb_hi[i]]))

        def mem_used() -> int:
            return int(mem.sum())

        def materialize(si: int) -> np.ndarray:
            parts = [r[a == si] for r, a in zip(all_rows, all_assign)]
            parts = [p for p in parts if len(p)]
            return (
                np.concatenate(parts) if parts else np.zeros(0, np.int64)
            )

        def split_sub(si: int) -> None:
            """Qualified & large: replace sub by <= C_B minor-tree children."""
            rows = materialize(si)
            beta = max(len(rows) // (c_l * c_b), 1)
            groups = min(c_b, max(len(rows) // (beta * c_l), 2))
            trim2 = groups * beta * c_l
            tree, _, assign2 = build_group_median_tree(
                points[rows[:trim2]], groups, beta, c_l
            )
            kid_ids = grow_subs(groups)
            kid_arr = np.asarray(kid_ids, dtype=np.int32)
            leftover = rows[trim2:]
            la = (
                tree.route(points[leftover])
                if len(leftover)
                else np.zeros(0, np.int32)
            )
            new_assign = np.concatenate([kid_arr[assign2], kid_arr[la]])
            # rewrite the arrival log: si's rows now belong to its children
            pos = 0
            for arr_a in all_assign:
                msk = arr_a == si
                c = int(msk.sum())
                if c:
                    arr_a[msk] = new_assign[pos : pos + c]
                    pos += c
            # children state: counts/MBBs over their actual rows
            kc = np.bincount(
                new_assign - kid_ids[0], minlength=groups
            ).astype(np.int64)
            count[kid_ids] = kc
            mem[kid_ids] = beta
            korder = np.argsort(new_assign, kind="stable")
            kstarts = np.concatenate([[0], np.cumsum(kc)])[:-1]
            seg = points[rows[korder]]
            nonzero = kc > 0
            if nonzero.any():
                klo = np.minimum.reduceat(seg, kstarts[nonzero], axis=0)
                khi = np.maximum.reduceat(seg, kstarts[nonzero], axis=0)
                kid_nz = np.asarray(kid_ids)[nonzero]
                mbb_lo[kid_nz] = klo
                mbb_hi[kid_nz] = khi
            refine_map[si] = (tree, kid_ids)
            count[si] = 0
            mem[si] = 0
            active[si] = False

        def flush(si: int) -> None:
            full = int(count[si] - disk[si] * c_l) // c_l
            if full > 0:
                store.write_run(full)
                disk[si] += full
            mem[si] = 1
            active[si] = False

        def pick_victim() -> Optional[int]:
            # farthest active subspace (max-heap of the paper); splitting a
            # qualified subspace with >= C_B pages takes priority over
            # flushing it
            cand = [
                (qdist(i), i)
                for i in range(len(count))
                if active[i] and i not in refine_map
            ]
            if not cand:
                return None
            dist, i = max(cand)
            pages_i = -(-int(count[i]) // c_l)
            if dist == 0.0 and pages_i >= c_b:
                split_sub(i)
                return pick_victim()
            return i

        # the sampled pages are the subspaces' initial buffered contents
        ingest(idx[samp_use], samp_assign.astype(np.int32))

        # Step 2: distribute remaining pages with the heap flush policy
        rest = idx[np.concatenate([samp_extra, rest_local])] if (
            len(samp_extra) or len(rest_local)
        ) else np.zeros(0, dtype=np.int64)
        store.read_run(-(-len(rest) // c_l))
        for start in range(0, len(rest), c_l):
            rows = rest[start : start + c_l]
            uniq = ingest(rows, route(rows))
            # page-granular buffer bookkeeping, only where a page boundary
            # was actually crossed
            crossing = uniq[
                (count[uniq] - disk[uniq] * c_l) > mem[uniq] * c_l
            ]
            for g in crossing:
                g = int(g)
                if g in refine_map:  # split mid-page: rows already rerouted
                    continue
                pts = int(count[g])
                in_mem = pts - int(disk[g]) * c_l
                while in_mem > int(mem[g]) * c_l:
                    if active[g]:
                        if mem_used() >= M:
                            v = pick_victim()
                            if v is not None:
                                flush(v)
                                if v == g:
                                    break
                                continue
                        mem[g] += 1
                    else:
                        # inactive: single page, flushed whenever it fills
                        store.write_run(1)
                        disk[g] += 1
                        in_mem = pts - int(disk[g]) * c_l

        # Step 3: refine actives (their pages are in memory -> no reads).
        # One stable argsort of the arrival log yields every subspace's rows
        # in stream order.
        n_sub = len(count)
        all_a = np.concatenate(all_assign)
        all_r = np.concatenate(all_rows)
        gorder = np.argsort(all_a, kind="stable")
        sorted_rows = all_r[gorder]
        bounds = np.concatenate(
            [[0], np.cumsum(np.bincount(all_a, minlength=n_sub))]
        )
        nodes: list[Optional[Node]] = [None] * n_sub
        for i in range(n_sub):
            if i in refine_map:
                continue
            rows = sorted_rows[bounds[i] : bounds[i + 1]]
            if len(rows) == 0:
                continue
            if active[i]:
                entries = refine_subspace(points, rows, c_l, c_b, store)
                if len(entries) == 1:
                    nodes[i] = entries[0]
                else:
                    nodes[i] = Node(
                        mbb=mbb_of(points[rows]), page_id=-1, children=entries
                    )
            else:
                # flush trailing partial page; becomes an unrefined node
                rem = len(rows) - int(disk[i]) * c_l
                if rem > 0:
                    store.write_run(1)
                    disk[i] += 1
                nodes[i] = Node(
                    mbb=mbb_of(points[rows]),
                    page_id=-1,
                    raw_pages=int(disk[i]),
                    raw_points=rows,
                )

        # collapse nested splits bottom-up into entry lists + Step 4 merging
        def collect(si: int) -> Optional[Node]:
            if si not in refine_map:
                return nodes[si]
            tree, kids = refine_map[si]
            kid_nodes = [collect(k) for k in kids]
            cand = [kn if _mergeable(kn) else None for kn in kid_nodes]
            groups = merge_branches(tree, cand, c_b)
            _assign_pages(groups, store)
            real = [kn for kn in kid_nodes if kn is not None]
            for kn in real:
                if kn.page_id == -1:
                    page = store.alloc()
                    store.write(page)
                    kn.page_id = page
            if not real:
                return None
            if len(real) == 1:
                return real[0]
            page = store.alloc()
            store.write(page)
            return Node(
                mbb=np.stack(
                    [
                        np.min([k.mbb[0] for k in real], axis=0),
                        np.max([k.mbb[1] for k in real], axis=0),
                    ]
                ),
                page_id=page,
                children=real,
            )

        top_nodes: list[Optional[Node]] = [
            collect(s) for s in range(n_groups)
        ]
        cand = [tn if _mergeable(tn) else None for tn in top_nodes]
        groups = merge_branches(mst, cand, c_b)
        _assign_pages(groups, store)
        for tn in top_nodes:
            if tn is not None and tn.page_id == -1:
                page = store.alloc()
                store.write(page)
                tn.page_id = page
        return [tn for tn in top_nodes if tn is not None]


def _mergeable(n: Optional[Node]) -> bool:
    return n is not None and n.page_id == -1 and not n.is_leaf


def _assign_pages(groups, store) -> None:
    for group in groups:
        page = store.alloc()
        store.write(page)
        for nd in group:
            nd.page_id = page
