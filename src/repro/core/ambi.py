"""AMBI: Adaptive Multidimensional Bulkloaded Index (paper Section 4).

The index is built on demand while queries are processed.  The whole dataset
starts as a single *unrefined* root node; refining an unrefined node runs the
adaptive analogue of FMBI's Steps 1-4 (Section 4.1):

  * Step 2 keeps a max-heap of active subspaces ordered by their distance to
    the current query and flushes the farthest first, so qualified subspaces
    stay in memory;
  * a qualified subspace holding >= C_B pages is *split* (minor SplitTree of
    its in-memory pages) instead of flushed — its children join the heap;
  * after distribution only the active subspaces are refined (Algorithm 1,
    free: their pages are in memory); inactive subspaces become unrefined
    nodes that later queries refine on demand (sparse -> Algorithm 1 after
    re-reading their pages, dense -> recursive adaptive build);
  * Algorithm 2 merging includes unrefined subspaces — a sparse subspace of
    P pages always yields exactly P leaf entries, so its entry count is known
    before refinement (paper Section 4.1).

The node set AMBI converges to is independent of the query order; with
queries covering the whole space it coincides with FMBI.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from .fmbi import Index, Node, merge_branches, refine_subspace
from .pagestore import PageStore, branch_capacity, leaf_capacity
from .queries import knn_query, mindist_sq, window_query
from .splittree import build_group_median_tree, mbb_of


@dataclasses.dataclass
class _Sub:
    """A live subspace during adaptive distribution."""

    idx_chunks: list
    mem_pages: int
    disk_pages: int
    active: bool = True

    def points_count(self) -> int:
        return sum(len(c) for c in self.idx_chunks)


class AMBI:
    def __init__(
        self,
        points: np.ndarray,
        buffer_pages: int,
        store: Optional[PageStore] = None,
        rng: Optional[np.random.Generator] = None,
    ):
        self.points = points
        self.M = buffer_pages
        self.store = store or PageStore(buffer_pages)
        self.rng = rng or np.random.default_rng(0)
        n, d = points.shape
        self.d = d
        self.c_l = leaf_capacity(d)
        self.c_b = branch_capacity(d)
        root_page = self.store.alloc()
        self.root = Node(
            mbb=mbb_of(points) if n else np.zeros((2, d)),
            page_id=root_page,
            raw_pages=-(-n // self.c_l),
            raw_points=np.arange(n),
        )
        self._query_dist: Callable[[np.ndarray], float] = lambda mbb: 0.0
        self.index = Index(self.root, d, self.c_l, self.c_b, self.store, points)

    # -- public query API --------------------------------------------------
    def window(self, lo, hi):
        lo = np.asarray(lo, dtype=np.float64)
        hi = np.asarray(hi, dtype=np.float64)
        self._query_dist = lambda mbb: _mindist_box_sq(mbb, lo, hi)
        return window_query(self.index, lo, hi, refiner=self._refine)

    def knn(self, q, k: int):
        q = np.asarray(q, dtype=np.float64)
        self._query_dist = lambda mbb: mindist_sq(mbb, q)
        return knn_query(self.index, q, k, refiner=self._refine)

    def is_fully_refined(self) -> bool:
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.is_unrefined:
                return False
            if n.children:
                stack.extend(n.children)
        return True

    # -- refinement --------------------------------------------------------
    def _refine(self, node: Node) -> Optional[Node]:
        """Refine an unrefined node in place; returns it (or None if empty)."""
        idx = node.raw_points
        if idx is None or len(idx) == 0:
            return None
        pages = -(-len(idx) // self.c_l)
        if pages <= self.M:
            # sparse: reload its pages and refine with Algorithm 1
            self.store.read_run(node.raw_pages)
            entries = refine_subspace(
                self.points, idx, self.c_l, self.c_b, self.store
            )
            _become(node, entries, self.points, idx)
            return node
        return self._adaptive_build(node)

    def _adaptive_build(self, node: Node) -> Node:
        """Adaptive Steps 1-4 scoped to a dense unrefined node."""
        points, store, c_l, c_b, M = (
            self.points,
            self.store,
            self.c_l,
            self.c_b,
            self.M,
        )
        idx = node.raw_points
        n = len(idx)
        p_total = -(-n // c_l)
        alpha = max(M // c_b, 1)

        # Step 1: sample alpha*C_B pages, build the Major SplitTree
        sample_pages = min(alpha * c_b, p_total)
        store.read_run(sample_pages)
        need = min(sample_pages * c_l, n)
        perm = self.rng.permutation(n)
        samp_local = np.sort(perm[:need])
        rest_local = np.sort(perm[need:])
        n_groups = max(need // (alpha * c_l), 1)
        trim = n_groups * alpha * c_l
        samp_use, samp_extra = samp_local[:trim], samp_local[trim:]
        mst, _, samp_assign = build_group_median_tree(
            points[idx[samp_use]], n_groups, alpha, c_l
        )

        # live routing forest: major MST -> (optional nested minor trees)
        subs: list[_Sub] = [
            _Sub([idx[samp_use[samp_assign == s]]], alpha, 0)
            for s in range(n_groups)
        ]
        refine_map: dict[int, tuple] = {}  # sub id -> (tree, child sub ids)

        def route(rows: np.ndarray) -> np.ndarray:
            out = mst.route(points[rows])
            pending = {s for s in np.unique(out) if int(s) in refine_map}
            while pending:
                s = pending.pop()
                tree, kids = refine_map[int(s)]
                sel = out == s
                sub_assign = tree.route(points[rows[sel]])
                out[sel] = np.asarray(kids, dtype=np.int32)[sub_assign]
                pending |= {
                    t for t in np.unique(out[sel]) if int(t) in refine_map
                }
            return out

        def mem_used() -> int:
            return sum(s.mem_pages for s in subs)

        def qdist(s: _Sub) -> float:
            pts = (
                np.concatenate(s.idx_chunks)
                if len(s.idx_chunks) > 1
                else s.idx_chunks[0]
            )
            if len(pts) == 0:
                return np.inf
            return self._query_dist(mbb_of(points[pts]))

        def split_sub(si: int) -> None:
            """Qualified & large: replace sub by C_B minor-tree children."""
            s = subs[si]
            rows = np.concatenate(s.idx_chunks)
            beta = max(s.points_count() // (c_l * c_b), 1)
            groups = min(c_b, max(s.points_count() // (beta * c_l), 2))
            trim2 = groups * beta * c_l
            tree, _, assign = build_group_median_tree(
                points[rows[:trim2]], groups, beta, c_l
            )
            kid_ids = []
            for g in range(groups):
                kid = _Sub([rows[:trim2][assign == g]], beta, 0)
                subs.append(kid)
                kid_ids.append(len(subs) - 1)
            leftover = rows[trim2:]
            if len(leftover):
                a = tree.route(points[leftover])
                for g in np.unique(a):
                    subs[kid_ids[int(g)]].idx_chunks.append(
                        leftover[a == g]
                    )
            refine_map[si] = (tree, kid_ids)
            s.idx_chunks = []
            s.mem_pages = 0
            s.active = False

        def flush(si: int) -> None:
            s = subs[si]
            pts = s.points_count()
            full = (pts - s.disk_pages * c_l) // c_l
            if full > 0:
                store.write_run(full)
                s.disk_pages += full
            s.mem_pages = 1
            s.active = False

        def pick_victim() -> Optional[int]:
            # farthest active subspace (max-heap of the paper); splitting a
            # qualified subspace with >= C_B pages takes priority over
            # flushing it
            cand = [
                (qdist(s), i)
                for i, s in enumerate(subs)
                if s.active and i not in refine_map
            ]
            if not cand:
                return None
            dist, i = max(cand)
            pages_i = -(-subs[i].points_count() // c_l)
            if dist == 0.0 and pages_i >= c_b:
                split_sub(i)
                return pick_victim()
            return i

        # Step 2: distribute remaining pages with the heap flush policy
        rest = idx[np.concatenate([samp_extra, rest_local])] if (
            len(samp_extra) or len(rest_local)
        ) else np.zeros(0, dtype=np.int64)
        store.read_run(-(-len(rest) // c_l))
        for start in range(0, len(rest), c_l):
            rows = rest[start : start + c_l]
            a = route(rows)
            for g in np.unique(a):
                s = subs[int(g)]
                sel = rows[a == g]
                s.idx_chunks.append(sel)
                # page-granular buffer bookkeeping
                pts = s.points_count()
                in_mem = pts - s.disk_pages * c_l
                while in_mem > s.mem_pages * c_l:
                    if s.active:
                        if mem_used() >= M:
                            v = pick_victim()
                            if v is not None:
                                flush(v)
                                if v == int(g):
                                    break
                                continue
                        s.mem_pages += 1
                    else:
                        # inactive: single page, flushed whenever it fills
                        store.write_run(1)
                        s.disk_pages += 1
                        in_mem = pts - s.disk_pages * c_l

        # Step 3: refine actives (their pages are in memory -> no reads)
        live = [
            (i, s) for i, s in enumerate(subs) if i not in refine_map
        ]
        nodes: list[Optional[Node]] = [None] * len(subs)
        for i, s in live:
            rows = (
                np.concatenate(s.idx_chunks)
                if s.idx_chunks
                else np.zeros(0, dtype=np.int64)
            )
            if len(rows) == 0:
                continue
            if s.active:
                entries = refine_subspace(points, rows, c_l, c_b, store)
                if len(entries) == 1:
                    nodes[i] = entries[0]
                else:
                    nodes[i] = Node(
                        mbb=mbb_of(points[rows]), page_id=-1, children=entries
                    )
            else:
                # flush trailing partial page; becomes an unrefined node
                rem = len(rows) - s.disk_pages * c_l
                if rem > 0:
                    store.write_run(1)
                    s.disk_pages += 1
                nodes[i] = Node(
                    mbb=mbb_of(points[rows]),
                    page_id=-1,
                    raw_pages=int(s.disk_pages),
                    raw_points=rows,
                )

        # collapse nested splits bottom-up into entry lists + Step 4 merging
        def collect(si: int) -> Optional[Node]:
            if si not in refine_map:
                return nodes[si]
            tree, kids = refine_map[si]
            kid_nodes = [collect(k) for k in kids]
            cand = [kn if _mergeable(kn) else None for kn in kid_nodes]
            groups = merge_branches(tree, cand, c_b)
            _assign_pages(groups, store)
            real = [kn for kn in kid_nodes if kn is not None]
            for kn in real:
                if kn.page_id == -1:
                    page = store.alloc()
                    store.write(page)
                    kn.page_id = page
            if not real:
                return None
            if len(real) == 1:
                return real[0]
            page = store.alloc()
            store.write(page)
            return Node(
                mbb=np.stack(
                    [
                        np.min([k.mbb[0] for k in real], axis=0),
                        np.max([k.mbb[1] for k in real], axis=0),
                    ]
                ),
                page_id=page,
                children=real,
            )

        top_nodes: list[Optional[Node]] = [
            collect(s) for s in range(n_groups)
        ]
        cand = [tn if _mergeable(tn) else None for tn in top_nodes]
        groups = merge_branches(mst, cand, c_b)
        _assign_pages(groups, store)
        for tn in top_nodes:
            if tn is not None and tn.page_id == -1:
                page = store.alloc()
                store.write(page)
                tn.page_id = page
        entries = [tn for tn in top_nodes if tn is not None]
        _become(node, entries, points, idx)
        return node


def _mergeable(n: Optional[Node]) -> bool:
    return n is not None and n.page_id == -1 and not n.is_leaf


def _assign_pages(groups, store) -> None:
    for group in groups:
        page = store.alloc()
        store.write(page)
        for nd in group:
            nd.page_id = page


def _become(node: Node, entries: list[Node], points, idx) -> None:
    """Mutate an unrefined node into its refined form (keeps parent links)."""
    node.raw_points = None
    node.raw_pages = 0
    if len(entries) == 1:
        e = entries[0]
        node.mbb = e.mbb
        node.page_id = e.page_id
        node.children = e.children
        node.point_idx = e.point_idx
        node.raw_pages = e.raw_pages
        node.raw_points = e.raw_points
    else:
        node.children = entries
        node.mbb = np.stack(
            [
                np.min([e.mbb[0] for e in entries], axis=0),
                np.max([e.mbb[1] for e in entries], axis=0),
            ]
        )


def _mindist_box_sq(mbb: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> float:
    gap = np.maximum(mbb[0] - hi, 0.0) + np.maximum(lo - mbb[1], 0.0)
    return float(np.dot(gap, gap))
