"""Sharded device-resident query engine (paper Section 5 on the DeviceTable).

PRs 2–3 made the flat ``NodeTable`` / compiled ``DeviceTable`` the real
query engine, but the distributed path still ran on the old ``JaxIndex``
grid.  This module maps the paper's central-server / m-local-servers
architecture onto the compiled engine:

  * :class:`ShardedDeviceTable` — ``NodeTable.shard(m)`` partitions a
    bulk-loaded (or ``NodeTable.merged``) table into m per-shard
    ``DeviceTable`` pytrees plus a top-level *router*: the shard subspace
    MBBs (for a merged table, exactly the central SplitTree's per-server
    subspaces).  Every shard addresses the global dataset — shard ``perm``
    entries are global row ids — so results merge by concatenation with no
    id translation.
  * :func:`window_query_batch_sharded` — windows fan out only to the
    shards whose subspace MBB intersects the query box (the paper's
    "qualified servers"); each shard batch runs the compiled
    ``window_query_batch_jax`` engine and per-query ids concatenate.
    Since the shards partition the dataset, the union is id-identical to
    the single-table engine.
  * :func:`knn_query_batch_sharded` — the paper's two-round SpatialHadoop
    protocol.  Round 1 sends each query to its *home* shard (smallest
    router-MBB mindist) for local exact top-k; the k-th local distance is
    the certified pruning radius.  The certificate — every unprobed shard
    has mindist exceeding the radius — is checked per query, and round 2
    escalates only the (query, shard) pairs where it fails (including the
    ``k >= points-per-shard`` case, where the radius is +inf and every
    shard qualifies).  Two rounds always suffice: probing every shard
    within the round-1 radius can only shrink the k-th distance, so no
    shard outside it can ever contribute.
  * :func:`knn_batch_shard_map` / :func:`window_count_batch_shard_map` —
    the collective formulation for an actual device mesh: shards pad to a
    uniform leaf layout (:meth:`ShardedDeviceTable.stacked`), ``shard_map``
    runs the local round on every device in parallel, and an
    ``all_gather`` (k-NN merge) or ``psum`` (window counts) completes the
    global round.  On CPU runners the same code executes under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Router arithmetic runs in float32 — the same dtype the compiled engine
tests leaf MBBs in, and shard root boxes contain their leaf boxes after
the (monotonic) f32 cast — so the routed visit set is always a superset
of the leaves the single-table engine scans, and the parity contract of
``core/queries_jax.py`` carries over unchanged: id-identical windows (as
sets) and id-identical k-NN under unique f32 distances.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .distributed import gather_topk_merge
from .geometry import boxes_intersect_windows, boxes_mindist_sq
from .nodetable import NodeTable
from .queries_jax import (
    BIG,
    DeviceTable,
    _knn_core,
    knn_query_batch_jax,
    window_query_batch_jax,
)

P = jax.sharding.PartitionSpec

try:  # jax >= 0.5: top-level API
    _shard_map = jax.shard_map
except AttributeError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


# --------------------------------------------------------------------------
# degraded-mode protocol: shard outages + completeness certificates
# --------------------------------------------------------------------------
class ShardUnavailable(RuntimeError):
    """A shard cannot serve (dispatch failed past retry / breaker open).

    Raised *into* the sharded query protocols by the injected ``runner``;
    with ``return_certs=True`` the protocol degrades (answers from the
    remaining shards + a per-query certificate), without it the outage
    propagates to the caller unchanged.
    """

    def __init__(self, shard: int, reason: str = ""):
        self.shard = int(shard)
        super().__init__(
            f"shard {shard} unavailable" + (f": {reason}" if reason else "")
        )


@dataclasses.dataclass
class CompletenessCertificate:
    """Per-query provenance of a (possibly degraded) sharded answer.

    ``complete`` — every shard relevant to this query answered; the result
    is exactly the healthy protocol's.  ``certified_exact`` — the returned
    ids are provably the exact answer even if shards were down: trivially
    true when complete, and true for k-NN when every down shard's router
    mindist strictly exceeds the k-th returned f32 distance (the same
    exclusion certificate round 2 escalates on — the dead shard provably
    holds no closer point).  ``missing_shards`` / ``missing_lo`` /
    ``missing_hi`` are the unanswered subspaces that *could* affect the
    answer (empty iff ``certified_exact``): the repair queue, and for a
    window query the region the caller must treat as unknown.
    """

    complete: bool
    certified_exact: bool
    missing_shards: tuple = ()
    missing_lo: np.ndarray = None  # (u, d) f32 router MBBs, row per shard
    missing_hi: np.ndarray = None

    @classmethod
    def intact(cls) -> "CompletenessCertificate":
        return cls(complete=True, certified_exact=True)

    @classmethod
    def degraded(
        cls, sdev: "ShardedDeviceTable", missing, *, exact: bool = False
    ) -> "CompletenessCertificate":
        missing = tuple(int(s) for s in missing)
        return cls(
            complete=False,
            certified_exact=exact and not missing,
            missing_shards=missing,
            missing_lo=sdev.shard_lo[list(missing)].copy(),
            missing_hi=sdev.shard_hi[list(missing)].copy(),
        )


def _run_shard(runner, s: int, thunk):
    """One shard dispatch through the injected resilience runner (or
    directly when serving without one)."""
    if runner is None:
        return thunk()
    return runner(int(s), thunk)


# --------------------------------------------------------------------------
# sharded table: m DeviceTables + the subspace-MBB router
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ShardedDeviceTable:
    """m per-shard :class:`DeviceTable` pytrees behind an MBB router.

    When built through :meth:`from_table` the instance remembers its
    source table, dataset, and each shard's subspace root rows, so the
    adaptive serving path can re-export *only* the shards whose subspaces
    a graft touched (:meth:`refresh`) instead of re-sharding the world.
    """

    shards: list
    shard_lo: np.ndarray  # (m, d) float32 router MBBs (shard root boxes)
    shard_hi: np.ndarray
    n_points: int
    source_table: NodeTable = None   # refresh scaffolding (from_table only)
    source_points: np.ndarray = None
    shard_roots: list = None         # per shard: source-table root rows
    partial: bool = False
    upload_stats: object = None      # UploadStats sink for (re)exports
    compressed: bool = False         # bf16 compressed-MBB shard exports

    @property
    def m(self) -> int:
        return len(self.shards)

    @property
    def dim(self) -> int:
        return int(self.shard_lo.shape[1])

    @classmethod
    def from_tables(
        cls,
        tables: list[NodeTable],
        points: np.ndarray,
        dtype=np.float32,
        *,
        partial: bool = False,
        stats=None,
        compressed: bool = False,
    ) -> "ShardedDeviceTable":
        """From per-shard tables whose ``perm`` entries are global row ids
        (``NodeTable.shard`` output, or ``shard_build_tables``)."""
        if not tables:
            raise ValueError("need at least one shard table")
        points = np.asarray(points)
        shards = [
            DeviceTable.from_table(t, points, dtype=dtype, partial=partial,
                                   stats=stats, compressed=compressed)
            for t in tables
        ]
        return cls(
            shards=shards,
            shard_lo=np.stack([t.mbb_lo[0].astype(dtype) for t in tables]),
            shard_hi=np.stack([t.mbb_hi[0].astype(dtype) for t in tables]),
            n_points=int(sum(s.n_points for s in shards)),
            partial=partial,
            upload_stats=stats,
            compressed=compressed,
        )

    @classmethod
    def from_table(
        cls,
        table: NodeTable,
        points: np.ndarray,
        m: int,
        dtype=np.float32,
        *,
        partial: bool = False,
        stats=None,
        compressed: bool = False,
    ) -> "ShardedDeviceTable":
        sizes = table.subtree_points()
        plan = table.shard_plan(m, sizes)
        tables = [cls._extract(table, roots, sizes) for roots in plan]
        self = cls.from_tables(tables, points, dtype=dtype, partial=partial,
                               stats=stats, compressed=compressed)
        self.source_table = table
        self.source_points = np.asarray(points)
        self.shard_roots = plan
        return self

    @staticmethod
    def _extract(table: NodeTable, roots, sizes) -> NodeTable:
        if list(roots) == [0]:
            return table
        return table.subtable(roots, sizes=sizes)

    # -- adaptive refresh ---------------------------------------------------
    def shards_of_rows(self, rows) -> list[int]:
        """Which shards own the given source-table rows (ancestor climb
        through the parent array — grafted rows always hang below a root
        that existed when the shard plan was made)."""
        if self.shard_roots is None:
            raise ValueError("no shard plan recorded; build via from_table")
        owner = {int(r): s for s, b in enumerate(self.shard_roots) for r in b}
        par = self.source_table.parent_rows()
        out: set[int] = set()
        for r in rows:
            r = int(r)
            while r >= 0 and r not in owner:
                r = int(par[r])
            if r >= 0:
                out.add(owner[r])
        return sorted(out)

    def refresh(self, shard_ids) -> None:
        """Re-export only the listed shards from the (grafted) source
        table — the delta unit of the sharded serving path: a graft
        invalidates exactly the shard owning its subspace, every other
        shard's device arrays are untouched."""
        if self.source_table is None:
            raise ValueError("no source recorded; build via from_table")
        sizes = self.source_table.subtree_points()
        dtype = self.shard_lo.dtype
        for s in sorted(set(int(s) for s in shard_ids)):
            t = self._extract(self.source_table, self.shard_roots[s], sizes)
            self.shards[s] = DeviceTable.from_table(
                t, self.source_points, dtype=dtype, partial=self.partial,
                stats=self.upload_stats, compressed=self.compressed,
            )
            self.shard_lo[s] = t.mbb_lo[0].astype(dtype)
            self.shard_hi[s] = t.mbb_hi[0].astype(dtype)
        self.n_points = int(sum(s.n_points for s in self.shards))

    def remap_source_rows(self, remap: np.ndarray) -> None:
        """Rebase the shard plan after ``NodeTable.compact``."""
        if self.shard_roots is not None:
            self.shard_roots = [
                [int(remap[r]) for r in b] for b in self.shard_roots
            ]

    @classmethod
    def from_index(
        cls, index, m: int, dtype=np.float32, *, compressed: bool = False
    ) -> "ShardedDeviceTable":
        """From a built ``core.fmbi.Index`` (or a refined AMBI's ``.index``)."""
        return cls.from_table(index.table, index.points, m, dtype=dtype,
                              compressed=compressed)

    @classmethod
    def from_parallel_build(
        cls, build, points: np.ndarray, dtype=np.float32
    ) -> "ShardedDeviceTable":
        """From a host m-server simulation (``parallel_bulk_load``): the
        merged table's server subtrees become the shards verbatim, so the
        TPU layout and the Figure-11 simulation share one representation."""
        merged = build.merged_table()
        m = int(merged.child_count[0])
        tables = [merged.subtable([1 + s]) for s in range(m)]
        return cls.from_tables(tables, points, dtype=dtype)

    def stacked(self) -> dict:
        """Uniform (m, L, S, ·) leaf layout for the ``shard_map`` round.

        Shards pad to the widest leaf table with empty leaves (inverted
        MBBs, dtype-max coordinates, zero fill counts) that every masked
        test already ignores.  Levels are not stacked — the collective
        round scans leaf blocks directly."""
        if any(s.n_cold for s in self.shards):
            raise ValueError(
                "stacked() needs fully refined shards (partial exports "
                "carry cold rows only the host-routed path can serve)"
            )
        L = max(s.n_leaves for s in self.shards)
        S = max(s.leaf_size for s in self.shards)
        d = self.dim
        m = self.m
        lp = np.full((m, L, S, d), BIG, dtype=np.float32)
        li = np.full((m, L, S), -1, dtype=np.int32)
        lc = np.zeros((m, L), dtype=np.int32)
        llo = np.full((m, L, d), BIG, dtype=np.float32)
        lhi = np.full((m, L, d), -BIG, dtype=np.float32)
        for s, dev in enumerate(self.shards):
            ls, ss = dev.n_leaves, dev.leaf_size
            lp[s, :ls, :ss] = np.asarray(dev.leaf_pts)
            li[s, :ls, :ss] = np.asarray(dev.leaf_ids)
            lc[s, :ls] = np.asarray(dev.leaf_counts)
            llo[s, :ls] = np.asarray(dev.leaf_lo)
            lhi[s, :ls] = np.asarray(dev.leaf_hi)
        return {
            "leaf_pts": lp, "leaf_ids": li, "leaf_counts": lc,
            "leaf_lo": llo, "leaf_hi": lhi, "n_points": self.n_points,
        }


# --------------------------------------------------------------------------
# distributed window: router fan-out + per-shard compiled collection
# --------------------------------------------------------------------------
def window_query_batch_sharded(
    sdev: ShardedDeviceTable,
    los: np.ndarray,
    his: np.ndarray,
    *,
    use_kernel: bool | None = None,
    fused: bool | None = None,
    runner=None,
    return_certs: bool = False,
) -> list[np.ndarray]:
    """Distributed batched window query: per-query global row-id arrays.

    Only qualified shards (router MBB intersects the box) receive a
    query, each shard serves its sub-batch through the compiled engine,
    and per-query results concatenate — the shards partition the dataset,
    so the union is id-identical (as a set) to the single-table engine.

    ``runner(shard_id, thunk)`` is the serving layer's resilience hook
    (retry + breaker around each shard dispatch); a runner that raises
    :class:`ShardUnavailable` marks the shard down.  With
    ``return_certs=True`` an outage *degrades* the batch — the return is
    ``(results, certs)`` where each :class:`CompletenessCertificate`
    names the unanswered subspace MBBs (a window over a dead shard can
    never be certified exact: any point of its subspace may qualify).
    Without it the outage propagates.
    """
    los = np.atleast_2d(np.asarray(los, dtype=np.float64))
    his = np.atleast_2d(np.asarray(his, dtype=np.float64))
    q0 = los.shape[0]
    hit = boxes_intersect_windows(
        sdev.shard_lo, sdev.shard_hi,
        los.astype(np.float32), his.astype(np.float32),
    )  # (Q, m) — f32, the dtype the per-shard engine tests boxes in
    parts: list[list[np.ndarray]] = [[] for _ in range(q0)]
    down: list[int] = []
    for s, dev in enumerate(sdev.shards):
        qsel = np.flatnonzero(hit[:, s])
        if qsel.size == 0:
            continue
        try:
            res = _run_shard(
                runner, s,
                lambda dev=dev, qsel=qsel: window_query_batch_jax(
                    dev, los[qsel], his[qsel], use_kernel=use_kernel,
                    fused=fused,
                ),
            )
        except ShardUnavailable:
            if not return_certs:
                raise
            down.append(s)
            continue
        for qi, ids in zip(qsel, res):
            if len(ids):
                parts[qi].append(ids)
    results = [
        np.concatenate(p) if p else np.zeros(0, dtype=np.int64) for p in parts
    ]
    if not return_certs:
        return results
    certs = []
    for qi in range(q0):
        miss = [s for s in down if hit[qi, s]]
        certs.append(
            CompletenessCertificate.intact()
            if not miss
            else CompletenessCertificate.degraded(sdev, miss)
        )
    return results, certs


# --------------------------------------------------------------------------
# distributed k-NN: two rounds with a certified pruning radius
# --------------------------------------------------------------------------
def knn_query_batch_sharded(
    sdev: ShardedDeviceTable,
    qs: np.ndarray,
    k: int,
    *,
    use_kernel: bool | None = None,
    fused: bool | None = None,
    runner=None,
    return_certs: bool = False,
) -> list[np.ndarray]:
    """Distributed batched k-NN: per-query ascending-distance global ids.

    Two rounds (paper Section 5 / SpatialHadoop).  Round 1: each query
    probes its home shard (smallest router mindist) for a local exact
    top-k; the k-th local f32 distance is the pruning radius (+inf when
    the shard holds fewer than k points).  Round 2: per query, every
    other shard whose router mindist is within the radius — the shards
    whose exclusion certificate *fails* — is probed too; shards outside
    the radius are certified non-contributing and never touched.  The
    final merge sorts each query's pooled (distance, id) candidates and
    keeps ``min(k, n)``; distances are the same f32 values the
    single-table engine computes, so ids match it exactly whenever
    distances are unique (ties at the k-th boundary are unspecified in
    both engines).

    Degraded mode (``runner`` + ``return_certs=True``, as for the window
    protocol): a query whose home shard is down re-routes round 1 to the
    next-closest *available* shard, round 2 skips down shards, and the
    per-query certificate applies the same f32 exclusion test to the dead
    shards — when every down shard's router mindist strictly exceeds the
    k-th returned distance the partial answer is ``certified_exact``
    (the shard provably holds no closer point); otherwise its subspace
    MBB is reported missing.
    """
    qs = np.atleast_2d(np.asarray(qs, dtype=np.float64))
    q0 = qs.shape[0]
    m = sdev.m
    # f32 router mindists: the same dtype (and box values) the per-shard
    # engine prunes leaves with, so certificates are mutually consistent
    minds = boxes_mindist_sq(
        sdev.shard_lo, sdev.shard_hi, qs.astype(np.float32)
    )
    cand_ids: list[list[np.ndarray]] = [[] for _ in range(q0)]
    cand_d2: list[list[np.ndarray]] = [[] for _ in range(q0)]
    probed = np.zeros((q0, m), dtype=bool)
    avail = np.ones(m, dtype=bool)

    def probe(s: int, qidx: np.ndarray) -> bool:
        def thunk():
            return knn_query_batch_jax(
                sdev.shards[s], qs[qidx], k,
                use_kernel=use_kernel, fused=fused, return_dists=True,
            )

        try:
            ids, d2 = _run_shard(runner, s, thunk)
        except ShardUnavailable:
            if not return_certs:
                raise
            avail[s] = False
            return False
        for qi, i_s, d_s in zip(qidx, ids, d2):
            cand_ids[qi].append(i_s)
            cand_d2[qi].append(d_s)
        probed[qidx, s] = True
        return True

    # round 1: home = closest *available* shard; a query whose home dies
    # mid-round re-routes to the next closest until one answers (or every
    # shard is down, in which case it has no round-1 radius)
    unhomed = np.arange(q0)
    while unhomed.size and avail.any():
        mm = np.where(avail[None, :], minds[unhomed], np.inf)
        homes = np.argmin(mm, axis=1)
        rerouted: list[np.ndarray] = []
        for s in np.unique(homes):
            qidx = unhomed[homes == s]
            if not probe(int(s), qidx):
                rerouted.append(qidx)
        unhomed = (
            np.concatenate(rerouted) if rerouted
            else np.zeros(0, dtype=np.int64)
        )

    # certified pruning radius: the k-th home-shard distance (ascending),
    # +inf when the home shard cannot fill k results on its own
    radius = np.full(q0, np.inf, dtype=np.float64)
    for qi in range(q0):
        if cand_d2[qi] and len(cand_d2[qi][0]) >= k:
            radius[qi] = float(cand_d2[qi][0][k - 1])

    # round 2: escalate exactly the (query, shard) pairs whose exclusion
    # certificate fails (router mindist within the radius; <= keeps ties)
    for s in range(m):
        if not avail[s]:
            continue
        need = np.flatnonzero(~probed[:, s] & (minds[:, s] <= radius))
        if need.size:
            probe(s, need)

    out: list[np.ndarray] = []
    out_d2: list[np.ndarray] = []
    keep = min(k, sdev.n_points)
    for qi in range(q0):
        if len(cand_ids[qi]) == 0:
            out.append(np.zeros(0, dtype=np.int64))
            out_d2.append(np.zeros(0, dtype=np.float32))
            continue
        if len(cand_ids[qi]) == 1:
            # single probed shard: its local top-k IS the global answer,
            # already in engine order (m=1, or a certified-complete home)
            out.append(cand_ids[qi][0][:keep].astype(np.int64))
            out_d2.append(cand_d2[qi][0][:keep])
            continue
        ids = np.concatenate(cand_ids[qi])
        d2 = np.concatenate(cand_d2[qi])
        order = np.argsort(d2, kind="stable")[:keep]
        out.append(ids[order].astype(np.int64))
        out_d2.append(d2[order])
    if not return_certs:
        return out
    down = np.flatnonzero(~avail)
    certs = []
    for qi in range(q0):
        if down.size == 0:
            certs.append(CompletenessCertificate.intact())
            continue
        # the same exclusion test round 2 uses, against the *final* k-th
        # distance: a down shard with mindist strictly beyond it provably
        # holds no point of the true top-k (a short result leaves the
        # k-th distance +inf, so nothing clears)
        kth = float(out_d2[qi][k - 1]) if len(out_d2[qi]) >= k else np.inf
        miss = [int(s) for s in down if not (minds[qi, s] > kth)]
        certs.append(CompletenessCertificate.degraded(sdev, miss, exact=True))
    return out, certs


# --------------------------------------------------------------------------
# collective rounds under shard_map (device-mesh formulation)
# --------------------------------------------------------------------------
def _check_mesh(stacked: dict, mesh, axis: str) -> np.ndarray:
    """The mesh axis must carry exactly one device per shard; returns the
    stacked leaf-point array."""
    m = mesh.shape[axis]
    lp = stacked["leaf_pts"]
    if lp.shape[0] != m:
        raise ValueError(
            f"mesh axis {axis!r} has {m} devices but table has "
            f"{lp.shape[0]} shards"
        )
    return lp


def knn_batch_shard_map(
    stacked: dict,
    qs: np.ndarray,
    k: int,
    mesh,
    axis: str = "data",
) -> tuple[np.ndarray, np.ndarray]:
    """Two-round k-NN as one compiled collective over a device mesh.

    ``stacked`` is :meth:`ShardedDeviceTable.stacked`; the mesh's
    ``axis`` plays the m local servers (its size must equal the shard
    count).  Each device scans *all* of its shard's leaf blocks — the
    local round is exact by construction — then the global round is an
    ``all_gather`` of the per-shard (distance, id) top-k and one merge
    top-k, exactly the ``shard_knn`` protocol but over the DeviceTable
    layout with global ids (no local-slot translation).

    Returns ``(d2, ids)`` of shape (Q, k'), ascending per query, where
    ``k' = min(k, L*S)``; rows beyond a query's reachable points carry
    ``id = -1`` with +inf distance.
    """
    lp = _check_mesh(stacked, mesh, axis)
    n_l = lp.shape[1]
    n_total = int(stacked["n_points"])
    qs_j = jnp.asarray(np.atleast_2d(np.asarray(qs, dtype=np.float32)))

    def body(lp_l, li_l, lc_l, llo_l, lhi_l):
        dev = DeviceTable(
            leaf_pts=lp_l[0], leaf_ids=li_l[0], leaf_counts=lc_l[0],
            leaf_lo=llo_l[0], leaf_hi=lhi_l[0], levels=(),
            n_points=n_total,
        )
        # full-budget local round: every leaf scanned, certificate trivial
        ids, d2, _ = _knn_core(dev, qs_j, k, n_l, False)
        top_d2, sel, _ = gather_topk_merge(d2, ids, axis, d2.shape[-1])
        return top_d2[None], sel[None]

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
    )
    d2, ids = fn(
        jnp.asarray(lp), jnp.asarray(stacked["leaf_ids"]),
        jnp.asarray(stacked["leaf_counts"]), jnp.asarray(stacked["leaf_lo"]),
        jnp.asarray(stacked["leaf_hi"]),
    )
    # every shard holds the same merged answer; shard 0's copy suffices
    return np.asarray(d2[0]), np.asarray(ids[0])


def window_count_batch_shard_map(
    stacked: dict,
    los: np.ndarray,
    his: np.ndarray,
    mesh,
    axis: str = "data",
) -> np.ndarray:
    """Exact batched window counts as one ``psum`` collective.

    Each device counts its shard's qualifying points (leaf-blocked
    containment scan, padding masked by the fill counts); the global
    count is the cross-shard sum.  The host-routed
    :func:`window_query_batch_sharded` stays the work-proportional
    collection engine — this is the mesh-resident counting round.
    """
    lp = _check_mesh(stacked, mesh, axis)
    los_j = jnp.asarray(np.atleast_2d(np.asarray(los, dtype=np.float32)))
    his_j = jnp.asarray(np.atleast_2d(np.asarray(his, dtype=np.float32)))
    s, d = lp.shape[2], lp.shape[3]

    def body(lp_l, lc_l):
        pts = lp_l[0]                                     # (L, S, d)
        valid = (
            jnp.arange(s, dtype=jnp.int32)[None, :] < lc_l[0][:, None]
        )                                                  # (L, S)
        # static unroll over dimensions: (Q, L, S) planes only, no
        # (Q, L, S, d) broadcast temporaries (the frontier-test idiom)
        inside = valid[None]
        for j in range(d):
            inside = inside & (
                (pts[..., j][None] >= los_j[:, j][:, None, None])
                & (pts[..., j][None] <= his_j[:, j][:, None, None])
            )
        local = jnp.sum(inside, axis=(1, 2)).astype(jnp.int32)
        return jax.lax.psum(local, axis)[None]

    fn = _shard_map(
        body, mesh=mesh, in_specs=(P(axis), P(axis)), out_specs=P(axis),
    )
    counts = fn(jnp.asarray(lp), jnp.asarray(stacked["leaf_counts"]))
    return np.asarray(counts[0])
