"""Query processing over ``core`` indexes: window (range) and k-NN.

Both queries follow the paper's top-down traversal: starting from the root,
visit every node whose MBB may contain results; leaves are scanned and
filtered.  Each node visit charges one buffered page read to the index's
``PageStore`` (merged nodes share pages, so the LRU buffer — not the tree
shape — decides whether a visit costs I/O, exactly as in the paper).

k-NN uses the standard best-first search with an incremental result heap
(Hjaltason & Samet), which both FMBI and the competitor R-tree variants use
in the paper's unified framework.
"""
from __future__ import annotations

import heapq
import itertools

import numpy as np

from .fmbi import Index, Node
from .pagestore import IOStats


# --------------------------------------------------------------------------
# geometry helpers
# --------------------------------------------------------------------------
def mbb_intersects(mbb: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> bool:
    return bool(np.all(mbb[0] <= hi) and np.all(mbb[1] >= lo))


def mindist_sq(mbb: np.ndarray, q: np.ndarray) -> float:
    """Squared min distance from point ``q`` to box ``mbb`` (0 if inside)."""
    d = np.maximum(mbb[0] - q, 0.0) + np.maximum(q - mbb[1], 0.0)
    return float(np.dot(d, d))


# --------------------------------------------------------------------------
# window query
# --------------------------------------------------------------------------
def window_query(
    index: Index,
    lo: np.ndarray,
    hi: np.ndarray,
    *,
    refiner=None,
) -> tuple[np.ndarray, IOStats]:
    """All dataset rows inside [lo, hi].  Returns (row indices, io delta).

    ``refiner(node)`` is AMBI's hook: called on qualifying unrefined nodes to
    build their subtree on demand before traversal continues.
    """
    store = index.store
    before = store.stats.snapshot()
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    out: list[np.ndarray] = []
    stack = [index.root]
    while stack:
        node = stack.pop()
        if not mbb_intersects(node.mbb, lo, hi):
            continue
        store.read(node.page_id)
        if node.is_unrefined:
            if refiner is None:
                raise RuntimeError("unrefined node reached without a refiner")
            node = refiner(node)
            if node is None:
                continue
            stack.append(node)
            continue
        if node.is_leaf:
            pts = index.points[node.point_idx]
            mask = np.all((pts >= lo) & (pts <= hi), axis=1)
            if mask.any():
                out.append(node.point_idx[mask])
        else:
            stack.extend(node.children)
    res = np.concatenate(out) if out else np.zeros(0, dtype=np.int64)
    return res, store.stats.delta(before)


# --------------------------------------------------------------------------
# k-NN query (best-first)
# --------------------------------------------------------------------------
def knn_query(
    index: Index,
    q: np.ndarray,
    k: int,
    *,
    refiner=None,
) -> tuple[np.ndarray, IOStats]:
    """k nearest dataset rows to ``q``.  Returns (row indices, io delta)."""
    store = index.store
    before = store.stats.snapshot()
    q = np.asarray(q, dtype=np.float64)
    counter = itertools.count()  # tie-breaker for heap ordering
    heap: list = [(0.0, next(counter), index.root)]
    best: list = []  # max-heap of (-dist_sq, row)
    while heap:
        dist, _, node = heapq.heappop(heap)
        if len(best) == k and dist > -best[0][0]:
            break
        store.read(node.page_id)
        if node.is_unrefined:
            if refiner is None:
                raise RuntimeError("unrefined node reached without a refiner")
            node = refiner(node)
            if node is None:
                continue
            heapq.heappush(heap, (mindist_sq(node.mbb, q), next(counter), node))
            continue
        if node.is_leaf:
            pts = index.points[node.point_idx]
            d2 = np.sum((pts - q) ** 2, axis=1)
            for dd, row in zip(d2, node.point_idx):
                if len(best) < k:
                    heapq.heappush(best, (-dd, int(row)))
                elif dd < -best[0][0]:
                    heapq.heapreplace(best, (-dd, int(row)))
        else:
            kth = -best[0][0] if len(best) == k else np.inf
            for c in node.children:
                md = mindist_sq(c.mbb, q)
                if md <= kth:
                    heapq.heappush(heap, (md, next(counter), c))
    rows = np.asarray(
        [r for _, r in sorted(best, key=lambda t: -t[0])], dtype=np.int64
    )
    return rows, store.stats.delta(before)


# --------------------------------------------------------------------------
# brute-force oracles (for tests)
# --------------------------------------------------------------------------
def window_oracle(points: np.ndarray, lo, hi) -> np.ndarray:
    mask = np.all((points >= np.asarray(lo)) & (points <= np.asarray(hi)), axis=1)
    return np.flatnonzero(mask)


def knn_oracle(points: np.ndarray, q, k: int) -> np.ndarray:
    d2 = np.sum((points - np.asarray(q)) ** 2, axis=1)
    return np.argsort(d2, kind="stable")[:k]
