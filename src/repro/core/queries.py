"""Query processing over ``core`` indexes: window (range) and k-NN.

Both queries follow the paper's top-down traversal — visit every node whose
MBB may contain results, scan and filter leaves — but execute it against the
flat :class:`~repro.core.nodetable.NodeTable` instead of an object graph:

  * **Window** queries run *level-synchronous frontier traversal*: the whole
    frontier's boxes are tested against the window with two broadcast
    comparisons, survivors expand through the CSR child ranges in one ragged
    gather, and all qualifying leaves are filtered with a single comparison
    over their concatenated ``perm`` rows.  No per-node Python work remains
    on the geometry path.
  * **k-NN** keeps best-first search (Hjaltason & Samet) over rows — the
    traversal order is what pins the I/O accounting — but child mindists are
    computed vectorized per expansion and leaf scans are one distance
    evaluation plus one ``argpartition`` merge.
  * Batched entry points (``window_query_batch`` / ``knn_query_batch``)
    execute many queries against one traversal, Flood-style: branch pages
    are visited (and charged) once per batch, leaf work is vectorized across
    the query batch, and k-NN prunes with vectorized mindists over the leaf
    table (one shared ``(L, d)`` view straight out of the node table).

I/O equivalence
---------------
Every node visit charges one buffered page read through the index's LRU
``PageStore``, and the LRU makes charges *order*-dependent.  The frontier
pass therefore only computes the visited set and the results; the page reads
are then replayed in exactly the depth-first order the object-graph engine
used (children expanded onto a stack, visited in reverse — see
``_charge_reads_dfs``), so ``IOStats`` stay bit-identical to the PR-1 scan
engine.  ``tests/test_flat_queries.py`` pins this against the retained
object-graph reference implementations.

AMBI's on-demand refinement mutates the table mid-traversal, so when a
``refiner`` is supplied the sequential row-at-a-time traversal runs instead
(the construction I/O it charges must interleave with the query's page reads
exactly as before); the ``refiner(row)`` hook refines an unrefined row in
place and returns False when the row is empty.
"""
from __future__ import annotations

import heapq
import itertools

import numpy as np

from .fmbi import Index
from .geometry import mbb_intersects, mindist_sq  # noqa: F401 — re-exported (legacy home)
from .nodetable import NodeTable, ragged_ranges
from .pagestore import IOStats


def _merge_topk(
    best_d: np.ndarray, best_r: np.ndarray,
    d2: np.ndarray, rows: np.ndarray, k: int,
):
    """Merge leaf candidates into the running top-k (one partition, no heap)."""
    d = np.concatenate([best_d, d2])
    r = np.concatenate([best_r, rows])
    if len(d) > k:
        sel = np.argpartition(d, k - 1)[:k]
        d, r = d[sel], r[sel]
    return d, r


class _TopKBuffer:
    """Preallocated top-k accumulator: one scratch pair reused across every
    leaf merge (and across queries in a batch) instead of per-leaf
    ``concatenate`` churn.  Selection is the same ``argpartition`` as
    :func:`_merge_topk`, so results and tie behaviour are identical."""

    __slots__ = ("k", "d", "r", "n")

    def __init__(self, k: int, max_leaf: int):
        self.k = k
        self.d = np.empty(k + max_leaf, dtype=np.float64)
        self.r = np.empty(k + max_leaf, dtype=np.int64)
        self.n = 0

    def reset(self) -> None:
        self.n = 0

    @property
    def kth(self) -> float:
        return float(self.d[: self.n].max()) if self.n == self.k else np.inf

    def merge(self, d2: np.ndarray, rows: np.ndarray) -> None:
        m = len(d2)
        self.d[self.n : self.n + m] = d2
        self.r[self.n : self.n + m] = rows
        n = self.n + m
        if n > self.k:
            sel = np.argpartition(self.d[:n], self.k - 1)[: self.k]
            self.d[: self.k] = self.d[sel]
            self.r[: self.k] = self.r[sel]
            n = self.k
        self.n = n

    def result(self) -> np.ndarray:
        order = np.argsort(self.d[: self.n], kind="stable")
        return self.r[: self.n][order]


# --------------------------------------------------------------------------
# I/O replay (the LRU makes read charges order-dependent)
# --------------------------------------------------------------------------
def _charge_reads_dfs(table: NodeTable, hit: np.ndarray, store) -> None:
    """Charge one page read per hit row in the object-graph engine's exact
    depth-first pop order (stack seeded with the root, children extended in
    list order, therefore visited in reverse).

    The hit set is downward-closed — a row qualifies only if its parent did —
    so filtering the table's cached full DFS order by the hit mask yields
    precisely the pruned traversal's read sequence: the extra rows a full
    walk visits under non-hit nodes are all non-hit themselves and the stack
    discipline keeps the hit rows' relative order unchanged."""
    dfs = table.dfs_order()
    read = store.read
    for p in table.page_id[dfs[hit[dfs]]]:
        read(int(p))


# --------------------------------------------------------------------------
# window query
# --------------------------------------------------------------------------
def window_query(
    index: Index,
    lo: np.ndarray,
    hi: np.ndarray,
    *,
    refiner=None,
) -> tuple[np.ndarray, IOStats]:
    """All dataset rows inside [lo, hi].  Returns (row indices, io delta).

    ``refiner(row)`` is AMBI's hook: called on qualifying unrefined rows to
    build their subtree on demand before traversal continues.
    """
    store = index.store
    before = store.stats.snapshot()
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    if refiner is not None:
        res = _window_adaptive(index, lo, hi, refiner)
        return res, store.stats.delta(before)
    t = index.table
    mlo, mhi = t.mbb_lo, t.mbb_hi
    hit = np.zeros(t.n_nodes, dtype=bool)
    frontier = np.zeros(1, dtype=np.int64)
    out: list[np.ndarray] = []
    while frontier.size:
        m = np.all(mlo[frontier] <= hi, axis=1) & np.all(
            mhi[frontier] >= lo, axis=1
        )
        rows = frontier[m]
        if rows.size == 0:
            break
        hit[rows] = True
        if t.unrefined[rows].any():
            raise RuntimeError("unrefined node reached without a refiner")
        leaf = t.leaf_start[rows] >= 0
        lrows = rows[leaf]
        if lrows.size:
            cand = t.perm[ragged_ranges(t.leaf_start[lrows], t.leaf_count[lrows])]
            pts = index.points[cand]
            mask = np.all((pts >= lo) & (pts <= hi), axis=1)
            if mask.any():
                out.append(cand[mask])
        brows = rows[~leaf]
        frontier = ragged_ranges(t.first_child[brows], t.child_count[brows])
    _charge_reads_dfs(t, hit, store)
    res = np.concatenate(out) if out else np.zeros(0, dtype=np.int64)
    return res, store.stats.delta(before)


def _window_adaptive(index: Index, lo, hi, refiner) -> np.ndarray:
    """Sequential row-DFS for refining traversals (order-faithful I/O)."""
    t = index.table
    store = index.store
    out: list[np.ndarray] = []
    stack = [0]
    while stack:
        r = stack.pop()
        if not (np.all(t.mbb_lo[r] <= hi) and np.all(t.mbb_hi[r] >= lo)):
            continue
        store.read(int(t.page_id[r]))
        if t.unrefined[r]:
            if refiner(r):
                stack.append(r)  # revisit: the row now holds the subtree
            continue
        if t.leaf_start[r] >= 0:
            cand = t.point_rows(r)
            pts = index.points[cand]
            mask = np.all((pts >= lo) & (pts <= hi), axis=1)
            if mask.any():
                out.append(cand[mask])
        else:
            stack.extend(t.children_of(r))
    return np.concatenate(out) if out else np.zeros(0, dtype=np.int64)


def window_query_batch(
    index: Index,
    los: np.ndarray,
    his: np.ndarray,
    *,
    refiner=None,
) -> tuple[list[np.ndarray], IOStats]:
    """Execute ``Q`` window queries in one frontier traversal.

    Returns (per-query row-index arrays, io delta).  A node is visited — and
    its page read charged — once if *any* query in the batch intersects it,
    which is the batch's I/O amortization; leaf points are filtered against
    all active queries with a single broadcast comparison.  With a
    ``refiner`` the sequential traversal runs instead (see module docstring).
    """
    store = index.store
    before = store.stats.snapshot()
    los = np.atleast_2d(np.asarray(los, dtype=np.float64))
    his = np.atleast_2d(np.asarray(his, dtype=np.float64))
    nq = los.shape[0]
    if refiner is not None:
        res = _window_batch_adaptive(index, los, his, refiner)
        return res, store.stats.delta(before)
    t = index.table
    mlo, mhi = t.mbb_lo, t.mbb_hi
    hitmask = np.zeros(t.n_nodes, dtype=bool)
    frontier = np.zeros(1, dtype=np.int64)
    act = np.ones((1, nq), dtype=bool)
    # per query: which leaf rows qualify (filtered in one gather at the end,
    # so a leaf's points are only ever compared against the queries that
    # actually reach it — the object-graph engine's work, vectorized)
    pending: list[list[np.ndarray]] = [[] for _ in range(nq)]
    while frontier.size:
        hit = act & (
            np.all(mlo[frontier][:, None, :] <= his[None, :, :], axis=2)
            & np.all(mhi[frontier][:, None, :] >= los[None, :, :], axis=2)
        )  # (F, Q)
        any_hit = hit.any(axis=1)
        rows = frontier[any_hit]
        if rows.size == 0:
            break
        hit = hit[any_hit]
        hitmask[rows] = True
        if t.unrefined[rows].any():
            raise RuntimeError("unrefined node reached without a refiner")
        leaf = t.leaf_start[rows] >= 0
        lrows = rows[leaf]
        if lrows.size:
            lhit = hit[leaf]
            for qi in np.flatnonzero(lhit.any(axis=0)):
                pending[qi].append(lrows[lhit[:, qi]])
        brows = rows[~leaf]
        frontier = ragged_ranges(t.first_child[brows], t.child_count[brows])
        act = np.repeat(hit[~leaf], t.child_count[brows], axis=0)
    _charge_reads_dfs(t, hitmask, store)
    res = []
    for qi in range(nq):
        if not pending[qi]:
            res.append(np.zeros(0, dtype=np.int64))
            continue
        rows = np.concatenate(pending[qi])
        cand = t.perm[ragged_ranges(t.leaf_start[rows], t.leaf_count[rows])]
        pts = index.points[cand]
        mask = np.all((pts >= los[qi]) & (pts <= his[qi]), axis=1)
        res.append(cand[mask])
    return res, store.stats.delta(before)


def _window_batch_adaptive(index: Index, los, his, refiner):
    t = index.table
    store = index.store
    nq = los.shape[0]
    out: list[list[np.ndarray]] = [[] for _ in range(nq)]
    stack: list[tuple[int, np.ndarray]] = [(0, np.arange(nq))]
    while stack:
        r, qids = stack.pop()
        hit = np.all(t.mbb_lo[r] <= his[qids], axis=1) & np.all(
            t.mbb_hi[r] >= los[qids], axis=1
        )
        if not hit.any():
            continue
        qids = qids[hit]
        store.read(int(t.page_id[r]))
        if t.unrefined[r]:
            if refiner(r):
                stack.append((r, qids))
            continue
        if t.leaf_start[r] >= 0:
            cand = t.point_rows(r)
            pts = index.points[cand]
            inside = np.all(
                (pts[None, :, :] >= los[qids, None, :])
                & (pts[None, :, :] <= his[qids, None, :]),
                axis=2,
            )
            for qi, m in zip(qids, inside):
                if m.any():
                    out[qi].append(cand[m])
        else:
            stack.extend((c, qids) for c in t.children_of(r))
    return [np.concatenate(o) if o else np.zeros(0, dtype=np.int64) for o in out]


# --------------------------------------------------------------------------
# k-NN query (best-first)
# --------------------------------------------------------------------------
def knn_query(
    index: Index,
    q: np.ndarray,
    k: int,
    *,
    refiner=None,
) -> tuple[np.ndarray, IOStats]:
    """k nearest dataset rows to ``q``.  Returns (row indices, io delta).

    Best-first over table rows: the heap order (and therefore every page
    read) is identical to the object-graph engine; expanding a branch
    computes all child mindists in one vectorized pass.
    """
    store = index.store
    before = store.stats.snapshot()
    q = np.asarray(q, dtype=np.float64)
    t = index.table
    counter = itertools.count()  # tie-breaker for heap ordering
    heap: list = [(0.0, next(counter), 0)]
    best_d = np.full(0, np.inf)
    best_r = np.zeros(0, dtype=np.int64)
    while heap:
        dist, _, r = heapq.heappop(heap)
        kth = best_d.max() if len(best_d) == k else np.inf
        if dist > kth:
            break
        store.read(int(t.page_id[r]))
        if t.unrefined[r]:
            if refiner is None:
                raise RuntimeError("unrefined node reached without a refiner")
            if not refiner(r):
                continue
            md = mindist_sq(
                np.stack([t.mbb_lo[r], t.mbb_hi[r]]), q
            )
            heapq.heappush(heap, (md, next(counter), r))
            continue
        if t.leaf_start[r] >= 0:
            cand = t.point_rows(r)
            pts = index.points[cand]
            d2 = np.sum((pts - q) ** 2, axis=1)
            best_d, best_r = _merge_topk(best_d, best_r, d2, cand, k)
        else:
            kth = best_d.max() if len(best_d) == k else np.inf
            ch = np.arange(
                t.first_child[r], t.first_child[r] + t.child_count[r]
            )
            gap = np.maximum(t.mbb_lo[ch] - q, 0.0) + np.maximum(
                q - t.mbb_hi[ch], 0.0
            )
            mds = np.einsum("ij,ij->i", gap, gap)
            for c, md in zip(ch, mds):
                if md <= kth:
                    heapq.heappush(heap, (float(md), next(counter), int(c)))
    order = np.argsort(best_d, kind="stable")
    return best_r[order], store.stats.delta(before)


def knn_query_batch(
    index: Index,
    qs: np.ndarray,
    k: int,
) -> tuple[list[np.ndarray], IOStats]:
    """Execute ``Q`` k-NN queries against one leaf-table traversal.

    Branch pages are read once per batch (in the engine's depth-first
    order); the leaf boxes come straight out of the node table as shared
    ``(L, d)`` views — nothing is stacked per batch, let alone per query.
    Each query prunes at leaf granularity with one vectorized mindist pass,
    scanning leaves in ascending-mindist order until the running k-th
    distance certifies no unscanned leaf can compete (the best-first
    guarantee); the top-k accumulates in one preallocated buffer reused
    across leaves and queries.  Leaf page reads are charged per scan through
    the shared LRU buffer, so overlapping queries hit the buffer instead of
    re-reading.

    Unrefined (AMBI) rows are not supported here: a batch prunes with the
    full leaf table, which an on-demand build does not have yet — fully
    refine first or use per-query :func:`knn_query`.
    """
    store = index.store
    before = store.stats.snapshot()
    qs = np.atleast_2d(np.asarray(qs, dtype=np.float64))
    t = index.table
    if t.unrefined.any():
        raise RuntimeError("knn_query_batch requires a fully refined index")

    # one traversal: charge each branch page once, in depth-first pop order;
    # leaves keep that same order so mindist ties scan identically
    dfs = t.dfs_order()
    leaf_in_dfs = t.leaf_start[dfs] >= 0
    pid = t.page_id
    read = store.read
    for r in dfs[~leaf_in_dfs]:
        read(int(pid[r]))
    leaf_rows = dfs[leaf_in_dfs]
    leaf_lo = t.mbb_lo[leaf_rows]
    leaf_hi = t.mbb_hi[leaf_rows]
    starts = t.leaf_start[leaf_rows]
    counts = t.leaf_count[leaf_rows]

    topk = _TopKBuffer(k, int(counts.max()) if len(counts) else 1)
    results: list[np.ndarray] = []
    for q in qs:
        gap = np.maximum(leaf_lo - q, 0.0) + np.maximum(q - leaf_hi, 0.0)
        mind = np.sum(gap * gap, axis=1)  # (L,)
        order = np.argsort(mind, kind="stable")
        topk.reset()
        for li in order:
            if mind[li] > topk.kth:
                break
            read(int(pid[leaf_rows[li]]))
            cand = t.perm[starts[li] : starts[li] + counts[li]]
            pts = index.points[cand]
            d2 = np.sum((pts - q) ** 2, axis=1)
            topk.merge(d2, cand)
        results.append(topk.result())
    return results, store.stats.delta(before)


# --------------------------------------------------------------------------
# brute-force oracles (for tests)
# --------------------------------------------------------------------------
def window_oracle(points: np.ndarray, lo, hi) -> np.ndarray:
    mask = np.all((points >= np.asarray(lo)) & (points <= np.asarray(hi)), axis=1)
    return np.flatnonzero(mask)


def knn_oracle(points: np.ndarray, q, k: int) -> np.ndarray:
    d2 = np.sum((points - np.asarray(q)) ** 2, axis=1)
    return np.argsort(d2, kind="stable")[:k]
