"""Query processing over ``core`` indexes: window (range) and k-NN.

Both queries follow the paper's top-down traversal: starting from the root,
visit every node whose MBB may contain results; leaves are scanned and
filtered.  Each node visit charges one buffered page read to the index's
``PageStore`` (merged nodes share pages, so the LRU buffer — not the tree
shape — decides whether a visit costs I/O, exactly as in the paper).

k-NN follows best-first search (Hjaltason & Samet) over *nodes*, but leaf
scans are array-level: one distance evaluation plus one ``argpartition``
merge per leaf instead of a per-point result-heap insertion.  The traversal
order, pruning thresholds, and therefore the page reads are identical to the
classical incremental formulation.

Batched entry points (``window_query_batch`` / ``knn_query_batch``) execute
many queries against one traversal, the move Flood-style learned indexes
make for query throughput: branch pages are visited (and charged) once per
batch rather than once per query, and leaf filtering is vectorized across
the whole query batch.
"""
from __future__ import annotations

import heapq
import itertools

import numpy as np

from .fmbi import Index, Node
from .pagestore import IOStats


# --------------------------------------------------------------------------
# geometry helpers
# --------------------------------------------------------------------------
def mbb_intersects(mbb: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> bool:
    return bool(np.all(mbb[0] <= hi) and np.all(mbb[1] >= lo))


def mindist_sq(mbb: np.ndarray, q: np.ndarray) -> float:
    """Squared min distance from point ``q`` to box ``mbb`` (0 if inside)."""
    d = np.maximum(mbb[0] - q, 0.0) + np.maximum(q - mbb[1], 0.0)
    return float(np.dot(d, d))


def _merge_topk(
    best_d: np.ndarray, best_r: np.ndarray,
    d2: np.ndarray, rows: np.ndarray, k: int,
):
    """Merge leaf candidates into the running top-k (one partition, no heap)."""
    d = np.concatenate([best_d, d2])
    r = np.concatenate([best_r, rows])
    if len(d) > k:
        sel = np.argpartition(d, k - 1)[:k]
        d, r = d[sel], r[sel]
    return d, r


# --------------------------------------------------------------------------
# window query
# --------------------------------------------------------------------------
def window_query(
    index: Index,
    lo: np.ndarray,
    hi: np.ndarray,
    *,
    refiner=None,
) -> tuple[np.ndarray, IOStats]:
    """All dataset rows inside [lo, hi].  Returns (row indices, io delta).

    ``refiner(node)`` is AMBI's hook: called on qualifying unrefined nodes to
    build their subtree on demand before traversal continues.
    """
    store = index.store
    before = store.stats.snapshot()
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    out: list[np.ndarray] = []
    stack = [index.root]
    while stack:
        node = stack.pop()
        if not mbb_intersects(node.mbb, lo, hi):
            continue
        store.read(node.page_id)
        if node.is_unrefined:
            if refiner is None:
                raise RuntimeError("unrefined node reached without a refiner")
            node = refiner(node)
            if node is None:
                continue
            stack.append(node)
            continue
        if node.is_leaf:
            pts = index.points[node.point_idx]
            mask = np.all((pts >= lo) & (pts <= hi), axis=1)
            if mask.any():
                out.append(node.point_idx[mask])
        else:
            stack.extend(node.children)
    res = np.concatenate(out) if out else np.zeros(0, dtype=np.int64)
    return res, store.stats.delta(before)


def window_query_batch(
    index: Index,
    los: np.ndarray,
    his: np.ndarray,
    *,
    refiner=None,
) -> tuple[list[np.ndarray], IOStats]:
    """Execute ``Q`` window queries in one traversal.

    Returns (per-query row-index arrays, io delta).  A node is visited — and
    its page read charged — once if *any* query in the batch intersects it,
    which is the batch's I/O amortization; leaf points are filtered against
    all active queries with a single broadcast comparison.  ``refiner`` is
    called on unrefined nodes that qualify for at least one query.
    """
    store = index.store
    before = store.stats.snapshot()
    los = np.atleast_2d(np.asarray(los, dtype=np.float64))
    his = np.atleast_2d(np.asarray(his, dtype=np.float64))
    nq = los.shape[0]
    out: list[list[np.ndarray]] = [[] for _ in range(nq)]
    stack: list[tuple[Node, np.ndarray]] = [(index.root, np.arange(nq))]
    while stack:
        node, qids = stack.pop()
        hit = np.all(node.mbb[0] <= his[qids], axis=1) & np.all(
            node.mbb[1] >= los[qids], axis=1
        )
        if not hit.any():
            continue
        qids = qids[hit]
        store.read(node.page_id)
        if node.is_unrefined:
            if refiner is None:
                raise RuntimeError("unrefined node reached without a refiner")
            node = refiner(node)
            if node is None:
                continue
            stack.append((node, qids))
            continue
        if node.is_leaf:
            pts = index.points[node.point_idx]
            inside = np.all(
                (pts[None, :, :] >= los[qids, None, :])
                & (pts[None, :, :] <= his[qids, None, :]),
                axis=2,
            )  # (|qids|, leaf)
            for qi, m in zip(qids, inside):
                if m.any():
                    out[qi].append(node.point_idx[m])
        else:
            stack.extend((c, qids) for c in node.children)
    res = [
        np.concatenate(o) if o else np.zeros(0, dtype=np.int64) for o in out
    ]
    return res, store.stats.delta(before)


# --------------------------------------------------------------------------
# k-NN query (best-first)
# --------------------------------------------------------------------------
def knn_query(
    index: Index,
    q: np.ndarray,
    k: int,
    *,
    refiner=None,
) -> tuple[np.ndarray, IOStats]:
    """k nearest dataset rows to ``q``.  Returns (row indices, io delta)."""
    store = index.store
    before = store.stats.snapshot()
    q = np.asarray(q, dtype=np.float64)
    counter = itertools.count()  # tie-breaker for heap ordering
    heap: list = [(0.0, next(counter), index.root)]
    best_d = np.full(0, np.inf)
    best_r = np.zeros(0, dtype=np.int64)
    while heap:
        dist, _, node = heapq.heappop(heap)
        kth = best_d.max() if len(best_d) == k else np.inf
        if dist > kth:
            break
        store.read(node.page_id)
        if node.is_unrefined:
            if refiner is None:
                raise RuntimeError("unrefined node reached without a refiner")
            node = refiner(node)
            if node is None:
                continue
            heapq.heappush(heap, (mindist_sq(node.mbb, q), next(counter), node))
            continue
        if node.is_leaf:
            pts = index.points[node.point_idx]
            d2 = np.sum((pts - q) ** 2, axis=1)
            best_d, best_r = _merge_topk(
                best_d, best_r, d2, node.point_idx, k
            )
        else:
            kth = best_d.max() if len(best_d) == k else np.inf
            for c in node.children:
                md = mindist_sq(c.mbb, q)
                if md <= kth:
                    heapq.heappush(heap, (md, next(counter), c))
    order = np.argsort(best_d, kind="stable")
    return best_r[order], store.stats.delta(before)


def knn_query_batch(
    index: Index,
    qs: np.ndarray,
    k: int,
) -> tuple[list[np.ndarray], IOStats]:
    """Execute ``Q`` k-NN queries against one leaf-table traversal.

    The tree is walked once per batch: every branch page is read once and
    the leaf boxes are collected into (L, d) arrays.  Each query then prunes
    at leaf granularity — box mindists for all leaves in one vectorized
    pass, leaves scanned in ascending-mindist order until the running k-th
    distance certifies no unscanned leaf can compete (the best-first
    guarantee).  Leaf page reads are charged per scan through the shared LRU
    buffer, so overlapping queries in a batch hit the buffer instead of
    re-reading.

    Unrefined (AMBI) nodes are not supported here: a batch prunes with the
    full leaf table, which an on-demand build does not have yet — fully
    refine first or use per-query :func:`knn_query`.
    """
    store = index.store
    before = store.stats.snapshot()
    qs = np.atleast_2d(np.asarray(qs, dtype=np.float64))

    # one traversal: collect leaves, charge each branch page once
    leaves: list[Node] = []
    stack = [index.root]
    while stack:
        node = stack.pop()
        if node.is_unrefined:
            raise RuntimeError(
                "knn_query_batch requires a fully refined index"
            )
        if node.is_leaf:
            leaves.append(node)
        else:
            store.read(node.page_id)
            stack.extend(node.children)
    leaf_lo = np.stack([l.mbb[0] for l in leaves])
    leaf_hi = np.stack([l.mbb[1] for l in leaves])

    results: list[np.ndarray] = []
    for q in qs:
        gap = np.maximum(leaf_lo - q, 0.0) + np.maximum(q - leaf_hi, 0.0)
        mind = np.sum(gap * gap, axis=1)  # (L,)
        order = np.argsort(mind, kind="stable")
        best_d = np.full(0, np.inf)
        best_r = np.zeros(0, dtype=np.int64)
        for li in order:
            if len(best_d) == k and mind[li] > best_d.max():
                break
            leaf = leaves[li]
            store.read(leaf.page_id)
            pts = index.points[leaf.point_idx]
            d2 = np.sum((pts - q) ** 2, axis=1)
            best_d, best_r = _merge_topk(
                best_d, best_r, d2, leaf.point_idx, k
            )
        results.append(best_r[np.argsort(best_d, kind="stable")])
    return results, store.stats.delta(before)


# --------------------------------------------------------------------------
# brute-force oracles (for tests)
# --------------------------------------------------------------------------
def window_oracle(points: np.ndarray, lo, hi) -> np.ndarray:
    mask = np.all((points >= np.asarray(lo)) & (points <= np.asarray(hi)), axis=1)
    return np.flatnonzero(mask)


def knn_oracle(points: np.ndarray, q, k: int) -> np.ndarray:
    d2 = np.sum((points - np.asarray(q)) ** 2, axis=1)
    return np.argsort(d2, kind="stable")[:k]
