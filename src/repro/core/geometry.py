"""Shared box geometry: intersection and mindist helpers.

Every traversal layer needs the same three predicates — box-vs-box
intersection, point-to-box mindist, box-to-box mindist — and they had
drifted into per-file copies (``queries.py``, ``ambi.py``,
``distributed.py``).  This module is the single home for the scalar forms
plus the batched forms the sharded query router uses (one (Q, m) plane per
predicate, no Python loop).

Conventions: a box is either an ``(2, d)`` stacked ``[lo; hi]`` array
(the ``mbb`` layout construction code carries) or a separate ``lo``/``hi``
pair; batched variants take ``(m, d)`` column pairs.  All tests are
closed-interval, matching the paper's window semantics.
"""
from __future__ import annotations

import numpy as np


# --------------------------------------------------------------------------
# scalar forms (one box, one query)
# --------------------------------------------------------------------------
def mbb_intersects(mbb: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> bool:
    """Does box ``mbb`` ((2, d) [lo; hi]) intersect the window [lo, hi]?"""
    return bool(np.all(mbb[0] <= hi) and np.all(mbb[1] >= lo))


def mindist_sq(mbb: np.ndarray, q: np.ndarray) -> float:
    """Squared min distance from point ``q`` to box ``mbb`` (0 if inside)."""
    d = np.maximum(mbb[0] - q, 0.0) + np.maximum(q - mbb[1], 0.0)
    return float(np.dot(d, d))


def mindist_box_sq(mbb: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> float:
    """Squared min distance between box ``mbb`` and box [lo, hi] (0 when
    they intersect)."""
    gap = np.maximum(mbb[0] - hi, 0.0) + np.maximum(lo - mbb[1], 0.0)
    return float(np.dot(gap, gap))


# --------------------------------------------------------------------------
# batched forms (m boxes x Q queries): the sharded router's primitives
# --------------------------------------------------------------------------
def boxes_intersect_windows(
    box_lo: np.ndarray, box_hi: np.ndarray, los: np.ndarray, his: np.ndarray
) -> np.ndarray:
    """(Q, m) mask: does box ``j`` intersect window ``i``?"""
    return np.all(box_lo[None, :, :] <= his[:, None, :], axis=2) & np.all(
        box_hi[None, :, :] >= los[:, None, :], axis=2
    )


def boxes_mindist_sq(
    box_lo: np.ndarray, box_hi: np.ndarray, qs: np.ndarray
) -> np.ndarray:
    """(Q, m) squared min distances from query points to boxes."""
    gap = np.maximum(box_lo[None, :, :] - qs[:, None, :], 0.0) + np.maximum(
        qs[:, None, :] - box_hi[None, :, :], 0.0
    )
    return np.einsum("qmd,qmd->qm", gap, gap)
