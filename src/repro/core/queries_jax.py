"""Compiled device-resident query engine over the flat ``NodeTable``.

The NumPy engine in ``queries.py`` is the paper-faithful authority — it
charges the LRU page I/O the paper costs indexes by — but its batched hot
paths still execute on the host.  This module compiles the same batched
window and k-NN queries for the accelerator: the ``NodeTable`` is exported
once into fixed-shape device arrays (:class:`DeviceTable`) and every query
batch then runs as a couple of jit-compiled dispatches with no per-query
Python on the geometry path.

Execution model
---------------
  * **Level-synchronous frontier traversal.**  The table's rows are
    re-blocked by BFS depth (``NodeTable.device_layout``); descending the
    tree is a static unrolled loop over level blocks in which the whole
    level's MBBs are tested against the whole query batch with one masked
    broadcast comparison, and survival propagates to the next level through
    a fixed-fanout parent-position gather.  There is no dynamic frontier —
    every row is tested, masked by its parent's bit — which keeps all
    shapes static while computing exactly the visited set of the NumPy
    engine (MBB nesting makes the hit set downward-closed).
  * **Window collection is work-proportional.**  The traversal's (Q, L)
    leaf hit mask is flattened into a list of (query, leaf) *pairs* — the
    batch's true candidate set — padded to a power-of-two bucket and
    scanned leaf-block by leaf-block.  Cost scales with the candidate
    leaves the batch actually touches (the property the NumPy engine has),
    not with Q x max-per-query, and the compiled variants are bounded by
    the pair-bucket sizes.  Qualifying ids are packed host-side with two
    vectorized NumPy selections (the only remaining host work).
  * **k-NN scans fixed candidate budgets with certificates.**  Each query
    takes its C closest leaves by box mindist (indices-only ``top_k`` —
    XLA CPU's top_k with live values is pathologically slow), scans them,
    and certifies exactness against the mindist of the closest unscanned
    leaf (computed by masking the scanned leaves to +inf and taking a row
    min).  The budget doubles until every certificate holds, so results
    are exact; budgets are powers of two, bounding compiled variants.
  * **Fused leaf kernels.**  The per-candidate containment test
    (``kernels/window_filter.window_mask_gathered``) and candidate
    distance scan (``kernels/knn_topk.gathered_dist2``) run as Pallas
    kernels on TPU (``use_kernel=None`` auto-selects; interpret mode
    exercises the same kernels on CPU CI) with an equivalent jnp path for
    plain XLA backends.

Parity contract
---------------
For float32-representable inputs, window results are exactly the NumPy
engine's id sets: containment is an exact comparison on identical values.
k-NN candidate sets are certified complete by the best-first bound (k-th
distance <= mindist of the closest unscanned leaf), so returned ids are
exact nearest neighbors *under float32 distance arithmetic*: the NumPy
engine ranks by float64, so two neighbors whose true squared distances
differ by less than one f32 ulp can order differently at the k-th
boundary (never observed under the suite's pinned seeds; exact ties are
unspecified in both engines — tie-heavy tests compare distances).
Result *order* within a window result set is unspecified; compare as
sets.  The device path charges no simulated I/O — ``IOStats`` remain the
NumPy engine's job.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from .jax_index import _pow2
from .nodetable import NodeTable

BIG = float(np.finfo(np.float32).max)

# one dispatch scans at most this many (query, leaf) pairs; bigger
# candidate sets stream in chunks so memory stays bounded and compiled
# variants stay the handful of power-of-two bucket sizes below the cap
PAIR_CHUNK = 16384

# retrace counters (trace-time side effects): tests pin compile growth
TRACE_COUNTS = {
    "frontier": 0,
    "window_collect": 0,
    "knn_core": 0,
    "pair_pack": 0,   # on-device (query, leaf) pair compaction chunks
    "id_pack": 0,     # on-device qualifying-id compaction buckets
    "knn_sel": 0,     # on-device pending-query gathers (budget escalation)
}


def trace_counts() -> dict:
    """Snapshot of the retrace counters (a copy, safe to diff against)."""
    return dict(TRACE_COUNTS)

# host -> device upload accounting: the adaptive-serving tests prove a graft
# refreshes the device table by uploading only its delta (full_exports stays
# at the boot count; each refresh uploads exactly the new leaf blocks)
@dataclasses.dataclass
class UploadStats:
    """Host -> device upload counters.

    Instance-scoped: each ``DeviceQueryServer`` (and each explicitly
    threaded export) owns its own sink, so two servers in one process
    keep independent delta-only-upload proofs.  ``UPLOAD_STATS`` below is
    the module-level default sink for code that exports tables without a
    server (and for the upload totals of otherwise-unowned exports).
    Supports dict-style reads for the counter names.
    """

    full_exports: int = 0        # DeviceTable.from_table calls
    delta_refreshes: int = 0     # DeviceTable.apply_delta calls
    uploaded_leaf_blocks: int = 0  # leaf blocks shipped host -> device
    uploaded_points: int = 0       # live points inside those blocks

    def __getitem__(self, key: str) -> int:
        if key not in self.__dataclass_fields__:
            raise KeyError(key)
        return getattr(self, key)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def reset(self) -> dict:
        """Zero the counters; returns the pre-reset values."""
        old = self.as_dict()
        for k in self.__dataclass_fields__:
            setattr(self, k, 0)
        return old

    def record_export(self, n_blocks: int, n_points: int) -> None:
        self.full_exports += 1
        self.uploaded_leaf_blocks += int(n_blocks)
        self.uploaded_points += int(n_points)

    def record_delta(self, n_blocks: int, n_points: int) -> None:
        self.delta_refreshes += 1
        self.uploaded_leaf_blocks += int(n_blocks)
        self.uploaded_points += int(n_points)


UPLOAD_STATS = UploadStats()


def reset_upload_stats() -> dict:
    """Zero the module-default upload counters; returns pre-reset values."""
    return UPLOAD_STATS.reset()


def _use_kernel_default() -> bool:
    from ..kernels import ops as kops

    return kops._on_tpu()


def _fused_default() -> bool:
    """Resolve the ``fused`` flag: the ``REPRO_FUSED`` env var (1/0) wins —
    0 pins the first-generation host-packing path for A/B runs — else the
    fused on-device packing engine is the default."""
    env = os.environ.get("REPRO_FUSED")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    return True


def _levels_to_jax(levels) -> tuple:
    """Host level blocks -> the per-depth device tuples ``DeviceTable``
    carries (shared by the full export and the delta refresh)."""
    return tuple(
        (
            jnp.asarray(lv["lo"]),
            jnp.asarray(lv["hi"]),
            jnp.asarray(lv["parent"]),
            jnp.asarray(lv["slot"]),
        )
        for lv in levels
    )


def _levels_c_to_jax(levels) -> tuple:
    """Compressed (bf16 outward-rounded) bound columns per level block.

    Kept as a parallel tuple rather than widening the level tuples so the
    uncompressed pytree structure — and therefore every existing jit cache
    entry — is unchanged."""
    from .nodetable import compress_boxes_bf16

    out = []
    for lv in levels:
        if "lo_c" in lv:
            lo_c, hi_c = lv["lo_c"], lv["hi_c"]
        else:
            lo_c, hi_c = compress_boxes_bf16(lv["lo"], lv["hi"])
        out.append((jnp.asarray(lo_c), jnp.asarray(hi_c)))
    return tuple(out)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceTable:
    """Fixed-shape device export of a ``NodeTable``.

    ``levels`` is a tuple of per-depth blocks ``(lo, hi, parent, slot)``
    (see ``NodeTable.device_layout`` for the exact semantics).  The whole
    object is a pytree, so it is passed to jitted cores as a runtime
    argument and two tables with identical shapes share compilations.
    ``leaf_ids_host`` keeps the id blocks host-side for the NumPy packing
    stage of window collection.

    A *partial* export (``from_table(..., partial=True)`` over a table with
    unrefined AMBI rows) additionally carries the cold axis: unrefined-row
    MBBs in ``cold_lo``/``cold_hi`` whose hits :func:`frontier_leaf_hits`
    surfaces past the leaf columns, and the ``leaf_rows``/``cold_rows``
    host maps :meth:`apply_delta` uses to refresh the export incrementally
    after the host grafts new subtrees.
    """

    leaf_pts: jnp.ndarray    # (L, S, d) leaf-blocked points, pad = dtype max
    leaf_ids: jnp.ndarray    # (L, S) int32 dataset rows, pad = -1
    leaf_counts: jnp.ndarray # (L,) int32 live slots per leaf block
    leaf_lo: jnp.ndarray     # (L, d)
    leaf_hi: jnp.ndarray     # (L, d)
    levels: tuple            # per depth: (lo (n,d), hi (n,d), parent, slot)
    cold_lo: jnp.ndarray = None  # (U, d) unrefined-row MBBs (partial export)
    cold_hi: jnp.ndarray = None  # (U, d)
    # compressed-MBB layout (from_table(compressed=True)): outward-rounded
    # bf16 copies of every bound column.  Traversal against them yields a
    # superset of the f32 hit set at half the bound bandwidth; the f32
    # columns above stay authoritative for the certified re-check.
    leaf_lo_c: jnp.ndarray = None  # (L, d) bf16
    leaf_hi_c: jnp.ndarray = None  # (L, d) bf16
    levels_c: tuple = None         # per depth: (lo_c, hi_c) bf16
    n_points: int = None
    leaf_ids_host: np.ndarray = None
    leaf_rows: np.ndarray = None  # (L,) table row behind each leaf slot
    cold_rows: np.ndarray = None  # (U,) table row behind each cold slot
    upload_stats: "UploadStats" = None  # sink for this table's uploads

    def tree_flatten(self):
        # n_points and the host maps are host-only scaffolding: excluded
        # from the pytree (aux is part of the jit cache key, and no jitted
        # core reads any of them), so shard tables with identical shapes
        # but different live fills share compilations; traced
        # reconstructions carry None, which lazy accessors rebuild
        return (
            (self.leaf_pts, self.leaf_ids, self.leaf_counts, self.leaf_lo,
             self.leaf_hi, self.levels, self.cold_lo, self.cold_hi,
             self.leaf_lo_c, self.leaf_hi_c, self.levels_c),
            (),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def compressed(self) -> bool:
        return self.leaf_lo_c is not None

    @property
    def n_leaves(self) -> int:
        return self.leaf_pts.shape[0]

    @property
    def n_cold(self) -> int:
        return 0 if self.cold_lo is None else self.cold_lo.shape[0]

    @property
    def leaf_size(self) -> int:
        return self.leaf_pts.shape[1]

    @property
    def dim(self) -> int:
        return self.leaf_pts.shape[2]

    @property
    def host_ids(self) -> np.ndarray:
        """Host-side leaf id blocks; rebuilt (and cached) if this instance
        came out of a pytree round-trip that dropped the scaffolding."""
        if self.leaf_ids_host is None:
            self.leaf_ids_host = np.asarray(self.leaf_ids)
        return self.leaf_ids_host

    def live_points(self) -> int:
        """Live point count (sum of leaf fills); like :attr:`host_ids`,
        lazily recovered when a pytree round-trip dropped the scaffolding."""
        if self.n_points is None:
            self.n_points = int(np.asarray(self.leaf_counts).sum())
        return self.n_points

    @classmethod
    def from_table(
        cls,
        table: NodeTable,
        points: np.ndarray,
        dtype=np.float32,
        *,
        partial: bool = False,
        compressed: bool = False,
        stats: "UploadStats" = None,
    ) -> "DeviceTable":
        """Export ``table`` over ``points`` (a full upload).

        ``n_points`` is the table's *live* point count (the sum of its leaf
        fills), not ``len(points)`` — a shard table addresses the global
        dataset but owns only its slice, and result lengths truncate to
        what the table can actually return.  For a whole-dataset fully
        refined table the two are equal; a partial export counts only the
        refined points.

        ``compressed=True`` additionally ships the outward-rounded bf16
        bound columns (see ``NodeTable.device_layout``) the fused engine
        traverses against, halving bound-column bandwidth; results stay
        id-identical because every compressed box contains its f32 box and
        the collection stage re-checks against the exact f32 columns.
        """
        lay = table.device_layout(
            np.asarray(points), dtype=dtype, partial=partial,
            compressed=compressed,
        )
        levels = _levels_to_jax(lay["levels"])
        sink = stats if stats is not None else UPLOAD_STATS
        sink.record_export(
            lay["leaf_pts"].shape[0], int(lay["leaf_counts"].sum())
        )
        return cls(
            leaf_pts=jnp.asarray(lay["leaf_pts"]),
            leaf_ids=jnp.asarray(lay["leaf_ids"]),
            leaf_counts=jnp.asarray(lay["leaf_counts"]),
            leaf_lo=jnp.asarray(lay["leaf_lo"]),
            leaf_hi=jnp.asarray(lay["leaf_hi"]),
            levels=levels,
            cold_lo=jnp.asarray(lay["cold_lo"]),
            cold_hi=jnp.asarray(lay["cold_hi"]),
            leaf_lo_c=(jnp.asarray(lay["leaf_lo_c"]) if compressed else None),
            leaf_hi_c=(jnp.asarray(lay["leaf_hi_c"]) if compressed else None),
            levels_c=(_levels_c_to_jax(lay["levels"]) if compressed else None),
            n_points=int(lay["leaf_counts"].sum()),
            leaf_ids_host=lay["leaf_ids"],
            leaf_rows=lay["leaf_rows"],
            cold_rows=lay["cold_rows"],
            upload_stats=sink,
        )

    @classmethod
    def from_index(cls, index, dtype=np.float32, *, compressed: bool = False,
                   stats: "UploadStats" = None) -> "DeviceTable":
        """From a built ``core.fmbi.Index`` (table + dataset)."""
        return cls.from_table(index.table, index.points, dtype=dtype,
                              compressed=compressed, stats=stats)

    def apply_delta(self, table: NodeTable, points: np.ndarray) -> "DeviceTable":
        """Incremental refresh after host-side grafts: returns a *new*
        ``DeviceTable`` (double-buffered — the caller keeps serving this
        one until it swaps) in which only the freshly grafted leaf blocks
        are uploaded from the host.

        Grafting never mutates an existing refined leaf — it refines an
        unrefined row in place and appends new rows — so every leaf slot
        this export already holds stays valid verbatim: the big point/id
        payload is extended device-side (old blocks are reused, padded to a
        wider slot count on device if a new leaf is fuller than any before)
        and only the new leaves' blocks cross the host/device boundary.
        The O(n_nodes) traversal metadata (level blocks, leaf/cold MBBs,
        fill counts) is recomputed host-side and re-uploaded — it is tiny
        next to the point payload and renumbering cold slots keeps the
        frontier encoding dense.
        """
        if self.leaf_rows is None:
            raise ValueError(
                "delta refresh needs the host scaffolding (leaf_rows); "
                "this table came out of a pytree round-trip — re-export "
                "with DeviceTable.from_table"
            )
        dtype = np.dtype(self.leaf_pts.dtype)
        big = np.finfo(dtype).max
        d = self.dim
        old_rows = self.leaf_rows
        known = np.zeros(table.n_nodes, dtype=bool)
        known[old_rows] = True
        rows_now = table.leaf_rows()
        new_rows = rows_now[~known[rows_now]]
        leaf_rows = np.concatenate([old_rows, new_rows])
        counts_new = table.leaf_count[new_rows]
        s_old = self.leaf_size
        S = max(s_old, int(counts_new.max()) if len(counts_new) else 1)
        lp, li = self.leaf_pts, self.leaf_ids
        if S > s_old:  # widen existing blocks device-side (no host upload)
            l_old = self.n_leaves
            lp = jnp.concatenate(
                [lp, jnp.full((l_old, S - s_old, d), big, dtype=lp.dtype)],
                axis=1,
            )
            li = jnp.concatenate(
                [li, jnp.full((l_old, S - s_old), -1, dtype=li.dtype)], axis=1
            )
        if len(new_rows):
            nb_pts, nb_ids = table.pack_leaf_blocks(
                new_rows, np.asarray(points), S, dtype
            )
            lp = jnp.concatenate([lp, jnp.asarray(nb_pts)], axis=0)
            li = jnp.concatenate([li, jnp.asarray(nb_ids)], axis=0)
        cold = np.flatnonzero(table.unrefined)
        level_blocks = table.level_blocks(
            table.slot_map(leaf_rows, cold), dtype
        )
        levels = _levels_to_jax(level_blocks)
        counts = table.leaf_count[leaf_rows].astype(np.int32)
        # compressed exports stay compressed across the delta: the bound
        # columns are O(n_nodes) metadata recomputed host-side anyway, so
        # re-rounding them costs nothing next to the point payload
        new_lo = table.mbb_lo[leaf_rows].astype(dtype)
        new_hi = table.mbb_hi[leaf_rows].astype(dtype)
        if self.compressed:
            from .nodetable import compress_boxes_bf16

            lo_c, hi_c = compress_boxes_bf16(new_lo, new_hi)
            leaf_lo_c = jnp.asarray(lo_c)
            leaf_hi_c = jnp.asarray(hi_c)
            levels_c = _levels_c_to_jax(level_blocks)
        else:
            leaf_lo_c = leaf_hi_c = levels_c = None
        ids_host = self.host_ids
        if len(new_rows):  # S can only widen when there are new leaves
            ids_host = np.concatenate(
                [
                    np.pad(ids_host, ((0, 0), (0, S - s_old)),
                           constant_values=-1),
                    nb_ids,
                ]
                if S > s_old
                else [ids_host, nb_ids]
            )
        sink = self.upload_stats if self.upload_stats is not None else UPLOAD_STATS
        sink.record_delta(len(new_rows), int(counts_new.sum()))
        return DeviceTable(
            leaf_pts=lp,
            leaf_ids=li,
            leaf_counts=jnp.asarray(counts),
            leaf_lo=jnp.asarray(new_lo),
            leaf_hi=jnp.asarray(new_hi),
            levels=levels,
            cold_lo=jnp.asarray(table.mbb_lo[cold].astype(dtype)),
            cold_hi=jnp.asarray(table.mbb_hi[cold].astype(dtype)),
            leaf_lo_c=leaf_lo_c,
            leaf_hi_c=leaf_hi_c,
            levels_c=levels_c,
            n_points=int(counts.sum()),
            leaf_ids_host=ids_host,
            leaf_rows=leaf_rows,
            cold_rows=cold,
            upload_stats=sink,
        )

    def remap_rows(self, remap: np.ndarray) -> None:
        """Rebase the host scaffolding after ``NodeTable.compact`` (row
        renumbering changes no leaf content, so the device arrays stay)."""
        if self.leaf_rows is not None:
            self.leaf_rows = remap[self.leaf_rows]
        if self.cold_rows is not None:
            self.cold_rows = remap[self.cold_rows]


# --------------------------------------------------------------------------
# level-synchronous frontier traversal
# --------------------------------------------------------------------------
@jax.jit
def frontier_leaf_hits(
    dev: DeviceTable, los: jnp.ndarray, his: jnp.ndarray
) -> jnp.ndarray:
    """(Q, L + U) mask of leaves — and, for a partial export, cold
    (unrefined) rows — whose MBB intersects each query window.

    One masked broadcast box test per level block; survival propagates
    down through the parent-position gather.  Columns ``[0, L)`` are leaf
    slots, columns ``[L, L + U)`` are the cold slots of a partial AMBI
    export (the serving layer's "this query needs the host" mask; U = 0
    for a fully refined table, so the shape reduces to the classic (Q, L)).
    Branch rows scatter into the sentinel row ``L + U`` of the
    accumulator, which is dropped.
    """
    TRACE_COUNTS["frontier"] += 1
    q = los.shape[0]
    n_slots = dev.n_leaves + dev.n_cold
    d = dev.dim
    leaf_hit = jnp.zeros((n_slots + 1, q), dtype=bool)
    prev = None
    for lo_l, hi_l, parent, slot in dev.levels:
        # static unroll over dimensions: (n_level, Q) planes, no
        # (n_level, Q, d) temporaries
        hit = None
        for j in range(d):
            h = (lo_l[:, j][:, None] <= his[:, j][None, :]) & (
                hi_l[:, j][:, None] >= los[:, j][None, :]
            )
            hit = h if hit is None else hit & h
        if prev is not None:
            hit = hit & prev[parent]
        leaf_hit = leaf_hit.at[slot].max(hit)
        prev = hit
    return leaf_hit[:n_slots].T


# --------------------------------------------------------------------------
# fused engine: tiled frontier + on-device pair packing (second generation)
# --------------------------------------------------------------------------
def _level_bounds(dev: DeviceTable, i: int):
    """Bound columns the fused frontier tests level ``i`` against: the
    outward-rounded bf16 copies when the export is compressed (half the
    bandwidth, hit set a superset of f32 — never a false negative), else
    the exact f32 columns."""
    if dev.levels_c is not None:
        return dev.levels_c[i]
    lo, hi, _, _ = dev.levels[i]
    return lo, hi


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _frontier_count(
    dev: DeviceTable, los: jnp.ndarray, his: jnp.ndarray, use_kernel: bool
):
    """Fused frontier pass: the (Q, L + U) hit mask *plus* the number of
    (query, leaf) candidate pairs, in one dispatch.

    The mask stays on device (the pair-packing stage consumes it there);
    only the scalar pair count crosses to the host, where it picks the
    power-of-two pair bucket.  With ``use_kernel`` each level block's box
    test runs as the VMEM-tiled Pallas kernel (``box_hits_tiled``); the
    jnp path unrolls per-dimension (n_level, Q) planes exactly like
    :func:`frontier_leaf_hits`.  A compressed export is traversed against
    its bf16 bounds — the resulting superset costs only extra candidate
    pairs, which the exact-f32 collection stage rejects."""
    TRACE_COUNTS["frontier"] += 1
    q = los.shape[0]
    n_slots = dev.n_leaves + dev.n_cold
    d = dev.dim
    leaf_hit = jnp.zeros((n_slots + 1, q), dtype=bool)
    prev = None
    for i, (_, _, parent, slot) in enumerate(dev.levels):
        lo_l, hi_l = _level_bounds(dev, i)
        if use_kernel:
            from ..kernels import ops as kops

            hit = kops.box_hits_tiled(lo_l, hi_l, los, his) > 0
        else:
            hit = None
            for j in range(d):
                h = (
                    lo_l[:, j].astype(jnp.float32)[:, None] <= his[:, j][None, :]
                ) & (
                    hi_l[:, j].astype(jnp.float32)[:, None] >= los[:, j][None, :]
                )
                hit = h if hit is None else hit & h
        if prev is not None:
            hit = hit & prev[parent]
        leaf_hit = leaf_hit.at[slot].max(hit)
        prev = hit
    hits = leaf_hit[:n_slots].T
    n_pairs = jnp.sum(hits[:, : dev.n_leaves].astype(jnp.int32))
    return hits, n_pairs


def _compact_idx(mask_flat, first: int, count: int, offset):
    """Stream compaction via cumsum + binary search: the positions of set
    bits ``offset + first .. offset + first + count`` of a flat 0/1 mask
    (1-based ranks), plus the mask's total.

    XLA lowers ``jnp.nonzero``/scatter compaction poorly on CPU (a 131k
    mask costs ~6 ms); a monotone cumsum probed by ``searchsorted`` is
    ~10x cheaper there and vectorizes fine on TPU.  ``offset`` is a traced
    scalar so chunked callers share one compiled variant per chunk width.
    Ranks past the total return clamped positions — mask with the returned
    total."""
    s = jnp.cumsum(mask_flat.astype(jnp.int32))
    ranks = jnp.arange(first, first + count, dtype=jnp.int32) + offset
    pos = jnp.searchsorted(s, ranks)
    pos = jnp.minimum(pos, mask_flat.shape[0] - 1).astype(jnp.int32)
    return pos, ranks, s[-1]


@functools.partial(jax.jit, static_argnames=("pc", "use_kernel"))
def _fused_pack_scan(
    dev: DeviceTable,
    los: jnp.ndarray,
    his: jnp.ndarray,
    hits: jnp.ndarray,
    offset,
    pc: int,
    use_kernel: bool,
):
    """One dispatch from hit mask to qualifying ids: pack the chunk's
    (query, leaf) pairs on device, scan them, and count per query.

    Replaces the first-generation host round-trip (mask transfer,
    ``np.nonzero``, bucket fill, re-upload) with on-device compaction —
    the mask never leaves the device.  Row-major flattening keeps pairs
    query-grouped, so chunk outputs concatenate into query-grouped ids.
    The box test *and* containment run against the exact f32 columns —
    this is the certified re-check that keeps a compressed traversal
    id-identical.  Returns the (pc, S) ids-or-minus-one matrix, per-query
    qualifying counts, and the chunk's id total."""
    TRACE_COUNTS["pair_pack"] += 1
    TRACE_COUNTS["window_collect"] += 1
    flat = hits[:, : dev.n_leaves].reshape(-1)
    pos, ranks, n_pairs = _compact_idx(flat, 1, pc, offset)
    pair_valid = (ranks <= n_pairs).astype(jnp.int32)
    q_idx = pos // dev.n_leaves
    leaf_idx = pos % dev.n_leaves
    if use_kernel:
        from ..kernels import ops as kops

        ids_or, pair_counts = kops.pair_window_ids(
            los, his, dev.leaf_lo, dev.leaf_hi, dev.leaf_pts, dev.leaf_ids,
            dev.leaf_counts, q_idx, leaf_idx, pair_valid,
        )
    else:
        from ..kernels import ref as kref

        ids_or, pair_counts = kref.pair_window_ids_ref(
            los, his, dev.leaf_lo, dev.leaf_hi, dev.leaf_pts, dev.leaf_ids,
            dev.leaf_counts, q_idx, leaf_idx, pair_valid,
        )
    per_query = jax.ops.segment_sum(
        pair_counts, q_idx, num_segments=los.shape[0]
    )
    return ids_or, per_query, jnp.sum(pair_counts)


@functools.partial(jax.jit, static_argnames=("r",))
def _fused_id_pack(ids_or: jnp.ndarray, r: int):
    """On-device qualifying-id compaction: the non-negative entries of the
    (P, S) id matrix packed into an ``r``-slot bucket, in pair order.

    Used when compiled kernels are available (TPU), where shipping the
    packed ids beats shipping the (P, S) matrix; the CPU path extracts on
    the host instead (transfer is cheap there, device compaction is not)."""
    TRACE_COUNTS["id_pack"] += 1
    flat = ids_or.reshape(-1)
    pos, ranks, total = _compact_idx(flat >= 0, 1, r, jnp.int32(0))
    return jnp.where(ranks <= total, flat[pos], -1)


def _window_batch_fused(
    dev: DeviceTable,
    los: np.ndarray,
    his: np.ndarray,
    use_kernel: bool,
    return_cold: bool,
    device_id_pack: bool | None = None,
):
    """Fused window batch: device-resident from frontier to scanned ids.

    Two dispatches in the common (single-chunk) case — frontier + pair
    count, then pack + scan + count — with one scalar sync between them to
    pick the pair bucket.  ``device_id_pack`` (default: only where
    compiled kernels run) additionally compacts the qualifying ids on
    device so the transfer is work-proportional; on CPU the (P, S) matrix
    transfer + NumPy extraction is faster than any XLA compaction."""
    if device_id_pack is None:
        from ..kernels import ops as kops

        device_id_pack = kops.compiled_supported()
    los = np.atleast_2d(np.asarray(los, dtype=np.float32))
    his = np.atleast_2d(np.asarray(his, dtype=np.float32))
    (los, his), q0 = _pad_batch([los, his], [BIG, -BIG])
    losj, hisj = jnp.asarray(los), jnp.asarray(his)
    hits, n_pairs = _frontier_count(dev, losj, hisj, use_kernel)
    p0 = int(n_pairs)
    cold = None
    if return_cold:
        cold = np.asarray(hits[:q0, dev.n_leaves :])
    if p0 == 0:
        empty = [np.zeros(0, dtype=np.int64) for _ in range(q0)]
        return (empty, cold) if return_cold else empty
    parts = []
    per_query = np.zeros(los.shape[0], dtype=np.int64)
    for a in range(0, p0, PAIR_CHUNK):
        pc = _pow2(min(p0 - a, PAIR_CHUNK))
        ids_or, pq, total = _fused_pack_scan(
            dev, losj, hisj, hits, np.int32(a), pc, use_kernel
        )
        per_query += np.asarray(pq, dtype=np.int64)
        if device_id_pack:
            t = int(total)
            if t:
                packed = np.asarray(_fused_id_pack(ids_or, _pow2(t)))[:t]
                parts.append(packed.astype(np.int64))
        else:
            arr = np.asarray(ids_or)
            parts.append(arr[arr >= 0].astype(np.int64))
    all_ids = (
        np.concatenate(parts) if parts else np.zeros(0, dtype=np.int64)
    )
    res = np.split(all_ids, np.cumsum(per_query[:q0])[:-1])
    return (res, cold) if return_cold else res


# --------------------------------------------------------------------------
# window: pair-list candidate collection
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _pair_collect(
    dev: DeviceTable,
    los: jnp.ndarray,
    his: jnp.ndarray,
    q_idx: jnp.ndarray,      # (P,) query of each candidate pair
    leaf_idx: jnp.ndarray,   # (P,) leaf slot of each candidate pair
    pair_valid: jnp.ndarray, # (P,) padding mask
    use_kernel: bool,
):
    """Scan one bucket of (query, leaf) candidate pairs: gather each
    pair's leaf block and test containment against its query's box."""
    TRACE_COUNTS["window_collect"] += 1
    s = dev.leaf_size
    lo_p = los[q_idx]                         # (P, d)
    hi_p = his[q_idx]
    pts = dev.leaf_pts[leaf_idx]              # (P, S, d)
    # slot validity from the per-leaf fill counts: no (P, S) id gather
    valid = (
        jnp.arange(s, dtype=jnp.int32)[None, :]
        < dev.leaf_counts[leaf_idx][:, None]
    ) & pair_valid[:, None]
    if use_kernel:
        from ..kernels import ops as kops

        inside = (
            kops.window_mask_gathered(lo_p, hi_p, pts,
                                      valid.astype(jnp.int32)) > 0
        )
    else:
        inside = (
            jnp.all((pts >= lo_p[:, None, :]) & (pts <= hi_p[:, None, :]),
                    axis=2)
            & valid
        )
    return inside


def _pad_batch(arrs, fills):
    """Pad the query axis to a power-of-two bucket (bounds compiled
    variants across ragged batch sizes)."""
    q0 = arrs[0].shape[0]
    qp = _pow2(max(q0, 1))
    if qp == q0:
        return arrs, q0
    out = []
    for a, fill in zip(arrs, fills):
        pad = np.full((qp - q0,) + a.shape[1:], fill, dtype=a.dtype)
        out.append(np.concatenate([a, pad]))
    return out, q0


def window_query_batch_jax(
    dev: DeviceTable,
    los: np.ndarray,
    his: np.ndarray,
    *,
    use_kernel: bool | None = None,
    fused: bool | None = None,
    return_cold: bool = False,
) -> list[np.ndarray]:
    """Compiled batched window query: per-query arrays of dataset row ids.

    Ids are identical (as sets) to ``queries.window_query_batch`` for
    float32-representable inputs, and completeness is structural — every
    intersecting leaf becomes a candidate pair, so there is no budget to
    escalate.  Work scales with the candidate pairs the batch actually
    touches; the pair list streams in power-of-two buckets capped at
    ``PAIR_CHUNK`` so compiled variants stay bounded.

    ``fused`` (default on; ``REPRO_FUSED=0`` pins the first-generation
    path) keeps pair packing and id compaction on device — the frontier
    mask and candidate matrices never cross the host boundary, only bucket
    sizes (scalars) and the packed result ids do — and is the only path
    that exploits a compressed (bf16-MBB) export.

    On a *partial* export the returned ids cover only the refined leaves.
    ``return_cold=True`` additionally returns the (Q, U) cold-hit mask the
    frontier surfaced — per query, which unrefined rows it reached.  A
    query whose cold row is all-False is complete as returned; one that
    touches unindexed space must be answered (and its subspaces refined)
    host-side.  U = 0 for a refined table, so the mask is vacuously empty.
    """
    if use_kernel is None:
        use_kernel = _use_kernel_default()
    if fused is None:
        fused = _fused_default()
    if fused:
        return _window_batch_fused(dev, los, his, use_kernel, return_cold)
    los = np.atleast_2d(np.asarray(los, dtype=np.float32))
    his = np.atleast_2d(np.asarray(his, dtype=np.float32))
    # padding boxes are inverted: they can never intersect a leaf
    (los, his), q0 = _pad_batch([los, his], [BIG, -BIG])
    losj, hisj = jnp.asarray(los), jnp.asarray(his)
    hits = np.asarray(frontier_leaf_hits(dev, losj, hisj))[:q0]
    inter, cold = hits[:, : dev.n_leaves], hits[:, dev.n_leaves :]
    q_idx, leaf_idx = np.nonzero(inter)  # row-major: query-grouped
    p0 = len(q_idx)
    if p0 == 0:
        empty = [np.zeros(0, dtype=np.int64) for _ in range(q0)]
        return (empty, cold) if return_cold else empty
    parts, pair_counts = [], []
    for a in range(0, p0, PAIR_CHUNK):
        b = min(a + PAIR_CHUNK, p0)
        p = _pow2(b - a)
        qi = np.zeros(p, dtype=np.int32)
        li = np.zeros(p, dtype=np.int32)
        qi[: b - a] = q_idx[a:b]
        li[: b - a] = leaf_idx[a:b]
        pv = np.arange(p) < (b - a)
        inside = np.asarray(
            _pair_collect(
                dev, losj, hisj, jnp.asarray(qi), jnp.asarray(li),
                jnp.asarray(pv), use_kernel,
            )
        )
        ids = dev.host_ids[li]                # (P, S) host gather
        parts.append(ids[inside].astype(np.int64))
        pair_counts.append(inside.sum(axis=1)[: b - a])
    all_ids = np.concatenate(parts)
    per_pair = np.concatenate(pair_counts)
    per_query = np.bincount(q_idx, weights=per_pair, minlength=q0)
    res = np.split(all_ids, np.cumsum(per_query.astype(np.int64))[:-1])
    return (res, cold) if return_cold else res


# --------------------------------------------------------------------------
# k-NN: candidate-leaf scan + top-k merge
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("k", "n_candidate_leaves", "use_kernel")
)
def _knn_core(
    dev: DeviceTable,
    qs: jnp.ndarray,
    k: int,
    n_candidate_leaves: int,
    use_kernel: bool,
):
    """Scan each query's C closest leaves (by box mindist) and merge top-k.

    Returns (ids, d2, exact): ``exact`` certifies the best-first bound —
    the k-th distance does not exceed the mindist of the closest leaf left
    unscanned, so no unscanned leaf can hold a closer neighbor."""
    TRACE_COUNTS["knn_core"] += 1
    q = qs.shape[0]
    n_l, s, d = dev.leaf_pts.shape
    c = min(n_candidate_leaves, n_l)
    # box mindists accumulated per dimension: (Q, L) planes only
    mind = jnp.zeros((q, n_l), dtype=dev.leaf_lo.dtype)
    for j in range(d):
        g = jnp.maximum(
            dev.leaf_lo[:, j][None, :] - qs[:, j][:, None], 0.0
        ) + jnp.maximum(qs[:, j][:, None] - dev.leaf_hi[:, j][None, :], 0.0)
        mind = mind + g * g
    # indices-only top_k: keeping the values output live trips XLA CPU's
    # slow generic sort path (~10x); the unscanned bound is recovered below
    _, cand = jax.lax.top_k(-mind, c)
    flat_pts = dev.leaf_pts[cand].reshape(q, c * s, d)
    if use_kernel:
        from ..kernels import ops as kops

        # slot validity from the per-leaf fill counts: no (Q, C*S) id
        # gather — result ids are recovered after selection below
        flat_valid = (
            jnp.arange(s, dtype=jnp.int32)[None, None, :]
            < dev.leaf_counts[cand][:, :, None]
        ).reshape(q, c * s)
        d2 = kops.gathered_dist2(qs, flat_pts, flat_valid.astype(jnp.int32))
    else:
        # no mask needed: padding slots carry dtype-max coordinates, so
        # their squared distances overflow to +inf and never select
        d2 = jnp.sum((flat_pts - qs[:, None, :]) ** 2, axis=2)
    kk = min(k, c * s)
    # two-level merge: top-k within each leaf block, then across the C
    # block winners — same result set, much smaller sort fronts
    kl = min(kk, s)
    negl, til = jax.lax.top_k(-d2.reshape(q, c, s), kl)   # (Q, C, kl)
    negd, tim = jax.lax.top_k(negl.reshape(q, c * kl), kk)
    ti = (
        jnp.take_along_axis(til.reshape(q, c * kl), tim, axis=1)
        + (tim // kl) * s
    )
    leaf_sel = jnp.take_along_axis(cand, ti // s, axis=1)
    ids = dev.leaf_ids[leaf_sel, ti % s]
    d2k = -negd
    if c >= n_l:
        exact = jnp.ones(q, dtype=bool)
    elif kk < k:
        # fewer candidate slots than k: only a full scan certifies
        exact = jnp.zeros(q, dtype=bool)
    else:
        masked = mind.at[jnp.arange(q)[:, None], cand].set(jnp.inf)
        unscanned = jnp.min(masked, axis=1)
        # a kth drawn from a padding slot is BIG/inf: certificate fails
        exact = d2k[:, -1] <= unscanned
    return ids, d2k, exact


# --------------------------------------------------------------------------
# fused k-NN: compressed-bound candidate selection + on-device escalation
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("k", "n_candidate_leaves", "use_kernel")
)
def _knn_core_fused(
    dev: DeviceTable,
    qs: jnp.ndarray,
    b0,
    k: int,
    n_candidate_leaves: int,
    use_kernel: bool,
):
    """Fused-generation k-NN round.

    Differences to :func:`_knn_core`: candidate leaves are ranked by the
    *compressed* (bf16) box mindists when the export carries them — an
    outward-rounded box only shrinks the mindist, so the bound is a
    superset-safe underestimate and the exactness certificate derived
    from it stays conservative (kth <= compressed mindist <= f32 mindist
    — certifying against the underestimate is strictly harder, never
    wrong); the candidate scan streams through the fused pair kernel
    (``pair_dist2``) instead of an XLA-materialized (Q, C*S, d) gather;
    and outputs are padded to the c-independent width ``min(k, L*S)`` so
    escalation rounds scatter into one fixed result buffer."""
    TRACE_COUNTS["knn_core"] += 1
    q = qs.shape[0]
    n_l, s, d = dev.leaf_pts.shape
    c = min(n_candidate_leaves, n_l)
    if dev.leaf_lo_c is not None:
        blo, bhi = dev.leaf_lo_c, dev.leaf_hi_c
    else:
        blo, bhi = dev.leaf_lo, dev.leaf_hi
    mind = jnp.zeros((q, n_l), dtype=jnp.float32)
    for j in range(d):
        bl = blo[:, j].astype(jnp.float32)
        bh = bhi[:, j].astype(jnp.float32)
        g = jnp.maximum(bl[None, :] - qs[:, j][:, None], 0.0) + jnp.maximum(
            qs[:, j][:, None] - bh[None, :], 0.0
        )
        mind = mind + g * g
    _, cand = jax.lax.top_k(-mind, c)
    if use_kernel:
        from ..kernels import ops as kops

        q_rep = jnp.repeat(
            jnp.arange(q, dtype=jnp.int32)[:, None], c, axis=1
        ).reshape(-1)
        d2 = kops.pair_dist2(
            qs, dev.leaf_pts, dev.leaf_counts, q_rep, cand.reshape(-1)
        ).reshape(q, c, s)
    else:
        flat_pts = dev.leaf_pts[cand].reshape(q, c * s, d)
        d2 = jnp.sum((flat_pts - qs[:, None, :]) ** 2, axis=2).reshape(
            q, c, s
        )
    kk = min(k, c * s)
    kl = min(kk, s)
    negl, til = jax.lax.top_k(-d2, kl)                    # (Q, C, kl)
    negd, tim = jax.lax.top_k(negl.reshape(q, c * kl), kk)
    ti = (
        jnp.take_along_axis(til.reshape(q, c * kl), tim, axis=1)
        + (tim // kl) * s
    )
    leaf_sel = jnp.take_along_axis(cand, ti // s, axis=1)
    ids = dev.leaf_ids[leaf_sel, ti % s]
    d2k = -negd
    if c >= n_l:
        exact = jnp.ones(q, dtype=bool)
    elif kk < k:
        exact = jnp.zeros(q, dtype=bool)
    else:
        masked = mind.at[jnp.arange(q)[:, None], cand].set(jnp.inf)
        unscanned = jnp.min(masked, axis=1)
        exact = d2k[:, -1] <= unscanned
    kf = min(k, n_l * s)
    if kf > kk:  # c-independent output width for the escalation buffers
        ids = jnp.concatenate(
            [ids, jnp.full((q, kf - kk), -1, dtype=ids.dtype)], axis=1
        )
        d2k = jnp.concatenate(
            [d2k, jnp.full((q, kf - kk), BIG, dtype=d2k.dtype)], axis=1
        )
    # failed-certificate count over the real (non-padding) rows, computed
    # in the same dispatch: the only value the host syncs per round
    nfail = jnp.sum(
        (~exact) & (jnp.arange(q, dtype=jnp.int32) < b0)
    )
    return ids, d2k, exact, nfail


@functools.partial(jax.jit, static_argnames=("p",))
def _knn_pending(qs: jnp.ndarray, exact: jnp.ndarray, b0, p: int):
    """On-device escalation selection: pack the failed queries' indices
    into a ``p``-slot bucket and gather their coordinates — the host only
    learns *how many* certificates failed, never re-ships query rows.

    ``b0`` masks the batch's pow2 padding rows (their certificates are
    meaningless and must not consume bucket slots)."""
    TRACE_COUNTS["knn_sel"] += 1
    fail = (~exact) & (jnp.arange(exact.shape[0]) < b0)
    (idx,) = jnp.nonzero(fail, size=p, fill_value=0)
    idx = idx.astype(jnp.int32)
    valid = jnp.arange(p, dtype=jnp.int32) < jnp.sum(fail.astype(jnp.int32))
    return idx, valid, qs[idx]


@jax.jit
def _knn_merge_round(ids_buf, d2_buf, exact_buf, b0, idx, valid, ids_n,
                     d2_n, exact_n):
    """Scatter an escalation round's results over the fixed buffers.

    Padding slots (``valid`` False) are routed to an out-of-range index
    and dropped — ``fill_value=0`` slots must not race a genuine update
    of query 0 (duplicate-index scatter order is undefined).  Returns the
    merged buffers plus the remaining failed-certificate count, so each
    escalation round costs the host exactly one scalar sync."""
    n = ids_buf.shape[0]
    idx_w = jnp.where(valid, idx, n)
    ids_buf = ids_buf.at[idx_w].set(ids_n, mode="drop")
    d2_buf = d2_buf.at[idx_w].set(d2_n, mode="drop")
    exact_buf = exact_buf.at[idx_w].set(exact_n, mode="drop")
    nfail = jnp.sum(
        (~exact_buf) & (jnp.arange(n, dtype=jnp.int32) < b0)
    )
    return ids_buf, d2_buf, exact_buf, nfail


def _knn_batch_fused(
    dev: DeviceTable,
    qs: np.ndarray,
    k: int,
    use_kernel: bool,
    n_candidate_leaves: int | None,
    return_dists: bool,
    max_rounds: int | None = None,
    return_exact: bool = False,
):
    """Fused k-NN batch: budget escalation without host selection.

    Each round reruns only the queries whose certificate failed — packed,
    gathered, and scattered back on device; the host syncs one scalar per
    round (the failure count, which sizes the next power-of-two bucket)
    and transfers results once, after every certificate holds.

    ``max_rounds`` caps the escalation rounds beyond the first dispatch
    (the serving brownout tier); capped queries return their best-effort
    answer with a ``False`` entry in the ``return_exact`` mask."""
    q0 = qs.shape[0]
    s = dev.leaf_size
    cap = _pow2(dev.n_leaves)
    if n_candidate_leaves is None:
        c = min(_pow2(max(8, -(-2 * k) // s)), cap)
    else:
        c = min(_pow2(max(n_candidate_leaves, 1)), cap)
    (batch,), b0 = _pad_batch([qs], [0.0])
    qsj = jnp.asarray(batch)
    b0j = np.int32(b0)
    ids_buf, d2_buf, exact_buf, nfail = _knn_core_fused(
        dev, qsj, b0j, k, c, use_kernel
    )
    full_scan = c >= dev.n_leaves
    n_fail = int(nfail) if not full_scan else 0
    rounds = 0
    while n_fail and (max_rounds is None or rounds < max_rounds):
        c = min(c * 2, cap)
        idx, valid, qsel = _knn_pending(qsj, exact_buf, b0j, _pow2(n_fail))
        ids_n, d2_n, exact_n, _ = _knn_core_fused(
            dev, qsel, np.int32(0), k, c, use_kernel
        )
        ids_buf, d2_buf, exact_buf, nfail = _knn_merge_round(
            ids_buf, d2_buf, exact_buf, b0j, idx, valid, ids_n, d2_n,
            exact_n
        )
        full_scan = c >= dev.n_leaves
        n_fail = int(nfail) if not full_scan else 0
        rounds += 1
    m = min(k, dev.live_points())
    ids, d2k = jax.device_get((ids_buf[:b0, :m], d2_buf[:b0, :m]))
    results = [ids[j].astype(np.int64) for j in range(q0)]
    out = (results,)
    if return_dists:
        out = out + ([d2k[j] for j in range(q0)],)
    if return_exact:
        if full_scan:  # whole leaf table scanned: vacuously exact
            exact = np.ones(q0, dtype=bool)
        else:
            exact = np.asarray(jax.device_get(exact_buf[:b0]))[:q0].copy()
        out = out + (exact,)
    return out if len(out) > 1 else out[0]


def knn_query_batch_jax(
    dev: DeviceTable,
    qs: np.ndarray,
    k: int,
    *,
    use_kernel: bool | None = None,
    fused: bool | None = None,
    n_candidate_leaves: int | None = None,
    return_dists: bool = False,
    max_rounds: int | None = None,
    return_exact: bool = False,
) -> list[np.ndarray]:
    """Compiled batched k-NN: per-query ascending-distance row-id arrays.

    The candidate budget starts at a small power of two and doubles until
    every query's exactness certificate holds (or the whole leaf table is
    scanned), so results match ``queries.knn_query_batch`` — returned ids
    are exact k nearest (length ``min(k, n)``); among exactly tied
    distances the chosen ids may differ.  Escalation reruns only the
    queries whose certificate failed (repacked into a smaller power-of-two
    bucket), so one hard query does not double the whole batch's work.

    With ``return_dists`` the per-query float32 squared distances come
    back too, as ``(ids_list, d2_list)`` — the distributed two-round
    merge consumes them (the same f32 values every shard computes for the
    same (point, query) pair, so a cross-shard merge reproduces the
    single-table ranking).

    On a *partial* export the results are exact over the refined subset
    only (an all-cold export returns empty results): whether the cold
    subspaces could hold closer neighbors is the serving layer's check
    (mindist of each cold box against the k-th returned distance).

    ``max_rounds`` caps the escalation rounds beyond the first dispatch
    — the serving brownout tier's budget cap.  A capped query returns
    its best-effort answer (the exact k-NN over the candidate leaves
    scanned so far, a superset-ranked approximation); ``return_exact``
    appends a per-query bool mask naming which answers the certificate
    actually covers, so callers can label capped answers honestly
    instead of silently serving approximations."""
    if use_kernel is None:
        use_kernel = _use_kernel_default()
    if fused is None:
        fused = _fused_default()
    if max_rounds is not None and max_rounds < 0:
        raise ValueError(f"max_rounds must be >= 0, got {max_rounds}")
    qs = np.atleast_2d(np.asarray(qs, dtype=np.float32))
    q0 = qs.shape[0]
    if dev.n_leaves == 0:  # partial export before the first graft: the
        # device holds nothing scannable — every query is the host's
        out = ([np.zeros(0, dtype=np.int64) for _ in range(q0)],)
        if return_dists:
            out = out + ([np.zeros(0, dtype=np.float32) for _ in range(q0)],)
        if return_exact:
            out = out + (np.ones(q0, dtype=bool),)
        return out if len(out) > 1 else out[0]
    if fused:
        return _knn_batch_fused(
            dev, qs, k, use_kernel, n_candidate_leaves, return_dists,
            max_rounds, return_exact,
        )
    s = dev.leaf_size
    cap = _pow2(dev.n_leaves)
    if n_candidate_leaves is None:
        c = min(_pow2(max(8, -(-2 * k) // s)), cap)
    else:
        c = min(_pow2(max(n_candidate_leaves, 1)), cap)
    results: list = [None] * q0
    dists: list = [None] * q0
    exact_mask = np.ones(q0, dtype=bool)
    pending = np.arange(q0)
    rounds = 0
    while len(pending):
        (batch,), b0 = _pad_batch([qs[pending]], [0.0])
        ids, d2k, exact = jax.device_get(
            _knn_core(dev, jnp.asarray(batch), k, c, use_kernel)
        )
        done = exact[:b0] if c < dev.n_leaves else np.ones(b0, dtype=bool)
        flush = done
        if max_rounds is not None and rounds >= max_rounds:
            # budget cap (brownout): emit best-effort answers for the
            # still-failing queries and mark them inexact
            flush = np.ones(b0, dtype=bool)
        # padding fill (BIG/inf distances) sorts last, so the result is
        # always the first min(k, n) entries — no distance threshold needed
        # (live_points recovers the count after a pytree round-trip)
        m = min(k, dev.live_points())
        for j in np.flatnonzero(flush):
            results[pending[j]] = ids[j, :m].astype(np.int64)
            dists[pending[j]] = d2k[j, :m]
            exact_mask[pending[j]] = bool(done[j])
        pending = pending[~flush]
        c = min(c * 2, cap)
        rounds += 1
    out = (results,)
    if return_dists:
        out = out + (dists,)
    if return_exact:
        out = out + (exact_mask,)
    return out if len(out) > 1 else out[0]
