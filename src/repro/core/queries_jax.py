"""Compiled device-resident query engine over the flat ``NodeTable``.

The NumPy engine in ``queries.py`` is the paper-faithful authority — it
charges the LRU page I/O the paper costs indexes by — but its batched hot
paths still execute on the host.  This module compiles the same batched
window and k-NN queries for the accelerator: the ``NodeTable`` is exported
once into fixed-shape device arrays (:class:`DeviceTable`) and every query
batch then runs as a couple of jit-compiled dispatches with no per-query
Python on the geometry path.

Execution model
---------------
  * **Level-synchronous frontier traversal.**  The table's rows are
    re-blocked by BFS depth (``NodeTable.device_layout``); descending the
    tree is a static unrolled loop over level blocks in which the whole
    level's MBBs are tested against the whole query batch with one masked
    broadcast comparison, and survival propagates to the next level through
    a fixed-fanout parent-position gather.  There is no dynamic frontier —
    every row is tested, masked by its parent's bit — which keeps all
    shapes static while computing exactly the visited set of the NumPy
    engine (MBB nesting makes the hit set downward-closed).
  * **Window collection is work-proportional.**  The traversal's (Q, L)
    leaf hit mask is flattened into a list of (query, leaf) *pairs* — the
    batch's true candidate set — padded to a power-of-two bucket and
    scanned leaf-block by leaf-block.  Cost scales with the candidate
    leaves the batch actually touches (the property the NumPy engine has),
    not with Q x max-per-query, and the compiled variants are bounded by
    the pair-bucket sizes.  Qualifying ids are packed host-side with two
    vectorized NumPy selections (the only remaining host work).
  * **k-NN scans fixed candidate budgets with certificates.**  Each query
    takes its C closest leaves by box mindist (indices-only ``top_k`` —
    XLA CPU's top_k with live values is pathologically slow), scans them,
    and certifies exactness against the mindist of the closest unscanned
    leaf (computed by masking the scanned leaves to +inf and taking a row
    min).  The budget doubles until every certificate holds, so results
    are exact; budgets are powers of two, bounding compiled variants.
  * **Fused leaf kernels.**  The per-candidate containment test
    (``kernels/window_filter.window_mask_gathered``) and candidate
    distance scan (``kernels/knn_topk.gathered_dist2``) run as Pallas
    kernels on TPU (``use_kernel=None`` auto-selects; interpret mode
    exercises the same kernels on CPU CI) with an equivalent jnp path for
    plain XLA backends.

Parity contract
---------------
For float32-representable inputs, window results are exactly the NumPy
engine's id sets: containment is an exact comparison on identical values.
k-NN candidate sets are certified complete by the best-first bound (k-th
distance <= mindist of the closest unscanned leaf), so returned ids are
exact nearest neighbors *under float32 distance arithmetic*: the NumPy
engine ranks by float64, so two neighbors whose true squared distances
differ by less than one f32 ulp can order differently at the k-th
boundary (never observed under the suite's pinned seeds; exact ties are
unspecified in both engines — tie-heavy tests compare distances).
Result *order* within a window result set is unspecified; compare as
sets.  The device path charges no simulated I/O — ``IOStats`` remain the
NumPy engine's job.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .jax_index import _pow2
from .nodetable import NodeTable

BIG = float(np.finfo(np.float32).max)

# one dispatch scans at most this many (query, leaf) pairs; bigger
# candidate sets stream in chunks so memory stays bounded and compiled
# variants stay the handful of power-of-two bucket sizes below the cap
PAIR_CHUNK = 16384

# retrace counters (trace-time side effects): tests pin compile growth
TRACE_COUNTS = {"frontier": 0, "window_collect": 0, "knn_core": 0}

# host -> device upload accounting: the adaptive-serving tests prove a graft
# refreshes the device table by uploading only its delta (full_exports stays
# at the boot count; each refresh uploads exactly the new leaf blocks)
@dataclasses.dataclass
class UploadStats:
    """Host -> device upload counters.

    Instance-scoped: each ``DeviceQueryServer`` (and each explicitly
    threaded export) owns its own sink, so two servers in one process
    keep independent delta-only-upload proofs.  ``UPLOAD_STATS`` below is
    the module-level default sink for code that exports tables without a
    server (and for the upload totals of otherwise-unowned exports).
    Supports dict-style reads for the counter names.
    """

    full_exports: int = 0        # DeviceTable.from_table calls
    delta_refreshes: int = 0     # DeviceTable.apply_delta calls
    uploaded_leaf_blocks: int = 0  # leaf blocks shipped host -> device
    uploaded_points: int = 0       # live points inside those blocks

    def __getitem__(self, key: str) -> int:
        if key not in self.__dataclass_fields__:
            raise KeyError(key)
        return getattr(self, key)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def reset(self) -> dict:
        """Zero the counters; returns the pre-reset values."""
        old = self.as_dict()
        for k in self.__dataclass_fields__:
            setattr(self, k, 0)
        return old

    def record_export(self, n_blocks: int, n_points: int) -> None:
        self.full_exports += 1
        self.uploaded_leaf_blocks += int(n_blocks)
        self.uploaded_points += int(n_points)

    def record_delta(self, n_blocks: int, n_points: int) -> None:
        self.delta_refreshes += 1
        self.uploaded_leaf_blocks += int(n_blocks)
        self.uploaded_points += int(n_points)


UPLOAD_STATS = UploadStats()


def reset_upload_stats() -> dict:
    """Zero the module-default upload counters; returns pre-reset values."""
    return UPLOAD_STATS.reset()


def _use_kernel_default() -> bool:
    from ..kernels import ops as kops

    return kops._on_tpu()


def _levels_to_jax(levels) -> tuple:
    """Host level blocks -> the per-depth device tuples ``DeviceTable``
    carries (shared by the full export and the delta refresh)."""
    return tuple(
        (
            jnp.asarray(lv["lo"]),
            jnp.asarray(lv["hi"]),
            jnp.asarray(lv["parent"]),
            jnp.asarray(lv["slot"]),
        )
        for lv in levels
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceTable:
    """Fixed-shape device export of a ``NodeTable``.

    ``levels`` is a tuple of per-depth blocks ``(lo, hi, parent, slot)``
    (see ``NodeTable.device_layout`` for the exact semantics).  The whole
    object is a pytree, so it is passed to jitted cores as a runtime
    argument and two tables with identical shapes share compilations.
    ``leaf_ids_host`` keeps the id blocks host-side for the NumPy packing
    stage of window collection.

    A *partial* export (``from_table(..., partial=True)`` over a table with
    unrefined AMBI rows) additionally carries the cold axis: unrefined-row
    MBBs in ``cold_lo``/``cold_hi`` whose hits :func:`frontier_leaf_hits`
    surfaces past the leaf columns, and the ``leaf_rows``/``cold_rows``
    host maps :meth:`apply_delta` uses to refresh the export incrementally
    after the host grafts new subtrees.
    """

    leaf_pts: jnp.ndarray    # (L, S, d) leaf-blocked points, pad = dtype max
    leaf_ids: jnp.ndarray    # (L, S) int32 dataset rows, pad = -1
    leaf_counts: jnp.ndarray # (L,) int32 live slots per leaf block
    leaf_lo: jnp.ndarray     # (L, d)
    leaf_hi: jnp.ndarray     # (L, d)
    levels: tuple            # per depth: (lo (n,d), hi (n,d), parent, slot)
    cold_lo: jnp.ndarray = None  # (U, d) unrefined-row MBBs (partial export)
    cold_hi: jnp.ndarray = None  # (U, d)
    n_points: int = None
    leaf_ids_host: np.ndarray = None
    leaf_rows: np.ndarray = None  # (L,) table row behind each leaf slot
    cold_rows: np.ndarray = None  # (U,) table row behind each cold slot
    upload_stats: "UploadStats" = None  # sink for this table's uploads

    def tree_flatten(self):
        # n_points and the host maps are host-only scaffolding: excluded
        # from the pytree (aux is part of the jit cache key, and no jitted
        # core reads any of them), so shard tables with identical shapes
        # but different live fills share compilations; traced
        # reconstructions carry None, which lazy accessors rebuild
        return (
            (self.leaf_pts, self.leaf_ids, self.leaf_counts, self.leaf_lo,
             self.leaf_hi, self.levels, self.cold_lo, self.cold_hi),
            (),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_leaves(self) -> int:
        return self.leaf_pts.shape[0]

    @property
    def n_cold(self) -> int:
        return 0 if self.cold_lo is None else self.cold_lo.shape[0]

    @property
    def leaf_size(self) -> int:
        return self.leaf_pts.shape[1]

    @property
    def dim(self) -> int:
        return self.leaf_pts.shape[2]

    @property
    def host_ids(self) -> np.ndarray:
        """Host-side leaf id blocks; rebuilt (and cached) if this instance
        came out of a pytree round-trip that dropped the scaffolding."""
        if self.leaf_ids_host is None:
            self.leaf_ids_host = np.asarray(self.leaf_ids)
        return self.leaf_ids_host

    def live_points(self) -> int:
        """Live point count (sum of leaf fills); like :attr:`host_ids`,
        lazily recovered when a pytree round-trip dropped the scaffolding."""
        if self.n_points is None:
            self.n_points = int(np.asarray(self.leaf_counts).sum())
        return self.n_points

    @classmethod
    def from_table(
        cls,
        table: NodeTable,
        points: np.ndarray,
        dtype=np.float32,
        *,
        partial: bool = False,
        stats: "UploadStats" = None,
    ) -> "DeviceTable":
        """Export ``table`` over ``points`` (a full upload).

        ``n_points`` is the table's *live* point count (the sum of its leaf
        fills), not ``len(points)`` — a shard table addresses the global
        dataset but owns only its slice, and result lengths truncate to
        what the table can actually return.  For a whole-dataset fully
        refined table the two are equal; a partial export counts only the
        refined points.
        """
        lay = table.device_layout(
            np.asarray(points), dtype=dtype, partial=partial
        )
        levels = _levels_to_jax(lay["levels"])
        sink = stats if stats is not None else UPLOAD_STATS
        sink.record_export(
            lay["leaf_pts"].shape[0], int(lay["leaf_counts"].sum())
        )
        return cls(
            leaf_pts=jnp.asarray(lay["leaf_pts"]),
            leaf_ids=jnp.asarray(lay["leaf_ids"]),
            leaf_counts=jnp.asarray(lay["leaf_counts"]),
            leaf_lo=jnp.asarray(lay["leaf_lo"]),
            leaf_hi=jnp.asarray(lay["leaf_hi"]),
            levels=levels,
            cold_lo=jnp.asarray(lay["cold_lo"]),
            cold_hi=jnp.asarray(lay["cold_hi"]),
            n_points=int(lay["leaf_counts"].sum()),
            leaf_ids_host=lay["leaf_ids"],
            leaf_rows=lay["leaf_rows"],
            cold_rows=lay["cold_rows"],
            upload_stats=sink,
        )

    @classmethod
    def from_index(cls, index, dtype=np.float32, *,
                   stats: "UploadStats" = None) -> "DeviceTable":
        """From a built ``core.fmbi.Index`` (table + dataset)."""
        return cls.from_table(index.table, index.points, dtype=dtype,
                              stats=stats)

    def apply_delta(self, table: NodeTable, points: np.ndarray) -> "DeviceTable":
        """Incremental refresh after host-side grafts: returns a *new*
        ``DeviceTable`` (double-buffered — the caller keeps serving this
        one until it swaps) in which only the freshly grafted leaf blocks
        are uploaded from the host.

        Grafting never mutates an existing refined leaf — it refines an
        unrefined row in place and appends new rows — so every leaf slot
        this export already holds stays valid verbatim: the big point/id
        payload is extended device-side (old blocks are reused, padded to a
        wider slot count on device if a new leaf is fuller than any before)
        and only the new leaves' blocks cross the host/device boundary.
        The O(n_nodes) traversal metadata (level blocks, leaf/cold MBBs,
        fill counts) is recomputed host-side and re-uploaded — it is tiny
        next to the point payload and renumbering cold slots keeps the
        frontier encoding dense.
        """
        if self.leaf_rows is None:
            raise ValueError(
                "delta refresh needs the host scaffolding (leaf_rows); "
                "this table came out of a pytree round-trip — re-export "
                "with DeviceTable.from_table"
            )
        dtype = np.dtype(self.leaf_pts.dtype)
        big = np.finfo(dtype).max
        d = self.dim
        old_rows = self.leaf_rows
        known = np.zeros(table.n_nodes, dtype=bool)
        known[old_rows] = True
        rows_now = table.leaf_rows()
        new_rows = rows_now[~known[rows_now]]
        leaf_rows = np.concatenate([old_rows, new_rows])
        counts_new = table.leaf_count[new_rows]
        s_old = self.leaf_size
        S = max(s_old, int(counts_new.max()) if len(counts_new) else 1)
        lp, li = self.leaf_pts, self.leaf_ids
        if S > s_old:  # widen existing blocks device-side (no host upload)
            l_old = self.n_leaves
            lp = jnp.concatenate(
                [lp, jnp.full((l_old, S - s_old, d), big, dtype=lp.dtype)],
                axis=1,
            )
            li = jnp.concatenate(
                [li, jnp.full((l_old, S - s_old), -1, dtype=li.dtype)], axis=1
            )
        if len(new_rows):
            nb_pts, nb_ids = table.pack_leaf_blocks(
                new_rows, np.asarray(points), S, dtype
            )
            lp = jnp.concatenate([lp, jnp.asarray(nb_pts)], axis=0)
            li = jnp.concatenate([li, jnp.asarray(nb_ids)], axis=0)
        cold = np.flatnonzero(table.unrefined)
        levels = _levels_to_jax(
            table.level_blocks(table.slot_map(leaf_rows, cold), dtype)
        )
        counts = table.leaf_count[leaf_rows].astype(np.int32)
        ids_host = self.host_ids
        if len(new_rows):  # S can only widen when there are new leaves
            ids_host = np.concatenate(
                [
                    np.pad(ids_host, ((0, 0), (0, S - s_old)),
                           constant_values=-1),
                    nb_ids,
                ]
                if S > s_old
                else [ids_host, nb_ids]
            )
        sink = self.upload_stats if self.upload_stats is not None else UPLOAD_STATS
        sink.record_delta(len(new_rows), int(counts_new.sum()))
        return DeviceTable(
            leaf_pts=lp,
            leaf_ids=li,
            leaf_counts=jnp.asarray(counts),
            leaf_lo=jnp.asarray(table.mbb_lo[leaf_rows].astype(dtype)),
            leaf_hi=jnp.asarray(table.mbb_hi[leaf_rows].astype(dtype)),
            levels=levels,
            cold_lo=jnp.asarray(table.mbb_lo[cold].astype(dtype)),
            cold_hi=jnp.asarray(table.mbb_hi[cold].astype(dtype)),
            n_points=int(counts.sum()),
            leaf_ids_host=ids_host,
            leaf_rows=leaf_rows,
            cold_rows=cold,
            upload_stats=sink,
        )

    def remap_rows(self, remap: np.ndarray) -> None:
        """Rebase the host scaffolding after ``NodeTable.compact`` (row
        renumbering changes no leaf content, so the device arrays stay)."""
        if self.leaf_rows is not None:
            self.leaf_rows = remap[self.leaf_rows]
        if self.cold_rows is not None:
            self.cold_rows = remap[self.cold_rows]


# --------------------------------------------------------------------------
# level-synchronous frontier traversal
# --------------------------------------------------------------------------
@jax.jit
def frontier_leaf_hits(
    dev: DeviceTable, los: jnp.ndarray, his: jnp.ndarray
) -> jnp.ndarray:
    """(Q, L + U) mask of leaves — and, for a partial export, cold
    (unrefined) rows — whose MBB intersects each query window.

    One masked broadcast box test per level block; survival propagates
    down through the parent-position gather.  Columns ``[0, L)`` are leaf
    slots, columns ``[L, L + U)`` are the cold slots of a partial AMBI
    export (the serving layer's "this query needs the host" mask; U = 0
    for a fully refined table, so the shape reduces to the classic (Q, L)).
    Branch rows scatter into the sentinel row ``L + U`` of the
    accumulator, which is dropped.
    """
    TRACE_COUNTS["frontier"] += 1
    q = los.shape[0]
    n_slots = dev.n_leaves + dev.n_cold
    d = dev.dim
    leaf_hit = jnp.zeros((n_slots + 1, q), dtype=bool)
    prev = None
    for lo_l, hi_l, parent, slot in dev.levels:
        # static unroll over dimensions: (n_level, Q) planes, no
        # (n_level, Q, d) temporaries
        hit = None
        for j in range(d):
            h = (lo_l[:, j][:, None] <= his[:, j][None, :]) & (
                hi_l[:, j][:, None] >= los[:, j][None, :]
            )
            hit = h if hit is None else hit & h
        if prev is not None:
            hit = hit & prev[parent]
        leaf_hit = leaf_hit.at[slot].max(hit)
        prev = hit
    return leaf_hit[:n_slots].T


# --------------------------------------------------------------------------
# window: pair-list candidate collection
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _pair_collect(
    dev: DeviceTable,
    los: jnp.ndarray,
    his: jnp.ndarray,
    q_idx: jnp.ndarray,      # (P,) query of each candidate pair
    leaf_idx: jnp.ndarray,   # (P,) leaf slot of each candidate pair
    pair_valid: jnp.ndarray, # (P,) padding mask
    use_kernel: bool,
):
    """Scan one bucket of (query, leaf) candidate pairs: gather each
    pair's leaf block and test containment against its query's box."""
    TRACE_COUNTS["window_collect"] += 1
    s = dev.leaf_size
    lo_p = los[q_idx]                         # (P, d)
    hi_p = his[q_idx]
    pts = dev.leaf_pts[leaf_idx]              # (P, S, d)
    # slot validity from the per-leaf fill counts: no (P, S) id gather
    valid = (
        jnp.arange(s, dtype=jnp.int32)[None, :]
        < dev.leaf_counts[leaf_idx][:, None]
    ) & pair_valid[:, None]
    if use_kernel:
        from ..kernels import ops as kops

        inside = (
            kops.window_mask_gathered(lo_p, hi_p, pts,
                                      valid.astype(jnp.int32)) > 0
        )
    else:
        inside = (
            jnp.all((pts >= lo_p[:, None, :]) & (pts <= hi_p[:, None, :]),
                    axis=2)
            & valid
        )
    return inside


def _pad_batch(arrs, fills):
    """Pad the query axis to a power-of-two bucket (bounds compiled
    variants across ragged batch sizes)."""
    q0 = arrs[0].shape[0]
    qp = _pow2(max(q0, 1))
    if qp == q0:
        return arrs, q0
    out = []
    for a, fill in zip(arrs, fills):
        pad = np.full((qp - q0,) + a.shape[1:], fill, dtype=a.dtype)
        out.append(np.concatenate([a, pad]))
    return out, q0


def window_query_batch_jax(
    dev: DeviceTable,
    los: np.ndarray,
    his: np.ndarray,
    *,
    use_kernel: bool | None = None,
    return_cold: bool = False,
) -> list[np.ndarray]:
    """Compiled batched window query: per-query arrays of dataset row ids.

    Ids are identical (as sets) to ``queries.window_query_batch`` for
    float32-representable inputs, and completeness is structural — every
    intersecting leaf becomes a candidate pair, so there is no budget to
    escalate.  Work scales with the candidate pairs the batch actually
    touches; the pair list streams in power-of-two buckets capped at
    ``PAIR_CHUNK`` so compiled variants stay bounded.

    On a *partial* export the returned ids cover only the refined leaves.
    ``return_cold=True`` additionally returns the (Q, U) cold-hit mask the
    frontier surfaced — per query, which unrefined rows it reached.  A
    query whose cold row is all-False is complete as returned; one that
    touches unindexed space must be answered (and its subspaces refined)
    host-side.  U = 0 for a refined table, so the mask is vacuously empty.
    """
    if use_kernel is None:
        use_kernel = _use_kernel_default()
    los = np.atleast_2d(np.asarray(los, dtype=np.float32))
    his = np.atleast_2d(np.asarray(his, dtype=np.float32))
    # padding boxes are inverted: they can never intersect a leaf
    (los, his), q0 = _pad_batch([los, his], [BIG, -BIG])
    losj, hisj = jnp.asarray(los), jnp.asarray(his)
    hits = np.asarray(frontier_leaf_hits(dev, losj, hisj))[:q0]
    inter, cold = hits[:, : dev.n_leaves], hits[:, dev.n_leaves :]
    q_idx, leaf_idx = np.nonzero(inter)  # row-major: query-grouped
    p0 = len(q_idx)
    if p0 == 0:
        empty = [np.zeros(0, dtype=np.int64) for _ in range(q0)]
        return (empty, cold) if return_cold else empty
    parts, pair_counts = [], []
    for a in range(0, p0, PAIR_CHUNK):
        b = min(a + PAIR_CHUNK, p0)
        p = _pow2(b - a)
        qi = np.zeros(p, dtype=np.int32)
        li = np.zeros(p, dtype=np.int32)
        qi[: b - a] = q_idx[a:b]
        li[: b - a] = leaf_idx[a:b]
        pv = np.arange(p) < (b - a)
        inside = np.asarray(
            _pair_collect(
                dev, losj, hisj, jnp.asarray(qi), jnp.asarray(li),
                jnp.asarray(pv), use_kernel,
            )
        )
        ids = dev.host_ids[li]                # (P, S) host gather
        parts.append(ids[inside].astype(np.int64))
        pair_counts.append(inside.sum(axis=1)[: b - a])
    all_ids = np.concatenate(parts)
    per_pair = np.concatenate(pair_counts)
    per_query = np.bincount(q_idx, weights=per_pair, minlength=q0)
    res = np.split(all_ids, np.cumsum(per_query.astype(np.int64))[:-1])
    return (res, cold) if return_cold else res


# --------------------------------------------------------------------------
# k-NN: candidate-leaf scan + top-k merge
# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("k", "n_candidate_leaves", "use_kernel")
)
def _knn_core(
    dev: DeviceTable,
    qs: jnp.ndarray,
    k: int,
    n_candidate_leaves: int,
    use_kernel: bool,
):
    """Scan each query's C closest leaves (by box mindist) and merge top-k.

    Returns (ids, d2, exact): ``exact`` certifies the best-first bound —
    the k-th distance does not exceed the mindist of the closest leaf left
    unscanned, so no unscanned leaf can hold a closer neighbor."""
    TRACE_COUNTS["knn_core"] += 1
    q = qs.shape[0]
    n_l, s, d = dev.leaf_pts.shape
    c = min(n_candidate_leaves, n_l)
    # box mindists accumulated per dimension: (Q, L) planes only
    mind = jnp.zeros((q, n_l), dtype=dev.leaf_lo.dtype)
    for j in range(d):
        g = jnp.maximum(
            dev.leaf_lo[:, j][None, :] - qs[:, j][:, None], 0.0
        ) + jnp.maximum(qs[:, j][:, None] - dev.leaf_hi[:, j][None, :], 0.0)
        mind = mind + g * g
    # indices-only top_k: keeping the values output live trips XLA CPU's
    # slow generic sort path (~10x); the unscanned bound is recovered below
    _, cand = jax.lax.top_k(-mind, c)
    flat_pts = dev.leaf_pts[cand].reshape(q, c * s, d)
    if use_kernel:
        from ..kernels import ops as kops

        # slot validity from the per-leaf fill counts: no (Q, C*S) id
        # gather — result ids are recovered after selection below
        flat_valid = (
            jnp.arange(s, dtype=jnp.int32)[None, None, :]
            < dev.leaf_counts[cand][:, :, None]
        ).reshape(q, c * s)
        d2 = kops.gathered_dist2(qs, flat_pts, flat_valid.astype(jnp.int32))
    else:
        # no mask needed: padding slots carry dtype-max coordinates, so
        # their squared distances overflow to +inf and never select
        d2 = jnp.sum((flat_pts - qs[:, None, :]) ** 2, axis=2)
    kk = min(k, c * s)
    # two-level merge: top-k within each leaf block, then across the C
    # block winners — same result set, much smaller sort fronts
    kl = min(kk, s)
    negl, til = jax.lax.top_k(-d2.reshape(q, c, s), kl)   # (Q, C, kl)
    negd, tim = jax.lax.top_k(negl.reshape(q, c * kl), kk)
    ti = (
        jnp.take_along_axis(til.reshape(q, c * kl), tim, axis=1)
        + (tim // kl) * s
    )
    leaf_sel = jnp.take_along_axis(cand, ti // s, axis=1)
    ids = dev.leaf_ids[leaf_sel, ti % s]
    d2k = -negd
    if c >= n_l:
        exact = jnp.ones(q, dtype=bool)
    elif kk < k:
        # fewer candidate slots than k: only a full scan certifies
        exact = jnp.zeros(q, dtype=bool)
    else:
        masked = mind.at[jnp.arange(q)[:, None], cand].set(jnp.inf)
        unscanned = jnp.min(masked, axis=1)
        # a kth drawn from a padding slot is BIG/inf: certificate fails
        exact = d2k[:, -1] <= unscanned
    return ids, d2k, exact


def knn_query_batch_jax(
    dev: DeviceTable,
    qs: np.ndarray,
    k: int,
    *,
    use_kernel: bool | None = None,
    n_candidate_leaves: int | None = None,
    return_dists: bool = False,
) -> list[np.ndarray]:
    """Compiled batched k-NN: per-query ascending-distance row-id arrays.

    The candidate budget starts at a small power of two and doubles until
    every query's exactness certificate holds (or the whole leaf table is
    scanned), so results match ``queries.knn_query_batch`` — returned ids
    are exact k nearest (length ``min(k, n)``); among exactly tied
    distances the chosen ids may differ.  Escalation reruns only the
    queries whose certificate failed (repacked into a smaller power-of-two
    bucket), so one hard query does not double the whole batch's work.

    With ``return_dists`` the per-query float32 squared distances come
    back too, as ``(ids_list, d2_list)`` — the distributed two-round
    merge consumes them (the same f32 values every shard computes for the
    same (point, query) pair, so a cross-shard merge reproduces the
    single-table ranking).

    On a *partial* export the results are exact over the refined subset
    only (an all-cold export returns empty results): whether the cold
    subspaces could hold closer neighbors is the serving layer's check
    (mindist of each cold box against the k-th returned distance)."""
    if use_kernel is None:
        use_kernel = _use_kernel_default()
    qs = np.atleast_2d(np.asarray(qs, dtype=np.float32))
    q0 = qs.shape[0]
    if dev.n_leaves == 0:  # partial export before the first graft: the
        # device holds nothing scannable — every query is the host's
        empty = [np.zeros(0, dtype=np.int64) for _ in range(q0)]
        if return_dists:
            return empty, [np.zeros(0, dtype=np.float32) for _ in range(q0)]
        return empty
    s = dev.leaf_size
    cap = _pow2(dev.n_leaves)
    if n_candidate_leaves is None:
        c = min(_pow2(max(8, -(-2 * k) // s)), cap)
    else:
        c = min(_pow2(max(n_candidate_leaves, 1)), cap)
    results: list = [None] * q0
    dists: list = [None] * q0
    pending = np.arange(q0)
    while len(pending):
        (batch,), b0 = _pad_batch([qs[pending]], [0.0])
        ids, d2k, exact = jax.device_get(
            _knn_core(dev, jnp.asarray(batch), k, c, use_kernel)
        )
        done = exact[:b0] if c < dev.n_leaves else np.ones(b0, dtype=bool)
        # padding fill (BIG/inf distances) sorts last, so the result is
        # always the first min(k, n) entries — no distance threshold needed
        # (live_points recovers the count after a pytree round-trip)
        m = min(k, dev.live_points())
        for j in np.flatnonzero(done):
            results[pending[j]] = ids[j, :m].astype(np.int64)
            dists[pending[j]] = d2k[j, :m]
        pending = pending[~done]
        c = min(c * 2, cap)
    return (results, dists) if return_dists else results
