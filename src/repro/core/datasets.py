"""Dataset generators mirroring the paper's evaluation data.

OSM and NYCYT are not redistributable offline; these generators reproduce
their documented *shape*: OSM-like data is a world-map mixture of dense urban
clusters plus vast empty regions (oceans), NYCYT-like data is 5-D correlated
trip records (pickup x/y, dropoff x/y, time).  Uniform / gaussian / skewed
match the paper's repository extras.
"""
from __future__ import annotations

import numpy as np


def uniform(n: int, d: int = 2, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.random((n, d)).astype(np.float64)


def gaussian(n: int, d: int = 2, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    pts = rng.normal(0.5, 0.12, size=(n, d))
    return np.clip(pts, 0.0, 1.0).astype(np.float64)


def skewed(n: int, d: int = 2, seed: int = 0) -> np.ndarray:
    """Zipf-ish skew: coordinates concentrated near the origin."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, d)) ** 4
    return pts.astype(np.float64)


def osm_like(n: int, seed: int = 0) -> np.ndarray:
    """2-D: dense city clusters + sparse countryside + empty oceans."""
    rng = np.random.default_rng(seed)
    n_clusters = 64
    centers = rng.random((n_clusters, 2))
    # keep clusters on "land": reject centers in two ocean bands
    ocean = (centers[:, 0] < 0.18) | (
        (centers[:, 0] > 0.42) & (centers[:, 0] < 0.55)
    )
    centers[ocean, 0] = rng.random(ocean.sum()) * 0.25 + 0.6
    weights = rng.pareto(1.2, n_clusters) + 0.05
    weights /= weights.sum()
    n_cluster_pts = int(n * 0.85)
    counts = rng.multinomial(n_cluster_pts, weights)
    parts = []
    for c, k in zip(centers, counts):
        if k == 0:
            continue
        scale = rng.uniform(0.002, 0.03)
        parts.append(rng.normal(c, scale, size=(k, 2)))
    sprinkle = rng.random((n - n_cluster_pts, 2))
    sprinkle[:, 0] = sprinkle[:, 0] * 0.4 + 0.55  # countryside strip
    parts.append(sprinkle)
    pts = np.concatenate(parts)[:n]
    pts = np.clip(pts, 0.0, 1.0)
    return pts[np.random.default_rng(seed + 1).permutation(len(pts))].astype(
        np.float64
    )


def nycyt_like(n: int, d: int = 5, seed: int = 0) -> np.ndarray:
    """5-D correlated trips: (pickup_x, pickup_y, dropoff_x, dropoff_y, t).

    Pickups cluster around hotspots; dropoffs correlate with pickups (short
    trips dominate); time has rush-hour peaks.  ``d < 5`` selects the first
    d dimensions (paper Figure 9 protocol).
    """
    rng = np.random.default_rng(seed)
    hotspots = rng.random((12, 2)) * 0.6 + 0.2
    w = rng.pareto(1.5, 12) + 0.1
    w /= w.sum()
    which = rng.choice(12, size=n, p=w)
    pickup = hotspots[which] + rng.normal(0, 0.04, size=(n, 2))
    trip = rng.exponential(0.08, size=(n, 1)) * rng.normal(
        0, 1.0, size=(n, 2)
    )
    dropoff = pickup + trip
    peaks = np.array([0.35, 0.75])
    t = (
        peaks[rng.integers(0, 2, n)] + rng.normal(0, 0.1, n)
    ).reshape(n, 1)
    pts = np.concatenate([pickup, dropoff, t], axis=1)
    pts = np.clip(pts, 0.0, 1.0)
    return pts[:, :d].astype(np.float64)


GENERATORS = {
    "uniform": uniform,
    "gaussian": gaussian,
    "skewed": skewed,
    "osm": lambda n, seed=0: osm_like(n, seed),
    "nycyt": lambda n, seed=0, d=5: nycyt_like(n, d, seed),
}
