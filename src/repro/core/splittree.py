"""SplitTrees (Major and minor), array-encoded for vectorized traversal.

The paper's MST/mST are binary trees of (dimension, value) splits produced by
recursive median partitioning on the longest (highest-spread) dimension.  We
encode a tree as flat int/float arrays so that point->subspace routing is a
data-parallel gather loop — the form consumed by ``kernels/partition_assign``
(Pallas) and by ``numpy``/``jnp`` reference traversals.

Encoding (node 0 is the root; n internal nodes, n+1 leaves):
  split_dim[i]  int32   dimension of split i
  split_val[i]  float32 coordinate of split i
  left[i], right[i] int32: >= 0 -> internal node index;
                            < 0  -> leaf (subspace) id = -(x) - 1
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass
class FlatSplitTree:
    split_dim: np.ndarray  # (n,) int32
    split_val: np.ndarray  # (n,) float32
    left: np.ndarray       # (n,) int32
    right: np.ndarray      # (n,) int32
    n_leaves: int

    @property
    def n_splits(self) -> int:
        return int(self.split_dim.shape[0])

    def route(self, points: np.ndarray) -> np.ndarray:
        """Vectorized point -> leaf-id routing (numpy reference).

        Points within the right half-open interval go right:
        ``p[dim] > val -> right`` (points equal to the split value stay left,
        matching the paper's 'last point of the median page' convention).
        """
        n = points.shape[0]
        if self.n_splits == 0:
            return np.zeros(n, dtype=np.int32)
        # Full-width descent: every level is a handful of O(n) gathers with
        # no per-level subset compaction (the tree is balanced, so the loop
        # runs ~log2(n_leaves) times and finished lanes just idle).
        node = np.zeros(n, dtype=np.int32)   # current internal node
        out = np.full(n, -1, dtype=np.int32)
        done = np.zeros(n, dtype=bool)
        for _ in range(self.n_splits + 1):
            d = self.split_dim[node]
            v = self.split_val[node]
            coord = np.take_along_axis(points, d[:, None].astype(np.intp), 1)[:, 0]
            nxt = np.where(coord > v, self.right[node], self.left[node])
            leaf = (nxt < 0) & ~done
            out[leaf] = -nxt[leaf] - 1
            done |= leaf
            node = np.where(done, node, nxt)
            if done.all():
                break
        return out


class _TreeBuilder:
    def __init__(self):
        self.split_dim: list[int] = []
        self.split_val: list[float] = []
        self.left: list[int] = []
        self.right: list[int] = []
        self.leaf_payload: list = []

    def add_split(self, dim: int, val: float) -> int:
        i = len(self.split_dim)
        self.split_dim.append(dim)
        self.split_val.append(val)
        self.left.append(0)
        self.right.append(0)
        return i

    def add_leaf(self, payload) -> int:
        self.leaf_payload.append(payload)
        return -(len(self.leaf_payload) - 1) - 1

    def finish(self) -> tuple[FlatSplitTree, list]:
        tree = FlatSplitTree(
            split_dim=np.asarray(self.split_dim, dtype=np.int32),
            split_val=np.asarray(self.split_val, dtype=np.float32),
            left=np.asarray(self.left, dtype=np.int32),
            right=np.asarray(self.right, dtype=np.int32),
            n_leaves=len(self.leaf_payload),
        )
        return tree, self.leaf_payload


def longest_dimension(points: np.ndarray) -> int:
    """Dimension with the highest data spread (Spread-KDB convention, which
    the paper adopts for its median splits)."""
    if points.shape[0] == 0:
        return 0
    spread = points.max(axis=0) - points.min(axis=0)
    return int(np.argmax(spread))


def build_group_median_tree(
    points: np.ndarray,
    n_groups: int,
    group_pages: int,
    page_points: int,
    on_leaf: Callable[[np.ndarray, int], object] | None = None,
) -> tuple[FlatSplitTree, list, np.ndarray]:
    """Step-1 Major SplitTree construction.

    ``points`` are the sampled ``alpha * C_B`` pages' points.  The tree
    recursively splits the *page-group count* at the median group boundary —
    splitting a region of ``k`` groups (each group = ``group_pages`` full
    pages = ``group_pages * page_points`` points) into ⌊k/2⌋ and ⌈k/2⌉ groups
    — until every region holds exactly one group.  This is the paper's
    "split at the last point of the ⌊·/2⌋-th sorted page" rule applied at the
    α-page-group granularity, which is what makes Step 1 terminate with
    exactly C_B subspaces of α full pages each.

    Returns (tree, leaf_payloads, leaf_assignment_for_input_points).
    ``on_leaf(points_of_leaf, leaf_id)`` builds each payload (default: the
    point array itself).
    """
    assert points.shape[0] == n_groups * group_pages * page_points, (
        points.shape,
        n_groups,
        group_pages,
        page_points,
    )
    builder = _TreeBuilder()
    assign = np.empty(points.shape[0], dtype=np.int32)

    def rec(idx: np.ndarray, k: int) -> int:
        pts = points[idx]
        if k == 1:
            leaf_id = len(builder.leaf_payload)
            assign[idx] = leaf_id
            payload = on_leaf(pts, leaf_id) if on_leaf is not None else pts
            return builder.add_leaf(payload)
        dim = longest_dimension(pts)
        order = np.argsort(pts[:, dim], kind="stable")
        kl = k // 2
        cut = kl * group_pages * page_points
        split_val = float(pts[order[cut - 1], dim])
        node = builder.add_split(dim, split_val)
        li = rec(idx[order[:cut]], kl)
        ri = rec(idx[order[cut:]], k - kl)
        builder.left[node] = li
        builder.right[node] = ri
        return node

    root = rec(np.arange(points.shape[0]), n_groups)
    tree, payloads = builder.finish()
    if root < 0:  # degenerate single-leaf tree
        tree = FlatSplitTree(
            split_dim=np.zeros(0, np.int32),
            split_val=np.zeros(0, np.float32),
            left=np.zeros(0, np.int32),
            right=np.zeros(0, np.int32),
            n_leaves=1,
        )
    return tree, payloads, assign


def mbb_of(points: np.ndarray) -> np.ndarray:
    """Minimum bounding box as (2, d): [min; max]."""
    return np.stack([points.min(axis=0), points.max(axis=0)])


def pad_tree(tree: FlatSplitTree, n_splits: int) -> FlatSplitTree:
    """Pad a flat tree to a static size (for fixed-shape kernel launches).

    Padding splits are self-loops routed 'left to a dead leaf'; they are never
    reached because routing starts at node 0 of the real tree.
    """
    n = tree.n_splits
    if n >= n_splits:
        return tree
    pad = n_splits - n
    return FlatSplitTree(
        split_dim=np.concatenate([tree.split_dim, np.zeros(pad, np.int32)]),
        split_val=np.concatenate([tree.split_val, np.full(pad, np.inf, np.float32)]),
        left=np.concatenate([tree.left, np.full(pad, -1, np.int32)]),
        right=np.concatenate([tree.right, np.full(pad, -1, np.int32)]),
        n_leaves=tree.n_leaves,
    )
