"""Streaming ingest: a live LSM-tiered index over the scan-engine bulk loader.

The paper's thesis — linear-scan bulk loading is cheap enough to repeat —
makes the loader itself the natural *merge primitive* for a live index.
This module turns the one-shot FMBI into an LSM-style tiered structure:

  * **Point buffer.**  All coordinates live in one amortized-doubling array;
    a point's id is its row, forever.  Inserts append; nothing moves.
  * **Delta memtable.**  Recent inserts go to an in-memory delta: a small
    ``NodeTable`` rebuilt in place (``refine_subspace`` over the delta rows)
    every ``delta_index_every`` inserts, with the not-yet-indexed tail
    answered by brute force.  When the delta reaches ``delta_threshold``
    rows it is *flushed*: bulk-loaded into an immutable tier.
  * **Tiers.**  Immutable bulk-loaded ``NodeTable``s in size-tiered levels
    (``level = floor(log_ratio(size / delta_threshold))``).  After a flush,
    the two newest tiers merge while they sit on the same level, so sizes
    grow geometrically and each point is rewritten O(log n) times.
  * **Merging.**  A merge with no tombstoned input rows is a *fusion*:
    ``NodeTable.merged`` splices the two trees under a fresh root page —
    zero point movement, zero page rewrites.  With tombstones, the merge
    re-runs the scan-engine bulk loader over the live rows (charging a
    sequential re-read of the inputs' pages) and frees the retired tiers'
    pages back to the ``PageStore`` allocator.
  * **Tombstones.**  Deletes mark a bitmap; queries filter, and the marks
    are dropped when the rows they shadow are rewritten (flush or rebuild
    merge).  ``shadow`` counts tombstoned-but-still-physically-present
    rows — the k-NN over-fetch bound.

Queries fan out over (tiers..., delta, pending tail) and merge: window by
union (components are disjoint by construction), k-NN by a two-level top-k
merge with ``k + shadow`` per-component over-fetch and tombstone filtering.

``DeviceMirror`` maintains an append-only ``NodeTable`` image of the live
tiers for the device/serving path: tier attach appends the subtree,
fusion appends one branch row adopting copies of the two old roots, a
rebuild-merge neutralizes the retired rows (inverted MBBs, zero counts) —
rows are never removed, so ``DeviceTable.apply_delta`` uploads only the
new leaf blocks and the serving layer never re-exports from scratch.
"""
from __future__ import annotations

import json

import numpy as np

from ..analysis import runtime as _san
from .fmbi import Node, refine_subspace
from .ioutil import atomic_output
from .nodetable import NodeTable
from .pagestore import PageStore, branch_capacity, leaf_capacity

STREAM_VERSION = 1

_TABLE_COLS = (
    "mbb_lo", "mbb_hi", "page_id", "first_child", "child_count",
    "leaf_start", "leaf_count", "raw_pages", "unrefined", "perm",
)


def _pack_table(payload: dict, prefix: str, t: NodeTable) -> None:
    for col in _TABLE_COLS:
        payload[prefix + col] = getattr(t, col)


def _unpack_table(z, prefix: str, dim: int) -> NodeTable:
    n = len(z[prefix + "page_id"])
    n_perm = len(z[prefix + "perm"])
    t = NodeTable(dim, node_capacity=n + n // 8 + 16,
                  perm_capacity=n_perm + n_perm // 8 + 16)
    t._n = n
    t._np = n_perm
    t._mbb_lo[:n] = z[prefix + "mbb_lo"]
    t._mbb_hi[:n] = z[prefix + "mbb_hi"]
    t._page_id[:n] = z[prefix + "page_id"]
    t._first_child[:n] = z[prefix + "first_child"]
    t._child_count[:n] = z[prefix + "child_count"]
    t._leaf_start[:n] = z[prefix + "leaf_start"]
    t._leaf_count[:n] = z[prefix + "leaf_count"]
    t._raw_pages[:n] = z[prefix + "raw_pages"]
    t._unrefined[:n] = z[prefix + "unrefined"]
    t._perm[:n_perm] = z[prefix + "perm"]
    return t


class _TierView:
    """Duck-typed ``Index`` over the shared streaming point buffer — the
    NumPy query engines only touch ``table`` / ``store`` / ``points``."""

    __slots__ = ("table", "store", "points")

    def __init__(self, table: NodeTable, store: PageStore, points: np.ndarray):
        self.table = table
        self.store = store
        self.points = points


class Tier:
    """One immutable bulk-loaded component.

    ``rows`` are the global point ids physically present in ``table``
    (including rows tombstoned *after* the tier was built); ``fused`` marks
    tiers produced by structural fusion rather than a fresh bulk load.
    """

    __slots__ = ("tid", "rows", "table", "fused")

    def __init__(self, tid: int, rows: np.ndarray, table: NodeTable,
                 fused: bool = False):
        self.tid = int(tid)
        self.rows = rows
        self.table = table
        self.fused = bool(fused)

    def __len__(self) -> int:
        return len(self.rows)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Tier(tid={self.tid}, n={len(self.rows)}, fused={self.fused})"


class StreamingIndex:
    """A live LSM-tiered multidimensional index (host authority).

    Thread-compatibility: not internally locked — the serving layer
    serializes writers through its ``TableLock``.
    """

    def __init__(self, points, *, store=None, buffer_pages=256,
                 delta_threshold=2048, delta_index_every=256, size_ratio=4,
                 base_external=False, build_base=True):
        pts = np.atleast_2d(np.asarray(points, dtype=np.float64))
        if pts.ndim != 2:
            raise ValueError("points must be (n, d)")
        n, d = pts.shape
        if d < 1:
            raise ValueError("points must have at least one dimension")
        self.dim = d
        self.leaf_cap = leaf_capacity(d)
        self.branch_cap = branch_capacity(d)
        self.store = store if store is not None else PageStore(buffer_pages)
        self.delta_threshold = int(delta_threshold)
        self.delta_index_every = int(delta_index_every)
        self.size_ratio = max(int(size_ratio), 2)
        if self.delta_threshold < 1 or self.delta_index_every < 1:
            raise ValueError("thresholds must be positive")

        cap = max(n, 1024)
        self._pts = np.empty((cap, d), dtype=np.float64)
        self._pts[:n] = pts
        self._tomb = np.zeros(cap, dtype=bool)
        self._n = n

        self._delta = np.empty(self.delta_threshold + 16, dtype=np.int64)
        self._delta_n = 0
        self._delta_indexed = 0
        self._delta_table: NodeTable | None = None

        self.tiers: list[Tier] = []
        self._next_tid = 0
        self._shadow = 0

        # base handling: ``base_external`` means rows [0, base_n) live in an
        # external structure (the adaptive server's AMBI) — this index only
        # owns the overlay and never tiers them.
        self.base_external = bool(base_external)
        self.base_n = n if self.base_external else 0
        if n and not self.base_external and build_base:
            self.store.read_run(-(-n // self.leaf_cap))  # boot scan of the data
            table = self._build_table(np.arange(n, dtype=np.int64))
            self.tiers.append(Tier(self._alloc_tid(), np.arange(n, dtype=np.int64), table))

        # counters (bench + tests)
        self.inserted = 0
        self.deleted = 0
        self.flushes = 0
        self.merges = 0
        self.fusions = 0
        self.delta_rebuilds = 0
        self.point_reallocs = 0

        # structural event log the device mirror consumes
        self.track_events = False
        self._events: list[tuple] = []

    # -- construction ------------------------------------------------------
    @classmethod
    def from_index(cls, index, **kw):
        """Adopt a built ``Index`` (its table becomes tier 0, its store the
        shared substrate) without re-loading anything."""
        self = cls(index.points, store=index.store, build_base=False, **kw)
        rows = np.arange(len(index.points), dtype=np.int64)
        self.tiers.append(Tier(self._alloc_tid(), rows, index.table))
        return self

    def _alloc_tid(self) -> int:
        t = self._next_tid
        self._next_tid += 1
        return t

    # -- views -------------------------------------------------------------
    @property
    def points(self) -> np.ndarray:
        """Live view of the point buffer (row == id)."""
        return self._pts[:self._n]

    @property
    def n_ids(self) -> int:
        return self._n

    @property
    def n_live(self) -> int:
        return self._n - int(self._tomb[:self._n].sum())

    @property
    def shadow(self) -> int:
        """Tombstoned ids still physically present in some component."""
        return self._shadow

    def live_mask(self) -> np.ndarray:
        return ~self._tomb[:self._n]

    def live_ids(self) -> np.ndarray:
        return np.flatnonzero(~self._tomb[:self._n])

    def filter_live(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        if len(ids) == 0:
            return ids
        return ids[~self._tomb[ids]]

    def delta_live_rows(self) -> np.ndarray:
        """Live ids currently held only by the delta/pending components
        (i.e. not in any tier) — the serving layer unions these host-side."""
        rows = self._delta[:self._delta_n]
        return rows[~self._tomb[rows]]

    # -- ingest ------------------------------------------------------------
    def _ensure_points(self, need: int) -> None:
        cap = len(self._pts)
        if need <= cap:
            return
        new = max(need, 2 * cap)
        pts = np.empty((new, self.dim), dtype=np.float64)
        pts[:self._n] = self._pts[:self._n]
        tomb = np.zeros(new, dtype=bool)
        tomb[:self._n] = self._tomb[:self._n]
        self._pts, self._tomb = pts, tomb
        self.point_reallocs += 1

    def insert(self, pts) -> np.ndarray:
        """Append points; returns their assigned ids (buffer rows)."""
        _san.check_write(self, "insert")
        pts = np.atleast_2d(np.asarray(pts, dtype=np.float64))
        if pts.shape[1] != self.dim:
            raise ValueError(f"expected dim {self.dim}, got {pts.shape[1]}")
        q = len(pts)
        if q == 0:
            return np.empty(0, dtype=np.int64)
        self._ensure_points(self._n + q)
        ids = np.arange(self._n, self._n + q, dtype=np.int64)
        self._pts[self._n:self._n + q] = pts
        self._n += q
        self.inserted += q
        if self._delta_n + q > len(self._delta):
            grown = np.empty(max(self._delta_n + q, 2 * len(self._delta)),
                             dtype=np.int64)
            grown[:self._delta_n] = self._delta[:self._delta_n]
            self._delta = grown
        self._delta[self._delta_n:self._delta_n + q] = ids
        self._delta_n += q
        if self._delta_n >= self.delta_threshold:
            self._flush()
        elif self._delta_n - self._delta_indexed >= self.delta_index_every:
            self._reindex_delta()
        return ids

    def delete(self, ids) -> int:
        """Tombstone ids; returns how many were newly deleted."""
        _san.check_write(self, "delete")
        ids = np.unique(np.asarray(ids, dtype=np.int64).ravel())
        if len(ids) == 0:
            return 0
        if ids[0] < 0 or ids[-1] >= self._n:
            raise IndexError("delete id out of range")
        fresh = ids[~self._tomb[ids]]
        self._tomb[fresh] = True
        self._shadow += len(fresh)
        self.deleted += len(fresh)
        return len(fresh)

    # -- structure maintenance --------------------------------------------
    def _emit(self, *ev) -> None:
        if self.track_events:
            self._events.append(ev)

    def drain_events(self) -> list[tuple]:
        evs, self._events = self._events, []
        return evs

    def _build_table(self, rows: np.ndarray) -> NodeTable:
        """Bulk-load ``rows`` of the shared buffer into a fresh NodeTable
        (the scan-engine loader, charging its writes to the shared store)."""
        entries = refine_subspace(self.points, rows, self.leaf_cap,
                                  self.branch_cap, self.store)
        if len(entries) == 1:
            root = entries[0]
        else:
            lo = np.min([e.mbb[0] for e in entries], axis=0)
            hi = np.max([e.mbb[1] for e in entries], axis=0)
            page = self.store.alloc()
            self.store.write(page)
            root = Node(mbb=np.stack([lo, hi]), page_id=page, children=entries)
        return NodeTable.from_tree(root, self.dim, n_points_hint=len(rows))

    def _reindex_delta(self) -> None:
        if self._delta_table is not None:
            self.store.free_pages(self._delta_table.page_id)
        rows = self._delta[:self._delta_n].copy()
        # tombstoned delta rows stay physically indexed (queries filter);
        # they are dropped for good at flush time
        self._delta_table = self._build_table(rows)
        self._delta_indexed = self._delta_n
        self.delta_rebuilds += 1

    def _flush(self) -> None:
        rows = self._delta[:self._delta_n].copy()
        if self._delta_table is not None:
            self.store.free_pages(self._delta_table.page_id)
            self._delta_table = None
        self._delta_n = 0
        self._delta_indexed = 0
        dead = self._tomb[rows]
        live = rows[~dead]
        self._shadow -= int(dead.sum())
        if len(live) == 0:
            return
        table = self._build_table(live)
        tier = Tier(self._alloc_tid(), live, table)
        self.tiers.append(tier)
        self.flushes += 1
        self._emit("attach", tier)
        self._maybe_merge()

    def _level(self, size: int) -> int:
        if size <= self.delta_threshold:
            return 0
        return int(np.log(size / self.delta_threshold) // np.log(self.size_ratio))

    def _maybe_merge(self) -> None:
        # size-tiered policy: merge the two newest tiers while they occupy
        # the same level, so merges cascade geometrically (each id is
        # rewritten O(log n) times) instead of re-merging the big tier on
        # every flush (the quadratic failure mode).
        while len(self.tiers) >= 2:
            a, b = self.tiers[-2], self.tiers[-1]
            if self._level(len(a)) > self._level(len(b)):
                break
            self._merge_last_two()

    def _merge_last_two(self) -> None:
        b = self.tiers.pop()
        a = self.tiers.pop()
        rows = np.concatenate([a.rows, b.rows])
        dead = self._tomb[rows]
        ndead = int(dead.sum())
        if ndead == 0:
            # fusion: splice the two trees under a fresh root page — no
            # point movement, the constituent pages are reused verbatim
            root_page = self.store.alloc()
            self.store.write(root_page)
            ident = np.arange(self._n, dtype=np.int64)
            table = NodeTable.merged([a.table, b.table], [ident, ident],
                                     [0, 0], root_page)
            tier = Tier(self._alloc_tid(), rows, table, fused=True)
            self.fusions += 1
            self._emit("merge", (a, b), tier, True)
        else:
            live = rows[~dead]
            self._shadow -= ndead
            # the merge is a fresh scan-engine bulk load: charge a
            # sequential re-read of both inputs, then retire their pages
            in_pages = (len(np.unique(a.table.page_id))
                        + len(np.unique(b.table.page_id)))
            self.store.read_run(in_pages)
            tier = None
            if len(live):
                table = self._build_table(live)
                tier = Tier(self._alloc_tid(), live, table)
            self.store.free_pages(a.table.page_id)
            self.store.free_pages(b.table.page_id)
            self.merges += 1
            self._emit("merge", (a, b), tier, False)
        if tier is not None:
            self.tiers.append(tier)

    # -- queries (host authority) -----------------------------------------
    def _components(self) -> list[_TierView]:
        pts = self.points
        views = [_TierView(t.table, self.store, pts) for t in self.tiers]
        if self._delta_table is not None:
            views.append(_TierView(self._delta_table, self.store, pts))
        return views

    def _pending_rows(self) -> np.ndarray:
        return self._delta[self._delta_indexed:self._delta_n]

    def window(self, los, his) -> list[np.ndarray]:
        from .queries import window_query_batch

        los = np.atleast_2d(np.asarray(los, dtype=np.float64))
        his = np.atleast_2d(np.asarray(his, dtype=np.float64))
        nq = len(los)
        parts: list[list[np.ndarray]] = [[] for _ in range(nq)]
        for view in self._components():
            res, _ = window_query_batch(view, los, his)
            for i, ids in enumerate(res):
                parts[i].append(ids)
        pend = self.filter_live(self._pending_rows())
        if len(pend):
            p = self.points[pend]
            inside = ((p[None, :, :] >= los[:, None, :])
                      & (p[None, :, :] <= his[:, None, :])).all(axis=2)
            for i in range(nq):
                parts[i].append(pend[inside[i]])
        out = []
        for i in range(nq):
            ids = (np.concatenate(parts[i]) if parts[i]
                   else np.empty(0, dtype=np.int64))
            out.append(np.sort(self.filter_live(ids)))
        return out

    def knn(self, qs, k: int) -> list[np.ndarray]:
        from .queries import knn_query_batch

        qs = np.atleast_2d(np.asarray(qs, dtype=np.float64))
        nq = len(qs)
        k = int(k)
        # over-fetch: each component's top-(k+shadow) is guaranteed to
        # contain its k best *live* rows, whatever the tombstones hit
        k_eff = k + self._shadow
        cand: list[list[np.ndarray]] = [[] for _ in range(nq)]
        for view in self._components():
            res, _ = knn_query_batch(view, qs, k_eff)
            for i, ids in enumerate(res):
                cand[i].append(ids)
        pend = self.filter_live(self._pending_rows())
        out = []
        for i in range(nq):
            pool = cand[i] + ([pend] if len(pend) else [])
            ids = (np.unique(np.concatenate(pool)) if pool
                   else np.empty(0, dtype=np.int64))
            ids = self.filter_live(ids)
            d2 = np.sum((self.points[ids] - qs[i]) ** 2, axis=1)
            ids = ids[np.lexsort((ids, d2))[:k]]
            out.append(ids)
        return out

    # -- persistence -------------------------------------------------------
    def save(self, path, extra: dict | None = None) -> None:
        payload: dict = {
            "stream_version": np.int64(STREAM_VERSION),
            "dim": np.int64(self.dim),
            "n": np.int64(self._n),
            "points": self.points,
            "tomb": self._tomb[:self._n],
            "shadow": np.int64(self._shadow),
            "base_external": np.int64(self.base_external),
            "base_n": np.int64(self.base_n),
            "next_tid": np.int64(self._next_tid),
            "delta_threshold": np.int64(self.delta_threshold),
            "delta_index_every": np.int64(self.delta_index_every),
            "size_ratio": np.int64(self.size_ratio),
            "delta_rows": self._delta[:self._delta_n].copy(),
            "delta_indexed": np.int64(self._delta_indexed),
            "store_state": np.str_(json.dumps(self.store.state_dict())),
            "n_tiers": np.int64(len(self.tiers)),
        }
        for i, t in enumerate(self.tiers):
            payload[f"tier{i}_tid"] = np.int64(t.tid)
            payload[f"tier{i}_fused"] = np.int64(t.fused)
            payload[f"tier{i}_rows"] = t.rows
            _pack_table(payload, f"tier{i}_", t.table)
        if self._delta_table is not None:
            _pack_table(payload, "dtab_", self._delta_table)
        for key, val in (extra or {}).items():
            payload[f"meta_{key}"] = np.asarray(val)
        with atomic_output(path) as tmp:
            np.savez_compressed(tmp, **payload)

    @classmethod
    def load(cls, path):  # analysis: single-threaded(snapshot restore builds an unpublished instance)
        """Returns ``(stream, meta)`` where meta holds the ``extra`` dict."""
        with np.load(path, allow_pickle=False) as z:
            if int(z["stream_version"]) != STREAM_VERSION:
                raise ValueError("unknown stream snapshot version")
            dim = int(z["dim"])
            store = PageStore(1)
            store.load_state(json.loads(str(z["store_state"])))
            self = cls(z["points"], store=store, build_base=False,
                       delta_threshold=int(z["delta_threshold"]),
                       delta_index_every=int(z["delta_index_every"]),
                       size_ratio=int(z["size_ratio"]),
                       base_external=bool(int(z["base_external"])))
            self.base_n = int(z["base_n"])
            n = int(z["n"])
            self._tomb[:n] = z["tomb"]
            self._shadow = int(z["shadow"])
            self._next_tid = int(z["next_tid"])
            for i in range(int(z["n_tiers"])):
                table = _unpack_table(z, f"tier{i}_", dim)
                self.tiers.append(Tier(int(z[f"tier{i}_tid"]),
                                       z[f"tier{i}_rows"], table,
                                       fused=bool(int(z[f"tier{i}_fused"]))))
            drows = z["delta_rows"]
            self._delta[:len(drows)] = drows
            self._delta_n = len(drows)
            self._delta_indexed = int(z["delta_indexed"])
            if "dtab_page_id" in z.files:
                self._delta_table = _unpack_table(z, "dtab_", dim)
            meta = {k[len("meta_"):]: z[k] for k in z.files
                    if k.startswith("meta_")}
        return self, meta

    @staticmethod
    def is_stream_snapshot(path) -> bool:
        try:
            with np.load(path, allow_pickle=False) as z:
                return "stream_version" in z.files
        except (OSError, ValueError):
            return False


class DeviceMirror:
    """Append-only ``NodeTable`` image of a stream's live tiers.

    The serving layer exports *this* table to the device.  The contract
    that makes delta-only refresh possible: **rows are never removed**.

      * tier attach  -> ``append_subtree`` (new rows at the end)
      * fusion       -> copies of the two old roots + one new branch row
        adopting them; the old root rows are neutralized
      * rebuild-merge-> all rows of the retired tiers neutralized
        (inverted MBB, zero leaf count — invisible to window traversal
        and infinitely far for the k-NN leaf-table pruning), then the
        merged tier attaches like any other
      * every sync ends by rebuilding the root's child block: fresh
        copies of the live tier roots, adopted by row 0

    ``sync`` applies the stream's structural event log and returns the
    plan-surgery summary the sharded path needs (row remaps for moved
    root copies, retired spans, new roots to place).  Not thread-safe —
    callers serialize through the server's ``TableLock``.
    """

    def __init__(self, stream: StreamingIndex):
        if not stream.tiers:
            raise ValueError("device mirror needs at least one tier")
        self.stream = stream
        t = NodeTable(stream.dim, node_capacity=64, perm_capacity=64)
        root_page = stream.store.alloc()
        stream.store.write(root_page)
        t._grow_nodes(1)
        t._page_id[0] = root_page
        t._leaf_start[0] = -1
        self.table = t
        self.spans: dict[int, list[tuple[int, int]]] = {}
        self.root_rows: dict[int, int] = {}
        self._remap: dict[int, int] = {}
        self._retired: list[tuple[int, int]] = []
        stream.track_events = True
        stream.drain_events()  # discard pre-mirror history
        for tier in stream.tiers:
            self._attach(tier)
        self._rebuild_root()
        self._remap = {}
        self._retired = []

    # -- structural ops ----------------------------------------------------
    def _attach(self, tier: Tier) -> int:
        base = self.table.append_subtree(tier.table)
        self.spans[tier.tid] = [(base, base + tier.table.n_nodes)]
        self.root_rows[tier.tid] = base
        return base

    def _fuse(self, a: Tier, b: Tier, new: Tier) -> None:
        ra = self.root_rows.pop(a.tid)
        rb = self.root_rows.pop(b.tid)
        blk = self.table.append_row_copies(np.array([ra, rb], dtype=np.int64))
        self.table.neutralize_rows(np.array([ra, rb], dtype=np.int64))
        parent = self.table.append_branch(blk, 2, int(new.table.page_id[0]))
        self._remap[ra] = blk
        self._remap[rb] = blk + 1
        self.spans[new.tid] = (self.spans.pop(a.tid) + self.spans.pop(b.tid)
                               + [(blk, parent + 1)])
        self.root_rows[new.tid] = parent

    def _retire(self, tier: Tier) -> None:
        for lo, hi in self.spans.pop(tier.tid):
            self.table.neutralize_rows(np.arange(lo, hi, dtype=np.int64))
            self._retired.append((lo, hi))
        self.root_rows.pop(tier.tid, None)

    def _rebuild_root(self) -> None:
        tids = sorted(self.root_rows)
        if not tids:
            self.table.set_root_children(0, 0)
            return
        old = np.array([self.root_rows[t] for t in tids], dtype=np.int64)
        blk = self.table.append_row_copies(old)
        self.table.neutralize_rows(old)
        for j, tid in enumerate(tids):
            self._remap[int(old[j])] = blk + j
            self.root_rows[tid] = blk + j
            self.spans[tid].append((blk + j, blk + j + 1))
        self.table.set_root_children(blk, len(tids))

    def _resolve(self, row: int) -> int:
        while row in self._remap:
            row = self._remap[row]
        return row

    def sync(self):
        """Apply pending stream events.  Returns ``None`` when nothing
        changed, else a dict:

          * ``remap``        — resolved old-row -> new-row map for root
            copies whose *content is identical* (no re-upload needed)
          * ``retired``      — row spans neutralized this sync
          * ``add_rows``     — mirror rows of newly attached subspaces
            that no shard plan covers yet
        """
        _san.check_write(self, "sync")
        evs = self.stream.drain_events()
        if not evs:
            return None
        self._remap = {}
        self._retired = []
        pending: dict[int, int] = {}
        for ev in evs:
            if ev[0] == "attach":
                tier = ev[1]
                pending[tier.tid] = self._attach(tier)
            else:
                (a, b), new, fused = ev[1], ev[2], ev[3]
                if fused:
                    # constituents stay covered by their (remapped) plan
                    # entries; a pending constituent's row resolves through
                    # the remap to its copy under the new parent
                    self._fuse(a, b, new)
                else:
                    self._retire(a)
                    self._retire(b)
                    pending.pop(a.tid, None)
                    pending.pop(b.tid, None)
                    if new is not None:
                        pending[new.tid] = self._attach(new)
        self._rebuild_root()
        remap = {old: self._resolve(old) for old in list(self._remap)}
        add_rows = sorted({self._resolve(r) for r in pending.values()})
        info = {"remap": remap, "retired": list(self._retired),
                "add_rows": add_rows}
        self._remap = {}
        self._retired = []
        return info
