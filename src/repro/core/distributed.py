"""Parallel bulk loading and distributed query processing (paper Section 5).

Two layers:

1. ``parallel_bulk_load`` — the paper's central-server / m-local-servers
   architecture, simulated at page-I/O granularity for the Figure-11
   experiments.  The central server partitions a gamma*m page sample into m
   subspaces with a SplitTree, streams the remaining points to their owners,
   and every local server bulk loads its own FMBI.  The reported cost is the
   makespan (slowest server), per Beame et al. [4] as cited by the paper.

2. ``shard_build`` / ``shard_knn`` — the TPU-native mapping of the same
   architecture onto a device mesh with ``shard_map``: the "data" mesh axis
   plays the m local servers.  A global sample is all-gathered to compute
   the top-level splits (central Step 1), points travel to their owner shard
   with a fixed-capacity ``all_to_all`` (the network distribution step), and
   each shard builds its local ``JaxIndex`` independently.  Queries then
   touch only qualified shards; k-NN follows the paper's two-round
   SpatialHadoop protocol (local candidates, then a global top-k).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import jax_index
from .fmbi import Index, bulk_load
from .nodetable import NodeTable
from .pagestore import IOStats, PageStore, branch_capacity, leaf_capacity
from .splittree import build_group_median_tree

P = jax.sharding.PartitionSpec

try:  # jax >= 0.5: top-level API
    _shard_map = jax.shard_map
except AttributeError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


# --------------------------------------------------------------------------
# 1. host-level m-server simulation (Figure 11)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ParallelBuild:
    indexes: list[Index]
    central_io: IOStats
    per_server_io: list[IOStats]
    row_maps: list[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def makespan_io(self) -> int:
        """Parallel cost = slowest local server (paper Section 5)."""
        return max(s.total for s in self.per_server_io) if self.per_server_io else 0

    @property
    def total_io(self) -> int:
        return self.central_io.total + sum(s.total for s in self.per_server_io)

    def merged_table(self) -> NodeTable:
        """Combine the per-server node tables into one global table.

        Local dataset rows are mapped back to global ids through
        ``row_maps`` and each server's page ids are shifted into a single
        flat page namespace, so the result is a shippable snapshot of the
        whole distributed index: a synthetic root over the m server roots
        that any client can query (or ``NodeTable.save``) without touching
        the per-server stores.
        """
        offsets, off = [], 0
        for idx in self.indexes:
            offsets.append(off)
            off += idx.store.allocated_pages
        return NodeTable.merged(
            [idx.table for idx in self.indexes],
            self.row_maps,
            offsets,
            root_page=off,
        )

    def merged_index(self, points: np.ndarray, buffer_pages: int) -> Index:
        """A queryable :class:`Index` over :meth:`merged_table` with a fresh
        (cold) page store — the client-side view of the cluster's index."""
        d = points.shape[1]
        table = self.merged_table()
        store = PageStore(buffer_pages)
        store.mark_allocated(int(table.page_id.max()) + 1)
        return Index(table, d, leaf_capacity(d), branch_capacity(d), store, points)


def parallel_bulk_load(
    points: np.ndarray,
    m: int,
    buffer_pages: int,
    rng: np.random.Generator | None = None,
) -> ParallelBuild:
    """Bulk load FMBI on m servers; each server gets buffer_pages/m pages."""
    rng = rng or np.random.default_rng(0)
    n, d = points.shape
    c_l = leaf_capacity(d)
    central = PageStore(buffer_pages)
    if m == 1:
        store = PageStore(buffer_pages)
        idx = bulk_load(points, buffer_pages, store, rng)
        return ParallelBuild([idx], IOStats(), [store.stats], [np.arange(n)])

    # central server: SplitTree with m-1 splits over a gamma*m page sample
    gamma = max(buffer_pages // m, 1)
    p_total = -(-n // c_l)
    sample_pages = min(gamma * m, p_total)
    need = min(sample_pages * c_l, n)
    perm = rng.permutation(n)
    samp = perm[:need]
    group_pages = max(need // (m * c_l), 1)
    trim = m * group_pages * c_l
    central.read_run(sample_pages)
    tree, _, samp_assign = build_group_median_tree(
        points[samp[:trim]], m, group_pages, c_l
    )
    # stream the rest: the central server reads the remaining pages once
    rest = np.concatenate([samp[trim:], perm[need:]])
    central.read_run(-(-len(rest) // c_l))
    rest_assign = tree.route(points[rest]) if len(rest) else np.zeros(0, np.int32)

    server_buffer = max(buffer_pages // m, branch_capacity(d) + 1)
    indexes, per_io, row_maps = [], [], []
    for s in range(m):
        rows = np.concatenate(
            [samp[:trim][samp_assign == s], rest[rest_assign == s]]
        )
        store = PageStore(server_buffer)
        idx = bulk_load(points[rows], server_buffer, store, rng)
        indexes.append(idx)
        per_io.append(store.stats)
        row_maps.append(rows)
    return ParallelBuild(indexes, central.stats, per_io, row_maps)


def parallel_window_cost(
    build: ParallelBuild, lo: np.ndarray, hi: np.ndarray
) -> tuple[int, int]:
    """(n results, makespan page reads) for one window across servers —
    only qualified servers (subspace intersects the window) are probed."""
    from .geometry import mbb_intersects
    from .queries import window_query

    total, costs = 0, []
    for idx in build.indexes:
        if len(idx.points) == 0 or not mbb_intersects(idx.root.mbb, lo, hi):
            continue
        idx.store.buffer.clear()  # cold per-query cost (comparable across m)
        res, io = window_query(idx, lo, hi)
        total += len(res)
        costs.append(io.total)
    return total, (max(costs) if costs else 0)


# --------------------------------------------------------------------------
# 2. shard_map distributed build + queries (TPU-native Section 5)
# --------------------------------------------------------------------------
def gather_topk_merge(d2, rows, axis: str, k_out: int):
    """Global round of the two-round k-NN protocol, inside ``shard_map``:
    all-gather every shard's per-query (distance, id) top-k and merge to
    the ``k_out`` global best.  Returns (d2, ids, source) where ``source``
    is each result's position on the gather axis (its shard).  Shared by
    the ``JaxIndex`` path (``shard_knn``) and the DeviceTable path
    (``distributed_jax.knn_batch_shard_map``)."""
    all_d2 = jax.lax.all_gather(d2, axis)      # (m, Q, kk)
    all_rows = jax.lax.all_gather(rows, axis)
    m, q, kk = all_d2.shape
    flat_d2 = jnp.moveaxis(all_d2, 0, 1).reshape(q, m * kk)
    flat_rw = jnp.moveaxis(all_rows, 0, 1).reshape(q, m * kk)
    negv, topi = jax.lax.top_k(-flat_d2, k_out)
    sel_rows = jnp.take_along_axis(flat_rw, topi, axis=1)
    sel_src = (topi // kk).astype(jnp.int32)
    return -negv, sel_rows, sel_src


def _median_splits(sample: jnp.ndarray, levels: int):
    """Replicated median splits over a gathered sample (central Step 1)."""
    n, d = sample.shape
    g = jnp.zeros(n, dtype=jnp.int32)
    sdim = jnp.zeros((levels, 1 << levels), dtype=jnp.int32)
    sval = jnp.full((levels, 1 << levels), jnp.inf, dtype=sample.dtype)
    pts = sample
    for level in range(levels):
        n_groups = 1 << level
        size = n // n_groups
        gmax = jax.ops.segment_max(pts, g, num_segments=n_groups)
        gmin = jax.ops.segment_min(pts, g, num_segments=n_groups)
        dim_g = jnp.argmax(gmax - gmin, axis=1).astype(jnp.int32)
        key = pts[jnp.arange(n), dim_g[g]]
        order = jnp.lexsort((key, g))
        pts, g = pts[order], g[order]
        half = size // 2
        med = key[order][jnp.arange(n_groups) * size + (half - 1)]
        sdim = sdim.at[level, :n_groups].set(dim_g)
        sval = sval.at[level, :n_groups].set(med)
        g = g * 2 + (jnp.arange(n) % size >= half).astype(jnp.int32)
    return sdim, sval


def _route_tables(points, sdim, sval):
    g = jnp.zeros(points.shape[0], dtype=jnp.int32)
    for level in range(sdim.shape[0]):
        dim = sdim[level, g]
        val = sval[level, g]
        coord = points[jnp.arange(points.shape[0]), dim]
        g = g * 2 + (coord > val).astype(jnp.int32)
    return g


def shard_build(points, mesh, levels_local: int, axis: str = "data",
                sample_per_shard: int = 256):
    """Distributed FMBI build under shard_map.

    ``points``: (n, d) global array, row-sharded over ``axis``.  Returns the
    local index arrays, each with a leading per-shard dimension sharded over
    ``axis``:  (points_sorted, row_ids, split_dim, split_val, leaf_lo,
    leaf_hi, n_mine, gsplit_dim, gsplit_val).  ``row_ids`` carry *global*
    dataset row ids through the all_to_all (-1 for padding), so local
    query answers need no slot translation and ``shard_build_tables`` can
    flatten each shard into a globally-addressed :class:`NodeTable`.
    """
    n_shards = mesh.shape[axis]
    levels_global = int(np.log2(n_shards))
    assert (1 << levels_global) == n_shards, "shard count must be a power of 2"
    n, d = points.shape
    per = n // n_shards
    cap = max(2 * per // n_shards, per // n_shards + sample_per_shard, 8)

    def body(pts_local):
        pts_local = pts_local.reshape(per, d)
        # global row ids of this shard's input slice (the input is
        # row-sharded contiguously over the mesh axis)
        ids_local = (
            jax.lax.axis_index(axis).astype(jnp.int32) * per
            + jnp.arange(per, dtype=jnp.int32)
        )
        # --- central step: sample -> global splits (replicated) ----------
        stride = max(per // sample_per_shard, 1)
        sample_local = pts_local[::stride][:sample_per_shard]
        sample = jax.lax.all_gather(sample_local, axis).reshape(-1, d)
        if levels_global > 0:
            gs_dim, gs_val = _median_splits(sample, levels_global)
            owner = _route_tables(pts_local, gs_dim, gs_val)
        else:
            gs_dim = jnp.zeros((1, 1), jnp.int32)
            gs_val = jnp.zeros((1, 1), pts_local.dtype)
            # derived from a device-varying value (not a closed-over
            # constant): jax 0.4.x shard_map's replication check rejects
            # sorting a pure constant on a 1-device mesh
            owner = ids_local * 0
        # --- fixed-capacity dispatch to owner shards ----------------------
        order = jnp.argsort(owner)
        pts_sorted = pts_local[order]
        ids_sorted = ids_local[order]
        owner_sorted = owner[order]
        first = jnp.searchsorted(owner_sorted, jnp.arange(n_shards))
        pos = jnp.arange(per) - first[owner_sorted]
        dropped = pos >= cap  # overflow beyond capacity -> spare slot
        send = jnp.full((n_shards, cap + 1, d),
                        jnp.finfo(pts_local.dtype).max,
                        dtype=pts_local.dtype)
        send_ids = jnp.full((n_shards, cap + 1), -1, dtype=jnp.int32)
        sendmask = jnp.zeros((n_shards, cap + 1), dtype=jnp.int32)
        safe_pos = jnp.where(dropped, cap, pos)
        send = send.at[owner_sorted, safe_pos].set(pts_sorted)
        send_ids = send_ids.at[owner_sorted, safe_pos].set(ids_sorted)
        sendmask = sendmask.at[owner_sorted, safe_pos].max(
            jnp.where(dropped, 0, 1))
        send, send_ids = send[:, :cap], send_ids[:, :cap]
        sendmask = sendmask[:, :cap]
        if n_shards > 1:
            recv = jax.lax.all_to_all(send, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
            recv_ids = jax.lax.all_to_all(send_ids, axis, split_axis=0,
                                          concat_axis=0, tiled=True)
            recvmask = jax.lax.all_to_all(sendmask, axis, split_axis=0,
                                          concat_axis=0, tiled=True)
        else:
            recv, recv_ids, recvmask = send, send_ids, sendmask
        pts_mine = recv.reshape(-1, d)
        valid = recvmask.reshape(-1).astype(bool)
        big = jnp.finfo(pts_mine.dtype).max
        pts_mine = jnp.where(valid[:, None], pts_mine, big)
        # carry the points' global identities through the shuffle: local
        # indexes answer with dataset row ids, not anonymous slots
        row_ids = jnp.where(valid, recv_ids.reshape(-1), -1)
        # --- local FMBI build ---------------------------------------------
        local = jax_index.build(pts_mine, levels_local, row_ids)
        n_mine = valid.sum().reshape(1)
        out = (
            local.points_sorted[None], local.row_ids[None],
            local.split_dim[None], local.split_val[None],
            local.leaf_lo[None], local.leaf_hi[None],
            n_mine[None], gs_dim[None], gs_val[None],
        )
        return out

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=P(axis, None),
        out_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis),
                   P(axis), P(axis), P(axis)),
    )
    return fn(points)


def unpack_local_index(shard_out, shard: int, levels_local: int):
    """Materialize shard ``shard``'s JaxIndex from ``shard_build`` output."""
    ps, ri, sd, sv, lo, hi, nm, gd, gv = shard_out
    n_leaves = 1 << levels_local
    return jax_index.JaxIndex(
        points_sorted=ps[shard], row_ids=ri[shard], split_dim=sd[shard],
        split_val=sv[shard], leaf_lo=lo[shard], leaf_hi=hi[shard],
        levels=levels_local, leaf_size=ps[shard].shape[0] // n_leaves,
    )


def table_from_jax_index(jidx) -> NodeTable:
    """Flatten a ``JaxIndex`` leaf grid into a one-level :class:`NodeTable`.

    Empty (all-padding) leaves are dropped and leaf MBBs are recomputed
    tight over the valid points (the grid's segment boxes include the
    +inf padding sentinels).  ``perm`` takes the grid's ``row_ids``
    verbatim, so a ``shard_build`` shard — which carries global dataset
    ids through the all_to_all — flattens into a table that addresses the
    global dataset, ready for the sharded device engine.
    """
    from .fmbi import Node

    pts = np.asarray(jidx.points_sorted, dtype=np.float64)
    ids = np.asarray(jidx.row_ids)
    n_l, s = jidx.n_leaves, jidx.leaf_size
    d = pts.shape[1]
    grid = pts.reshape(n_l, s, d)
    ids2 = ids.reshape(n_l, s)
    valid = ids2 >= 0
    live = np.flatnonzero(valid.any(axis=1))
    if len(live) == 0:
        raise ValueError("grid holds no valid points")
    lo = np.where(valid[..., None], grid, np.inf).min(axis=1)
    hi = np.where(valid[..., None], grid, -np.inf).max(axis=1)
    leaves = [
        Node(
            mbb=np.stack([lo[l], hi[l]]),
            page_id=1 + j,
            point_idx=ids2[l][valid[l]].astype(np.int64),
        )
        for j, l in enumerate(live)
    ]
    if len(leaves) == 1:
        root = leaves[0]
        root.page_id = 0
    else:
        root = Node(
            mbb=np.stack([lo[live].min(axis=0), hi[live].max(axis=0)]),
            page_id=0,
            children=leaves,
        )
    return NodeTable.from_tree(root, d, n_points_hint=int(valid.sum()))


def shard_build_tables(shard_out, levels_local: int) -> list[NodeTable]:
    """Per-shard :class:`NodeTable`s from ``shard_build`` output — the
    bridge that lands the TPU build on the same representation as the
    host m-server simulation (``ParallelBuild`` / ``NodeTable.merged`` /
    the sharded device engine)."""
    n_shards = np.asarray(shard_out[0]).shape[0]
    return [
        table_from_jax_index(unpack_local_index(shard_out, s, levels_local))
        for s in range(n_shards)
    ]


def shard_knn(shard_out, queries, k: int, mesh, levels_local: int,
              axis: str = "data", n_candidate_leaves: int = 8):
    """Two-round distributed k-NN (paper Section 5 / SpatialHadoop):
    local candidates per shard, then a global top-k over gathered
    (distance, row) candidates."""
    n_shards = mesh.shape[axis]
    ps, ri, sd, sv, lo, hi, *_ = shard_out
    n_leaves = 1 << levels_local
    leaf_size = ps.shape[1] // n_leaves

    def body(ps_l, ri_l, sd_l, sv_l, lo_l, hi_l):
        local = jax_index.JaxIndex(
            points_sorted=ps_l.reshape(-1, ps_l.shape[-1]),
            row_ids=ri_l.reshape(-1),
            split_dim=sd_l.reshape(sd_l.shape[1:]),
            split_val=sv_l.reshape(sv_l.shape[1:]),
            leaf_lo=lo_l.reshape(lo_l.shape[1:]),
            leaf_hi=hi_l.reshape(hi_l.shape[1:]),
            levels=levels_local, leaf_size=leaf_size,
        )
        rows, d2, _ = jax_index.knn(local, queries, k,
                                    n_candidate_leaves=n_candidate_leaves)
        top_d2, sel_rows, sel_shard = gather_topk_merge(d2, rows, axis, k)
        return top_d2[None], sel_rows[None], sel_shard[None]

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis)),
    )
    d2, rows, shards = fn(ps, ri, sd, sv, lo, hi)
    # all shards hold the same global answer; shard 0's copy suffices
    return d2[0], rows[0], shards[0]
