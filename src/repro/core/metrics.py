"""Index quality metrics: the paper's Table 1 / Figure 4 statistics.

Computed straight off the flat :class:`~repro.core.nodetable.NodeTable` —
leaf extents, fills, and subtree cardinalities are column reductions, not
object-graph walks.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .fmbi import Index


@dataclasses.dataclass
class LeafStats:
    count: int
    total_area: float       # sum over leaves of prod(side lengths)
    total_perimeter: float  # 2 * sum of side lengths per leaf (2D perimeter;
                            # for d>2 this is the paper's analogous L1 margin)
    avg_fill: float         # points per leaf / leaf capacity
    max_over_mean: float    # subspace balance (paper Fig 4a: 1.06 for OSM)
    min_over_mean: float

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


def leaf_stats(index: Index) -> LeafStats:
    t = index.table
    rows = t.leaf_rows()
    ext = t.mbb_hi[rows] - t.mbb_lo[rows]
    count = len(rows)
    sides_sum = float(ext.sum())
    area_sum = float(np.prod(ext, axis=1).sum()) if count else 0.0
    fill = float(t.leaf_count[rows].sum()) / (max(count, 1) * index.leaf_cap)
    # root-entry balance (Fig 4a): points under each child of the root
    # (unrefined subtrees count their raw ranges)
    if t.child_count[0] > 0:
        subtree = t.subtree_points()
        sizes = subtree[
            t.first_child[0] : t.first_child[0] + t.child_count[0]
        ].astype(np.float64)
    else:
        sizes = np.asarray([1.0])
    mean = sizes.mean() if sizes.size else 1.0
    return LeafStats(
        count=count,
        total_area=area_sum,
        total_perimeter=2.0 * sides_sum,
        avg_fill=fill,
        max_over_mean=float(sizes.max() / mean),
        min_over_mean=float(sizes.min() / mean),
    )


def overlap_area_2d(index: Index) -> float:
    """Total pairwise overlap area of sibling leaf MBBs (0 for FMBI by
    construction; positive for Hilbert packing)."""
    t = index.table
    rows = t.leaf_rows()
    if len(rows) == 0 or index.dim != 2:
        return 0.0
    los, his = t.mbb_lo[rows], t.mbb_hi[rows]
    n = len(rows)
    total = 0.0
    for i in range(n):
        j = slice(i + 1, n)
        lo = np.maximum(los[j], los[i])
        hi = np.minimum(his[j], his[i])
        ext = np.clip(hi - lo, 0.0, None)
        total += float(np.prod(ext, axis=1).sum())
    return total
