"""Index quality metrics: the paper's Table 1 / Figure 4 statistics."""
from __future__ import annotations

import dataclasses

import numpy as np

from .fmbi import Index, Node


@dataclasses.dataclass
class LeafStats:
    count: int
    total_area: float       # sum over leaves of prod(side lengths)
    total_perimeter: float  # 2 * sum of side lengths per leaf (2D perimeter;
                            # for d>2 this is the paper's analogous L1 margin)
    avg_fill: float         # points per leaf / leaf capacity
    max_over_mean: float    # subspace balance (paper Fig 4a: 1.06 for OSM)
    min_over_mean: float

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


def leaf_stats(index: Index) -> LeafStats:
    sides_sum = 0.0
    area_sum = 0.0
    count = 0
    fill = 0.0
    for leaf in index.root.iter_leaves():
        ext = leaf.mbb[1] - leaf.mbb[0]
        sides_sum += float(ext.sum())
        area_sum += float(np.prod(ext))
        count += 1
        fill += len(leaf.point_idx) / index.leaf_cap
    # root-entry balance (Fig 4a)
    sizes = []
    if index.root.children:
        for c in index.root.children:
            sizes.append(_subtree_points(c))
    sizes = np.asarray(sizes if sizes else [1], dtype=np.float64)
    mean = sizes.mean() if sizes.size else 1.0
    return LeafStats(
        count=count,
        total_area=area_sum,
        total_perimeter=2.0 * sides_sum,
        avg_fill=fill / max(count, 1),
        max_over_mean=float(sizes.max() / mean),
        min_over_mean=float(sizes.min() / mean),
    )


def _subtree_points(node: Node) -> int:
    total = 0
    stack = [node]
    while stack:
        n = stack.pop()
        if n.is_leaf:
            total += len(n.point_idx)
        elif n.is_unrefined:
            total += len(n.raw_points)
        elif n.children:
            stack.extend(n.children)
    return total


def overlap_area_2d(index: Index) -> float:
    """Total pairwise overlap area of sibling leaf MBBs (0 for FMBI by
    construction; positive for Hilbert packing)."""
    leaves = list(index.root.iter_leaves())
    if not leaves or index.dim != 2:
        return 0.0
    boxes = np.stack([l.mbb for l in leaves])  # (n, 2, d)
    n = len(boxes)
    total = 0.0
    # grid-bucket to avoid O(n^2) for large leaf counts
    for i in range(n):
        lo_i, hi_i = boxes[i]
        j = slice(i + 1, n)
        lo = np.maximum(boxes[j, 0], lo_i)
        hi = np.minimum(boxes[j, 1], hi_i)
        ext = np.clip(hi - lo, 0.0, None)
        total += float(np.prod(ext, axis=1).sum())
    return total
