"""Core library: the paper's contribution (FMBI / AMBI / parallel loading)."""
from .ambi import AMBI
from .baselines import LOADERS, bulk_load_hilbert, bulk_load_kdb
from .baselines import bulk_load_omt, bulk_load_str, bulk_load_waffle
from .fmbi import Index, Node, bulk_load, refine_subspace
from .metrics import leaf_stats
from .nodetable import NodeTable, NodeView
from .pagestore import IOStats, PageStore, branch_capacity, leaf_capacity
from .queries import (
    knn_oracle,
    knn_query,
    knn_query_batch,
    window_oracle,
    window_query,
    window_query_batch,
)
from .streaming import DeviceMirror, StreamingIndex

ALL_LOADERS = dict(LOADERS, fmbi=lambda pts, M, store=None: bulk_load(pts, M, store))

__all__ = [
    "AMBI",
    "ALL_LOADERS",
    "DeviceMirror",
    "LOADERS",
    "StreamingIndex",
    "Index",
    "IOStats",
    "Node",
    "PageStore",
    "branch_capacity",
    "bulk_load",
    "bulk_load_hilbert",
    "bulk_load_kdb",
    "bulk_load_omt",
    "bulk_load_str",
    "bulk_load_waffle",
    "knn_oracle",
    "knn_query",
    "knn_query_batch",
    "leaf_capacity",
    "leaf_stats",
    "NodeTable",
    "NodeView",
    "refine_subspace",
    "window_oracle",
    "window_query",
    "window_query_batch",
]
