"""FMBI: Fast Multidimensional Bulkloaded Index (paper Section 3).

Five-step, scan-based bulk loading.  All sorting happens in main memory (the
defining property of the method); disk I/O is charged to a ``PageStore`` at
page granularity, faithfully following the paper's cost accounting:

  Step 1  read alpha*C_B random pages, build the Major SplitTree (MST)
  Step 2  single linear scan of the remaining pages, routing points through
          the MST into subspace buffers; buffer-overflow flushes render
          subspaces inactive
  Step 3  refine every *sparse* subspace (fits in the buffer) with the minor
          SplitTree recursion of Algorithm 1
  Step 4  conceptually merge underflowed branches (Algorithm 2) so that small
          entry lists share disk pages
  Step 5  recursively bulk load each *dense* subspace as a fresh dataset

The in-memory ``Node`` tree doubles as the physical index: every node carries
the id of the disk page its entry list (branch) or point payload (leaf) lives
on, so query processing can charge buffered page reads exactly like the
paper's framework.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .pagestore import IOStats, PageStore, branch_capacity, leaf_capacity
from .splittree import (
    FlatSplitTree,
    build_group_median_tree,
    longest_dimension,
    mbb_of,
)


# --------------------------------------------------------------------------
# Index node
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Node:
    mbb: np.ndarray                      # (2, d) [min; max]
    page_id: int                         # disk page holding this node's data
    children: Optional[list["Node"]] = None  # branch: child entries
    point_idx: Optional[np.ndarray] = None   # leaf: dataset row indices
    # AMBI: an unrefined node owns raw data pages not yet formed into a tree.
    raw_pages: int = 0                       # number of unrefined disk pages
    raw_points: Optional[np.ndarray] = None  # dataset row indices (unrefined)

    @property
    def is_leaf(self) -> bool:
        return self.point_idx is not None

    @property
    def is_unrefined(self) -> bool:
        return self.raw_points is not None

    def n_entries(self) -> int:
        if self.is_leaf:
            return len(self.point_idx)
        if self.is_unrefined:
            # an unrefined sparse subspace of P pages will always produce P
            # leaf entries when processed (paper Section 4.1)
            return self.raw_pages
        return len(self.children)

    def iter_leaves(self):
        stack = [self]
        while stack:
            n = stack.pop()
            if n.is_leaf:
                yield n
            elif n.children:
                stack.extend(n.children)


@dataclasses.dataclass
class Index:
    root: Node
    dim: int
    leaf_cap: int
    branch_cap: int
    store: PageStore
    points: np.ndarray  # the dataset (index leaves reference rows)

    def count_nodes(self) -> tuple[int, int]:
        leaves = branches = 0
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.is_leaf:
                leaves += 1
            elif n.is_unrefined:
                pass
            else:
                branches += 1
                stack.extend(n.children)
        return leaves, branches

    def distinct_pages(self) -> int:
        """Physical index size in pages (merged nodes share pages)."""
        pages = set()
        stack = [self.root]
        while stack:
            n = stack.pop()
            pages.add(n.page_id)
            if n.children:
                stack.extend(n.children)
        return len(pages)


# --------------------------------------------------------------------------
# Algorithm 1: minor-SplitTree refinement of a (sparse) subspace
# --------------------------------------------------------------------------
def refine_subspace(
    points: np.ndarray,
    idx: np.ndarray,
    leaf_cap: int,
    branch_cap: int,
    store: PageStore,
) -> list[Node]:
    """``generate_entries(P)`` of the paper: post-order recursion over the
    minor SplitTree, emitting FMBI leaf entries for single pages and wrapping
    entry lists that exceed C_B into branch entries.  All sorting is
    in-memory; the only I/O is writing finalized leaf/branch pages.

    Returns the subspace's root entry list (1..C_B nodes).
    """
    if len(idx) == 0:
        return []

    def rec(sub_idx: np.ndarray, n_pages: int) -> list[Node]:
        pts = points[sub_idx]
        if n_pages <= 1:
            page = store.alloc()
            store.write(page)
            return [Node(mbb=mbb_of(pts), page_id=page, point_idx=sub_idx)]
        dim = longest_dimension(pts)
        order = np.argsort(pts[:, dim], kind="stable")
        n_left = n_pages // 2
        cut = n_left * leaf_cap  # left half is ⌊P/2⌋ *full* pages
        ne1 = rec(sub_idx[order[:cut]], n_left)
        ne2 = rec(sub_idx[order[cut:]], n_pages - n_left)
        if len(ne1) + len(ne2) <= branch_cap:
            return ne1 + ne2
        out = []
        for ne in (ne1, ne2):
            page = store.alloc()
            store.write(page)
            mbb = np.stack(
                [
                    np.min([e.mbb[0] for e in ne], axis=0),
                    np.max([e.mbb[1] for e in ne], axis=0),
                ]
            )
            out.append(Node(mbb=mbb, page_id=page, children=ne))
        return out

    total_pages = max(1, -(-len(idx) // leaf_cap))
    return rec(idx, total_pages)


# --------------------------------------------------------------------------
# Algorithm 2: merging of underflowed branches over the MST
# --------------------------------------------------------------------------
def merge_branches(
    tree: FlatSplitTree,
    subspace_nodes: list[Optional[Node]],
    branch_cap: int,
) -> list[list[Node]]:
    """Post-order MST traversal (Algorithm 2 of the paper).

    ``subspace_nodes[i]`` is the candidate node of MST leaf ``i`` — a branch
    whose entry-list page has *not yet been written* — or ``None`` for dense
    (unprocessed) subspaces, the paper's φ.  Nodes whose entry lists fit
    together within ``C_B`` are merged conceptually: their lists will share
    one disk page, while the FMBI root keeps one entry per subspace.

    Returns the final page groups; the caller allocates/writes one page per
    group and stamps ``page_id`` on every member.
    """
    groups: list[list[Node]] = []

    def emit(group: list[Node]) -> None:
        if group:
            groups.append(group)

    def mergeable(group: list[Node]) -> bool:
        return all(not n.is_leaf for n in group)

    def rec(child: int) -> Optional[list[Node]]:
        if child < 0:  # MST leaf -> subspace
            n = subspace_nodes[-child - 1]
            return None if n is None else [n]
        nl = rec(tree.left[child])
        nr = rec(tree.right[child])
        if nl is None:
            return nr
        if nr is None:
            return nl
        tl = sum(x.n_entries() for x in nl)
        tr = sum(x.n_entries() for x in nr)
        if tl + tr <= branch_cap and mergeable(nl) and mergeable(nr):
            return nl + nr  # merge: single shared page downstream
        # no merge possible: pass the smaller list upstream as the candidate
        if tl < tr:
            emit(nr)
            return nl
        emit(nl)
        return nr

    if tree.n_splits == 0:
        for n in subspace_nodes:
            if n is not None:
                emit([n])
        return groups
    last = rec(0)
    if last:
        emit(last)
    return groups


# --------------------------------------------------------------------------
# Step 2 buffer simulation
# --------------------------------------------------------------------------
class SubspaceBuffers:
    """Models the Step-2 buffer at page granularity.

    Each subspace accumulates routed points.  Active subspaces keep all their
    pages in memory; on buffer exhaustion the allocating subspace flushes its
    full pages (-> inactive, paper Step 2).  A ``flush_victim`` hook lets
    AMBI substitute its distance max-heap victim selection.
    """

    def __init__(self, n_sub, leaf_cap, buffer_pages, store, init_pages):
        self.n = n_sub
        self.leaf_cap = leaf_cap
        self.M = buffer_pages
        self.store = store
        init = np.asarray(init_pages, dtype=np.int64)
        self.counts = init * leaf_cap            # points routed so far
        self.mem_pages = init.copy()             # buffer pages held
        self.disk_pages = np.zeros(n_sub, dtype=np.int64)
        self.active = np.ones(n_sub, dtype=bool)

    @property
    def mem_used(self) -> int:
        return int(self.mem_pages.sum())

    def pages_of(self, s: int) -> int:
        return int(-(-self.counts[s] // self.leaf_cap))

    def add_points(self, s: int, k: int, flush_victim=None) -> None:
        while k > 0:
            in_mem_pts = int(self.counts[s]) - int(self.disk_pages[s]) * self.leaf_cap
            room = int(self.mem_pages[s]) * self.leaf_cap - in_mem_pts
            if room > 0:
                take = min(k, room)
                self.counts[s] += take
                k -= take
                continue
            # need a fresh buffer page
            if self.mem_used >= self.M:
                victim = s if flush_victim is None else flush_victim(s)
                if victim is None:
                    # caller declined to flush (AMBI split path); spill over
                    self.mem_pages[s] += 1
                    self.counts[s] += min(k, self.leaf_cap)
                    k -= min(k, self.leaf_cap)
                    continue
                self.flush(int(victim))
                if victim != s:
                    continue
            self.mem_pages[s] += 1

    def flush(self, s: int) -> None:
        """Write subspace ``s``'s full in-memory pages to disk (Step 2)."""
        in_mem_pts = int(self.counts[s]) - int(self.disk_pages[s]) * self.leaf_cap
        full = in_mem_pts // self.leaf_cap
        if full > 0:
            self.store.write_run(full)
            self.disk_pages[s] += full
        self.mem_pages[s] = 1  # retain a single (partial) memory page
        self.active[s] = False

    def final_flush_partial(self, s: int) -> None:
        rem = int(self.counts[s]) - int(self.disk_pages[s]) * self.leaf_cap
        if rem > 0:
            self.store.write_run(1)
            self.disk_pages[s] += 1


# --------------------------------------------------------------------------
# The bulk loader
# --------------------------------------------------------------------------
def bulk_load(
    points: np.ndarray,
    buffer_pages: int,
    store: Optional[PageStore] = None,
    rng: Optional[np.random.Generator] = None,
    *,
    charge_source_read: bool = True,
    _depth: int = 0,
) -> Index:
    """Bulk load FMBI over ``points`` with a ``buffer_pages`` buffer."""
    rng = rng or np.random.default_rng(0)
    store = store or PageStore(buffer_pages)
    n, d = points.shape
    c_l = leaf_capacity(d)
    c_b = branch_capacity(d)
    p_total = -(-n // c_l)
    alpha = max(buffer_pages // c_b, 1)

    # ---- base case: the whole (sub)dataset fits in the buffer -----------
    if p_total <= min(buffer_pages, alpha * c_b) or n <= c_l:
        if charge_source_read:
            store.read_run(p_total)
        entries = refine_subspace(points, np.arange(n), c_l, c_b, store)
        if len(entries) == 1:
            root = entries[0]
        else:
            page = store.alloc()
            store.write(page)
            root = Node(mbb=mbb_of(points), page_id=page, children=entries)
        return Index(root, d, c_l, c_b, store, points)

    # ---- Step 1: initial partitioning / Major SplitTree -----------------
    sample_pages = alpha * c_b
    page_of_point = np.arange(n) // c_l
    perm = rng.permutation(p_total)
    sampled = perm[:sample_pages]
    store.read_run(sample_pages)  # random page reads
    samp_mask = np.zeros(p_total, dtype=bool)
    samp_mask[sampled] = True
    samp_sel = samp_mask[page_of_point]
    samp_idx = np.flatnonzero(samp_sel)
    # a sampled trailing partial page can leave the sample short; top up so
    # that Step 1 operates on exactly alpha*C_B full pages
    need = sample_pages * c_l
    if len(samp_idx) < need:
        extra = np.flatnonzero(~samp_sel)[: need - len(samp_idx)]
        samp_sel[extra] = True
        samp_idx = np.flatnonzero(samp_sel)

    mst, _, samp_assign = build_group_median_tree(
        points[samp_idx], n_groups=c_b, group_pages=alpha, page_points=c_l
    )

    # ---- Step 2: distribute remaining pages -----------------------------
    rest_idx = np.flatnonzero(~samp_sel)
    store.read_run(-(-len(rest_idx) // c_l))
    bufs = SubspaceBuffers(c_b, c_l, buffer_pages, store, [alpha] * c_b)
    sub_points: list[list[np.ndarray]] = [[] for _ in range(c_b)]
    for s in range(c_b):
        sub_points[s].append(samp_idx[samp_assign == s])
    if len(rest_idx) > 0:
        assign = mst.route(points[rest_idx])
        # stream in file order at page granularity to model flush order
        for start in range(0, len(rest_idx), c_l):
            sl = slice(start, start + c_l)
            a = assign[sl]
            ridx = rest_idx[sl]
            for s in np.unique(a):
                sel = ridx[a == s]
                sub_points[int(s)].append(sel)
                bufs.add_points(int(s), len(sel))

    # ---- Step 3: refine sparse subspaces (actives first: pages are free)
    sub_idx = [
        np.concatenate(sp) if sp else np.zeros(0, dtype=np.int64)
        for sp in sub_points
    ]
    subspace_nodes: list[Optional[Node]] = [None] * c_b
    dense: list[int] = []
    for s in np.argsort(~bufs.active, kind="stable"):
        s = int(s)
        pages_s = bufs.pages_of(s)
        if pages_s > buffer_pages:
            dense.append(s)
            continue
        if len(sub_idx[s]) == 0:
            continue
        if not bufs.active[s]:
            store.read_run(int(bufs.disk_pages[s]))  # reload flushed pages
        entries = refine_subspace(points, sub_idx[s], c_l, c_b, store)
        node_mbb = (
            mbb_of(points[sub_idx[s]]) if len(sub_idx[s]) else np.zeros((2, d))
        )
        if len(entries) == 1:
            subspace_nodes[s] = entries[0]  # already has its own page
        else:
            # page deferred: assigned after Step 4 merging
            subspace_nodes[s] = Node(mbb=node_mbb, page_id=-1, children=entries)

    # ---- Step 4: conceptual merging, then write the root-entry pages ----
    merge_candidates: list[Optional[Node]] = [
        sn if (sn is not None and sn.page_id == -1) else None
        for sn in subspace_nodes
    ]
    groups = merge_branches(mst, merge_candidates, c_b)
    for group in groups:
        page = store.alloc()
        store.write(page)
        for node in group:
            node.page_id = page

    # ---- Step 5: dense subspaces -> recursive bulk load ------------------
    for s in dense:
        bufs.final_flush_partial(s)
        sub = bulk_load(
            points[sub_idx[s]],
            buffer_pages,
            store,
            rng,
            charge_source_read=True,
            _depth=_depth + 1,
        )
        _rebase_leaves(sub.root, sub_idx[s])
        subspace_nodes[s] = sub.root

    root_page = store.alloc()
    store.write(root_page)
    root = Node(
        mbb=mbb_of(points),
        page_id=root_page,
        children=[sn for sn in subspace_nodes if sn is not None],
    )
    return Index(root, d, c_l, c_b, store, points)


def _rebase_leaves(node: Node, base_idx: np.ndarray) -> None:
    stack = [node]
    while stack:
        n = stack.pop()
        if n.is_leaf:
            n.point_idx = base_idx[n.point_idx]
        elif n.is_unrefined:
            n.raw_points = base_idx[n.raw_points]
        elif n.children:
            stack.extend(n.children)
