"""FMBI: Fast Multidimensional Bulkloaded Index (paper Section 3).

Five-step, scan-based bulk loading.  All sorting happens in main memory (the
defining property of the method); disk I/O is charged to a ``PageStore`` at
page granularity, faithfully following the paper's cost accounting:

  Step 1  read alpha*C_B random pages, build the Major SplitTree (MST)
  Step 2  single linear scan of the remaining pages, routing points through
          the MST into subspace buffers; buffer-overflow flushes render
          subspaces inactive
  Step 3  refine every *sparse* subspace (fits in the buffer) with the minor
          SplitTree recursion of Algorithm 1
  Step 4  conceptually merge underflowed branches (Algorithm 2) so that small
          entry lists share disk pages
  Step 5  recursively bulk load each *dense* subspace as a fresh dataset

Construction assembles a transient ``Node`` tree — every node carries the id
of the disk page its entry list (branch) or point payload (leaf) lives on —
which ``bulk_load`` flattens into the flat :class:`~repro.core.nodetable.NodeTable`
the query layer traverses; page-read charging through the table is
bit-identical to walking the tree (see ``core/queries.py``).

Scan engine
-----------
The hot paths run as true array-level scans, not interpreter loops:

  * Step 2 routes the whole stream once through the MST, derives per-page x
    per-subspace occupancy with a single ``bincount``, and *replays* the
    buffer's flush decisions from the prefix-sum occupancy arrays
    (:func:`_replay_step2`).  Only page-boundary crossings — O(total pages)
    events — are simulated; the per-point work is all vectorized.  The replay
    is decision-for-decision identical to the scalar ``SubspaceBuffers``
    simulation (kept below as the reference; ``bulk_load(step2="scalar")``
    runs it, and a regression test asserts identical ``IOStats`` and
    identical subspace assignments).
  * Each subspace's rows are gathered with one stable argsort of the routing
    assignment instead of per-page list appends.
  * :func:`refine_subspace` presorts the subspace once per dimension and
    partitions those orders in place, replacing the O(n log^2 n) re-sorting
    recursion with O(d n log n) boolean partitions.  Ties break by original
    stream order rather than by the re-sorted arrangement the naive
    recursion carried, so with duplicate coordinates a cut may land tied
    points on the other side; page counts, entry lists, and therefore the
    I/O accounting are unaffected (they depend only on page arithmetic).
    Leaf pages are allocated and written in run-granular batches
    (``PageStore.write_seq``) with ids identical to the per-page sequence.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from .nodetable import NodeTable, NodeView
from .pagestore import IOStats, PageStore, branch_capacity, leaf_capacity
from .splittree import (
    FlatSplitTree,
    build_group_median_tree,
    mbb_of,
)


# --------------------------------------------------------------------------
# Index node
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Node:
    mbb: np.ndarray                      # (2, d) [min; max]
    page_id: int                         # disk page holding this node's data
    children: Optional[list["Node"]] = None  # branch: child entries
    point_idx: Optional[np.ndarray] = None   # leaf: dataset row indices
    # AMBI: an unrefined node owns raw data pages not yet formed into a tree.
    raw_pages: int = 0                       # number of unrefined disk pages
    raw_points: Optional[np.ndarray] = None  # dataset row indices (unrefined)

    @property
    def is_leaf(self) -> bool:
        return self.point_idx is not None

    @property
    def is_unrefined(self) -> bool:
        return self.raw_points is not None

    def n_entries(self) -> int:
        if self.is_leaf:
            return len(self.point_idx)
        if self.is_unrefined:
            # an unrefined sparse subspace of P pages will always produce P
            # leaf entries when processed (paper Section 4.1)
            return self.raw_pages
        return len(self.children)

    def iter_leaves(self):
        stack = [self]
        while stack:
            n = stack.pop()
            if n.is_leaf:
                yield n
            elif n.children:
                stack.extend(n.children)


class Index:
    """A built index: a flat :class:`NodeTable` plus its substrate.

    The table is the query-time representation (see ``core/nodetable.py``);
    construction code passes the transient ``Node`` tree it assembled and
    the constructor flattens it.  ``root`` exposes a thin read-only
    ``NodeView`` for code that still walks the object shape (metrics,
    tests, examples).
    """

    def __init__(self, root, dim, leaf_cap, branch_cap, store, points):
        if isinstance(root, NodeTable):
            self.table = root
        else:
            self.table = NodeTable.from_tree(root, dim, n_points_hint=len(points))
        self.dim = dim
        self.leaf_cap = leaf_cap
        self.branch_cap = branch_cap
        self.store = store
        self.points = points  # the dataset (leaf perm ranges reference rows)

    @property
    def root(self) -> NodeView:
        return NodeView(self.table, 0)

    def count_nodes(self) -> tuple[int, int]:
        t = self.table
        leaves = int(((t.leaf_start >= 0) & ~t.unrefined).sum())
        branches = int((t.child_count > 0).sum())
        return leaves, branches

    def distinct_pages(self) -> int:
        """Physical index size in pages (merged nodes share pages)."""
        return len(np.unique(self.table.page_id))

    # -- snapshots ---------------------------------------------------------
    def save(self, path, *, include_points: bool = True) -> None:
        """Single-``.npz`` snapshot: table + substrate metadata (+ points)."""
        self.table.save(
            path,
            points=self.points if include_points else None,
            extra={
                "buffer_pages": self.store.buffer.capacity,
                "next_page_id": self.store.allocated_pages,
            },
        )

    @classmethod
    def load(cls, path, points: Optional[np.ndarray] = None) -> "Index":
        """Rebuild an :class:`Index` from a snapshot with a fresh (cold)
        ``PageStore`` of the original buffer capacity."""
        table, meta, pts = NodeTable.load(path)
        if points is not None:
            pts = points
        if pts is None:
            raise ValueError("snapshot has no points; pass them explicitly")
        store = PageStore(int(meta.get("buffer_pages", 64)))
        store.mark_allocated(
            int(meta.get("next_page_id", int(table.page_id.max()) + 1))
        )
        d = pts.shape[1]
        return cls(table, d, leaf_capacity(d), branch_capacity(d), store, pts)


# --------------------------------------------------------------------------
# Algorithm 1: minor-SplitTree refinement of a (sparse) subspace
# --------------------------------------------------------------------------
def refine_subspace(
    points: np.ndarray,
    idx: np.ndarray,
    leaf_cap: int,
    branch_cap: int,
    store: PageStore,
) -> list[Node]:
    """``generate_entries(P)`` of the paper: post-order recursion over the
    minor SplitTree, emitting FMBI leaf entries for single pages and wrapping
    entry lists that exceed C_B into branch entries.  All sorting is
    in-memory; the only I/O is writing finalized leaf/branch pages.

    The subspace is argsorted once per dimension up front; every recursive
    split partitions those orders membership-preservingly, so the per-node
    sorted views cost O(d * m) boolean compressions instead of a fresh
    O(m log m) sort.  Node MBBs and split spreads come straight from the
    sorted extremes, eliminating the per-node min/max reductions.  Subtrees
    that can never wrap (page count <= C_B) allocate and write their leaf
    pages as one run.

    Returns the subspace's root entry list (1..C_B nodes).
    """
    m = len(idx)
    if m == 0:
        return []
    pts = points[idx]
    d = pts.shape[1]
    cols = [np.ascontiguousarray(pts[:, j]) for j in range(d)]
    orders = [np.argsort(c, kind="stable") for c in cols]
    flag = np.zeros(m, dtype=bool)

    def spread_dim(orders_) -> int:
        # spread from the sorted extremes; ties resolve to the first max,
        # matching np.argmax over (max - min) in the naive recursion
        best, best_spread = 0, -np.inf
        for j in range(d):
            o = orders_[j]
            spread = cols[j][o[-1]] - cols[j][o[0]]
            if spread > best_spread:
                best, best_spread = j, spread
        return best

    def partition(orders_, dim: int, cut: int):
        o = orders_[dim]
        left_set = o[:cut]
        flag[left_set] = True
        left, right = [], []
        for j, oj in enumerate(orders_):
            if j == dim:
                left.append(left_set)
                right.append(o[cut:])
            else:
                mj = flag[oj]
                left.append(oj[mj])
                right.append(oj[~mj])
        flag[left_set] = False
        return left, right

    def make_leaf(orders_, page: int, last_dim: Optional[int]) -> Node:
        mbb = np.array(
            [
                [c[o[0]] for c, o in zip(cols, orders_)],
                [c[o[-1]] for c, o in zip(cols, orders_)],
            ]
        )
        local = orders_[last_dim] if last_dim is not None else None
        return Node(
            mbb=mbb,
            page_id=page,
            point_idx=idx[local] if local is not None else idx,
        )

    def leaf_run(orders_, n_pages: int, last_dim: Optional[int]) -> list[Node]:
        """A subtree of <= C_B pages can never wrap: it is exactly
        ``n_pages`` leaves, emitted in DFS order as one alloc/write run."""
        first = store.alloc(n_pages)
        store.write_seq(first, n_pages)
        out: list[Node] = []

        def lrec(orders__, n_pages_: int, last_dim_: Optional[int]) -> None:
            if n_pages_ <= 1:
                out.append(make_leaf(orders__, first + len(out), last_dim_))
                return
            dim = spread_dim(orders__)
            n_left = n_pages_ // 2
            cut = n_left * leaf_cap  # left half is ⌊P/2⌋ *full* pages
            left, right = partition(orders__, dim, cut)
            lrec(left, n_left, dim)
            lrec(right, n_pages_ - n_left, dim)

        lrec(orders_, n_pages, last_dim)
        return out

    def rec(orders_, n_pages: int, last_dim: Optional[int]) -> list[Node]:
        if n_pages <= branch_cap:
            return leaf_run(orders_, n_pages, last_dim)
        dim = spread_dim(orders_)
        n_left = n_pages // 2
        cut = n_left * leaf_cap
        left, right = partition(orders_, dim, cut)
        ne1 = rec(left, n_left, dim)
        ne2 = rec(right, n_pages - n_left, dim)
        if len(ne1) + len(ne2) <= branch_cap:
            return ne1 + ne2
        out = []
        for ne in (ne1, ne2):
            page = store.alloc()
            store.write(page)
            mbb = np.stack(
                [
                    np.min([e.mbb[0] for e in ne], axis=0),
                    np.max([e.mbb[1] for e in ne], axis=0),
                ]
            )
            out.append(Node(mbb=mbb, page_id=page, children=ne))
        return out

    total_pages = max(1, -(-m // leaf_cap))
    return rec(orders, total_pages, None)


# --------------------------------------------------------------------------
# Algorithm 2: merging of underflowed branches over the MST
# --------------------------------------------------------------------------
def merge_branches(
    tree: FlatSplitTree,
    subspace_nodes: list[Optional[Node]],
    branch_cap: int,
) -> list[list[Node]]:
    """Post-order MST traversal (Algorithm 2 of the paper).

    ``subspace_nodes[i]`` is the candidate node of MST leaf ``i`` — a branch
    whose entry-list page has *not yet been written* — or ``None`` for dense
    (unprocessed) subspaces, the paper's φ.  Nodes whose entry lists fit
    together within ``C_B`` are merged conceptually: their lists will share
    one disk page, while the FMBI root keeps one entry per subspace.

    Returns the final page groups; the caller allocates/writes one page per
    group and stamps ``page_id`` on every member.
    """
    groups: list[list[Node]] = []

    def emit(group: list[Node]) -> None:
        if group:
            groups.append(group)

    def mergeable(group: list[Node]) -> bool:
        return all(not n.is_leaf for n in group)

    def rec(child: int) -> Optional[list[Node]]:
        if child < 0:  # MST leaf -> subspace
            n = subspace_nodes[-child - 1]
            return None if n is None else [n]
        nl = rec(tree.left[child])
        nr = rec(tree.right[child])
        if nl is None:
            return nr
        if nr is None:
            return nl
        tl = sum(x.n_entries() for x in nl)
        tr = sum(x.n_entries() for x in nr)
        if tl + tr <= branch_cap and mergeable(nl) and mergeable(nr):
            return nl + nr  # merge: single shared page downstream
        # no merge possible: pass the smaller list upstream as the candidate
        if tl < tr:
            emit(nr)
            return nl
        emit(nl)
        return nr

    if tree.n_splits == 0:
        for n in subspace_nodes:
            if n is not None:
                emit([n])
        return groups
    last = rec(0)
    if last:
        emit(last)
    return groups


# --------------------------------------------------------------------------
# Step 2 buffer simulation (scalar reference)
# --------------------------------------------------------------------------
class SubspaceBuffers:
    """Models the Step-2 buffer at page granularity (scalar reference).

    Each subspace accumulates routed points.  Active subspaces keep all their
    pages in memory; on buffer exhaustion the allocating subspace flushes its
    full pages (-> inactive, paper Step 2).  A ``flush_victim`` hook lets
    AMBI substitute its distance max-heap victim selection.

    The production Step-2 path is :func:`_replay_step2`, which reproduces
    this state machine's decisions from vectorized prefix sums; this class is
    retained as the executable specification it is validated against.
    """

    def __init__(self, n_sub, leaf_cap, buffer_pages, store, init_pages):
        self.n = n_sub
        self.leaf_cap = leaf_cap
        self.M = buffer_pages
        self.store = store
        init = np.asarray(init_pages, dtype=np.int64)
        self.counts = init * leaf_cap            # points routed so far
        self.mem_pages = init.copy()             # buffer pages held
        self.disk_pages = np.zeros(n_sub, dtype=np.int64)
        self.active = np.ones(n_sub, dtype=bool)

    @property
    def mem_used(self) -> int:
        return int(self.mem_pages.sum())

    def pages_of(self, s: int) -> int:
        return int(-(-self.counts[s] // self.leaf_cap))

    def add_points(self, s: int, k: int, flush_victim=None) -> None:
        while k > 0:
            in_mem_pts = int(self.counts[s]) - int(self.disk_pages[s]) * self.leaf_cap
            room = int(self.mem_pages[s]) * self.leaf_cap - in_mem_pts
            if room > 0:
                take = min(k, room)
                self.counts[s] += take
                k -= take
                continue
            # need a fresh buffer page
            if self.mem_used >= self.M:
                victim = s if flush_victim is None else flush_victim(s)
                if victim is None:
                    # caller declined to flush (AMBI split path); spill over
                    self.mem_pages[s] += 1
                    self.counts[s] += min(k, self.leaf_cap)
                    k -= min(k, self.leaf_cap)
                    continue
                self.flush(int(victim))
                if victim != s:
                    continue
            self.mem_pages[s] += 1

    def flush(self, s: int) -> None:
        """Write subspace ``s``'s full in-memory pages to disk (Step 2)."""
        in_mem_pts = int(self.counts[s]) - int(self.disk_pages[s]) * self.leaf_cap
        full = in_mem_pts // self.leaf_cap
        if full > 0:
            self.store.write_run(full)
            self.disk_pages[s] += full
        self.mem_pages[s] = 1  # retain a single (partial) memory page
        self.active[s] = False

    def final_flush_partial(self, s: int) -> None:
        rem = int(self.counts[s]) - int(self.disk_pages[s]) * self.leaf_cap
        if rem > 0:
            self.store.write_run(1)
            self.disk_pages[s] += 1


# --------------------------------------------------------------------------
# Step 2: vectorized distribution
# --------------------------------------------------------------------------
def _group_slices(assign: np.ndarray, n_sub: int):
    """Stable group-by: ``order[bounds[s]:bounds[s+1]]`` are the positions
    with ``assign == s``, preserving stream order within each group."""
    order = np.argsort(assign, kind="stable")
    counts = np.bincount(assign, minlength=n_sub)
    bounds = np.concatenate([[0], np.cumsum(counts)])
    return order, bounds


def _replay_step2(
    assign: np.ndarray,
    c_b: int,
    c_l: int,
    buffer_pages: int,
    alpha: int,
    store: PageStore,
):
    """Replay the Step-2 buffer decisions from prefix-occupancy arrays.

    ``assign`` is the MST subspace of every streamed point, in file order.
    One ``bincount`` produces the per-page x per-subspace occupancy; its
    per-subspace prefix sums tell exactly when each subspace's in-memory
    point count crosses a page boundary.  Only those crossings — O(pages)
    events, ordered by (page, subspace) like the scalar simulation — are
    replayed through the grow-or-flush state machine of
    :class:`SubspaceBuffers`; everything per-point stays in numpy.

    Returns (counts, disk_pages, active): the final buffer state.  Flush
    writes are charged to ``store`` with totals identical to the scalar run.
    """
    n_rest = len(assign)
    counts0 = alpha * c_l  # every subspace starts with its sampled pages
    if n_rest == 0:
        return (
            np.full(c_b, counts0, dtype=np.int64),
            np.zeros(c_b, dtype=np.int64),
            np.ones(c_b, dtype=bool),
        )
    n_chunks = -(-n_rest // c_l)
    chunk = np.arange(n_rest, dtype=np.int64) // c_l
    occ = np.bincount(
        chunk * c_b + assign.astype(np.int64), minlength=n_chunks * c_b
    )
    # cum[t, s]: points routed to s after page t has been distributed
    cum = occ.reshape(n_chunks, c_b).cumsum(axis=0) + counts0
    cum_t = np.ascontiguousarray(cum.T)  # (c_b, n_chunks) for searchsorted

    mem = np.full(c_b, alpha, dtype=np.int64)
    disk = np.zeros(c_b, dtype=np.int64)
    active = np.ones(c_b, dtype=bool)
    mem_used = int(alpha) * c_b
    writes = 0

    heap: list[tuple[int, int]] = []

    def push(s: int) -> None:
        cap = int(disk[s] + mem[s]) * c_l
        t = int(np.searchsorted(cum_t[s], cap, side="right"))
        if t < n_chunks:
            heapq.heappush(heap, (t, s))

    for s in range(c_b):
        push(s)
    while heap:
        t, s = heapq.heappop(heap)
        target = int(cum_t[s, t])
        while int(disk[s] + mem[s]) * c_l < target:
            if mem_used >= buffer_pages:
                # flush: the in-memory pages are all full; afterwards the
                # subspace keeps one (empty) page plus the fresh one
                writes += int(mem[s])
                disk[s] += mem[s]
                mem_used += 2 - int(mem[s])
                mem[s] = 2
                active[s] = False
            else:
                mem[s] += 1
                mem_used += 1
        push(s)
    store.write_run(writes)
    return cum[-1].astype(np.int64), disk, active


def _distribute_scalar(
    assign: np.ndarray,
    rest_idx: np.ndarray,
    samp_idx: np.ndarray,
    samp_assign: np.ndarray,
    c_b: int,
    c_l: int,
    buffer_pages: int,
    alpha: int,
    store: PageStore,
):
    """The seed's page-by-page Step-2 loop (reference implementation)."""
    bufs = SubspaceBuffers(c_b, c_l, buffer_pages, store, [alpha] * c_b)
    sub_points: list[list[np.ndarray]] = [[] for _ in range(c_b)]
    for s in range(c_b):
        sub_points[s].append(samp_idx[samp_assign == s])
    for start in range(0, len(rest_idx), c_l):
        sl = slice(start, start + c_l)
        a = assign[sl]
        ridx = rest_idx[sl]
        for s in np.unique(a):
            sel = ridx[a == s]
            sub_points[int(s)].append(sel)
            bufs.add_points(int(s), len(sel))
    sub_idx = [
        np.concatenate(sp) if sp else np.zeros(0, dtype=np.int64)
        for sp in sub_points
    ]
    return sub_idx, bufs.counts.copy(), bufs.disk_pages.copy(), bufs.active.copy()


def _distribute_vectorized(
    assign: np.ndarray,
    rest_idx: np.ndarray,
    samp_idx: np.ndarray,
    samp_assign: np.ndarray,
    c_b: int,
    c_l: int,
    buffer_pages: int,
    alpha: int,
    store: PageStore,
):
    """Array-level Step 2: one group-by for the rows, one replay for the
    buffer decisions.  Produces the same subspace row lists (same order) and
    the same I/O as :func:`_distribute_scalar`."""
    counts, disk, active = _replay_step2(
        assign, c_b, c_l, buffer_pages, alpha, store
    )
    samp_order, samp_bounds = _group_slices(samp_assign, c_b)
    rest_order, rest_bounds = _group_slices(assign, c_b)
    samp_sorted = samp_idx[samp_order]
    rest_sorted = rest_idx[rest_order]
    sub_idx = [
        np.concatenate(
            [
                samp_sorted[samp_bounds[s] : samp_bounds[s + 1]],
                rest_sorted[rest_bounds[s] : rest_bounds[s + 1]],
            ]
        )
        for s in range(c_b)
    ]
    return sub_idx, counts, disk, active


# --------------------------------------------------------------------------
# The bulk loader
# --------------------------------------------------------------------------
def bulk_load(
    points: np.ndarray,
    buffer_pages: int,
    store: Optional[PageStore] = None,
    rng: Optional[np.random.Generator] = None,
    *,
    charge_source_read: bool = True,
    step2: str = "vectorized",
) -> Index:
    """Bulk load FMBI over ``points`` with a ``buffer_pages`` buffer.

    ``step2`` selects the distribution engine: ``"vectorized"`` (default,
    prefix-sum replay) or ``"scalar"`` (the page-by-page reference loop);
    both produce identical indexes and identical ``IOStats``.  The result is
    a flat :class:`Index` (the construction tree is flattened into a
    :class:`NodeTable` and discarded).
    """
    rng = rng or np.random.default_rng(0)
    store = store or PageStore(buffer_pages)
    d = points.shape[1]
    root = _bulk_load_tree(
        points,
        buffer_pages,
        store,
        rng,
        charge_source_read=charge_source_read,
        step2=step2,
    )
    return Index(root, d, leaf_capacity(d), branch_capacity(d), store, points)


def _bulk_load_tree(
    points: np.ndarray,
    buffer_pages: int,
    store: PageStore,
    rng: np.random.Generator,
    *,
    charge_source_read: bool = True,
    step2: str = "vectorized",
    _depth: int = 0,
) -> Node:
    """The five-step construction; returns the transient ``Node`` root."""
    n, d = points.shape
    c_l = leaf_capacity(d)
    c_b = branch_capacity(d)
    p_total = -(-n // c_l)
    alpha = max(buffer_pages // c_b, 1)

    # ---- base case: the whole (sub)dataset fits in the buffer -----------
    if p_total <= min(buffer_pages, alpha * c_b) or n <= c_l:
        if charge_source_read:
            store.read_run(p_total)
        entries = refine_subspace(points, np.arange(n), c_l, c_b, store)
        if len(entries) == 1:
            return entries[0]
        page = store.alloc()
        store.write(page)
        return Node(mbb=mbb_of(points), page_id=page, children=entries)

    # ---- Step 1: initial partitioning / Major SplitTree -----------------
    sample_pages = alpha * c_b
    page_of_point = np.arange(n) // c_l
    perm = rng.permutation(p_total)
    sampled = perm[:sample_pages]
    store.read_run(sample_pages)  # random page reads
    samp_mask = np.zeros(p_total, dtype=bool)
    samp_mask[sampled] = True
    samp_sel = samp_mask[page_of_point]
    samp_idx = np.flatnonzero(samp_sel)
    # a sampled trailing partial page can leave the sample short; top up so
    # that Step 1 operates on exactly alpha*C_B full pages
    need = sample_pages * c_l
    if len(samp_idx) < need:
        extra = np.flatnonzero(~samp_sel)[: need - len(samp_idx)]
        samp_sel[extra] = True
        samp_idx = np.flatnonzero(samp_sel)

    mst, _, samp_assign = build_group_median_tree(
        points[samp_idx], n_groups=c_b, group_pages=alpha, page_points=c_l
    )

    # ---- Step 2: distribute remaining pages -----------------------------
    rest_idx = np.flatnonzero(~samp_sel)
    store.read_run(-(-len(rest_idx) // c_l))
    assign = (
        mst.route(points[rest_idx])
        if len(rest_idx)
        else np.zeros(0, dtype=np.int32)
    )
    distribute = (
        _distribute_scalar if step2 == "scalar" else _distribute_vectorized
    )
    sub_idx, counts, disk_pages, active = distribute(
        assign, rest_idx, samp_idx, samp_assign,
        c_b, c_l, buffer_pages, alpha, store,
    )

    # ---- Step 3: refine sparse subspaces (actives first: pages are free)
    pages_of = -(-counts // c_l)
    subspace_nodes: list[Optional[Node]] = [None] * c_b
    dense: list[int] = []
    for s in np.argsort(~active, kind="stable"):
        s = int(s)
        if pages_of[s] > buffer_pages:
            dense.append(s)
            continue
        if len(sub_idx[s]) == 0:
            continue
        if not active[s]:
            store.read_run(int(disk_pages[s]))  # reload flushed pages
        entries = refine_subspace(points, sub_idx[s], c_l, c_b, store)
        node_mbb = (
            mbb_of(points[sub_idx[s]]) if len(sub_idx[s]) else np.zeros((2, d))
        )
        if len(entries) == 1:
            subspace_nodes[s] = entries[0]  # already has its own page
        else:
            # page deferred: assigned after Step 4 merging
            subspace_nodes[s] = Node(mbb=node_mbb, page_id=-1, children=entries)

    # ---- Step 4: conceptual merging, then write the root-entry pages ----
    merge_candidates: list[Optional[Node]] = [
        sn if (sn is not None and sn.page_id == -1) else None
        for sn in subspace_nodes
    ]
    groups = merge_branches(mst, merge_candidates, c_b)
    for group in groups:
        page = store.alloc()
        store.write(page)
        for node in group:
            node.page_id = page

    # ---- Step 5: dense subspaces -> recursive bulk load ------------------
    for s in dense:
        if counts[s] - disk_pages[s] * c_l > 0:  # trailing partial page
            store.write_run(1)
        sub_root = _bulk_load_tree(
            points[sub_idx[s]],
            buffer_pages,
            store,
            rng,
            charge_source_read=True,
            step2=step2,
            _depth=_depth + 1,
        )
        _rebase_leaves(sub_root, sub_idx[s])
        subspace_nodes[s] = sub_root

    root_page = store.alloc()
    store.write(root_page)
    return Node(
        mbb=mbb_of(points),
        page_id=root_page,
        children=[sn for sn in subspace_nodes if sn is not None],
    )


def _rebase_leaves(node: Node, base_idx: np.ndarray) -> None:
    stack = [node]
    while stack:
        n = stack.pop()
        if n.is_leaf:
            n.point_idx = base_idx[n.point_idx]
        elif n.is_unrefined:
            n.raw_points = base_idx[n.raw_points]
        elif n.children:
            stack.extend(n.children)
