"""Simulated disk-page store with I/O accounting and an LRU buffer.

The paper evaluates every index inside a unified disk-based framework with
4 KiB pages and an LRU buffer sized as a fraction of the dataset.  This module
is the JAX-framework analogue of that substrate: pages are identified by
integer ids, reads/writes are counted, and an LRU buffer absorbs repeated
accesses exactly as the paper's buffer does.

Capacities follow the paper's arithmetic for 4 KiB pages:
  * leaf entry  = d float32 coords + 4-byte record id  -> C_L = 4096 // (4d+4)
  * branch entry = MBB (2 points, 2*d float32) + 4-byte pointer
                                                -> C_B = 4096 // (8d+4)
For d=2 this reproduces the paper's C_L = 341 and C_B = 204 verbatim.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

PAGE_SIZE = 4096
COORD_BYTES = 4
ID_BYTES = 4
POINTER_BYTES = 4


def leaf_capacity(d: int, page_size: int = PAGE_SIZE) -> int:
    """Points per leaf page (paper: C_L = 341 for d = 2)."""
    return page_size // (COORD_BYTES * d + ID_BYTES)


def branch_capacity(d: int, page_size: int = PAGE_SIZE) -> int:
    """Entries per branch page (paper: C_B = 204 for d = 2)."""
    return page_size // (2 * COORD_BYTES * d + POINTER_BYTES)


@dataclasses.dataclass
class IOStats:
    """Counters of simulated page I/O (the paper's cost metric)."""

    reads: int = 0
    writes: int = 0

    @property
    def total(self) -> int:
        return self.reads + self.writes

    def __add__(self, other: "IOStats") -> "IOStats":
        return IOStats(self.reads + other.reads, self.writes + other.writes)

    def snapshot(self) -> "IOStats":
        return IOStats(self.reads, self.writes)

    def delta(self, since: "IOStats") -> "IOStats":
        return IOStats(self.reads - since.reads, self.writes - since.writes)


class LRUBuffer:
    """LRU page buffer: a read of a resident page is free, as in the paper."""

    def __init__(self, capacity_pages: int):
        self.capacity = max(int(capacity_pages), 1)
        self._pages: OrderedDict[int, None] = OrderedDict()

    def __contains__(self, page_id: int) -> bool:
        return page_id in self._pages

    def __len__(self) -> int:
        return len(self._pages)

    def touch(self, page_id: int) -> bool:
        """Access a page; returns True on hit (no I/O)."""
        if page_id in self._pages:
            self._pages.move_to_end(page_id)
            return True
        self._pages[page_id] = None
        if len(self._pages) > self.capacity:
            self._pages.popitem(last=False)
        return False

    def evict(self, page_id: int) -> None:
        self._pages.pop(page_id, None)

    def clear(self) -> None:
        self._pages.clear()

    def load_run(self, page_ids) -> None:
        """Set the buffer to exactly ``page_ids`` (oldest first).

        Used by the run fast paths: after touching a run of >= capacity
        distinct pages, the buffer holds precisely the trailing ``capacity``
        pages of the run — whatever was resident before is evicted, so the
        state can be written directly instead of replayed touch by touch.
        """
        self._pages = OrderedDict.fromkeys(int(p) for p in page_ids)


class PageStore:
    """A page-granular simulated disk.

    Page *contents* are kept only as opaque python objects (the algorithms in
    ``core`` operate on in-memory numpy views of the data and charge I/O
    explicitly).  The store's job is strictly accounting: reads, writes, and
    buffered re-reads.
    """

    def __init__(self, buffer_pages: int, page_size: int = PAGE_SIZE):
        self.page_size = page_size
        self.stats = IOStats()
        self.buffer = LRUBuffer(buffer_pages)
        self._next_id = 0
        # Free-list of recycled page-id runs, kept sorted and coalesced as
        # ``[start, length]`` pairs.  Pages freed when a merged-away tier is
        # retired are handed back out by ``alloc`` (first fit) before the
        # high-water mark advances, so sustained ingest does not leak ids.
        self._free: list[list[int]] = []
        # Optional fault-injection hook, called as ``hook(op, n_pages)`` at
        # the *entry* of each accounted I/O op — before any counter or
        # buffer mutation, so an injected failure leaves the store's state
        # untouched and the op is safely retryable.
        self.fault_hook = None

    def _fault(self, op: str, n: int) -> None:
        if self.fault_hook is not None:
            self.fault_hook(op, n)

    # -- snapshot state ----------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable state for snapshot barriers: the allocator,
        the I/O counters, and the exact LRU residency/order (recovery must
        reproduce buffered-vs-charged reads bit for bit)."""
        return {
            "page_size": self.page_size,
            "next_id": self._next_id,
            "reads": self.stats.reads,
            "writes": self.stats.writes,
            "buffer_capacity": self.buffer.capacity,
            "buffer_pages": [int(p) for p in self.buffer._pages],
            "free_runs": [[int(s), int(ln)] for s, ln in self._free],
        }

    def load_state(self, state: dict) -> None:
        self.page_size = int(state["page_size"])
        self._next_id = int(state["next_id"])
        self.stats = IOStats(int(state["reads"]), int(state["writes"]))
        self.buffer = LRUBuffer(int(state["buffer_capacity"]))
        self.buffer.load_run(state["buffer_pages"])
        self._free = [[int(s), int(ln)] for s, ln in state.get("free_runs", [])]

    # -- allocation -------------------------------------------------------
    def alloc(self, n: int = 1) -> int:
        """Reserve ``n`` consecutive page ids; returns the first id.

        Recycled runs (``free_range``) are reused first-fit before the
        high-water mark advances.
        """
        n = int(n)
        for i, (s, ln) in enumerate(self._free):
            if ln >= n:
                if ln == n:
                    del self._free[i]
                else:
                    self._free[i] = [s + n, ln - n]
                return s
        first = self._next_id
        self._next_id += n
        return first

    def free_range(self, first: int, n: int = 1) -> None:
        """Return ``n`` consecutive page ids starting at ``first`` to the
        allocator.  The freed pages are evicted from the LRU buffer: a
        recycled id must behave exactly like a fresh one for I/O accounting
        (its first read after re-allocation is a charged miss, never a free
        hit inherited from the retired owner)."""
        first, n = int(first), int(n)
        if n <= 0:
            return
        for pid in range(first, first + n):
            self.buffer.evict(pid)
        self._free.append([first, n])
        self._free.sort()
        merged = [self._free[0]]
        for s, ln in self._free[1:]:
            ps, pln = merged[-1]
            if s <= ps + pln:
                merged[-1][1] = max(pln, s + ln - ps)
            else:
                merged.append([s, ln])
        self._free = merged

    def free_pages(self, page_ids) -> None:
        """Free an arbitrary set of page ids (grouped into runs)."""
        ids = np.unique(np.asarray(list(page_ids), dtype=np.int64))
        if len(ids) == 0:
            return
        breaks = np.flatnonzero(np.diff(ids) != 1) + 1
        for run in np.split(ids, breaks):
            self.free_range(int(run[0]), len(run))

    @property
    def allocated_pages(self) -> int:
        """Allocator high-water mark (ids ever handed out)."""
        return self._next_id

    @property
    def free_page_count(self) -> int:
        return sum(ln for _, ln in self._free)

    @property
    def live_pages(self) -> int:
        """Pages currently owned by some index (high-water minus freed)."""
        return self._next_id - self.free_page_count

    def mark_allocated(self, n_pages: int) -> None:
        """Advance the allocator past ``n_pages`` already-existing pages —
        used when adopting an index whose pages were allocated elsewhere
        (snapshot load, merged per-server tables)."""
        self._next_id = max(self._next_id, int(n_pages))

    # -- accounted I/O ----------------------------------------------------
    def read(self, page_id: int, *, bypass_buffer: bool = False) -> None:
        self._fault("read", 1)
        self._read_accounted(page_id, bypass_buffer)

    def _read_accounted(self, page_id: int, bypass_buffer: bool = False) -> None:
        if bypass_buffer or not self.buffer.touch(page_id):
            self.stats.reads += 1

    def read_many(self, page_ids, *, bypass_buffer: bool = False) -> None:
        """Read a sequence of pages through the buffer.

        Fast path: for a run of *distinct* pages longer than the LRU
        capacity, a page at run position >= capacity cannot be resident when
        touched (the preceding ``capacity`` distinct touches have evicted
        it), so only the leading ``capacity`` pages go through the touch
        loop; the rest are bulk-charged as misses and the buffer is set to
        the trailing ``capacity`` pages.  Accounting is identical to the
        per-page loop — without the O(run) interpreter iteration.
        """
        ids = np.asarray(list(page_ids), dtype=np.int64)
        self._fault("read_many", len(ids))
        if bypass_buffer:
            self.stats.reads += len(ids)
            return
        cap = self.buffer.capacity
        n = len(ids)
        if n > cap and len(np.unique(ids)) == n:
            for pid in ids[:cap]:
                self._read_accounted(int(pid))
            self.stats.reads += n - cap
            self.buffer.load_run(ids[-cap:])
            return
        for pid in ids:
            self._read_accounted(int(pid))

    def read_run(self, n_pages: int) -> None:
        """A bulk sequential read of ``n_pages`` fresh (unbuffered) pages."""
        self._fault("read_run", int(n_pages))
        self.stats.reads += int(n_pages)

    def write(self, page_id: int) -> None:
        self.stats.writes += 1
        # A freshly written page is resident (it was produced in memory).
        self.buffer.touch(page_id)

    def write_seq(self, first_id: int, n_pages: int) -> None:
        """Write ``n_pages`` consecutive pages starting at ``first_id``.

        Accounting-equivalent to ``n_pages`` individual :meth:`write` calls in
        ascending id order (same write count, same final LRU state) but issued
        as one run-granular call so bulk writers avoid per-page call overhead.
        Runs longer than the buffer capacity skip the touch loop entirely:
        only the trailing ``capacity`` pages can remain resident.
        """
        n_pages = int(n_pages)
        self.stats.writes += n_pages
        cap = self.buffer.capacity
        if n_pages >= cap:
            self.buffer.load_run(range(first_id + n_pages - cap, first_id + n_pages))
            return
        for pid in range(first_id, first_id + n_pages):
            self.buffer.touch(pid)

    def write_run(self, n_pages: int) -> None:
        self.stats.writes += int(n_pages)

    # -- derived costs ----------------------------------------------------
    def external_sort_cost(self, n_pages: int, buffer_pages: int) -> IOStats:
        """I/O of textbook external merge sort of ``n_pages`` with an
        ``buffer_pages``-page buffer: run formation (read+write everything)
        plus ⌈log_{B-1}(P/B)⌉ merge passes, each reading+writing everything.

        This is charged (not executed) for the sort-based competitor loaders,
        mirroring how the paper accounts their construction cost.
        """
        import math

        p = max(int(n_pages), 1)
        b = max(int(buffer_pages), 2)
        if p <= b:  # fits in memory: single read pass, no spill
            return IOStats(reads=p, writes=0)
        runs = math.ceil(p / b)
        passes = max(1, math.ceil(math.log(max(runs, 2), b - 1)))
        # run formation (r+w) + merge passes (r+w each), final write included
        reads = p * (1 + passes)
        writes = p * (1 + passes)
        return IOStats(reads=reads, writes=writes)

    def charge(self, stats: IOStats) -> None:
        self.stats.reads += stats.reads
        self.stats.writes += stats.writes
