"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute_s    = FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory_s     = HBM bytes / (chips * 819e9 B/s)
  collective_s = collective bytes / (chips * 50e9 B/s per ICI link)

Sources:
  * ``parse_collectives`` extracts every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute from the compiled HLO
    text, *including ops inside scan while-bodies*: the parser builds the
    computation call graph, finds each while loop's trip count from its
    condition's comparison constant, and multiplies nested ops accordingly.
    XLA's ``cost_analysis`` counts while bodies once, so this multiplier
    recovery is what makes scanned-layer models analyzable at all.
  * FLOPs / HBM bytes come from depth-probe extrapolation
    (``probe_extrapolate``): the compiled cost_analysis of unrolled 1- and
    2-superblock variants gives exact per-block costs including fusion
    effects; totals are base + per_block * n_blocks.  An analytic model
    (``analytic_flops``) cross-checks the probes; tests assert both agree on
    fully-unrolled small configs.
"""
from __future__ import annotations

import re
from collections import defaultdict

# --- TPU v5e hardware constants ------------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
MXU_MIN_DIM = 128

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}



def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` across the jax 0.4 -> 0.7 drift: older
    jax returns a per-device list of dicts, newer jax one dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}

def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, e.g. 'bf16[2,1024,512]{2,1,0}' or a
    tuple '(f32[8], f32[8])'."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# computation headers have nested parens in tuple-typed params, e.g.
#   %wide.region_0.1_spmd.clone (arg: (s32[], f32[8,16]{1,0})) -> (...) {
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) \(.*-> .*\{\s*$")
_OP_RE = re.compile(
    r"^\s+(?:ROOT )?%?([\w\.\-]+) = ((?:\([^=]*?\)|[\w\[\]{},\. ]+?)) "
    r"([\w\-]+)\((.*)$"
)


def _split_computations(txt: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in txt.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def parse_collectives(txt: str) -> dict:
    """Collective bytes from compiled HLO text with while-loop multipliers.

    Returns {'by_kind': {kind: bytes}, 'counts': {kind: n}, 'total_bytes'}.
    """
    comps = _split_computations(txt)

    # per-computation: collective (kind, bytes), calls (callee, trip_mult)
    coll: dict[str, list] = defaultdict(list)
    calls: dict[str, list] = defaultdict(list)
    trip_of_cond: dict[str, int] = {}

    for cname, lines in comps.items():
        for line in lines:
            m = _OP_RE.match(line)
            if m is None:
                continue
            _, rtype, op, rest = m.groups()
            if op in COLLECTIVES or op in {c + "-start" for c in COLLECTIVES}:
                kind = op.replace("-start", "")
                coll[cname].append((kind, _shape_bytes(rtype)))
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", rest)
                if mb:
                    trip = _trip_count(comps.get(mc.group(1), [])) if mc else 1
                    calls[cname].append((mb.group(1), trip))
                    if mc:
                        trip_of_cond[mb.group(1)] = trip
            else:
                for mm in re.finditer(
                    r"(?:calls|to_apply|body|condition|branch_computations)="
                    r"[{%]?([\w\.\-, %]+)", rest
                ):
                    for callee in re.split(r"[,\s]+", mm.group(1)):
                        callee = callee.strip().lstrip("%")
                        if callee and callee in comps:
                            calls[cname].append((callee, 1))

    # propagate multipliers from ENTRY through the call graph
    m = re.search(r"^ENTRY %?([\w\.\-]+)", txt, re.M)
    entry = m.group(1) if m else next(iter(comps), None)

    by_kind: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    seen_stack = set()

    def walk(cname: str, mult: float):
        if cname in seen_stack:  # cycle guard
            return
        seen_stack.add(cname)
        for kind, b in coll.get(cname, ()):
            by_kind[kind] += b * mult
            counts[kind] += 1
        for callee, trip in calls.get(cname, ()):
            walk(callee, mult * trip)
        seen_stack.discard(cname)

    if entry:
        walk(entry, 1.0)
    return {
        "by_kind": dict(by_kind),
        "counts": dict(counts),
        "total_bytes": float(sum(by_kind.values())),
    }


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count from a while condition: the comparison constant."""
    best = 1
    for line in cond_lines:
        m = re.search(r"constant\((\d+)\)", line)
        if m:
            best = max(best, int(m.group(1)))
    return best


def op_census(txt: str) -> dict:
    """Counts of interesting ops in the entry module (reshape/transpose
    pressure, fusion counts — the 'profile' for the perf loop)."""
    census: dict[str, int] = defaultdict(int)
    for op in ("fusion", "reshape", "transpose", "copy", "while",
               "dynamic-slice", "dynamic-update-slice", "scatter", "gather",
               *COLLECTIVES):
        census[op] = len(re.findall(rf"= [\w\[\]{{}},\. ]+ {op}\(", txt))
    return dict(census)


# --------------------------------------------------------------------------
# probe extrapolation + analytic model
# --------------------------------------------------------------------------
def probe_extrapolate(probe: dict, n_blocks: int) -> dict:
    """Per-block costs from unrolled 1-/2-block probes -> full-depth totals.

    total(n) = base + per_block * n, from total(1) and total(2)."""
    one, two = probe["blocks1"], probe["blocks2"]
    out = {}
    for key in ("flops", "bytes_accessed", "collective_bytes"):
        per = two[key] - one[key]
        base = one[key] - per
        out[key] = base + per * n_blocks
        out[f"{key}_per_block"] = per
    return out


def analytic_flops(cfg, shape, n_micro: int = 1) -> dict:
    """Closed-form FLOPs for one step of the cell (global, all chips).

    Forward matmul flops 2*N_active_nonembed*T + attention; train multiplies
    by 4 (bwd 2x + full-remat recompute 1x); microbatching does not change
    totals.  Cross-checked against XLA cost_analysis in tests."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    d, hd = cfg.d_model, cfg.hd
    kinds = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    sb = cfg.superblock

    def layer_flops(i: int, T: int, S_ctx: int) -> float:
        k, f = kinds[i % sb], ffns[i % sb]
        fl = 0.0
        if k in ("attn", "local", "global"):
            proj = 2 * T * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd \
                + 2 * T * cfg.n_heads * hd * d
            # EXECUTED flops: the q-chunked einsum computes every (q, k)
            # score and masks afterwards, so causal masking does NOT halve
            # the work, and naive local attention pays the full context;
            # the sliced-KV path (local_slice_opt) pays window + chunk.
            if k == "local" and cfg.local_window and kind != "decode":
                if cfg.local_slice_opt:
                    cq = min(cfg.chunk_q, T // B)
                    ctx = min(cfg.local_window + cq, S_ctx)
                else:
                    ctx = S_ctx
            elif k == "local" and cfg.local_window and kind == "decode":
                ctx = min(cfg.local_window, S_ctx)
            else:
                ctx = S_ctx
            att = 2 * 2 * B * cfg.n_heads * (T // B) * ctx * hd
            fl += proj + att
        elif k == "mamba":
            di = cfg.mamba_expand * d
            N = cfg.mamba_d_state
            fl += 2 * T * d * (2 * di + 2 * (di // cfg.mamba_head_dim) * N
                               + di // cfg.mamba_head_dim) \
                + 2 * T * di * d
            H = di // cfg.mamba_head_dim
            c = cfg.la_chunk
            fl += 2 * T * H * (2 * c * N + 2 * N * cfg.mamba_head_dim
                               + c * cfg.mamba_head_dim)
        elif k == "rwkv":
            fl += 2 * T * d * d * 5  # r,k,v,g,o
            H = d // cfg.rwkv_head_dim
            c = cfg.la_chunk
            dk = cfg.rwkv_head_dim
            fl += 2 * T * H * (2 * c * dk + 2 * dk * dk + c * dk)
        if f == "dense":
            fl += 2 * 3 * T * d * cfg.d_ff
        elif f == "moe":
            fl += 2 * T * d * cfg.n_experts  # router
            fl += 2 * 3 * T * cfg.moe_top_k * cfg.capacity_factor * d * \
                (cfg.moe_dff or cfg.d_ff)
            if cfg.dense_residual:
                fl += 2 * 3 * T * d * cfg.d_ff
        elif f == "rwkv_cm":
            fl += 2 * T * (2 * d * cfg.d_ff + d * d)
        return fl

    if kind == "decode":
        T = B  # one token per sequence
        S_ctx = S
    else:
        T = B * S
        S_ctx = S

    fwd = 0.0
    n_full = cfg.n_layers
    for i in range(n_full):
        fwd += layer_flops(i, T, S_ctx)
    if cfg.encoder_layers and kind != "decode":
        # encoder over frames + decoder cross-attention
        Te = B * S
        for i in range(cfg.encoder_layers):
            fwd += (2 * Te * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
                    + 2 * Te * cfg.n_heads * hd * d
                    + 2 * 2 * B * cfg.n_heads * S * S * hd * 0.5
                    + 2 * 3 * Te * d * cfg.d_ff)
        Td = T // 8 if kind != "decode" else T
        fwd += n_full * (2 * Td * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
                         + 2 * Td * cfg.n_heads * hd * d
                         + 2 * 2 * B * cfg.n_heads * (Td // B) * S * hd)
    # logits / loss head
    T_head = (B * (S // 8) if cfg.encoder_layers else T) if kind == "train" \
        else B
    fwd += 2 * T_head * d * cfg.vocab

    mult = 4.0 if kind == "train" else 1.0  # bwd 2x + remat recompute 1x
    # useful model flops: 6*N_active*D for training, 2*N_active*D forward
    per_tok = 6 if kind == "train" else 2
    model_flops = per_tok * cfg.params_count()[1] * (
        T_head if kind == "train" else T
    )
    return {
        "fwd_flops": fwd,
        "total_flops": fwd * mult,
        "model_flops_6nd": model_flops,
    }


def analytic_hbm_bytes(cfg, shape, n_micro: int = 1) -> float:
    """Estimated HBM traffic per step (global, all chips) — the fallback
    when probe extrapolation is degenerate (negative per-block deltas from
    cross-depth fusion differences).

    train:   params read 3x (fwd + remat-fwd + bwd) + grad write/read (4B)
             + optimizer state r/w + activation traffic
    prefill: params 1x + KV cache write + activations
    decode:  params 1x + full cache read + tiny activations
    """
    total, active = cfg.params_count()
    B, S = shape.global_batch, shape.seq_len
    pbytes = total * 2  # bf16
    act_unit = cfg.d_model * 2
    if shape.kind == "train":
        tokens = B * S
        act = 8 * tokens * act_unit * cfg.n_layers
        grads = total * 4 * 2
        opt = total * 2 * 2
        return 3 * pbytes + grads + opt + act
    if shape.kind == "prefill":
        tokens = B * S
        cache = (2 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd * 2
                 if cfg.n_heads else 0)
        act = 6 * tokens * act_unit * cfg.n_layers
        return pbytes + cache + act
    # decode
    cache = 2 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd * 2
    return pbytes + cache


# --------------------------------------------------------------------------
# query-kernel rooflines: achieved vs peak bandwidth per fused kernel
# --------------------------------------------------------------------------
# Minimum-traffic models for the PR-7 tiled kernel family: each counts the
# bytes a perfect cache would still have to move (every input once, every
# output once).  Achieved GB/s from a wall-clock measurement over these
# bytes is therefore a *lower bound* on true traffic — re-streamed tiles
# only push the real number higher, so peak_fraction is conservative.

def bytes_box_hits_tiled(n: int, nq: int, d: int,
                         box_bytes: int = 4) -> int:
    """(n boxes x nq windows) intersection-mask kernel traffic.

    ``box_bytes=2`` models the compressed bf16-MBB layout — the knob whose
    bandwidth halving this roofline exists to show."""
    return 2 * n * d * box_bytes + 2 * nq * d * 4 + n * nq * 4


def bytes_pair_window_ids(p: int, s: int, d: int) -> int:
    """Fused (query, leaf) pair window scan: per pair one leaf block of
    points + ids + count + one query box in, one id row + count out."""
    per_pair = s * d * 4 + s * 4 + 4 + 2 * d * 4 + s * 4 + 4
    return p * per_pair


def bytes_leaf_mindist_tiled(nq: int, n_l: int, d: int,
                             box_bytes: int = 4) -> int:
    """(nq x L) squared-mindist kernel traffic."""
    return 2 * n_l * d * box_bytes + nq * d * 4 + nq * n_l * 4


def bytes_pair_dist2(p: int, s: int, d: int) -> int:
    """Fused (query, leaf) candidate-distance kernel traffic."""
    per_pair = s * d * 4 + 4 + d * 4 + s * 4
    return p * per_pair


def kernel_roofline(bytes_moved: float, seconds: float,
                    bw: float = HBM_BW) -> dict:
    """Achieved-vs-peak bandwidth for one kernel invocation.

    ``bw`` defaults to the TPU v5e HBM roof; pass a host-measured STREAM
    number when the wall-clock came from the CPU backend (interpret-mode
    Pallas timings are *not* meaningful inputs — measure the compiled
    path)."""
    if seconds <= 0:
        raise ValueError("seconds must be positive")
    achieved = bytes_moved / seconds
    return {
        "bytes": float(bytes_moved),
        "seconds": float(seconds),
        "achieved_gbps": achieved / 1e9,
        "peak_gbps": bw / 1e9,
        "peak_fraction": achieved / bw,
    }


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> dict:
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    bound = max(compute_s, memory_s, collective_s)
    terms["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    return terms
