"""Generate the §Dry-run and §Roofline tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""
from __future__ import annotations

import json
import pathlib

from .. import roofline
from ..configs.base import SHAPES, get_config

DRYRUN = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"
CHIPS = {"16x16": 256, "2x16x16": 512}


def load_cells() -> list[dict]:
    return [json.loads(f.read_text()) for f in sorted(DRYRUN.glob("*.json"))]


def roofline_row(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = CHIPS[rec["mesh"]]
    n_micro = rec.get("n_micro", 1)
    ana = roofline.analytic_flops(cfg, shape, n_micro)
    flops = ana["total_flops"]
    if "probe" in rec:
        # grounded per-block HLO numbers, extrapolated to full depth
        ext = roofline.probe_extrapolate(rec["probe"], cfg.n_blocks)
        hbm = ext["bytes_accessed"] * chips  # probes report per-device
        probe_flops = ext["flops"] * chips
    else:
        hbm = rec["cost_analysis_raw"]["bytes_accessed"] * chips
        probe_flops = rec["cost_analysis_raw"]["flops"] * chips
    if hbm <= 0:
        # cross-depth fusion differences can make the probe delta
        # degenerate; fall back to the analytic traffic model
        hbm = roofline.analytic_hbm_bytes(cfg, shape, n_micro)
    coll = rec["collectives"]["total_bytes"]
    terms = roofline.roofline_terms(flops, hbm, coll, chips)
    model_flops = ana["model_flops_6nd"]
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"].replace("_s", ""),
        "roofline_fraction": terms["roofline_fraction"],
        "flops_analytic": flops,
        "flops_probe": probe_flops,
        "model_flops_6nd": model_flops,
        "useful_ratio": model_flops / flops if flops else 0.0,
        "hbm_bytes": hbm,
        "coll_bytes": coll,
        "temp_gib_dev": rec["memory"]["temp_bytes_per_device"] / 2**30,
        "args_gib_dev": rec["memory"]["argument_bytes_per_device"] / 2**30,
    }


def main():
    cells = load_cells()
    rows = [roofline_row(r) for r in cells if r["mesh"] == "16x16"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| roofline_frac | 6ND/HLO | temp GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| {r['dominant']} | {r['roofline_fraction']:.2f} "
            f"| {r['useful_ratio']:.2f} | {r['temp_gib_dev']:.1f} |"
        )
    print()
    # multi-pod pass summary
    mp = [r for r in cells if r["mesh"] == "2x16x16"]
    print(f"multi-pod (2x16x16) cells passed: {len(mp)}")


if __name__ == "__main__":
    main()
