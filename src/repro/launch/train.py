"""End-to-end training driver.

Runs real steps on the local device(s): data pipeline -> jitted microbatched
train step -> periodic async checkpoints, with crash-safe restart (resumes
from the latest complete snapshot, including pipeline state).  The same
code path the dry-run lowers is executed here for real.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --steps 50 --batch 8 --seq 256 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import all_configs, get_config
from ..data.pipeline import PipelineState, TokenPipeline
from ..models import model as M
from ..models.sharding import axes_for_mesh
from ..train import optimizer as opt_mod
from ..train.checkpoint import CheckpointManager
from ..train.trainer import make_train_step
from .mesh import make_host_mesh, use_mesh


def reduced_config(cfg, *, layers=2, d_model=128, vocab=512):
    """Shrink an arch config to a CPU-trainable size, same family wiring."""
    sb = cfg.superblock
    n_layers = max(layers * sb, sb) + cfg.remainder_layers
    kw = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
        d_ff=d_model * 3,
        vocab=vocab,
        head_dim=d_model // 4,
        dtype="float32",
        chunk_q=64,
        la_chunk=16,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, moe_top_k=min(cfg.moe_top_k, 2),
                  moe_dff=d_model * 3)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2)
    if cfg.family == "rwkv":
        kw.update(rwkv_head_dim=d_model // 4)
    if cfg.attn_every:
        kw.update(mamba_d_state=16, mamba_head_dim=d_model // 4)
    return dataclasses.replace(cfg, **kw)


def build_batch(pipe, cfg, shape_batch, seq):
    b = pipe.global_batch(shape_batch)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    if cfg.encoder_layers:
        rng = np.random.default_rng(0)
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (shape_batch, seq, cfg.d_model)), jnp.float32
        )
    if cfg.frontend == "patch_stub":
        rng = np.random.default_rng(0)
        M.VLM_PATCH_TOKENS = min(M.VLM_PATCH_TOKENS, seq // 4)
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (shape_batch, M.VLM_PATCH_TOKENS, cfg.d_model)),
            jnp.float32,
        )
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=sorted(all_configs()))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the config for CPU execution")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = make_host_mesh()
    axes = axes_for_mesh(mesh)

    opt_name = "adamw"
    optimizer = opt_mod.get_optimizer(opt_name, lr=args.lr)
    step_fn = jax.jit(make_train_step(cfg, axes, optimizer, args.micro))

    pipe = TokenPipeline(cfg.vocab, args.seq, n_shards=1, seed=0)
    mgr = CheckpointManager(args.ckpt_dir)
    start = 0
    restored, extra = mgr.restore()
    with use_mesh(mesh):
        if restored is not None:
            print(f"restored step {extra['step']}")
            params = restored["params"]
            opt_state = restored["opt"]
            start = extra["step"]
            pipe.state = PipelineState.from_dict(extra["pipeline"])
        else:
            params = M.init_params(cfg, jax.random.key(0))
            opt_state = optimizer.init(params)

        losses = []
        for step in range(start, args.steps):
            batch = build_batch(pipe, cfg, args.batch, args.seq)
            t0 = time.time()
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.asarray(step, jnp.int32)
            )
            loss = float(metrics["loss"])
            losses.append(loss)
            print(
                f"step {step:4d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):8.3f} "
                f"{time.time()-t0:6.2f}s",
                flush=True,
            )
            if (step + 1) % args.ckpt_every == 0:
                mgr.save_async(
                    step + 1,
                    {"params": params, "opt": opt_state},
                    extra={"step": step + 1,
                           "pipeline": pipe.state.as_dict()},
                )
        mgr.wait()
    if len(losses) > 2:
        print(f"loss: first {losses[0]:.4f} -> last {losses[-1]:.4f} "
              f"({'DECREASED' if losses[-1] < losses[0] else 'no decrease'})")
    return losses


if __name__ == "__main__":
    main()
