import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

For each cell this proves the distribution config is coherent without
hardware: the jitted step (train / prefill / decode per the shape's kind)
is lowered with ShapeDtypeStruct stand-ins (no allocation), compiled for
the production mesh, and its ``memory_analysis`` / ``cost_analysis`` /
collective schedule are recorded for EXPERIMENTS.md §Dry-run and the
roofline analysis (§Roofline).

Usage:
  python -m repro.launch.dryrun --arch internlm2-20b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --probes   # + roofline probe modules
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs.base import SHAPES, cells, get_config
from ..models import model as M
from ..models.sharding import axes_for_mesh
from ..train import optimizer as opt_mod
from ..train.trainer import make_train_step, pick_microbatches
from .mesh import make_production_mesh, use_mesh

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments"


def _mesh_tag(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


def lower_cell(cfg, shape, mesh, *, probe_blocks: int | None = None,
               extra_cfg: dict | None = None, force_micro: int | None = None):
    """Lower + compile one cell.  Returns (lowered, compiled, meta).

    probe_blocks: if set, builds a depth-reduced UNROLLED variant (the
    roofline probe) with that many superblocks and no remainder layers.
    """
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    if probe_blocks is not None:
        cfg = dataclasses.replace(
            cfg,
            n_layers=probe_blocks * cfg.superblock,
            encoder_layers=min(cfg.encoder_layers, probe_blocks)
            if cfg.encoder_layers else 0,
        )
    axes = axes_for_mesh(mesh)
    params = M.abstract_params(cfg, mesh)
    inputs = M.input_specs(cfg, shape, mesh)
    n_dp = 1
    for a in axes.dp:
        n_dp *= mesh.shape[a]

    with use_mesh(mesh):
        if shape.kind == "train":
            opt_name = opt_mod.pick_for(cfg)
            optimizer = opt_mod.get_optimizer(opt_name)
            opt_state = jax.eval_shape(optimizer.init, params)
            opt_specs = optimizer.state_specs(M.param_pspecs(cfg, axes))
            opt_state = jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
                ),
                opt_state,
                opt_specs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
            n_micro = force_micro or pick_microbatches(cfg, shape, n_dp)
            import jax.numpy as _jnp
            accum = _jnp.bfloat16 if opt_name == "adafactor" else _jnp.float32
            step_fn = make_train_step(cfg, axes, optimizer, n_micro,
                                      accum_dtype=accum)
            step = jax.ShapeDtypeStruct((), jnp.int32)
            # donate params+opt so the update aliases its inputs in place
            lowered = jax.jit(step_fn, donate_argnums=(0, 1)).lower(
                params, opt_state, inputs, step
            )
            meta = {"kind": "train", "optimizer": opt_name,
                    "n_micro": n_micro,
                    "accum_dtype": str(accum.__name__)}
        elif shape.kind == "prefill":
            def prefill_fn(p, b):
                return M.prefill(p, cfg, b, axes)

            lowered = jax.jit(prefill_fn).lower(params, inputs)
            meta = {"kind": "prefill"}
        else:  # decode
            def decode_fn(p, token, cache, pos):
                return M.decode_step(p, cfg, token, cache, pos, axes)

            lowered = jax.jit(decode_fn).lower(
                params, inputs["token"], inputs["cache"], inputs["pos"]
            )
            meta = {"kind": "decode"}
        compiled = lowered.compile()
    return lowered, compiled, meta


def run_cell(cfg, shape, mesh, *, probes: bool = False,
             save: bool = True, extra_cfg: dict | None = None,
             tag: str = "", force_micro: int | None = None) -> dict:
    from .. import roofline

    t0 = time.time()
    lowered, compiled, meta = lower_cell(cfg, shape, mesh,
                                         extra_cfg=extra_cfg,
                                         force_micro=force_micro)
    ma = compiled.memory_analysis()
    ca = roofline.cost_analysis_dict(compiled)
    rec = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": _mesh_tag(mesh),
        **meta,
        "compile_s": round(time.time() - t0, 2),
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
        },
        "cost_analysis_raw": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
    }
    # collective schedule from the compiled HLO (while-body multipliers
    # resolved by the parser)
    txt = compiled.as_text()
    rec["collectives"] = roofline.parse_collectives(txt)
    rec["hlo_ops"] = roofline.op_census(txt)

    if probes:
        probe = {}
        for nb in (1, 2):
            _, c, _ = lower_cell(cfg, shape, mesh, probe_blocks=nb,
                                 extra_cfg=extra_cfg)
            pca = roofline.cost_analysis_dict(c)
            pc = roofline.parse_collectives(c.as_text())
            probe[f"blocks{nb}"] = {
                "flops": pca.get("flops", 0.0),
                "bytes_accessed": pca.get("bytes accessed", 0.0),
                "collective_bytes": pc["total_bytes"],
            }
        rec["probe"] = probe

    if save:
        outdir = RESULTS_DIR / "dryrun"
        outdir.mkdir(parents=True, exist_ok=True)
        name = f"{cfg.name}_{shape.name}_{rec['mesh']}{tag}.json"
        (outdir / name).write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probes", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    args = ap.parse_args()

    meshes = []
    if not args.multi_pod_only:
        meshes.append(make_production_mesh(multi_pod=False))
    if not args.single_pod_only:
        meshes.append(make_production_mesh(multi_pod=True))

    todo = []
    if args.all:
        todo = [(c, s) for c, s, skip in cells() if not skip]
    else:
        todo = [(get_config(args.arch), SHAPES[args.shape])]

    failures = []
    for cfg, shape in todo:
        for mesh in meshes:
            label = f"{cfg.name} x {shape.name} @ {_mesh_tag(mesh)}"
            try:
                probes = args.probes and len(mesh.shape) == 2
                rec = run_cell(cfg, shape, mesh, probes=probes)
                print(
                    f"OK   {label}: compile {rec['compile_s']}s, "
                    f"temp/dev {rec['memory']['temp_bytes_per_device']/2**30:.2f} GiB, "
                    f"args/dev {rec['memory']['argument_bytes_per_device']/2**30:.2f} GiB, "
                    f"coll {rec['collectives']['total_bytes']/2**30:.2f} GiB",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures.append((label, repr(e)))
                print(f"FAIL {label}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for l, e in failures:
            print(" ", l, e)
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
