"""Serving driver: batched greedy generation over any selectable arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.base import all_configs, get_config
from ..models import model as M
from ..serve.engine import LMServer
from .mesh import make_host_mesh, use_mesh
from .train import reduced_config


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b",
                    choices=sorted(all_configs()))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if cfg.encoder_layers or cfg.frontend != "none":
        raise SystemExit(
            "serve driver targets decoder-only archs; use examples/ for "
            "enc-dec and vlm flows"
        )
    mesh = make_host_mesh()
    with use_mesh(mesh):
        params = M.init_params(cfg, jax.random.key(0))
        server = LMServer(cfg, params)
        rng = np.random.default_rng(0)
        prompts = rng.integers(
            0, cfg.vocab, (args.batch, args.prompt_len)
        ).astype(np.int32)
        t0 = time.time()
        out = server.generate(prompts, max_new=args.max_new)
        dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on this host)")
    print("first sequence:", out[0].tolist())
    return out


if __name__ == "__main__":
    main()
