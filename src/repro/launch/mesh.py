"""Production meshes.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches JAX device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across the 0.4 -> 0.7 API drift: newer jax wants
    explicit ``axis_types`` (Auto keeps the legacy sharding semantics),
    jax 0.4 has no such kwarg (Auto is the only behaviour)."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):  # jax 0.4: no AxisType/axis_types
        return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` where it
    exists (jax >= 0.6), the ``Mesh`` object's own context manager (which
    sets the thread-resident mesh ``with_sharding_constraint`` resolves
    PartitionSpecs against) on jax 0.4."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests/examples (uses however many local devices)."""
    return make_mesh((data, model), ("data", "model"))
