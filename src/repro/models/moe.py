"""Mixture-of-Experts FFN: shard_map expert parallelism, sort-based dispatch.

Lesson recorded from the dry-run (EXPERIMENTS.md §Perf): a jit-level
sort/scatter dispatch leaves GSPMD unable to shard the data-dependent
gather/scatter — it replicates the (T*k, D) dispatch buffers and a 235B MoE
prefill explodes to 142 GiB/device of temp.  The fix is explicit SPMD:
``shard_map`` over (dp x tp), where each model-axis rank owns E/tp experts
and dispatches *its own* tokens locally:

  * routing (softmax + top-k) is computed per shard (replicated math across
    tp — negligible next to expert FLOPs);
  * tokens whose expert lives on another rank fall into a sentinel row, so
    every gather/scatter is shard-local with static shapes;
  * partial expert outputs are summed with ``psum`` over the model axis
    (the standard EP combine);
  * dispatch runs in token chunks (lax.scan) to bound live buffers.

Capacity semantics are the usual Switch drop: per chunk, each expert
accepts ``capacity_factor * chunk * k / E`` tokens; overflow falls back to
the residual stream.  Arctic's dense-residual FFN runs outside the
shard_map as a plain (TP-sharded) SwiGLU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import Param, swiglu
from .sharding import ambient_mesh, shard_map_compat

TOKEN_CHUNK = 8192


def moe_defs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_dff or cfg.d_ff, cfg.n_experts
    return {
        "router": Param((d, e), (None, None)),
        "w1": Param((e, d, f), ("tp", "fsdp", None)),
        "w3": Param((e, d, f), ("tp", "fsdp", None)),
        "w2": Param((e, f, d), ("tp", None, "fsdp")),
    }


def capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.moe_top_k / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def _dispatch_chunk(xc, ec, wc, w1, w3, w2, lo, E_l, C, dtype):
    """Shard-local dispatch of one token chunk.

    xc: (T, D); ec/wc: (T, K) expert ids / weights; experts [lo, lo+E_l)
    live here.  Returns (T, D) partial output (zeros for remote experts).
    """
    T, D = xc.shape
    K = ec.shape[1]
    flat_e = ec.reshape(-1)
    flat_w = wc.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), K)
    local = (flat_e >= lo) & (flat_e < lo + E_l)
    fe = jnp.where(local, flat_e - lo, E_l)          # sentinel expert E_l
    order = jnp.argsort(fe)
    sfe, stok, sw = fe[order], tok[order], flat_w[order]
    first = jnp.searchsorted(sfe, jnp.arange(E_l + 1))
    pos = jnp.arange(T * K) - first[sfe]
    drop = (pos >= C) | (sfe == E_l)
    sslot = jnp.where(drop, C, pos)
    buf = jnp.zeros((E_l + 1, C + 1, D), dtype)
    buf = buf.at[sfe, sslot].set(xc[stok])
    buf = buf[:E_l, :C]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w3)
    ob = jnp.einsum("ecf,efd->ecd", h, w2)           # (E_l, C, D)

    ge = jnp.minimum(sfe, E_l - 1)
    gs = jnp.minimum(sslot, C - 1)
    contrib = jnp.where(drop[:, None], 0.0, ob[ge, gs] * sw[:, None])
    return jnp.zeros((T, D), dtype).at[stok].add(contrib)


def moe_ffn(p, cfg, x, axes):
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]
    tp = axes.tp

    mesh = ambient_mesh()
    try:
        n_dp = 1
        for a in (axes.dp if isinstance(axes.dp, tuple) else (axes.dp,)):
            n_dp *= mesh.shape[a]
    except Exception:
        mesh, n_dp = None, 1
    batch_spec = dp if (mesh is not None and B % max(n_dp, 1) == 0) else None

    def body(router, w1, w3, w2, xt):
        E_l = w1.shape[0]
        my = jax.lax.axis_index(tp) * E_l
        Bl, Sl, _ = xt.shape
        T = Bl * Sl
        xf = xt.reshape(T, D)
        gates = jax.nn.softmax(
            xf.astype(jnp.float32) @ router.astype(jnp.float32), axis=-1
        )
        topw, tope = jax.lax.top_k(gates, K)
        topw = (topw / jnp.sum(topw, -1, keepdims=True)).astype(xt.dtype)

        chunk = min(TOKEN_CHUNK, T)
        while T % chunk:
            chunk -= 1
        n_ch = T // chunk
        C = capacity(cfg, chunk)

        if n_ch == 1:
            out = _dispatch_chunk(
                xf, tope, topw, w1, w3, w2, my, E_l, C, xt.dtype
            )
        else:
            def step(_, ins):
                xc, ec, wc = ins
                return 0, _dispatch_chunk(
                    xc, ec, wc, w1, w3, w2, my, E_l, C, xt.dtype
                )

            _, outs = jax.lax.scan(
                step, 0,
                (
                    xf.reshape(n_ch, chunk, D),
                    tope.reshape(n_ch, chunk, K),
                    topw.reshape(n_ch, chunk, K),
                ),
            )
            out = outs.reshape(T, D)
        out = jax.lax.psum(out, tp)  # EP combine across expert shards
        return out.reshape(Bl, Sl, D)

    fn = shard_map_compat(
        body,
        mesh,
        in_specs=(
            P(None, None),        # router: replicated
            P(tp, None, None),    # experts sharded over the model axis
            P(tp, None, None),
            P(tp, None, None),
            P(batch_spec, None, None),
        ),
        out_specs=P(batch_spec, None, None),
    )
    out = fn(p["router"], p["w1"], p["w3"], p["w2"], x)

    if cfg.dense_residual:
        out = out + swiglu(x, p["dense"]["w1"], p["dense"]["w3"],
                           p["dense"]["w2"])
    return out


def aux_loss(p, cfg, x):
    """Load-balancing auxiliary loss (Switch-style f*P)."""
    T = x.shape[0] * x.shape[1]
    gates = jax.nn.softmax(
        x.reshape(T, -1).astype(jnp.float32) @ p["router"].astype(jnp.float32),
        axis=-1,
    )
    _, tope = jax.lax.top_k(gates, cfg.moe_top_k)
    onehot = jax.nn.one_hot(tope, cfg.n_experts).sum(1)  # (T, E)
    f = onehot.mean(0)
    prob = gates.mean(0)
    return cfg.n_experts * jnp.sum(f * prob)
