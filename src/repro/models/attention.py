"""Attention: GQA with RoPE / qk-norm / sliding-window / cross-attention.

Prefill and training run q-chunked (``cfg.chunk_q``): the score matrix is
materialized one query block at a time, so 32k-sequence prefill never builds
an S x S tensor.  Two local-attention execution paths exist:

  * naive  — scores against the full K, sliding-window *masked* (simple,
             wasteful: S/w x more FLOPs at long S);
  * sliced — each q-chunk attends to a dynamic K/V slice of width
             (chunk + window): the compute matches the window exactly.

The naive path is the dry-run baseline; ``local_slice_opt=True`` switches to
the sliced path (one of the hillclimb optimizations in EXPERIMENTS.md §Perf).

Decode attends a single token against the cache; local layers keep a ring
buffer of ``window`` positions, global layers the full sequence (sharded
over the 'model' axis on the sequence dim when kv-heads < tp shards —
flash-decoding-style partial softmax, reduced by XLA collectives).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Param, rms_norm, rope
from .sharding import constrain

NEG = -2.0e38


def attn_defs(cfg, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    defs = {
        "wq": Param((d, cfg.n_heads * hd), ("fsdp", "tp")),
        "wk": Param((d, cfg.n_kv_heads * hd), ("fsdp", "tp")),
        "wv": Param((d, cfg.n_kv_heads * hd), ("fsdp", "tp")),
        "wo": Param((cfg.n_heads * hd, d), ("tp", "fsdp")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = Param((hd,), (None,), init="ones")
        defs["k_norm"] = Param((hd,), (None,), init="ones")
    return defs


def _project_qkv(p, cfg, xq, xkv, pos_q, pos_kv, axes, use_rope=True):
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    hd = cfg.hd
    q = (xq @ p["wq"]).reshape(B, Sq, cfg.n_heads, hd)
    k = (xkv @ p["wk"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    v = (xkv @ p["wv"]).reshape(B, Skv, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, pos_q, cfg.rope_theta)
        k = rope(k, pos_kv, cfg.rope_theta)
    q = constrain(q, axes, ("fsdp", None, "tp", None))
    k = constrain(k, axes, ("fsdp", None, None, None))
    v = constrain(v, axes, ("fsdp", None, None, None))
    return q, k, v


def _sdpa_block(q, k, v, mask, cfg, axes=None):
    """(B, cq, H, hd) x (B, Skv, Hk, hd) -> (B, cq, H, hd).

    KV heads are repeated to the full head count before the score einsum so
    the flat head dimension stays 'tp'-sharded — reshaping H into (Hk, rep)
    breaks GSPMD propagation and silently replicates the score tensor (a
    142 GiB/device lesson from the dry-run; see EXPERIMENTS.md §Perf)."""
    B, cq, H, hd = q.shape
    Hk = k.shape[2]
    rep = H // Hk
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if axes is not None:
        k = constrain(k, axes, ("fsdp", None, "tp", None))
        v = constrain(v, axes, ("fsdp", None, "tp", None))
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) / (hd ** 0.5)
    if axes is not None:
        scores = constrain(scores, axes, ("fsdp", "tp", None, None))
    scores = jnp.where(mask[:, None], scores, NEG)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return out


def attention(p, cfg, x, axes, *, causal=True, window=0, positions=None):
    """Full-sequence (train/prefill) attention, q-chunked.

    Returns (out (B,S,D), k, v) so callers can stash the KV cache."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, x, positions, positions, axes)
    cq = min(cfg.chunk_q, S)
    while S % cq:  # largest divisor of S not exceeding chunk_q
        cq -= 1
    n_chunks = S // cq
    sliced = window and getattr(cfg, "local_slice_opt", False) and S > window

    def chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        pos_q = i * cq + jnp.arange(cq)
        if sliced:
            # K/V slice [chunk_start - window, chunk_end)
            start = jnp.maximum(i * cq - window, 0)
            width = cq + window
            ks = jax.lax.dynamic_slice_in_dim(k, start, width, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, width, axis=1)
            pos_k = start + jnp.arange(width)
        else:
            ks, vs = k, v
            pos_k = jnp.arange(S)
        mask = jnp.ones((1, cq, pos_k.shape[0]), bool)
        if causal:
            mask &= pos_q[None, :, None] >= pos_k[None, None, :]
        if window:
            mask &= pos_q[None, :, None] - pos_k[None, None, :] < window
        return _sdpa_block(qs, ks, vs, mask, cfg, axes)

    if n_chunks <= 1:
        out = chunk(0)
    else:
        outs = jax.lax.map(chunk, jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, cfg.n_heads, cfg.hd)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"], k, v


def decode_attention(p, cfg, x, cache_k, cache_v, pos, axes, *, window=0):
    """One-token decode against a cache.

    cache_k/v: (B, S_cache, Hk, hd) — ring buffer if ``window`` (S_cache ==
    window), else the full context.  ``pos``: (B,) current positions.
    Returns (out (B,1,D), new_k, new_v)."""
    B = x.shape[0]
    S_cache = cache_k.shape[1]
    q, k1, v1 = _project_qkv(
        p, cfg, x, x, pos[:, None], pos[:, None], axes
    )
    slot = (pos % S_cache) if window else pos
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k1[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v1[:, 0])
    kpos = jnp.arange(S_cache)[None, :]
    if window:
        # ring buffer: entry age = pos - stored position; compute stored pos
        stored = pos[:, None] - ((pos[:, None] - kpos) % S_cache)
        valid = (stored >= 0) & (stored <= pos[:, None])
        # rope was applied at the true positions when entries were written
        mask = valid
    else:
        mask = kpos <= pos[:, None]
    out = _sdpa_block(q, cache_k, cache_v, mask[:, None, :], cfg, axes)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    return out @ p["wo"], cache_k, cache_v


def cross_attention(p, cfg, x, enc_k, enc_v, axes):
    """Decoder cross-attention against precomputed encoder K/V."""
    B, Sq, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, Sq, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    mask = jnp.ones((1, Sq, enc_k.shape[1]), bool)
    out = _sdpa_block(q, enc_k, enc_v, mask, cfg, axes)
    out = out.reshape(B, Sq, cfg.n_heads * hd)
    return out @ p["wo"]


def encode_kv(p, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    B, S, _ = enc_out.shape
    hd = cfg.hd
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v
