"""Mamba block in the SSD (Mamba-2 style) form, for Jamba's hybrid layers.

TPU-native adaptation (DESIGN.md): Mamba-1's per-channel decay makes the
chunked matmul form materialize per-position state tensors, which maps
poorly onto the MXU; the SSD reformulation (scalar decay per head per step)
admits exactly the chunked GLA execution used for RWKV6, so both hybrids
share one well-tested engine.  Structure kept from Mamba: in-projection to
(x, z) with expansion, causal depthwise conv on x, data-dependent (dt, B, C)
heads, D skip connection, and SiLU(z) gating.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Param
from .linear_attn import bounded_log_decay, chunked_gla, gla_decode
from .sharding import constrain

CONV_K = 4


def mamba_defs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    hd = cfg.mamba_head_dim
    H = di // hd
    N = cfg.mamba_d_state
    return {
        "in_proj": Param((d, 2 * di), ("fsdp", "tp")),     # x, z
        "conv_w": Param((CONV_K, di), (None, "tp"), scale=0.5),
        "wB": Param((d, H * N), ("fsdp", "tp")),
        "wC": Param((d, H * N), ("fsdp", "tp")),
        "w_dt": Param((d, H), ("fsdp", "tp")),
        "dt_bias": Param((H,), (None,), init="zeros"),
        "D": Param((H,), (None,), init="ones"),
        "out_proj": Param((di, d), ("tp", "fsdp")),
    }


def _causal_conv(x, w, prev=None):
    """Depthwise causal conv1d: x (B,S,di), w (K,di), prev (B,K-1,di)."""
    B, S, di = x.shape
    if prev is None:
        prev = jnp.zeros((B, CONV_K - 1, di), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(
        xp[:, k : k + S] * w[k] for k in range(CONV_K)
    )
    return jax.nn.silu(out), xp[:, -(CONV_K - 1) :]


def mamba_mix(p, cfg, x, axes, *, conv_prev=None, state0=None):
    """(B,S,D) -> (B,S,D); returns (out, new_conv_state, final_gla_state)."""
    B, S, D = x.shape
    di = cfg.mamba_expand * D
    hd = cfg.mamba_head_dim
    H = di // hd
    N = cfg.mamba_d_state
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    xin = constrain(xin, axes, ("fsdp", None, "tp"))
    xin, conv_state = _causal_conv(xin, p["conv_w"], conv_prev)
    Bm = (x @ p["wB"]).reshape(B, S, H, N)     # "k"
    Cm = (x @ p["wC"]).reshape(B, S, H, N)     # "r"
    v = xin.reshape(B, S, H, hd)               # "v"
    dt = (x @ p["w_dt"]) + p["dt_bias"]
    log_a = bounded_log_decay(dt).reshape(B, S, H, 1)  # scalar decay per head
    y, state = chunked_gla(
        Cm, Bm, v, log_a, chunk=min(cfg.la_chunk, S), state0=state0,
        axes=axes,
    )
    y = y + p["D"][None, None, :, None] * v    # skip
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    return y @ p["out_proj"], conv_state, state


def mamba_mix_decode(p, cfg, x1, conv_prev, state):
    """One token: x1 (B,D).  Returns (out, new_conv_prev, new_state)."""
    B, D = x1.shape
    di = cfg.mamba_expand * D
    hd = cfg.mamba_head_dim
    H = di // hd
    N = cfg.mamba_d_state
    xz = x1 @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    xp = jnp.concatenate([conv_prev, xin[:, None]], axis=1)  # (B, K, di)
    xin = jax.nn.silu(sum(xp[:, k] * p["conv_w"][k] for k in range(CONV_K)))
    Bm = (x1 @ p["wB"]).reshape(B, H, N)
    Cm = (x1 @ p["wC"]).reshape(B, H, N)
    v = xin.reshape(B, H, hd)
    dt = (x1 @ p["w_dt"]) + p["dt_bias"]
    log_a = bounded_log_decay(dt).reshape(B, H, 1)
    y, state = gla_decode(Cm, Bm, v, log_a, state)
    y = y + p["D"][None, :, None] * v
    y = y.reshape(B, di) * jax.nn.silu(z)
    return y @ p["out_proj"], xp[:, 1:], state
