"""Chunked gated linear attention — the shared engine for RWKV6 and Mamba.

Both sequence mixers obey the same matrix-state recurrence per head

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: dk x dv)
    y_t = r_t S_{t-1} (+ bonus (r_t . (u*k_t)) v_t   [RWKV6 only])

with w_t in (0,1): per-channel data-dependent decay for RWKV6 (Finch),
per-head scalar decay for the Mamba SSD form.  The TPU-native execution is
the chunked (block-parallel) form (GLA / Mamba-2 style):

  * within a chunk of length c, decays become cumulative products A_t
    (log-space cumsum) and the intra-chunk contribution is a (c x c) masked
    matmul — MXU work, no recurrence;
  * across chunks, the state carry is a (dk x dv) linear recurrence solved
    with ``jax.lax.associative_scan`` (log-depth, counted HLO — no opaque
    while loop).

Numeric-range adaptation (documented in DESIGN.md): log-decay is bounded to
[-LOG_DECAY_BOUND, 0) via a sigmoid so that within-chunk 1/A factors stay
inside float32 range (exp(c * bound) <= e^80 for c = 32).  The decode path
uses the exact recurrence (one einsum per token) and matches the chunked
form bit-for-bit in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LOG_DECAY_BOUND = 2.5


def bounded_log_decay(raw):
    """Map raw decay logits to log w in (-LOG_DECAY_BOUND, 0)."""
    return -LOG_DECAY_BOUND * jax.nn.sigmoid(raw.astype(jnp.float32))


def chunked_gla(r, k, v, log_w, *, chunk: int, u=None, state0=None,
                axes=None):
    """Chunked gated linear attention.

    r, k: (B, S, H, dk); v: (B, S, H, dv); log_w: (B, S, H, dk) or
    (B, S, H, 1) [scalar decay]; u: (H, dk) RWKV6 bonus or None.
    Returns (y (B,S,H,dv), final_state (B,H,dk,dv))."""
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    n = S // chunk
    f32 = jnp.float32

    def shard(x):  # keep the head dim 'tp'-sharded through the chunk math
        if axes is None:
            return x
        from .sharding import constrain

        return constrain(x, axes, ("fsdp", None, None, "tp", None))

    rc = shard(r.reshape(B, n, chunk, H, dk).astype(f32))
    kc = shard(k.reshape(B, n, chunk, H, dk).astype(f32))
    vc = shard(v.reshape(B, n, chunk, H, dv).astype(f32))
    lw = shard(log_w.reshape(B, n, chunk, H, log_w.shape[-1]).astype(f32))

    la_inc = jnp.cumsum(lw, axis=2)               # inclusive log cumprod
    la_exc = la_inc - lw                          # exclusive
    a_last = la_inc[:, :, -1]                     # (B, n, H, dkw)

    rq = rc * jnp.exp(la_exc)                     # r_t * A_{t-1}
    ks = kc * jnp.exp(-la_inc)                    # k_s / A_s
    kl = kc * jnp.exp(a_last[:, :, None] - la_inc)  # k_s * A_last / A_s

    # intra-chunk: strict lower-triangular (s < t) attention matmul
    scores = jnp.einsum("bnthd,bnshd->bnhts", rq, ks)
    if axes is not None:
        from .sharding import constrain

        scores = constrain(scores, axes, ("fsdp", None, "tp", None, None))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    y_intra = jnp.einsum("bnhts,bnshv->bnthv", scores, vc)
    if u is not None:  # RWKV6 bonus: current token, weighted by u
        bonus = jnp.einsum(
            "bnthd,hd,bnthd->bnth", rc, u.astype(f32), kc
        )
        y_intra = y_intra + bonus[..., None] * vc

    # per-chunk state contribution and decay
    b_chunk = jnp.einsum("bnshd,bnshv->bnhdv", kl, vc)  # (B,n,H,dk,dv)
    a_chunk = jnp.exp(a_last)                           # (B,n,H,dkw)
    if a_chunk.shape[-1] == 1:
        a_chunk = jnp.broadcast_to(a_chunk, a_chunk.shape[:-1] + (dk,))

    # inter-chunk: associative scan of S_i = diag(a_i) S_{i-1} + B_i
    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r[..., None] * b_l + b_r

    a_scan, b_scan = jax.lax.associative_scan(
        combine, (a_chunk, b_chunk), axis=1
    )
    if state0 is None:
        state0 = jnp.zeros((B, H, dk, dv), f32)
    # state entering chunk i = scanned state of chunks [0..i-1] + decayed S0
    a_all = jnp.concatenate(
        [jnp.ones_like(a_scan[:, :1]), a_scan], axis=1
    )  # cumulative decay up to chunk i (exclusive at index i)
    b_all = jnp.concatenate([jnp.zeros_like(b_scan[:, :1]), b_scan], axis=1)
    s_in = a_all[..., None] * state0[:, None] + b_all  # (B, n+1, H, dk, dv)
    y_inter = jnp.einsum(
        "bnthd,bnhdv->bnthv", rc * jnp.exp(la_exc), s_in[:, :-1]
    )
    y = (y_intra + y_inter).reshape(B, S, H, dv)
    return y.astype(r.dtype), s_in[:, -1]


def gla_decode(r, k, v, log_w, state, u=None):
    """Exact single-token recurrence.

    r, k: (B, H, dk); v: (B, H, dv); log_w: (B, H, dk|1);
    state: (B, H, dk, dv).  Returns (y (B,H,dv), new_state)."""
    f32 = jnp.float32
    r32, k32, v32 = r.astype(f32), k.astype(f32), v.astype(f32)
    w = jnp.exp(log_w.astype(f32))
    y = jnp.einsum("bhd,bhdv->bhv", r32, state)
    if u is not None:
        y = y + jnp.einsum("bhd,hd,bhd->bh", r32, u.astype(f32), k32)[
            ..., None
        ] * v32
    new_state = w[..., None] * state + k32[..., :, None] * v32[..., None, :]
    return y.astype(r.dtype), new_state


def gla_reference(r, k, v, log_w, *, u=None, state0=None):
    """Naive sequential oracle (tests): step-by-step recurrence."""
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    state = (
        jnp.zeros((B, H, dk, dv), jnp.float32) if state0 is None else state0
    )
    ys = []
    for t in range(S):
        y, state = gla_decode(
            r[:, t], k[:, t], v[:, t], log_w[:, t], state, u=u
        )
        ys.append(y)
    return jnp.stack(ys, axis=1), state
