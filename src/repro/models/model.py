"""Model facade: parameter trees, forwards, loss, and serve steps.

Public API (everything the launcher / trainer / server needs):

  model_defs(cfg)            Param-descriptor tree (single source of truth)
  init_params(cfg, key)      real parameters (smoke tests / examples)
  abstract_params(cfg, mesh) ShapeDtypeStructs + NamedShardings (dry-run)
  param_pspecs(cfg, axes)    PartitionSpec tree
  forward(...)               logits for a token/embedding batch
  loss_fn(...)               causal-LM loss (+ MoE aux)
  make_prefill / make_decode serve steps with cache pytrees
  input_specs(cfg, shape, mesh)  ShapeDtypeStruct stand-ins per cell
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from . import transformer as tfm
from .layers import (embed_defs, init_tree, logits as logits_fn,
                     mask_padded_vocab, shape_tree, spec_tree)
from .sharding import (MeshAxes, axes_for_mesh, constrain,
                       safe_named_sharding, shape_safe_spec)

# fraction of the sequence that is patch/frame stub input for vlm / encdec
VLM_PATCH_TOKENS = 256
ENCDEC_DECODER_FRACTION = 8  # decoder seq = seq_len // 8


def model_defs(cfg) -> dict:
    defs = {"embed": embed_defs(cfg)}
    cross = cfg.encoder_layers > 0
    defs["blocks"] = tfm.stack_defs(
        tfm.superblock_defs(cfg, cross=cross), cfg.n_blocks
    )
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    for i in range(cfg.remainder_layers):
        li = cfg.n_blocks * cfg.superblock + i
        defs[f"rem{i}"] = tfm.block_defs(
            cfg, kinds[li % cfg.superblock], ffns[li % cfg.superblock],
            cross=cross,
        )
    if cfg.encoder_layers:
        defs["encoder"] = tfm.stack_defs(
            tfm.block_defs(cfg, "attn", "dense"), cfg.encoder_layers
        )
    if cfg.frontend == "patch_stub":
        # frozen projection standing in for the ViT output head
        from .layers import Param

        defs["patch_proj"] = Param(
            (cfg.d_model, cfg.d_model), ("fsdp", None)
        )
    return defs


def init_params(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return init_tree(model_defs(cfg), key, dtype)


def param_pspecs(cfg, axes: MeshAxes):
    return spec_tree(model_defs(cfg), axes)


def abstract_params(cfg, mesh, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    axes = axes_for_mesh(mesh)
    shapes = shape_tree(model_defs(cfg), dtype)
    specs = param_pspecs(cfg, axes)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, shape_safe_spec(mesh, p, s.shape)),
        ),
        shapes,
        specs,
    )


# --------------------------------------------------------------------------
# forwards
# --------------------------------------------------------------------------
def embed_tokens(params, cfg, tokens, axes):
    x = params["embed"]["tok"][tokens]
    x = constrain(x, axes, ("fsdp", None, None))
    return x.astype(jnp.dtype(cfg.dtype))


def encoder_forward(params, cfg, frames, axes):
    """Encoder stack over stub frame embeddings (B, S_enc, D)."""
    def body(carry, pblk):
        y, _ = tfm.apply_block(
            pblk, cfg, "attn", "dense", carry, axes, "train", None, None,
            causal=False,
        )
        return y, None

    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, frames.astype(jnp.dtype(cfg.dtype)),
                        params["encoder"])
    return x


def forward(params, cfg, batch, axes, mode="train", cache=None, pos=None):
    """Token/embedding batch -> (logits, new_cache).

    batch keys: 'tokens' (B,S); vlm adds 'patch_embeds' (B,P,D); encdec adds
    'frames' (B,S_enc,D).  decode mode: tokens is (B,1), pos (B,)."""
    enc_out = None
    if cfg.encoder_layers and mode != "decode":
        enc_out = encoder_forward(params, cfg, batch["frames"], axes)
    x = embed_tokens(params, cfg, batch["tokens"], axes)
    if cfg.frontend == "patch_stub" and mode != "decode":
        patches = batch["patch_embeds"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    x, new_cache = tfm.run_stack(
        params, cfg, x, axes, mode, cache=cache, pos=pos, enc_out=enc_out
    )
    if mode == "prefill":
        x = x[:, -1:]  # only the last position feeds the first decode step
    out = logits_fn(x, params["embed"], cfg)
    return out, new_cache


def hidden_forward(params, cfg, batch, axes, mode="train"):
    """Forward up to final hidden states (no logits) — training path."""
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encoder_forward(params, cfg, batch["frames"], axes)
    x = embed_tokens(params, cfg, batch["tokens"], axes)
    if cfg.frontend == "patch_stub":
        patches = batch["patch_embeds"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    x, _ = tfm.run_stack(params, cfg, x, axes, mode, enc_out=enc_out)
    return x


LOSS_CHUNK = 2048  # tokens per loss chunk (bounds the f32 logits buffer)


def loss_fn(params, cfg, batch, axes):
    """Next-token cross entropy, computed in sequence chunks so the float32
    logits buffer never exceeds LOSS_CHUNK x vocab per batch row (a 262k
    vocab at 32k tokens/device would otherwise dominate HBM)."""
    x = hidden_forward(params, cfg, batch, axes)
    labels = batch["labels"]
    if cfg.frontend == "patch_stub":
        x = x[:, -labels.shape[1]:]  # loss only over token positions
    x = rms_norm_final(x, params, cfg)
    w = (params["embed"]["tok"].T if cfg.tied_embeddings
         else params["embed"]["out"])
    B, S, D = x.shape
    xs = x[:, :-1]
    tgt = labels[:, 1:]
    n_tok = S - 1
    chunk = min(LOSS_CHUNK, n_tok)
    while n_tok % chunk:
        chunk -= 1
    n_chunks = n_tok // chunk

    def body(acc, ins):
        xc, tc = ins  # (B, chunk, D), (B, chunk)
        lg = (xc @ w).astype(jnp.float32)
        lg = mask_padded_vocab(cfg, lg)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    xs_c = xs.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    tgt_c = tgt.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        jnp.zeros((), jnp.float32), (xs_c, tgt_c),
    )
    return total / (B * n_tok)


def rms_norm_final(x, params, cfg):
    from .layers import rms_norm

    return rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------
def make_cache_struct(cfg, batch: int, cache_len: int, mesh=None,
                      cross_len: int = 0, materialize: bool = False):
    """Cache pytree as ShapeDtypeStructs (dry-run) or zeros (tests)."""
    axes = axes_for_mesh(mesh) if mesh is not None else MeshAxes()
    defs = tfm.cache_defs(cfg, batch, cache_len, cross_len)

    def is_slot(x):
        return (
            isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
        )

    def walk(node, name=""):
        if is_slot(node):
            shape, logical = node
            # recurrent matrix states accumulate: keep them float32
            dtype = jnp.float32 if name == "state" else jnp.dtype(cfg.dtype)
            if mesh is not None:
                sh = safe_named_sharding(mesh, axes, logical, shape)
                return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)
            if materialize:
                return jnp.zeros(shape, dtype)
            return jax.ShapeDtypeStruct(shape, dtype)
        return {k: walk(v, k) for k, v in node.items()}

    return walk(defs)


def prefill(params, cfg, batch, axes):
    """Forward + cache construction.  Returns (last-token logits, cache)."""
    lg, cache = forward(params, cfg, batch, axes, mode="prefill")
    return lg[:, -1:], cache


def decode_step(params, cfg, token, cache, pos, axes):
    """One-token decode: token (B,1) int32, pos (B,) int32."""
    lg, cache = forward(
        params, cfg, {"tokens": token}, axes, mode="decode", cache=cache,
        pos=pos,
    )
    return lg, cache


# --------------------------------------------------------------------------
# input specs per (arch x shape) cell — ShapeDtypeStruct stand-ins
# --------------------------------------------------------------------------
def input_specs(cfg, shape, mesh, *, for_train: bool | None = None):
    """Dry-run inputs for a cell; weak-type-correct, shardable, no alloc."""
    axes = axes_for_mesh(mesh)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct(
            (b, s), i32,
            sharding=safe_named_sharding(mesh, axes, ("fsdp", None), (b, s)),
        )

    def emb(b, s):
        return jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=safe_named_sharding(
                mesh, axes, ("fsdp", None, None), (b, s, cfg.d_model)
            ),
        )

    kind = shape.kind
    if kind == "train":
        if cfg.encoder_layers:
            sd = S // ENCDEC_DECODER_FRACTION
            return {"frames": emb(B, S), "tokens": tok(B, sd),
                    "labels": tok(B, sd)}
        if cfg.frontend == "patch_stub":
            st = S - VLM_PATCH_TOKENS
            return {"patch_embeds": emb(B, VLM_PATCH_TOKENS),
                    "tokens": tok(B, st), "labels": tok(B, st)}
        return {"tokens": tok(B, S), "labels": tok(B, S)}
    if kind == "prefill":
        if cfg.encoder_layers:
            sd = S // ENCDEC_DECODER_FRACTION
            return {"frames": emb(B, S), "tokens": tok(B, sd)}
        if cfg.frontend == "patch_stub":
            return {"patch_embeds": emb(B, VLM_PATCH_TOKENS),
                    "tokens": tok(B, S - VLM_PATCH_TOKENS)}
        return {"tokens": tok(B, S)}
    # decode: one new token against a seq_len cache
    cross = S // ENCDEC_DECODER_FRACTION if cfg.encoder_layers else 0
    cache = make_cache_struct(cfg, B, S, mesh, cross_len=cross)
    return {
        "token": tok(B, 1),
        "pos": jax.ShapeDtypeStruct(
            (B,), i32,
            sharding=safe_named_sharding(mesh, axes, ("fsdp",), (B,)),
        ),
        "cache": cache,
    }
