"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Faithful structure: token-shift interpolation feeds r/k/v/g/w projections;
the decay w_t is *data-dependent* per channel (the defining RWKV6 feature),
produced by a low-rank (LoRA) head and bounded via ``bounded_log_decay``
(TPU float32-range adaptation, DESIGN.md).  The current-token bonus ``u``
follows the RWKV "time-first" term.  Sequence execution uses the chunked
GLA engine; decode carries (token-shift state, matrix state) exactly.

Simplification recorded in DESIGN.md: token-shift mixing coefficients are
learned per-channel constants (RWKV5-style) rather than LoRA-dynamic; the
data-dependence is kept where it defines Finch — the decay.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Param, rms_norm
from .linear_attn import bounded_log_decay, chunked_gla, gla_decode
from .sharding import constrain

DECAY_LORA = 64


def rwkv_tm_defs(cfg) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    return {
        "mix": Param((5, d), (None, None), init="zeros"),  # r,k,v,g,w shifts
        "wr": Param((d, d), ("fsdp", "tp")),
        "wk": Param((d, d), ("fsdp", "tp")),
        "wv": Param((d, d), ("fsdp", "tp")),
        "wg": Param((d, d), ("fsdp", "tp")),
        "wo": Param((d, d), ("tp", "fsdp")),
        "w0": Param((d,), (None,), init="zeros"),
        "w_lora_a": Param((d, DECAY_LORA), ("fsdp", None)),
        "w_lora_b": Param((DECAY_LORA, d), (None, "fsdp")),
        "u": Param((h, cfg.rwkv_head_dim), (None, None), init="zeros"),
        "ln_out": Param((d,), (None,), init="ones"),
    }


def rwkv_cm_defs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mix": Param((2, d), (None, None), init="zeros"),  # k, r shifts
        "wk": Param((d, f), ("fsdp", "tp")),
        "wv": Param((f, d), ("tp", "fsdp")),
        "wr": Param((d, d), ("fsdp", None)),
    }


def _token_shift(x, prev):
    """x_{t-1} stream: (B,S,D) shifted right, position 0 <- prev (B,D)."""
    shifted = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    return shifted


def _mix(x, shifted, mu):
    return x + (shifted - x) * jax.nn.sigmoid(mu)


def time_mix(p, cfg, x, axes, *, prev=None, state0=None):
    """(B,S,D) -> (B,S,D); returns (out, last_x, final_state)."""
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    prev = jnp.zeros((B, D), x.dtype) if prev is None else prev
    xs = _token_shift(x, prev)
    xr = _mix(x, xs, p["mix"][0])
    xk = _mix(x, xs, p["mix"][1])
    xv = _mix(x, xs, p["mix"][2])
    xg = _mix(x, xs, p["mix"][3])
    xw = _mix(x, xs, p["mix"][4])
    r = (xr @ p["wr"]).reshape(B, S, H, hd)
    k = (xk @ p["wk"]).reshape(B, S, H, hd)
    v = (xv @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (Finch): w0 + LoRA(x_w), bounded log-space
    w_raw = p["w0"] + (xw @ p["w_lora_a"]) @ p["w_lora_b"]
    log_w = bounded_log_decay(w_raw).reshape(B, S, H, hd)
    r = constrain(r, axes, ("fsdp", None, "tp", None))
    k = constrain(k, axes, ("fsdp", None, "tp", None))
    y, state = chunked_gla(
        r, k, v, log_w, chunk=min(cfg.la_chunk, S), u=p["u"], state0=state0,
        axes=axes,
    )
    y = rms_norm(y, jnp.ones((hd,), y.dtype), cfg.norm_eps)  # per-head norm
    y = y.reshape(B, S, D) * g
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    return y @ p["wo"], x[:, -1], state


def time_mix_decode(p, cfg, x1, prev, state):
    """One token: x1 (B,D).  Returns (out (B,D), new_prev, new_state)."""
    B, D = x1.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    xr = x1 + (prev - x1) * jax.nn.sigmoid(p["mix"][0])
    xk = x1 + (prev - x1) * jax.nn.sigmoid(p["mix"][1])
    xv = x1 + (prev - x1) * jax.nn.sigmoid(p["mix"][2])
    xg = x1 + (prev - x1) * jax.nn.sigmoid(p["mix"][3])
    xw = x1 + (prev - x1) * jax.nn.sigmoid(p["mix"][4])
    r = (xr @ p["wr"]).reshape(B, H, hd)
    k = (xk @ p["wk"]).reshape(B, H, hd)
    v = (xv @ p["wv"]).reshape(B, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w_raw = p["w0"] + (xw @ p["w_lora_a"]) @ p["w_lora_b"]
    log_w = bounded_log_decay(w_raw).reshape(B, H, hd)
    y, state = gla_decode(r, k, v, log_w, state, u=p["u"])
    y = rms_norm(y, jnp.ones((hd,), y.dtype), cfg.norm_eps)
    y = y.reshape(B, D) * g
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    return y @ p["wo"], x1, state


def channel_mix(p, cfg, x, *, prev=None):
    B, S, D = x.shape
    prev = jnp.zeros((B, D), x.dtype) if prev is None else prev
    xs = _token_shift(x, prev)
    xk = _mix(x, xs, p["mix"][0])
    xr = _mix(x, xs, p["mix"][1])
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1]


def channel_mix_decode(p, cfg, x1, prev):
    xk = x1 + (prev - x1) * jax.nn.sigmoid(p["mix"][0])
    xr = x1 + (prev - x1) * jax.nn.sigmoid(p["mix"][1])
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x1
