"""Logical-axis sharding: maps layer-semantic axes onto the mesh.

Logical names used by parameter/activation definitions:
  'fsdp'  -> the data-parallel axes (('pod','data') multi-pod, ('data',)
             single-pod): ZeRO-3 style parameter sharding
  'tp'    -> the tensor-parallel 'model' axis (heads / d_ff / experts)
  'seq'   -> sequence sharding for long-context decode caches
  None    -> replicated
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: tuple = ("data",)
    tp: str = "model"

    def resolve(self, logical) -> P:
        out = []
        for name in logical:
            if name == "fsdp":
                out.append(self.dp if len(self.dp) > 1 else self.dp[0])
            elif name == "tp":
                out.append(self.tp)
            elif name == "seq":
                out.append(self.tp)  # decode caches: shard sequence over tp
            elif name == "dp+tp":
                out.append(tuple(self.dp) + (self.tp,))
            elif name is None:
                out.append(None)
            else:
                raise ValueError(f"unknown logical axis {name!r}")
        return P(*out)

    def batch(self) -> P:
        return P(self.dp if len(self.dp) > 1 else self.dp[0])


def ambient_mesh():
    """The mesh the caller activated, across the jax 0.4 -> 0.7 API drift:
    ``jax.sharding.get_abstract_mesh()`` under ``jax.set_mesh``, the
    thread-resident physical mesh under the jax-0.4 ``with mesh:`` context.
    Returns None when no mesh is active."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except AttributeError:
        pass
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` where it exists; the experimental namespace (with
    the replication check off — jax 0.4's checker rejects valid psum
    patterns) otherwise.  ``mesh`` must be the active mesh."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as sm_old

    return sm_old(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def axes_for_mesh(mesh) -> MeshAxes:
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    return MeshAxes(dp=dp or ("data",), tp="model")


def constrain(x, axes: MeshAxes, logical):
    """with_sharding_constraint by logical names (no-op without a mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, axes.resolve(logical))
    except (ValueError, RuntimeError):
        return x


def named_sharding(mesh, axes: MeshAxes, logical) -> NamedSharding:
    return NamedSharding(mesh, axes.resolve(logical))


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def shape_safe_spec(mesh, spec: P, shape) -> P:
    """Drop spec axes that do not evenly divide the dimension (jit input
    shardings require even tiling; e.g. batch=1 long-context decode leaves
    the data axis idle, odd vocabs fall back to replicated)."""
    out = []
    for entry, dim in zip(tuple(spec), shape):
        out.append(entry if dim % _axis_size(mesh, entry) == 0 else None)
    return P(*out)


def safe_named_sharding(mesh, axes: MeshAxes, logical, shape) -> NamedSharding:
    return NamedSharding(mesh, shape_safe_spec(mesh, axes.resolve(logical), shape))
