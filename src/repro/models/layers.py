"""Parameter definitions and basic layers (norms, rope, MLP, embeddings).

Parameters are declared once as ``Param`` descriptors (shape + logical
sharding axes + init scale); the same tree drives real initialization,
``eval_shape`` dry-run structs, and PartitionSpec extraction — one source of
truth for structure and sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .sharding import MeshAxes


@dataclasses.dataclass(frozen=True)
class Param:
    shape: tuple
    logical: tuple            # logical sharding axes, len == ndim
    init: str = "normal"      # normal | zeros | ones
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)

    def materialize(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = self.scale if self.scale is not None else fan_in ** -0.5
        return (jax.random.normal(key, self.shape) * scale).astype(dtype)


def tree_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from tree_paths(v, prefix + (k,))
    else:
        yield prefix, tree


def init_tree(defs, key, dtype):
    """Materialize a Param-descriptor tree into arrays (per-leaf fold_in)."""
    leaves = list(tree_paths(defs))
    out = {}
    for i, (path, p) in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        node = out
        for seg in path[:-1]:
            node = node.setdefault(seg, {})
        node[path[-1]] = p.materialize(k, dtype)
    return out


def spec_tree(defs, axes: MeshAxes):
    """Same-structure tree of PartitionSpecs."""
    return jax.tree.map(
        lambda p: axes.resolve(p.logical),
        defs,
        is_leaf=lambda x: isinstance(x, Param),
    )


def shape_tree(defs, dtype):
    """Same-structure tree of ShapeDtypeStructs (no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
        defs,
        is_leaf=lambda x: isinstance(x, Param),
    )


# --------------------------------------------------------------------------
# layers
# --------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x, positions, theta: float = 1e4):
    """Rotary embedding over the last dim of (..., seq, heads, hd)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x, w1, w3, w2):
    """Gated MLP: (silu(x w1) * (x w3)) w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def mlp_defs(d: int, f: int) -> dict:
    return {
        "w1": Param((d, f), ("fsdp", "tp")),
        "w3": Param((d, f), ("fsdp", "tp")),
        "w2": Param((f, d), ("tp", "fsdp")),
    }


def embed_defs(cfg) -> dict:
    v = cfg.padded_vocab
    # d^-0.5 keeps tied-embedding logits at unit scale
    d = {"tok": Param((v, cfg.d_model), ("tp", "fsdp"),
                      scale=cfg.d_model ** -0.5)}
    if not cfg.tied_embeddings:
        d["out"] = Param((cfg.d_model, v), ("fsdp", "tp"))
    d["final_norm"] = Param((cfg.d_model,), (None,), init="ones")
    return d


def mask_padded_vocab(cfg, lg):
    if cfg.padded_vocab == cfg.vocab:
        return lg
    bad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
    return jnp.where(bad, jnp.asarray(-1e30, lg.dtype), lg)


def logits(x, params, cfg):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    lg = x @ (params["tok"].T if cfg.tied_embeddings else params["out"])
    return mask_padded_vocab(cfg, lg)
