"""Block assembly: superblock definitions, scan-over-layers, and caches.

Every architecture is a stack of *superblocks* (``cfg.superblock`` layers)
scanned with stacked parameters — HLO size stays flat in depth, which keeps
the 94-layer MoE and the 62-layer gemma3 compilable in seconds.  Mixed
architectures encode their pattern inside the superblock:

  gemma3   superblock = [local x5, global]   (+2 remainder local layers)
  jamba    superblock = [attn, mamba x7], FFN alternates dense/MoE
  rwkv6    superblock = [rwkv]               (time-mix + channel-mix)
  others   superblock = [global]

Caches are pytrees stacked along the block dimension and threaded through
the scan as xs/ys, so decode touches each layer's cache slice exactly once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mb
from . import moe as moe_mod
from . import rwkv as rk
from .layers import Param, mlp_defs, rms_norm, swiglu
from .sharding import constrain


# --------------------------------------------------------------------------
# parameter definitions
# --------------------------------------------------------------------------
def block_defs(cfg, kind: str, ffn_kind: str, cross: bool = False) -> dict:
    d = cfg.d_model
    out = {"norm1": Param((d,), (None,), init="ones")}
    if kind in ("attn", "local", "global"):
        out["mixer"] = attn.attn_defs(cfg)
    elif kind == "mamba":
        out["mixer"] = mb.mamba_defs(cfg)
    elif kind == "rwkv":
        out["mixer"] = rk.rwkv_tm_defs(cfg)
    else:
        raise ValueError(kind)
    if cross:
        out["norm_x"] = Param((d,), (None,), init="ones")
        out["xattn"] = attn.attn_defs(cfg)
    out["norm2"] = Param((d,), (None,), init="ones")
    if ffn_kind == "dense":
        out["ffn"] = mlp_defs(d, cfg.d_ff)
    elif ffn_kind == "moe":
        out["ffn"] = moe_mod.moe_defs(cfg)
        if cfg.dense_residual:
            out["ffn"]["dense"] = mlp_defs(d, cfg.d_ff)
    elif ffn_kind == "rwkv_cm":
        out["ffn"] = rk.rwkv_cm_defs(cfg)
    else:
        raise ValueError(ffn_kind)
    return out


def superblock_defs(cfg, cross: bool = False) -> dict:
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    return {
        f"l{i}": block_defs(cfg, kinds[i], ffns[i], cross=cross)
        for i in range(cfg.superblock)
    }


def stack_defs(defs, n: int):
    """Add the leading scan dimension to every Param descriptor."""
    return jax.tree.map(
        lambda p: Param((n,) + p.shape, (None,) + p.logical, p.init, p.scale),
        defs,
        is_leaf=lambda x: isinstance(x, Param),
    )


# --------------------------------------------------------------------------
# cache definitions (ShapeDtypeStruct trees for serving)
# --------------------------------------------------------------------------
def block_cache_defs(cfg, kind: str, ffn_kind: str, batch: int,
                     cache_len: int, cross_len: int = 0) -> dict:
    """Logical cache spec per layer: dict name -> (shape, logical axes)."""
    hd, hk = cfg.hd, cfg.n_kv_heads
    d = cfg.d_model
    out = {}
    if kind in ("attn", "global"):
        out["k"] = ((batch, cache_len, hk, hd), ("fsdp", "seq", None, None))
        out["v"] = ((batch, cache_len, hk, hd), ("fsdp", "seq", None, None))
    elif kind == "local":
        w = min(cfg.local_window, cache_len)
        out["k"] = ((batch, w, hk, hd), ("fsdp", None, None, None))
        out["v"] = ((batch, w, hk, hd), ("fsdp", None, None, None))
    elif kind == "mamba":
        di = cfg.mamba_expand * d
        H = di // cfg.mamba_head_dim
        out["conv"] = ((batch, mb.CONV_K - 1, di), ("fsdp", None, "tp"))
        out["state"] = (
            (batch, H, cfg.mamba_d_state, cfg.mamba_head_dim),
            ("fsdp", "tp", None, None),
        )
    elif kind == "rwkv":
        H = d // cfg.rwkv_head_dim
        out["state"] = (
            (batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
            ("fsdp", "tp", None, None),
        )
        out["shift_tm"] = ((batch, d), ("fsdp", None))
    if ffn_kind == "rwkv_cm":
        out["shift_cm"] = ((batch, d), ("fsdp", None))
    if cross_len:
        out["xk"] = ((batch, cross_len, hk, hd), ("fsdp", None, None, None))
        out["xv"] = ((batch, cross_len, hk, hd), ("fsdp", None, None, None))
    return out


def cache_defs(cfg, batch: int, cache_len: int, cross_len: int = 0) -> dict:
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    sb = {
        f"l{i}": block_cache_defs(cfg, kinds[i], ffns[i], batch, cache_len,
                                  cross_len)
        for i in range(cfg.superblock)
    }
    stacked = jax.tree.map(
        lambda sl: ((cfg.n_blocks,) + sl[0], (None,) + sl[1]),
        sb,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple),
    )
    out = {"blocks": stacked}
    for i in range(cfg.remainder_layers):
        li = cfg.n_blocks * cfg.superblock + i
        out[f"rem{i}"] = block_cache_defs(
            cfg, kinds[li % cfg.superblock], ffns[li % cfg.superblock],
            batch, cache_len, cross_len,
        )
    return out


# --------------------------------------------------------------------------
# block application
# --------------------------------------------------------------------------
def apply_block(p, cfg, kind, ffn_kind, x, axes, mode, cache, pos,
                enc_out=None, causal=True):
    """One layer.  mode: train | prefill | decode.  Returns (x, cache')."""
    new_cache = {}
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    window = cfg.local_window if kind == "local" else 0

    if kind in ("attn", "local", "global"):
        if mode == "decode":
            out, ck, cv = attn.decode_attention(
                p["mixer"], cfg, h, cache["k"], cache["v"], pos, axes,
                window=window,
            )
            new_cache.update(k=ck, v=cv)
        else:
            out, k, v = attn.attention(
                p["mixer"], cfg, h, axes, causal=causal, window=window
            )
            if mode == "prefill":
                if window:
                    S = k.shape[1]
                    w = min(window, S)
                    slots = (jnp.arange(S - w, S)) % w
                    ck = jnp.zeros(
                        (k.shape[0], w) + k.shape[2:], k.dtype
                    ).at[:, slots].set(k[:, -w:])
                    cv = jnp.zeros_like(ck).at[:, slots].set(v[:, -w:])
                else:
                    ck, cv = k, v
                new_cache.update(k=ck, v=cv)
    elif kind == "mamba":
        if mode == "decode":
            out, conv, st = mb.mamba_mix_decode(
                p["mixer"], cfg, h[:, 0], cache["conv"], cache["state"]
            )
            out = out[:, None]
        else:
            out, conv, st = mb.mamba_mix(p["mixer"], cfg, h, axes)
        if mode != "train":
            new_cache.update(conv=conv, state=st.astype(jnp.float32))
    elif kind == "rwkv":
        if mode == "decode":
            out, prev, st = rk.time_mix_decode(
                p["mixer"], cfg, h[:, 0], cache["shift_tm"], cache["state"]
            )
            out = out[:, None]
        else:
            out, prev, st = rk.time_mix(p["mixer"], cfg, h, axes)
        if mode != "train":
            new_cache.update(shift_tm=prev, state=st.astype(jnp.float32))
    x = x + out

    if enc_out is not None or ("xk" in (cache or {})):
        hx = rms_norm(x, p["norm_x"], cfg.norm_eps)
        if mode == "train" or (mode == "prefill" and enc_out is not None):
            xk, xv = attn.encode_kv(p["xattn"], cfg, enc_out)
            if mode == "prefill":
                new_cache.update(xk=xk, xv=xv)
        else:
            xk, xv = cache["xk"], cache["xv"]
            new_cache.update(xk=xk, xv=xv)
        x = x + attn.cross_attention(p["xattn"], cfg, hx, xk, xv, axes)

    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    if ffn_kind == "dense":
        f = swiglu(h2, p["ffn"]["w1"], p["ffn"]["w3"], p["ffn"]["w2"])
    elif ffn_kind == "moe":
        f = moe_mod.moe_ffn(p["ffn"], cfg, h2, axes)
    elif ffn_kind == "rwkv_cm":
        if mode == "decode":
            f, prev_cm = rk.channel_mix_decode(
                p["ffn"], cfg, h2[:, 0], cache["shift_cm"]
            )
            f = f[:, None]
        else:
            f, prev_cm = rk.channel_mix(p["ffn"], cfg, h2)
        if mode != "train":
            new_cache.update(shift_cm=prev_cm)
    x = x + f
    x = constrain(x, axes, ("fsdp", None, None))
    return x, (new_cache if new_cache else cache)


def apply_superblock(p, cfg, x, axes, mode, cache, pos, enc_out=None):
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()
    new_cache = {}
    for i in range(cfg.superblock):
        key = f"l{i}"
        x, c = apply_block(
            p[key], cfg, kinds[i], ffns[i], x, axes, mode,
            (cache or {}).get(key), pos, enc_out=enc_out,
        )
        new_cache[key] = c
    return x, new_cache


def run_stack(params, cfg, x, axes, mode, cache=None, pos=None,
              enc_out=None):
    """Scanned superblocks + remainder layers.

    ``params['blocks']`` is stacked (n_blocks, ...); ``cache['blocks']``
    likewise.  Returns (x, new_cache)."""
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()

    def body(carry, xs):
        pblk, cblk = xs
        y, c = apply_superblock(
            pblk, cfg, carry, axes, mode, cblk, pos, enc_out=enc_out
        )
        return y, c

    body = jax.checkpoint(body, prevent_cse=False)
    if cache is None:
        x, new_blocks = jax.lax.scan(
            lambda c, pb: body(c, (pb, None)), x, params["blocks"]
        )
    else:
        x, new_blocks = jax.lax.scan(
            body, x, (params["blocks"], cache["blocks"])
        )
    new_cache = None if mode == "train" else {"blocks": new_blocks}
    for i in range(cfg.remainder_layers):
        li = cfg.n_blocks * cfg.superblock + i
        k = kinds[li % cfg.superblock]
        f = ffns[li % cfg.superblock]
        x, c = apply_block(
            params[f"rem{i}"], cfg, k, f, x, axes, mode,
            (cache or {}).get(f"rem{i}"), pos, enc_out=enc_out,
        )
        if new_cache is not None:
            new_cache[f"rem{i}"] = c
    return x, new_cache
