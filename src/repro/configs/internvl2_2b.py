"""internvl2-2b — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

The ViT frontend is a STUB per the brief: ``input_specs()`` feeds
precomputed patch embeddings alongside the token stream."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    frontend="patch_stub",
))
