"""qwen3-moe-235b-a22b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,         # GQA kv=4
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    n_experts=128,
    moe_top_k=8,
    moe_dff=1536,
))
