"""seamless-m4t-medium — encoder-decoder, multimodal [arXiv:2308.11596; hf].

The audio frontend is a STUB per the brief: ``input_specs()`` feeds
precomputed frame embeddings to the encoder."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,          # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    frontend="audio_stub",
))
