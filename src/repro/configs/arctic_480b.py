"""arctic-480b — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,         # GQA kv=8
    d_ff=4864,            # dense-residual FFN hidden
    vocab=32000,
    n_experts=128,
    moe_top_k=2,
    moe_dff=4864,
    dense_residual=True,  # arctic's dense-MoE hybrid: parallel residual FFN
))
