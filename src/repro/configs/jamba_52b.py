"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE 16e top-2 on
alternate layers [arXiv:2403.19887; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,          # 4 superblocks of (1 attn + 7 mamba)
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    moe_top_k=2,
    moe_dff=14336,
    moe_every=2,          # MoE FFN on alternate layers
    attn_every=8,
    mamba_d_state=64,
    mamba_head_dim=64,
    mamba_expand=2,
))
