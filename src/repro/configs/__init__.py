"""Architecture registry: one exact config per assigned architecture."""
from .base import (
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_configs,
    cells,
    get_config,
)

__all__ = [
    "LONG_CONTEXT_ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "all_configs",
    "cells",
    "get_config",
]
