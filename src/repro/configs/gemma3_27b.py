"""gemma3-27b — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,          # 10 superblocks of (5 local + 1 global) + 2 local
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    qk_norm=True,
    local_window=1024,
    local_per_global=5,
))
