"""rwkv6-3b — Finch: attention-free, data-dependent decay [arXiv:2404.05892; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # time-mix heads (head_dim 64)
    n_kv_heads=40,
    d_ff=8960,            # channel-mix hidden
    vocab=65536,
    head_dim=64,
    rwkv_head_dim=64,
))
