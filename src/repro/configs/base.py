"""Model / run configuration dataclasses and the architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e4

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_dff: int = 0            # expert hidden size (d_ff used for dense path)
    moe_every: int = 1          # MoE FFN every k-th layer (jamba: 2)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # local/global attention mix (gemma3)
    local_window: int = 0       # sliding window size; 0 = all-global
    local_per_global: int = 0   # e.g. 5 -> pattern [5 x local, 1 x global]

    # hybrid (jamba): attention every k-th layer, rest mamba
    attn_every: int = 0         # e.g. 8 -> 1 attention + 7 mamba per block
    mamba_d_state: int = 64
    mamba_head_dim: int = 64
    mamba_expand: int = 2
    # rwkv
    rwkv_head_dim: int = 64
    # enc-dec
    encoder_layers: int = 0     # >0 => encoder-decoder (seamless)
    frontend: str = "none"      # none | patch_stub | audio_stub

    tied_embeddings: bool = False

    # performance knobs (hillclimb levers; see EXPERIMENTS.md §Perf)
    local_slice_opt: bool = False  # sliced-KV local attention (vs masked)

    # numeric / structure
    dtype: str = "bfloat16"
    chunk_q: int = 1024         # attention query-chunk (prefill/train)
    la_chunk: int = 64          # linear-attention chunk (rwkv/mamba)
    norm_eps: float = 1e-6

    @property
    def kv_repeat(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so embedding/logit shards tile evenly across the
        16-way model axis (Megatron-style padding; padded ids are masked)."""
        return -(-self.vocab // 256) * 256

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def superblock(self) -> int:
        """Layers per scanned repeating block."""
        if self.attn_every:
            return self.attn_every
        if self.local_per_global:
            return self.local_per_global + 1
        return 1

    @property
    def n_blocks(self) -> int:
        base = self.n_layers
        return base // self.superblock

    @property
    def remainder_layers(self) -> int:
        return self.n_layers - self.n_blocks * self.superblock

    def layer_kinds(self) -> list[str]:
        """Sequence-mixer kind for each position inside a superblock."""
        sb = self.superblock
        if self.attn_every:
            return ["attn"] + ["mamba"] * (sb - 1)
        if self.family == "rwkv":
            return ["rwkv"]
        if self.local_per_global:
            return ["local"] * self.local_per_global + ["global"]
        return ["global"]

    def ffn_kinds(self) -> list[str]:
        """FFN kind per position inside a superblock."""
        sb = self.superblock
        if self.n_experts and self.moe_every > 1:
            out = []
            for i in range(sb):
                out.append("moe" if i % self.moe_every == 1 else "dense")
            return out
        if self.n_experts:
            return ["moe"] * sb
        if self.family == "rwkv":
            return ["rwkv_cm"]  # channel-mix
        return ["dense"] * sb

    def params_count(self) -> tuple[int, int]:
        """(total, active) parameter counts (analytic, embeddings included)."""
        hd = self.hd
        d = self.d_model
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + \
            self.n_heads * hd * d
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = 3 * d * (self.moe_dff or self.d_ff)
        mamba_inner = self.mamba_expand * d
        mamba = d * (2 * mamba_inner) + mamba_inner * 4 + \
            2 * mamba_inner * self.mamba_d_state + mamba_inner * d + \
            mamba_inner * 2
        rwkv_tm = 5 * d * d  # r,k,v,g,o (+ small decay LoRA)
        rwkv_cm = 2 * d * self.d_ff + d * d  # k, v, r
        total = active = 0
        kinds = self.layer_kinds()
        ffns = self.ffn_kinds()
        sb = self.superblock
        n_full = self.n_layers if self.encoder_layers == 0 else self.n_layers
        for i in range(n_full):
            k = kinds[i % sb]
            f = ffns[i % sb]
            if k in ("attn", "local", "global"):
                total += attn
                active += attn
            elif k == "mamba":
                total += mamba
                active += mamba
            elif k == "rwkv":
                total += rwkv_tm
                active += rwkv_tm
            if f == "dense":
                total += dense_ffn
                active += dense_ffn
            elif f == "rwkv_cm":
                total += rwkv_cm
                active += rwkv_cm
            elif f == "moe":
                total += self.n_experts * moe_ffn + d * self.n_experts
                active += self.moe_top_k * moe_ffn + d * self.n_experts
                if self.dense_residual:
                    total += dense_ffn
                    active += dense_ffn
        if self.encoder_layers:
            # encoder self-attn + ffn, decoder adds cross-attention
            enc = self.encoder_layers * (attn + dense_ffn)
            total += enc + self.n_layers * attn  # cross-attn in decoder
            active += enc + self.n_layers * attn
        emb = self.vocab * d * (1 if self.tied_embeddings else 2)
        total += emb
        active += emb
        return total, active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs for which long_500k applies (sub-quadratic attention path);
# see DESIGN.md section 5 for the skip rationale of the rest.
LONG_CONTEXT_ARCHS = {"rwkv6-3b", "jamba-v0.1-52b", "gemma3-27b"}


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def all_configs() -> dict:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


def load_all() -> None:
    from . import (  # noqa: F401
        arctic_480b,
        gemma3_27b,
        internlm2_20b,
        internvl2_2b,
        jamba_52b,
        qwen3_0_6b,
        qwen3_1_7b,
        qwen3_moe_235b,
        rwkv6_3b,
        seamless_m4t_medium,
    )


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped long_500k cells excluded
    unless requested."""
    out = []
    for name, cfg in sorted(all_configs().items()):
        for sname, shape in SHAPES.items():
            skip = None
            if sname == "long_500k" and name not in LONG_CONTEXT_ARCHS:
                skip = "pure full-attention arch: no sub-quadratic path"
            if skip and not include_skipped:
                continue
            out.append((cfg, shape, skip))
    return out
