"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def partition_assign_ref(points, split_dim, split_val, *, levels: int):
    """Reference tree routing: plain gathers, no tiling."""
    n = points.shape[0]
    g = jnp.zeros(n, dtype=jnp.int32)
    rows = jnp.arange(n)
    for level in range(levels):
        dim = split_dim[level, g]
        val = split_val[level, g]
        coord = points[rows, dim]
        g = g * 2 + (coord > val).astype(jnp.int32)
    return g


def pairwise_dist2_ref(queries, points, valid):
    """Reference masked squared distances: direct subtraction."""
    d2 = jnp.sum(
        (queries[:, None, :] - points[None, :, :]) ** 2, axis=-1
    ).astype(jnp.float32)
    big = jnp.finfo(jnp.float32).max
    return jnp.where(valid[None, :] > 0, d2, big)


def knn_topk_ref(queries, points, valid, k: int):
    """Reference k-NN: full distance matrix + top_k."""
    d2 = pairwise_dist2_ref(queries, points, valid)
    neg, idx = jax.lax.top_k(-d2, k)
    return idx, -neg


def window_count_ref(lo, hi, points, valid):
    """Reference window counting: one broadcast containment test."""
    inside = jnp.all(
        (points[None, :, :] >= lo[:, None, :])
        & (points[None, :, :] <= hi[:, None, :]),
        axis=-1,
    ) & (valid[None, :] > 0)
    return jnp.sum(inside, axis=1).astype(jnp.int32)


def window_count_gathered_ref(lo, hi, points, valid):
    """Reference for the per-query gathered layout: (nq, npp, d) points."""
    inside = jnp.all(
        (points >= lo[:, None, :]) & (points <= hi[:, None, :]), axis=-1
    ) & (valid > 0)
    return jnp.sum(inside, axis=1).astype(jnp.int32)


def window_mask_gathered_ref(lo, hi, points, valid):
    """Reference containment mask for the per-query gathered layout."""
    inside = jnp.all(
        (points >= lo[:, None, :]) & (points <= hi[:, None, :]), axis=-1
    ) & (valid > 0)
    return inside.astype(jnp.int32)


def gathered_dist2_ref(queries, points, valid):
    """Reference per-query gathered squared distances: (nq, npp, d) points."""
    d2 = jnp.sum((points - queries[:, None, :]) ** 2, axis=-1).astype(
        jnp.float32
    )
    big = jnp.finfo(jnp.float32).max
    return jnp.where(valid > 0, d2, big)
