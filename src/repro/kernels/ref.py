"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def partition_assign_ref(points, split_dim, split_val, *, levels: int):
    """Reference tree routing: plain gathers, no tiling."""
    n = points.shape[0]
    g = jnp.zeros(n, dtype=jnp.int32)
    rows = jnp.arange(n)
    for level in range(levels):
        dim = split_dim[level, g]
        val = split_val[level, g]
        coord = points[rows, dim]
        g = g * 2 + (coord > val).astype(jnp.int32)
    return g


def pairwise_dist2_ref(queries, points, valid):
    """Reference masked squared distances: direct subtraction."""
    d2 = jnp.sum(
        (queries[:, None, :] - points[None, :, :]) ** 2, axis=-1
    ).astype(jnp.float32)
    big = jnp.finfo(jnp.float32).max
    return jnp.where(valid[None, :] > 0, d2, big)


def knn_topk_ref(queries, points, valid, k: int):
    """Reference k-NN: full distance matrix + top_k."""
    d2 = pairwise_dist2_ref(queries, points, valid)
    neg, idx = jax.lax.top_k(-d2, k)
    return idx, -neg


def window_count_ref(lo, hi, points, valid):
    """Reference window counting: one broadcast containment test."""
    inside = jnp.all(
        (points[None, :, :] >= lo[:, None, :])
        & (points[None, :, :] <= hi[:, None, :]),
        axis=-1,
    ) & (valid[None, :] > 0)
    return jnp.sum(inside, axis=1).astype(jnp.int32)


def window_count_gathered_ref(lo, hi, points, valid):
    """Reference for the per-query gathered layout: (nq, npp, d) points."""
    inside = jnp.all(
        (points >= lo[:, None, :]) & (points <= hi[:, None, :]), axis=-1
    ) & (valid > 0)
    return jnp.sum(inside, axis=1).astype(jnp.int32)


def window_mask_gathered_ref(lo, hi, points, valid):
    """Reference containment mask for the per-query gathered layout."""
    inside = jnp.all(
        (points >= lo[:, None, :]) & (points <= hi[:, None, :]), axis=-1
    ) & (valid > 0)
    return inside.astype(jnp.int32)


def gathered_dist2_ref(queries, points, valid):
    """Reference per-query gathered squared distances: (nq, npp, d) points."""
    d2 = jnp.sum((points - queries[:, None, :]) ** 2, axis=-1).astype(
        jnp.float32
    )
    big = jnp.finfo(jnp.float32).max
    return jnp.where(valid > 0, d2, big)


def box_hits_tiled_ref(lo, hi, qlo, qhi):
    """Reference box-intersection mask: (n, nq), f32 compare after widening
    any bf16 storage (matching the kernel's in-register cast)."""
    lo = lo.astype(jnp.float32)
    hi = hi.astype(jnp.float32)
    inter = (lo[:, None, :] <= qhi[None, :, :]) & (
        hi[:, None, :] >= qlo[None, :, :]
    )
    return jnp.all(inter, axis=-1).astype(jnp.int32)


def pair_window_ids_ref(qlo, qhi, leaf_lo, leaf_hi, leaf_pts, leaf_ids,
                        leaf_counts, q_idx, leaf_idx, pair_valid):
    """Reference fused pair scan: plain gathers, ids-or-minus-one."""
    lo_p = qlo[q_idx]                         # (P, d)
    hi_p = qhi[q_idx]
    pts = leaf_pts[leaf_idx]                  # (P, S, d)
    ids = leaf_ids[leaf_idx]                  # (P, S)
    s = leaf_pts.shape[1]
    valid = (
        jnp.arange(s, dtype=jnp.int32)[None, :]
        < leaf_counts[leaf_idx][:, None]
    ) & (pair_valid[:, None] > 0)
    box_ok = jnp.all(
        (leaf_lo[leaf_idx].astype(jnp.float32) <= hi_p)
        & (leaf_hi[leaf_idx].astype(jnp.float32) >= lo_p),
        axis=1,
    )
    inside = jnp.all(
        (pts >= lo_p[:, None, :]) & (pts <= hi_p[:, None, :]), axis=2
    ) & valid & box_ok[:, None]
    counts = jnp.sum(inside.astype(jnp.int32), axis=1)
    return jnp.where(inside, ids, -1), counts


def leaf_mindist_ref(queries, leaf_lo, leaf_hi):
    """Reference squared box mindists: (nq, L).

    Accumulates per dimension in the kernel's order so results are
    bit-identical (a fused jnp.sum can round differently by one ulp)."""
    lo = leaf_lo.astype(jnp.float32)
    hi = leaf_hi.astype(jnp.float32)
    acc = jnp.zeros((queries.shape[0], lo.shape[0]), jnp.float32)
    for k in range(queries.shape[1]):
        qk = queries[:, k][:, None]
        g = jnp.maximum(lo[:, k][None, :] - qk, 0.0) + jnp.maximum(
            qk - hi[:, k][None, :], 0.0
        )
        acc = acc + g * g
    return acc


def pair_dist2_ref(queries, leaf_pts, leaf_counts, q_idx, leaf_idx):
    """Reference fused pair distances: plain gathers, invalid = f32 max."""
    q = queries[q_idx]                        # (P, d)
    pts = leaf_pts[leaf_idx]                  # (P, S, d)
    s = leaf_pts.shape[1]
    d2 = jnp.sum((pts - q[:, None, :]) ** 2, axis=2)
    valid = (
        jnp.arange(s, dtype=jnp.int32)[None, :]
        < leaf_counts[leaf_idx][:, None]
    )
    big = jnp.finfo(jnp.float32).max
    return jnp.where(valid, d2, big)
