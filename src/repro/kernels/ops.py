"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to auto-detection: Pallas executes the kernel body in
Python on CPU (validation mode) and compiles to Mosaic on TPU.  All wrappers
handle padding to tile multiples so callers can pass ragged sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import knn_topk as _knn
from . import partition_assign as _pa
from . import ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def _pad_rows(x, mult, fill):
    n = x.shape[0]
    n_pad = -(-n // mult) * mult
    if n_pad == n:
        return x, n
    pad = jnp.full((n_pad - n,) + x.shape[1:], fill, dtype=x.dtype)
    return jnp.concatenate([x, pad]), n


def partition_assign(points, split_dim, split_val, *, levels: int,
                     tile: int = _pa.DEFAULT_TILE,
                     interpret: bool | None = None):
    """Leaf/subspace id per point via the Pallas routing kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    pts, n = _pad_rows(jnp.asarray(points, jnp.float32), tile, 0.0)
    out = _pa.partition_assign(
        pts, split_dim, split_val, levels=levels, tile=tile,
        interpret=interpret,
    )
    return out[:n]


def pairwise_dist2(queries, points, valid=None, *, qt=_knn.DEFAULT_QT,
                   pt=_knn.DEFAULT_PT, interpret: bool | None = None):
    """Masked (nq, np) squared distances via the Pallas tile kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    q = jnp.asarray(queries, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    if valid is None:
        valid = jnp.ones(p.shape[0], jnp.int32)
    qp, nq = _pad_rows(q, qt, 0.0)
    pp, n_p = _pad_rows(p, pt, 0.0)
    vp, _ = _pad_rows(jnp.asarray(valid, jnp.int32), pt, 0)
    d2 = _knn.pairwise_dist2(qp, pp, vp, qt=qt, pt=pt, interpret=interpret)
    return d2[:nq, :n_p]


def knn_topk(queries, points, k: int, valid=None, **kw):
    """k nearest points per query: Pallas distance tiles + XLA top-k merge.

    Returns (indices (nq, k), dists_sq (nq, k)).  The selection stage is a
    plain ``top_k`` because it is bandwidth-trivial next to the distance
    matrix; on TPU the distance tiles stream from the kernel while top_k
    consumes them (XLA fuses the consumer)."""
    d2 = pairwise_dist2(queries, points, valid=valid, **kw)
    neg, idx = jax.lax.top_k(-d2, k)
    return idx, -neg


# re-export oracles for test convenience
partition_assign_ref = ref.partition_assign_ref
pairwise_dist2_ref = ref.pairwise_dist2_ref
knn_topk_ref = ref.knn_topk_ref
