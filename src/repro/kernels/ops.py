"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to auto-detection: Pallas executes the kernel body in
Python on CPU (validation mode) and compiles to Mosaic on TPU.  All wrappers
handle padding to tile multiples so callers can pass ragged sizes.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import knn_topk as _knn
from . import partition_assign as _pa
from . import ref
from . import window_filter as _wf


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def interpret_default() -> bool:
    """Resolve the interpret flag: the ``REPRO_PALLAS_INTERPRET`` env var
    (1/0) wins — CI uses it to force interpret-mode kernel coverage on
    CPU-only runners — else compile to Mosaic exactly when a TPU is
    attached."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    return not _on_tpu()


def _pad_rows(x, mult, fill):
    n = x.shape[0]
    n_pad = -(-n // mult) * mult
    if n_pad == n:
        return x, n
    pad = jnp.full((n_pad - n,) + x.shape[1:], fill, dtype=x.dtype)
    return jnp.concatenate([x, pad]), n


def partition_assign(points, split_dim, split_val, *, levels: int,
                     tile: int = _pa.DEFAULT_TILE,
                     interpret: bool | None = None):
    """Leaf/subspace id per point via the Pallas routing kernel."""
    if interpret is None:
        interpret = interpret_default()
    pts, n = _pad_rows(jnp.asarray(points, jnp.float32), tile, 0.0)
    out = _pa.partition_assign(
        pts, split_dim, split_val, levels=levels, tile=tile,
        interpret=interpret,
    )
    return out[:n]


def pairwise_dist2(queries, points, valid=None, *, qt=_knn.DEFAULT_QT,
                   pt=_knn.DEFAULT_PT, interpret: bool | None = None):
    """Masked (nq, np) squared distances via the Pallas tile kernel."""
    if interpret is None:
        interpret = interpret_default()
    q = jnp.asarray(queries, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    if valid is None:
        valid = jnp.ones(p.shape[0], jnp.int32)
    qp, nq = _pad_rows(q, qt, 0.0)
    pp, n_p = _pad_rows(p, pt, 0.0)
    vp, _ = _pad_rows(jnp.asarray(valid, jnp.int32), pt, 0)
    d2 = _knn.pairwise_dist2(qp, pp, vp, qt=qt, pt=pt, interpret=interpret)
    return d2[:nq, :n_p]


# ceiling on how many distance-matrix elements a single knn_topk dispatch
# may materialize (fp32: 64 MiB); larger batches stream in query chunks
KNN_MAX_ELEMS = 16 * 1024 * 1024


def knn_topk(queries, points, k: int, valid=None, *,
             query_chunk: int | None = None, **kw):
    """k nearest points per query: Pallas distance tiles + XLA top-k merge.

    Returns (indices (nq, k), dists_sq (nq, k)).  The selection stage is a
    plain ``top_k`` because it is bandwidth-trivial next to the distance
    matrix; on TPU the distance tiles stream from the kernel while top_k
    consumes them (XLA fuses the consumer).

    Memory is capped: when the full (nq, np) distance matrix would exceed
    ``KNN_MAX_ELEMS`` elements, the query axis is processed in chunks (of
    ``query_chunk`` rows when given, else sized to the cap) so only one
    chunk's distances are live at a time."""
    nq = queries.shape[0]
    n_p = points.shape[0]
    if query_chunk is None and nq * max(n_p, 1) > KNN_MAX_ELEMS:
        query_chunk = max(KNN_MAX_ELEMS // max(n_p, 1), 1)
    if query_chunk is None or query_chunk >= nq:
        d2 = pairwise_dist2(queries, points, valid=valid, **kw)
        neg, idx = jax.lax.top_k(-d2, k)
        return idx, -neg
    idx_parts, dist_parts = [], []
    for start in range(0, nq, query_chunk):
        d2 = pairwise_dist2(
            queries[start : start + query_chunk], points, valid=valid, **kw
        )
        neg, idx = jax.lax.top_k(-d2, k)
        idx_parts.append(idx)
        dist_parts.append(-neg)
    return jnp.concatenate(idx_parts), jnp.concatenate(dist_parts)


def window_count(lo, hi, points, valid=None, *, qt=_wf.DEFAULT_QT,
                 pt=_wf.DEFAULT_PT, interpret: bool | None = None):
    """In-window point counts per query box via the Pallas tile kernel."""
    if interpret is None:
        interpret = interpret_default()
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    if valid is None:
        valid = jnp.ones(p.shape[0], jnp.int32)
    # query padding boxes are inverted (lo > hi): they can never match
    lo_p, nq = _pad_rows(lo, qt, 1.0)
    hi_p, _ = _pad_rows(hi, qt, 0.0)
    pp, _ = _pad_rows(p, pt, 0.0)
    vp, _ = _pad_rows(jnp.asarray(valid, jnp.int32), pt, 0)
    cnt = _wf.window_count_tiles(
        lo_p, hi_p, pp, vp, qt=qt, pt=pt, interpret=interpret
    )
    return cnt[:nq]


def window_count_gathered(lo, hi, points, valid, *, pt=_wf.DEFAULT_PT,
                          interpret: bool | None = None):
    """Per-query gathered layout: ``points`` is (nq, npp, d) with its own
    validity mask; the candidate axis is padded to a tile multiple here."""
    if interpret is None:
        interpret = interpret_default()
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    v = jnp.asarray(valid, jnp.int32)
    npp = p.shape[1]
    npp_pad = -(-max(npp, 1) // pt) * pt
    if npp_pad != npp:
        p = jnp.pad(p, ((0, 0), (0, npp_pad - npp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, npp_pad - npp)))
    return _wf.window_count_gathered(lo, hi, p, v, pt=pt, interpret=interpret)


def _pad_gathered(lo, hi, points, valid, pt):
    """Shared prep for the per-query gathered kernels: cast + pad the
    candidate axis to a tile multiple."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = None if hi is None else jnp.asarray(hi, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    v = jnp.asarray(valid, jnp.int32)
    npp = p.shape[1]
    npp_pad = -(-max(npp, 1) // pt) * pt
    if npp_pad != npp:
        p = jnp.pad(p, ((0, 0), (0, npp_pad - npp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, npp_pad - npp)))
    return lo, hi, p, v, npp


def window_mask_gathered(lo, hi, points, valid, *, pt=_wf.DEFAULT_PT,
                         interpret: bool | None = None):
    """Per-candidate containment mask (nq, npp) for the gathered layout —
    the collection stage of the device window engine."""
    if interpret is None:
        interpret = interpret_default()
    lo, hi, p, v, npp = _pad_gathered(lo, hi, points, valid, pt)
    out = _wf.window_mask_gathered(lo, hi, p, v, pt=pt, interpret=interpret)
    return out[:, :npp]


def gathered_dist2(queries, points, valid, *, pt=_knn.DEFAULT_PT,
                   interpret: bool | None = None):
    """Per-query gathered squared distances (nq, npp) — the candidate-leaf
    scan of the device k-NN engine (invalid slots carry float32 max)."""
    if interpret is None:
        interpret = interpret_default()
    q, _, p, v, npp = _pad_gathered(queries, None, points, valid, pt)
    out = _knn.gathered_dist2(q, p, v, pt=pt, interpret=interpret)
    return out[:, :npp]


# re-export oracles for test convenience
partition_assign_ref = ref.partition_assign_ref
pairwise_dist2_ref = ref.pairwise_dist2_ref
knn_topk_ref = ref.knn_topk_ref
window_count_ref = ref.window_count_ref
window_count_gathered_ref = ref.window_count_gathered_ref
window_mask_gathered_ref = ref.window_mask_gathered_ref
gathered_dist2_ref = ref.gathered_dist2_ref


def compiled_supported() -> bool:
    """True when ``interpret=False`` pallas_call can actually compile on
    the attached backend (Mosaic = TPU only; the CPU backend raises)."""
    return _on_tpu()


# --------------------------------------------------------------------------
# second-generation fused/tiled wrappers (the queries_jax hot path)
# --------------------------------------------------------------------------
def box_hits_tiled(lo, hi, qlo, qhi, *, nt: int | None = None,
                   qt: int | None = None, interpret: bool | None = None):
    """(n, nq) box-intersection mask via the VMEM-tiled kernel.

    ``lo``/``hi`` may be bf16 (compressed-MBB storage).  Padding boxes are
    inverted (lo = +max, hi = -max) and padding query windows likewise, so
    neither can ever intersect; both axes are sliced back."""
    if interpret is None:
        interpret = interpret_default()
    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi)
    qlo = jnp.asarray(qlo, jnp.float32)
    qhi = jnp.asarray(qhi, jnp.float32)
    n, d = lo.shape
    if nt is None or qt is None:
        nt0, qt0 = _wf.vmem_tiles(n, qlo.shape[0], d,
                                  in_bytes=lo.dtype.itemsize)
        nt = nt if nt is not None else nt0
        qt = qt if qt is not None else qt0
    big = float(jnp.finfo(jnp.float32).max)
    lo_p, n0 = _pad_rows(lo, nt, big)
    hi_p, _ = _pad_rows(hi, nt, -big)
    qlo_p, q0 = _pad_rows(qlo, qt, big)
    qhi_p, _ = _pad_rows(qhi, qt, -big)
    out = _wf.box_hits_tiled(lo_p, hi_p, qlo_p, qhi_p, nt=nt, qt=qt,
                             interpret=interpret)
    return out[:n0, :q0]


def pair_window_ids(qlo, qhi, leaf_lo, leaf_hi, leaf_pts, leaf_ids,
                    leaf_counts, q_idx, leaf_idx, pair_valid, *,
                    interpret: bool | None = None):
    """Fused (query, leaf) pair window scan: ``(ids_or (P, S), counts)``.

    One grid step per pair; the pair's leaf block is gathered into VMEM by
    the scalar-prefetch index maps, so no (P, S, d) temporary exists."""
    if interpret is None:
        interpret = interpret_default()
    return _wf.pair_window_ids(
        jnp.asarray(qlo, jnp.float32), jnp.asarray(qhi, jnp.float32),
        leaf_lo, leaf_hi, leaf_pts, leaf_ids, leaf_counts,
        q_idx, leaf_idx, pair_valid, interpret=interpret,
    )


def leaf_mindist_tiled(queries, leaf_lo, leaf_hi, *, qt: int = 128,
                       lt: int | None = None,
                       interpret: bool | None = None):
    """(nq, L) squared box mindists via the VMEM-tiled kernel.

    ``leaf_lo``/``leaf_hi`` may be bf16.  Padding leaves carry degenerate
    far-away boxes (lo = hi = +max) whose mindist overflows to +inf, so
    they can never be selected; both axes are sliced back."""
    if interpret is None:
        interpret = interpret_default()
    q = jnp.asarray(queries, jnp.float32)
    lo = jnp.asarray(leaf_lo)
    hi = jnp.asarray(leaf_hi)
    if lt is None:
        nt0, _ = _wf.vmem_tiles(lo.shape[0], q.shape[0], lo.shape[1],
                                in_bytes=lo.dtype.itemsize)
        lt = nt0
    big = float(jnp.finfo(jnp.float32).max)
    qp, nq = _pad_rows(q, qt, 0.0)
    lo_p, n_l = _pad_rows(lo, lt, big)
    hi_p, _ = _pad_rows(hi, lt, big)
    out = _knn.leaf_mindist_tiled(qp, lo_p, hi_p, qt=qt, lt=lt,
                                  interpret=interpret)
    return out[:nq, :n_l]


def pair_dist2(queries, leaf_pts, leaf_counts, q_idx, leaf_idx, *,
               interpret: bool | None = None):
    """Fused (query, leaf) candidate distances: (P, S), invalid = f32 max."""
    if interpret is None:
        interpret = interpret_default()
    return _knn.pair_dist2(
        jnp.asarray(queries, jnp.float32), leaf_pts, leaf_counts,
        q_idx, leaf_idx, interpret=interpret,
    )


box_hits_tiled_ref = ref.box_hits_tiled_ref
pair_window_ids_ref = ref.pair_window_ids_ref
leaf_mindist_ref = ref.leaf_mindist_ref
pair_dist2_ref = ref.pair_dist2_ref
