"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to auto-detection: Pallas executes the kernel body in
Python on CPU (validation mode) and compiles to Mosaic on TPU.  All wrappers
handle padding to tile multiples so callers can pass ragged sizes.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import knn_topk as _knn
from . import partition_assign as _pa
from . import ref
from . import window_filter as _wf


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def interpret_default() -> bool:
    """Resolve the interpret flag: the ``REPRO_PALLAS_INTERPRET`` env var
    (1/0) wins — CI uses it to force interpret-mode kernel coverage on
    CPU-only runners — else compile to Mosaic exactly when a TPU is
    attached."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    return not _on_tpu()


def _pad_rows(x, mult, fill):
    n = x.shape[0]
    n_pad = -(-n // mult) * mult
    if n_pad == n:
        return x, n
    pad = jnp.full((n_pad - n,) + x.shape[1:], fill, dtype=x.dtype)
    return jnp.concatenate([x, pad]), n


def partition_assign(points, split_dim, split_val, *, levels: int,
                     tile: int = _pa.DEFAULT_TILE,
                     interpret: bool | None = None):
    """Leaf/subspace id per point via the Pallas routing kernel."""
    if interpret is None:
        interpret = interpret_default()
    pts, n = _pad_rows(jnp.asarray(points, jnp.float32), tile, 0.0)
    out = _pa.partition_assign(
        pts, split_dim, split_val, levels=levels, tile=tile,
        interpret=interpret,
    )
    return out[:n]


def pairwise_dist2(queries, points, valid=None, *, qt=_knn.DEFAULT_QT,
                   pt=_knn.DEFAULT_PT, interpret: bool | None = None):
    """Masked (nq, np) squared distances via the Pallas tile kernel."""
    if interpret is None:
        interpret = interpret_default()
    q = jnp.asarray(queries, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    if valid is None:
        valid = jnp.ones(p.shape[0], jnp.int32)
    qp, nq = _pad_rows(q, qt, 0.0)
    pp, n_p = _pad_rows(p, pt, 0.0)
    vp, _ = _pad_rows(jnp.asarray(valid, jnp.int32), pt, 0)
    d2 = _knn.pairwise_dist2(qp, pp, vp, qt=qt, pt=pt, interpret=interpret)
    return d2[:nq, :n_p]


# ceiling on how many distance-matrix elements a single knn_topk dispatch
# may materialize (fp32: 64 MiB); larger batches stream in query chunks
KNN_MAX_ELEMS = 16 * 1024 * 1024


def knn_topk(queries, points, k: int, valid=None, *,
             query_chunk: int | None = None, **kw):
    """k nearest points per query: Pallas distance tiles + XLA top-k merge.

    Returns (indices (nq, k), dists_sq (nq, k)).  The selection stage is a
    plain ``top_k`` because it is bandwidth-trivial next to the distance
    matrix; on TPU the distance tiles stream from the kernel while top_k
    consumes them (XLA fuses the consumer).

    Memory is capped: when the full (nq, np) distance matrix would exceed
    ``KNN_MAX_ELEMS`` elements, the query axis is processed in chunks (of
    ``query_chunk`` rows when given, else sized to the cap) so only one
    chunk's distances are live at a time."""
    nq = queries.shape[0]
    n_p = points.shape[0]
    if query_chunk is None and nq * max(n_p, 1) > KNN_MAX_ELEMS:
        query_chunk = max(KNN_MAX_ELEMS // max(n_p, 1), 1)
    if query_chunk is None or query_chunk >= nq:
        d2 = pairwise_dist2(queries, points, valid=valid, **kw)
        neg, idx = jax.lax.top_k(-d2, k)
        return idx, -neg
    idx_parts, dist_parts = [], []
    for start in range(0, nq, query_chunk):
        d2 = pairwise_dist2(
            queries[start : start + query_chunk], points, valid=valid, **kw
        )
        neg, idx = jax.lax.top_k(-d2, k)
        idx_parts.append(idx)
        dist_parts.append(-neg)
    return jnp.concatenate(idx_parts), jnp.concatenate(dist_parts)


def window_count(lo, hi, points, valid=None, *, qt=_wf.DEFAULT_QT,
                 pt=_wf.DEFAULT_PT, interpret: bool | None = None):
    """In-window point counts per query box via the Pallas tile kernel."""
    if interpret is None:
        interpret = interpret_default()
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    if valid is None:
        valid = jnp.ones(p.shape[0], jnp.int32)
    # query padding boxes are inverted (lo > hi): they can never match
    lo_p, nq = _pad_rows(lo, qt, 1.0)
    hi_p, _ = _pad_rows(hi, qt, 0.0)
    pp, _ = _pad_rows(p, pt, 0.0)
    vp, _ = _pad_rows(jnp.asarray(valid, jnp.int32), pt, 0)
    cnt = _wf.window_count_tiles(
        lo_p, hi_p, pp, vp, qt=qt, pt=pt, interpret=interpret
    )
    return cnt[:nq]


def window_count_gathered(lo, hi, points, valid, *, pt=_wf.DEFAULT_PT,
                          interpret: bool | None = None):
    """Per-query gathered layout: ``points`` is (nq, npp, d) with its own
    validity mask; the candidate axis is padded to a tile multiple here."""
    if interpret is None:
        interpret = interpret_default()
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    v = jnp.asarray(valid, jnp.int32)
    npp = p.shape[1]
    npp_pad = -(-max(npp, 1) // pt) * pt
    if npp_pad != npp:
        p = jnp.pad(p, ((0, 0), (0, npp_pad - npp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, npp_pad - npp)))
    return _wf.window_count_gathered(lo, hi, p, v, pt=pt, interpret=interpret)


def _pad_gathered(lo, hi, points, valid, pt):
    """Shared prep for the per-query gathered kernels: cast + pad the
    candidate axis to a tile multiple."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = None if hi is None else jnp.asarray(hi, jnp.float32)
    p = jnp.asarray(points, jnp.float32)
    v = jnp.asarray(valid, jnp.int32)
    npp = p.shape[1]
    npp_pad = -(-max(npp, 1) // pt) * pt
    if npp_pad != npp:
        p = jnp.pad(p, ((0, 0), (0, npp_pad - npp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, npp_pad - npp)))
    return lo, hi, p, v, npp


def window_mask_gathered(lo, hi, points, valid, *, pt=_wf.DEFAULT_PT,
                         interpret: bool | None = None):
    """Per-candidate containment mask (nq, npp) for the gathered layout —
    the collection stage of the device window engine."""
    if interpret is None:
        interpret = interpret_default()
    lo, hi, p, v, npp = _pad_gathered(lo, hi, points, valid, pt)
    out = _wf.window_mask_gathered(lo, hi, p, v, pt=pt, interpret=interpret)
    return out[:, :npp]


def gathered_dist2(queries, points, valid, *, pt=_knn.DEFAULT_PT,
                   interpret: bool | None = None):
    """Per-query gathered squared distances (nq, npp) — the candidate-leaf
    scan of the device k-NN engine (invalid slots carry float32 max)."""
    if interpret is None:
        interpret = interpret_default()
    q, _, p, v, npp = _pad_gathered(queries, None, points, valid, pt)
    out = _knn.gathered_dist2(q, p, v, pt=pt, interpret=interpret)
    return out[:, :npp]


# re-export oracles for test convenience
partition_assign_ref = ref.partition_assign_ref
pairwise_dist2_ref = ref.pairwise_dist2_ref
knn_topk_ref = ref.knn_topk_ref
window_count_ref = ref.window_count_ref
window_count_gathered_ref = ref.window_count_gathered_ref
window_mask_gathered_ref = ref.window_mask_gathered_ref
gathered_dist2_ref = ref.gathered_dist2_ref
