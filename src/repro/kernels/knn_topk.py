"""Pallas TPU kernel: tiled squared-distance matrix for k-NN scanning.

The query-side hot loop of the paper (leaf scans during k-NN) is dominated
by distance evaluation.  The TPU-native formulation computes

    d2[q, p] = |q|^2 + |p|^2 - 2 q.p

so the inner product lands on the MXU and each (query-tile x point-tile)
block stays resident in VMEM.  Selection (top-k merge) is bandwidth-light
and runs as a plain XLA ``top_k`` over the kernel's output tiles — see
``ops.knn_topk`` for the fused pipeline.

Padding rows (row_id < 0, e.g. FMBI's partial-page sentinels) are masked to
+inf so they never enter a result set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_QT = 256
DEFAULT_PT = 512


def _dist2_kernel(q_ref, p_ref, valid_ref, out_ref):
    q = q_ref[...]                    # (qt, d)
    p = p_ref[...]                    # (pt, d)
    valid = valid_ref[...]            # (pt,)
    qq = jnp.sum(q * q, axis=1)       # (qt,)
    pp = jnp.sum(p * p, axis=1)       # (pt,)
    cross = jax.lax.dot_general(      # MXU: (qt, d) x (pt, d)^T
        q, p, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = qq[:, None] + pp[None, :] - 2.0 * cross
    d2 = jnp.maximum(d2, 0.0)         # numeric floor
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    out_ref[...] = jnp.where(valid[None, :] > 0, d2, big)


def _gathered_dist2_kernel(q_ref, p_ref, valid_ref, out_ref):
    q = q_ref[...]                    # (1, d)
    p = p_ref[...]                    # (1, pt, d)
    valid = valid_ref[...]            # (1, pt)
    acc = jnp.zeros(p.shape[:2], jnp.float32)
    for k in range(p.shape[2]):       # static unroll over dimensions keeps
        diff = p[..., k] - q[:, k][:, None]   # the working set at one plane
        acc = acc + diff * diff
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    out_ref[...] = jnp.where(valid > 0, acc, big)


@functools.partial(jax.jit, static_argnames=("pt", "interpret"))
def gathered_dist2(
    queries: jnp.ndarray,   # (nq, d) float32
    points: jnp.ndarray,    # (nq, npp, d) float32, npp % pt == 0
    valid: jnp.ndarray,     # (nq, npp) int32: 1 = real candidate, 0 = padding
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq, npp) masked squared distances, per-query gathered layout.

    This is the candidate-leaf scan of the device query engine: each query
    brings its own gathered candidate points (the contents of its closest
    leaves, padded to a fixed shape).  Query-major grid, one query row per
    block — the same layout as ``window_filter.window_count_gathered``.
    Selection (top-k merge) runs as plain XLA ``top_k`` on the output, which
    the consumer fuses.
    """
    nq, npp, d = points.shape
    assert npp % pt == 0, "pad the candidate axis to a tile multiple"
    grid = (nq, npp // pt)
    return pl.pallas_call(
        _gathered_dist2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, pt, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, npp), jnp.float32),
        interpret=interpret,
    )(queries, points, valid)


@functools.partial(
    jax.jit, static_argnames=("qt", "pt", "interpret")
)
def pairwise_dist2(
    queries: jnp.ndarray,   # (nq, d) float32, nq % qt == 0
    points: jnp.ndarray,    # (np, d) float32, np % pt == 0
    valid: jnp.ndarray,     # (np,) int32: 1 = real point, 0 = padding
    *,
    qt: int = DEFAULT_QT,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq, np) masked squared distances, computed in VMEM tiles."""
    nq, d = queries.shape
    n_p = points.shape[0]
    assert nq % qt == 0 and n_p % pt == 0, "pad inputs to tile multiples"
    grid = (nq // qt, n_p // pt)
    return pl.pallas_call(
        _dist2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((pt, d), lambda i, j: (j, 0)),
            pl.BlockSpec((pt,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((qt, pt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, n_p), jnp.float32),
        interpret=interpret,
    )(queries, points, valid)


# --------------------------------------------------------------------------
# second-generation tiled kernels (fused traversal + scan; see ops.py)
# --------------------------------------------------------------------------
def _leaf_mindist_kernel(q_ref, lo_ref, hi_ref, out_ref):
    q = q_ref[...]                          # (qt, d) float32
    lo = lo_ref[...].astype(jnp.float32)    # (lt, d) bounds (f32 or bf16)
    hi = hi_ref[...].astype(jnp.float32)
    acc = jnp.zeros((q.shape[0], lo.shape[0]), jnp.float32)
    for k in range(q.shape[1]):             # static unroll over dimensions:
        qk = q[:, k][:, None]               # one (qt, lt) plane at a time
        g = jnp.maximum(lo[:, k][None, :] - qk, 0.0) + jnp.maximum(
            qk - hi[:, k][None, :], 0.0
        )
        acc = acc + g * g
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("qt", "lt", "interpret"))
def leaf_mindist_tiled(
    queries: jnp.ndarray,   # (nq, d) float32, nq % qt == 0
    leaf_lo: jnp.ndarray,   # (L, d) leaf MBB lows (f32 or bf16), L % lt == 0
    leaf_hi: jnp.ndarray,   # (L, d)
    *,
    qt: int = 128,
    lt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq, L) squared box mindists, VMEM-tiled over both axes.

    The candidate-selection stage of the device k-NN engine.  Bound tiles
    may be bf16 (the compressed-MBB layout): outward rounding only widens a
    box, so a bf16 mindist never exceeds the f32 mindist — candidate
    selection stays a superset-safe underestimate and the exactness
    certificate derived from it is conservative (see queries_jax)."""
    nq, d = queries.shape
    n_l = leaf_lo.shape[0]
    assert nq % qt == 0 and n_l % lt == 0, "pad inputs to tile multiples"
    grid = (nq // qt, n_l // lt)
    return pl.pallas_call(
        _leaf_mindist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((lt, d), lambda i, j: (j, 0)),
            pl.BlockSpec((lt, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((qt, lt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, n_l), jnp.float32),
        interpret=interpret,
    )(queries, leaf_lo, leaf_hi)


def _pair_dist2_kernel(q_idx_ref, leaf_idx_ref, q_ref, pts_ref, cnt_ref,
                       out_ref):
    q = q_ref[...]                          # (1, d) this pair's query point
    p = pts_ref[...]                        # (1, S, d) this pair's leaf block
    cnt = cnt_ref[...]                      # (1,) live slots in the block
    s = p.shape[1]
    acc = jnp.zeros((1, s), jnp.float32)
    for k in range(p.shape[2]):             # static unroll over dimensions
        diff = p[..., k] - q[:, k][:, None]
        acc = acc + diff * diff
    valid = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1) < cnt[:, None]
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    out_ref[...] = jnp.where(valid, acc, big)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pair_dist2(
    queries: jnp.ndarray,     # (nq, d) float32 query points
    leaf_pts: jnp.ndarray,    # (L, S, d) float32 leaf-blocked points
    leaf_counts: jnp.ndarray, # (L,) int32 live slots per block
    q_idx: jnp.ndarray,       # (P,) int32 query of each candidate pair
    leaf_idx: jnp.ndarray,    # (P,) int32 leaf slot of each candidate pair
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused (query, leaf) candidate scan: (P, S) squared distances.

    Each pair's leaf block streams from the (L, S, d) table straight into
    VMEM through scalar-prefetch BlockSpec index maps — no XLA-materialized
    (P, S, d) gather.  Invalid slots carry float32 max so they sort last in
    the top-k merge."""
    n_p = q_idx.shape[0]
    _, s, d = leaf_pts.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_p,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, q, l: (q[i], 0)),
            pl.BlockSpec((1, s, d), lambda i, q, l: (l[i], 0, 0)),
            pl.BlockSpec((1,), lambda i, q, l: (l[i],)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda i, q, l: (i, 0)),
    )
    return pl.pallas_call(
        _pair_dist2_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_p, s), jnp.float32),
        interpret=interpret,
    )(q_idx.astype(jnp.int32), leaf_idx.astype(jnp.int32),
      queries, leaf_pts, leaf_counts)
