"""Pallas TPU kernel: tiled squared-distance matrix for k-NN scanning.

The query-side hot loop of the paper (leaf scans during k-NN) is dominated
by distance evaluation.  The TPU-native formulation computes

    d2[q, p] = |q|^2 + |p|^2 - 2 q.p

so the inner product lands on the MXU and each (query-tile x point-tile)
block stays resident in VMEM.  Selection (top-k merge) is bandwidth-light
and runs as a plain XLA ``top_k`` over the kernel's output tiles — see
``ops.knn_topk`` for the fused pipeline.

Padding rows (row_id < 0, e.g. FMBI's partial-page sentinels) are masked to
+inf so they never enter a result set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_QT = 256
DEFAULT_PT = 512


def _dist2_kernel(q_ref, p_ref, valid_ref, out_ref):
    q = q_ref[...]                    # (qt, d)
    p = p_ref[...]                    # (pt, d)
    valid = valid_ref[...]            # (pt,)
    qq = jnp.sum(q * q, axis=1)       # (qt,)
    pp = jnp.sum(p * p, axis=1)       # (pt,)
    cross = jax.lax.dot_general(      # MXU: (qt, d) x (pt, d)^T
        q, p, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = qq[:, None] + pp[None, :] - 2.0 * cross
    d2 = jnp.maximum(d2, 0.0)         # numeric floor
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    out_ref[...] = jnp.where(valid[None, :] > 0, d2, big)


def _gathered_dist2_kernel(q_ref, p_ref, valid_ref, out_ref):
    q = q_ref[...]                    # (1, d)
    p = p_ref[...]                    # (1, pt, d)
    valid = valid_ref[...]            # (1, pt)
    acc = jnp.zeros(p.shape[:2], jnp.float32)
    for k in range(p.shape[2]):       # static unroll over dimensions keeps
        diff = p[..., k] - q[:, k][:, None]   # the working set at one plane
        acc = acc + diff * diff
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    out_ref[...] = jnp.where(valid > 0, acc, big)


@functools.partial(jax.jit, static_argnames=("pt", "interpret"))
def gathered_dist2(
    queries: jnp.ndarray,   # (nq, d) float32
    points: jnp.ndarray,    # (nq, npp, d) float32, npp % pt == 0
    valid: jnp.ndarray,     # (nq, npp) int32: 1 = real candidate, 0 = padding
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq, npp) masked squared distances, per-query gathered layout.

    This is the candidate-leaf scan of the device query engine: each query
    brings its own gathered candidate points (the contents of its closest
    leaves, padded to a fixed shape).  Query-major grid, one query row per
    block — the same layout as ``window_filter.window_count_gathered``.
    Selection (top-k merge) runs as plain XLA ``top_k`` on the output, which
    the consumer fuses.
    """
    nq, npp, d = points.shape
    assert npp % pt == 0, "pad the candidate axis to a tile multiple"
    grid = (nq, npp // pt)
    return pl.pallas_call(
        _gathered_dist2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, pt, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, npp), jnp.float32),
        interpret=interpret,
    )(queries, points, valid)


@functools.partial(
    jax.jit, static_argnames=("qt", "pt", "interpret")
)
def pairwise_dist2(
    queries: jnp.ndarray,   # (nq, d) float32, nq % qt == 0
    points: jnp.ndarray,    # (np, d) float32, np % pt == 0
    valid: jnp.ndarray,     # (np,) int32: 1 = real point, 0 = padding
    *,
    qt: int = DEFAULT_QT,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq, np) masked squared distances, computed in VMEM tiles."""
    nq, d = queries.shape
    n_p = points.shape[0]
    assert nq % qt == 0 and n_p % pt == 0, "pad inputs to tile multiples"
    grid = (nq // qt, n_p // pt)
    return pl.pallas_call(
        _dist2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((pt, d), lambda i, j: (j, 0)),
            pl.BlockSpec((pt,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((qt, pt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, n_p), jnp.float32),
        interpret=interpret,
    )(queries, points, valid)
