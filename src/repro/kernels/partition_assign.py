"""Pallas TPU kernel: point -> subspace routing through a flat SplitTree.

This is FMBI's Step-2 hot loop (every point of the dataset traverses the
Major SplitTree once).  The TPU-native adaptation (DESIGN.md section 2):

  * the point stream is tiled into VMEM blocks (the "pages" of the paper's
    linear scan — one HBM read per point);
  * the per-point tree traversal uses **one-hot matmuls** instead of dynamic
    gathers: selecting ``split_val[level, g]`` for a tile of group ids ``g``
    becomes ``onehot(g) @ split_val[level]``, which maps onto the MXU rather
    than fighting TPU's lack of fast per-lane gathers;
  * the split tables live fully in VMEM (levels x 2^levels floats — a few
    KiB for any realistic branch capacity).

The tree layout is the *heap-form* balanced tree produced by
``core.jax_index.build`` (split tables indexed [level, group]), which is how
FMBI's Step-1/Step-3 median trees are represented on device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE = 1024


def _route_kernel(points_ref, dim_onehot_ref, split_val_ref, out_ref,
                  *, levels: int, n_groups: int):
    pts = points_ref[...]                      # (tile, d) f32
    tile = pts.shape[0]
    g = jnp.zeros((tile,), dtype=jnp.int32)
    group_ids = jax.lax.broadcasted_iota(jnp.int32, (tile, n_groups), 1)
    for level in range(levels):                # static unroll: tree depth
        onehot = (g[:, None] == group_ids).astype(pts.dtype)  # (tile, G)
        # gather-free selects: MXU matmuls against the level's tables
        val = onehot @ split_val_ref[level]                   # (tile,)
        dim_sel = onehot @ dim_onehot_ref[level]              # (tile, d)
        coord = jnp.sum(pts * dim_sel, axis=1)                # (tile,)
        g = g * 2 + (coord > val).astype(jnp.int32)
    out_ref[...] = g


@functools.partial(jax.jit, static_argnames=("levels", "tile", "interpret"))
def partition_assign(
    points: jnp.ndarray,       # (n, d) float32, n % tile == 0
    split_dim: jnp.ndarray,    # (levels, n_groups) int32
    split_val: jnp.ndarray,    # (levels, n_groups) float32
    *,
    levels: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """Leaf/subspace id per point.  ``interpret=True`` runs the kernel body
    on CPU for validation; on TPU pass ``interpret=False``."""
    n, d = points.shape
    n_groups = split_val.shape[1]
    assert n % tile == 0, "pad the point stream to a tile multiple"
    # sanitize padded table entries: 0 * inf = NaN would poison the one-hot
    # matmul, so unused (never-selected) slots become a large finite value
    big = jnp.asarray(jnp.finfo(jnp.float32).max, split_val.dtype)
    split_val = jnp.where(jnp.isfinite(split_val), split_val, big)
    # one-hot of split dimension per (level, group): (levels, G, d)
    dim_onehot = jax.nn.one_hot(split_dim, d, dtype=points.dtype)
    grid = (n // tile,)
    kernel = functools.partial(
        _route_kernel, levels=levels, n_groups=n_groups
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((levels, n_groups, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((levels, n_groups), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(points, dim_onehot, split_val)
