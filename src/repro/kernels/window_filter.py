"""Pallas TPU kernel: tiled window-containment counting for range queries.

The leaf-scan stage of batched window queries reduces to: for each query
box, count the candidate points falling inside it.  On TPU this is a pure
VPU problem — per (query-tile x point-tile) block the 2d coordinate
comparisons and the popcount reduction stay resident in VMEM, and the
per-query partial counts are accumulated across point tiles by revisiting
the output block along the innermost grid dimension (the standard Pallas
reduction idiom: zero on the first visit, ``+=`` afterwards).

Two layouts are provided:

  * :func:`window_count_tiles` — one shared point set scanned by every
    query (the flat leaf table);
  * :func:`window_count_gathered` — each query brings its own gathered
    candidate points, the shape ``core.jax_index.window_count`` produces
    after leaf-level pruning (query-major grid, one query row per block).

Padding points carry ``valid == 0`` and never count, mirroring the row_id
sentinel convention of ``kernels/knn_topk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_QT = 128
DEFAULT_PT = 512

# VMEM budget for one tiled block's working set (inputs + output), well
# under the ~16 MB/core so the pipeline can keep two blocks in flight
VMEM_TILE_BUDGET = 4 * 1024 * 1024


def vmem_tiles(n: int, q: int, d: int, in_bytes: int = 4,
               budget: int = VMEM_TILE_BUDGET) -> tuple[int, int]:
    """(nt, qt) tile sizes for an (n x q) box-test grid whose per-block
    working set — two (nt, d) bound tiles, two (qt, d) query tiles, and the
    (nt, qt) output plane — fits ``budget`` bytes of VMEM.

    Tiles respect the TPU minimums (8 sublanes x 128 lanes for f32; the
    bf16 bound tiles are cast to f32 in-register, so f32 minimums apply)
    and shrink the box axis first: the query axis is the broadcast axis,
    so a wide qt amortizes bound loads across more queries."""
    qt = min(128, _pow2_ceil(q))
    nt = 1024

    def block_bytes(nt_, qt_):
        return 2 * nt_ * d * in_bytes + 2 * qt_ * d * 4 + nt_ * qt_ * 4

    while nt > 8 and block_bytes(nt, qt) > budget:
        nt //= 2
    return max(nt, 8), max(qt, 8)


def _pow2_ceil(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


def _tiles_kernel(lo_ref, hi_ref, p_ref, valid_ref, out_ref):
    j = pl.program_id(1)
    lo = lo_ref[...]                  # (qt, d)
    hi = hi_ref[...]                  # (qt, d)
    p = p_ref[...]                    # (pt, d)
    valid = valid_ref[...]            # (pt,)
    acc = jnp.broadcast_to(valid[None, :] > 0, (lo.shape[0], p.shape[0]))
    for k in range(p.shape[1]):       # static unroll over dimensions keeps
        pk = p[:, k][None, :]         # the working set at one (qt, pt) plane
        acc = acc & (pk >= lo[:, k][:, None]) & (pk <= hi[:, k][:, None])
    cnt = jnp.sum(acc.astype(jnp.int32), axis=1)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += cnt


@functools.partial(jax.jit, static_argnames=("qt", "pt", "interpret"))
def window_count_tiles(
    lo: jnp.ndarray,        # (nq, d) float32, nq % qt == 0
    hi: jnp.ndarray,        # (nq, d) float32
    points: jnp.ndarray,    # (np, d) float32, np % pt == 0
    valid: jnp.ndarray,     # (np,) int32: 1 = real point, 0 = padding
    *,
    qt: int = DEFAULT_QT,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq,) in-window point counts over one shared point table."""
    nq, d = lo.shape
    n_p = points.shape[0]
    assert nq % qt == 0 and n_p % pt == 0, "pad inputs to tile multiples"
    grid = (nq // qt, n_p // pt)
    return pl.pallas_call(
        _tiles_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((qt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((pt, d), lambda i, j: (j, 0)),
            pl.BlockSpec((pt,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((qt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(lo, hi, points, valid)


def _gathered_mask_kernel(lo_ref, hi_ref, p_ref, valid_ref, out_ref):
    lo = lo_ref[...]                  # (1, d)
    hi = hi_ref[...]                  # (1, d)
    p = p_ref[...]                    # (1, pt, d)
    valid = valid_ref[...]            # (1, pt)
    acc = valid > 0
    for k in range(p.shape[2]):
        pk = p[..., k]                # (1, pt)
        acc = acc & (pk >= lo[:, k][:, None]) & (pk <= hi[:, k][:, None])
    out_ref[...] = acc.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("pt", "interpret"))
def window_mask_gathered(
    lo: jnp.ndarray,        # (nq, d) float32
    hi: jnp.ndarray,        # (nq, d) float32
    points: jnp.ndarray,    # (nq, npp, d) float32, npp % pt == 0
    valid: jnp.ndarray,     # (nq, npp) int32
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq, npp) per-candidate containment mask (1 = inside the query box).

    The *collection* variant of :func:`window_count_gathered`: instead of
    reducing to a count it keeps the full mask so the device query engine
    can pack the qualifying candidate ids into its fixed-shape result
    buffer.  Pure map over (query, candidate-tile) blocks — no revisit
    accumulation is needed.
    """
    nq, npp, d = points.shape
    assert npp % pt == 0, "pad the candidate axis to a tile multiple"
    grid = (nq, npp // pt)
    return pl.pallas_call(
        _gathered_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, pt, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, npp), jnp.int32),
        interpret=interpret,
    )(lo, hi, points, valid)


def _gathered_kernel(lo_ref, hi_ref, p_ref, valid_ref, out_ref):
    j = pl.program_id(1)
    lo = lo_ref[...]                  # (1, d)
    hi = hi_ref[...]                  # (1, d)
    p = p_ref[...]                    # (1, pt, d)
    valid = valid_ref[...]            # (1, pt)
    acc = valid > 0
    for k in range(p.shape[2]):
        pk = p[..., k]                # (1, pt)
        acc = acc & (pk >= lo[:, k][:, None]) & (pk <= hi[:, k][:, None])
    cnt = jnp.sum(acc.astype(jnp.int32), axis=1)  # (1,)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += cnt


@functools.partial(jax.jit, static_argnames=("pt", "interpret"))
def window_count_gathered(
    lo: jnp.ndarray,        # (nq, d) float32
    hi: jnp.ndarray,        # (nq, d) float32
    points: jnp.ndarray,    # (nq, npp, d) float32, npp % pt == 0
    valid: jnp.ndarray,     # (nq, npp) int32
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq,) in-window counts; each query scans its own gathered points."""
    nq, npp, d = points.shape
    assert npp % pt == 0, "pad the candidate axis to a tile multiple"
    grid = (nq, npp // pt)
    return pl.pallas_call(
        _gathered_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, pt, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(lo, hi, points, valid)


# --------------------------------------------------------------------------
# second-generation tiled kernels (fused traversal + scan; see ops.py)
# --------------------------------------------------------------------------
def _box_hits_kernel(lo_ref, hi_ref, qlo_ref, qhi_ref, out_ref):
    lo = lo_ref[...].astype(jnp.float32)    # (nt, d) box lows (f32 or bf16)
    hi = hi_ref[...].astype(jnp.float32)    # (nt, d)
    qlo = qlo_ref[...]                      # (qt, d) query lows, f32
    qhi = qhi_ref[...]                      # (qt, d)
    acc = None
    for k in range(lo.shape[1]):            # static unroll over dimensions:
        h = (lo[:, k][:, None] <= qhi[:, k][None, :]) & (
            hi[:, k][:, None] >= qlo[:, k][None, :]
        )                                   # one (nt, qt) plane at a time
        acc = h if acc is None else acc & h
    out_ref[...] = acc.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("nt", "qt", "interpret"))
def box_hits_tiled(
    lo: jnp.ndarray,        # (n, d) box lows (f32, or outward-rounded bf16)
    hi: jnp.ndarray,        # (n, d)
    qlo: jnp.ndarray,       # (nq, d) float32 query window lows, nq % qt == 0
    qhi: jnp.ndarray,       # (nq, d)
    *,
    nt: int = DEFAULT_PT,
    qt: int = DEFAULT_QT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n, nq) int32 box-intersection mask, VMEM-tiled over both axes.

    The per-level frontier box test of the device query engine: one level
    block's MBB columns against the whole query batch.  Bound tiles may be
    bf16 (the compressed-MBB layout) — they are widened to f32 in-register,
    so only the *storage* (and therefore the HBM traffic) is halved; the
    comparison itself is exact on the outward-rounded bounds, which keeps
    the hit mask a superset of the f32 mask (never a false negative)."""
    n, d = lo.shape
    nq = qlo.shape[0]
    assert n % nt == 0 and nq % qt == 0, "pad inputs to tile multiples"
    grid = (n // nt, nq // qt)
    return pl.pallas_call(
        _box_hits_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((nt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((qt, d), lambda i, j: (j, 0)),
            pl.BlockSpec((qt, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((nt, qt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, nq), jnp.int32),
        interpret=interpret,
    )(lo, hi, qlo, qhi)


def _pair_window_ids_kernel(
    q_idx_ref, leaf_idx_ref, pv_ref,        # scalar prefetch (SMEM)
    qlo_ref, qhi_ref, llo_ref, lhi_ref, pts_ref, ids_ref, cnt_ref,
    out_ids_ref, out_cnt_ref,
):
    i = pl.program_id(0)
    qlo = qlo_ref[...]                      # (1, d) this pair's query box
    qhi = qhi_ref[...]
    llo = llo_ref[...].astype(jnp.float32)  # (1, d) exact f32 leaf MBB
    lhi = lhi_ref[...].astype(jnp.float32)
    p = pts_ref[...]                        # (1, S, d) this pair's leaf block
    ids = ids_ref[...]                      # (1, S)
    cnt = cnt_ref[...]                      # (1,) live slots in the block
    s = p.shape[1]
    valid = (
        jax.lax.broadcasted_iota(jnp.int32, (1, s), 1) < cnt[:, None]
    ) & (pv_ref[i] > 0)
    # certified f32 re-check of the pair's leaf box: a pair surfaced by the
    # widened bf16 frontier whose exact MBB misses the window is dropped
    # here, before its slots can cost a containment test
    box_ok = None
    for k in range(p.shape[2]):
        ok = (llo[:, k] <= qhi[:, k]) & (lhi[:, k] >= qlo[:, k])
        box_ok = ok if box_ok is None else box_ok & ok
    acc = valid & box_ok[:, None]
    for k in range(p.shape[2]):             # exact containment on f32 points
        pk = p[..., k]                      # (1, S)
        acc = acc & (pk >= qlo[:, k][:, None]) & (pk <= qhi[:, k][:, None])
    out_ids_ref[...] = jnp.where(acc, ids, -1)
    out_cnt_ref[...] = jnp.sum(acc.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pair_window_ids(
    qlo: jnp.ndarray,       # (nq, d) float32 query window lows
    qhi: jnp.ndarray,       # (nq, d)
    leaf_lo: jnp.ndarray,   # (L, d) exact f32 leaf MBB lows
    leaf_hi: jnp.ndarray,   # (L, d)
    leaf_pts: jnp.ndarray,  # (L, S, d) float32 leaf-blocked points
    leaf_ids: jnp.ndarray,  # (L, S) int32 dataset rows, pad = -1
    leaf_counts: jnp.ndarray,  # (L,) int32 live slots per block
    q_idx: jnp.ndarray,     # (P,) int32 query of each candidate pair
    leaf_idx: jnp.ndarray,  # (P,) int32 leaf slot of each candidate pair
    pair_valid: jnp.ndarray,  # (P,) int32 padding mask
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused (query, leaf) pair scan: ``(ids_or (P, S), counts (P,))``.

    ``ids_or[p, s]`` is the dataset row of slot ``s`` of pair ``p``'s leaf
    when the point lies inside the pair's query window, else ``-1``; the
    device packing stage compacts the non-negatives.  The pair's leaf block
    and id row are pulled straight from the (L, S, d) leaf table into VMEM
    through scalar-prefetch BlockSpec index maps — the gather that the
    first-generation path materialized as an XLA (P, S, d) temporary is
    fused into the kernel's block streaming."""
    n_p = q_idx.shape[0]
    _, s, d = leaf_pts.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_p,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, q, l, pv: (q[i], 0)),
            pl.BlockSpec((1, d), lambda i, q, l, pv: (q[i], 0)),
            pl.BlockSpec((1, d), lambda i, q, l, pv: (l[i], 0)),
            pl.BlockSpec((1, d), lambda i, q, l, pv: (l[i], 0)),
            pl.BlockSpec((1, s, d), lambda i, q, l, pv: (l[i], 0, 0)),
            pl.BlockSpec((1, s), lambda i, q, l, pv: (l[i], 0)),
            pl.BlockSpec((1,), lambda i, q, l, pv: (l[i],)),
        ],
        out_specs=[
            pl.BlockSpec((1, s), lambda i, q, l, pv: (i, 0)),
            pl.BlockSpec((1,), lambda i, q, l, pv: (i,)),
        ],
    )
    return pl.pallas_call(
        _pair_window_ids_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_p, s), jnp.int32),
            jax.ShapeDtypeStruct((n_p,), jnp.int32),
        ],
        interpret=interpret,
    )(
        q_idx.astype(jnp.int32), leaf_idx.astype(jnp.int32),
        pair_valid.astype(jnp.int32),
        qlo, qhi, leaf_lo, leaf_hi, leaf_pts, leaf_ids, leaf_counts,
    )
