"""Pallas TPU kernel: tiled window-containment counting for range queries.

The leaf-scan stage of batched window queries reduces to: for each query
box, count the candidate points falling inside it.  On TPU this is a pure
VPU problem — per (query-tile x point-tile) block the 2d coordinate
comparisons and the popcount reduction stay resident in VMEM, and the
per-query partial counts are accumulated across point tiles by revisiting
the output block along the innermost grid dimension (the standard Pallas
reduction idiom: zero on the first visit, ``+=`` afterwards).

Two layouts are provided:

  * :func:`window_count_tiles` — one shared point set scanned by every
    query (the flat leaf table);
  * :func:`window_count_gathered` — each query brings its own gathered
    candidate points, the shape ``core.jax_index.window_count`` produces
    after leaf-level pruning (query-major grid, one query row per block).

Padding points carry ``valid == 0`` and never count, mirroring the row_id
sentinel convention of ``kernels/knn_topk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_QT = 128
DEFAULT_PT = 512


def _tiles_kernel(lo_ref, hi_ref, p_ref, valid_ref, out_ref):
    j = pl.program_id(1)
    lo = lo_ref[...]                  # (qt, d)
    hi = hi_ref[...]                  # (qt, d)
    p = p_ref[...]                    # (pt, d)
    valid = valid_ref[...]            # (pt,)
    acc = jnp.broadcast_to(valid[None, :] > 0, (lo.shape[0], p.shape[0]))
    for k in range(p.shape[1]):       # static unroll over dimensions keeps
        pk = p[:, k][None, :]         # the working set at one (qt, pt) plane
        acc = acc & (pk >= lo[:, k][:, None]) & (pk <= hi[:, k][:, None])
    cnt = jnp.sum(acc.astype(jnp.int32), axis=1)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += cnt


@functools.partial(jax.jit, static_argnames=("qt", "pt", "interpret"))
def window_count_tiles(
    lo: jnp.ndarray,        # (nq, d) float32, nq % qt == 0
    hi: jnp.ndarray,        # (nq, d) float32
    points: jnp.ndarray,    # (np, d) float32, np % pt == 0
    valid: jnp.ndarray,     # (np,) int32: 1 = real point, 0 = padding
    *,
    qt: int = DEFAULT_QT,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq,) in-window point counts over one shared point table."""
    nq, d = lo.shape
    n_p = points.shape[0]
    assert nq % qt == 0 and n_p % pt == 0, "pad inputs to tile multiples"
    grid = (nq // qt, n_p // pt)
    return pl.pallas_call(
        _tiles_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((qt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((qt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((pt, d), lambda i, j: (j, 0)),
            pl.BlockSpec((pt,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((qt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(lo, hi, points, valid)


def _gathered_mask_kernel(lo_ref, hi_ref, p_ref, valid_ref, out_ref):
    lo = lo_ref[...]                  # (1, d)
    hi = hi_ref[...]                  # (1, d)
    p = p_ref[...]                    # (1, pt, d)
    valid = valid_ref[...]            # (1, pt)
    acc = valid > 0
    for k in range(p.shape[2]):
        pk = p[..., k]                # (1, pt)
        acc = acc & (pk >= lo[:, k][:, None]) & (pk <= hi[:, k][:, None])
    out_ref[...] = acc.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("pt", "interpret"))
def window_mask_gathered(
    lo: jnp.ndarray,        # (nq, d) float32
    hi: jnp.ndarray,        # (nq, d) float32
    points: jnp.ndarray,    # (nq, npp, d) float32, npp % pt == 0
    valid: jnp.ndarray,     # (nq, npp) int32
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq, npp) per-candidate containment mask (1 = inside the query box).

    The *collection* variant of :func:`window_count_gathered`: instead of
    reducing to a count it keeps the full mask so the device query engine
    can pack the qualifying candidate ids into its fixed-shape result
    buffer.  Pure map over (query, candidate-tile) blocks — no revisit
    accumulation is needed.
    """
    nq, npp, d = points.shape
    assert npp % pt == 0, "pad the candidate axis to a tile multiple"
    grid = (nq, npp // pt)
    return pl.pallas_call(
        _gathered_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, pt, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, npp), jnp.int32),
        interpret=interpret,
    )(lo, hi, points, valid)


def _gathered_kernel(lo_ref, hi_ref, p_ref, valid_ref, out_ref):
    j = pl.program_id(1)
    lo = lo_ref[...]                  # (1, d)
    hi = hi_ref[...]                  # (1, d)
    p = p_ref[...]                    # (1, pt, d)
    valid = valid_ref[...]            # (1, pt)
    acc = valid > 0
    for k in range(p.shape[2]):
        pk = p[..., k]                # (1, pt)
        acc = acc & (pk >= lo[:, k][:, None]) & (pk <= hi[:, k][:, None])
    cnt = jnp.sum(acc.astype(jnp.int32), axis=1)  # (1,)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += cnt


@functools.partial(jax.jit, static_argnames=("pt", "interpret"))
def window_count_gathered(
    lo: jnp.ndarray,        # (nq, d) float32
    hi: jnp.ndarray,        # (nq, d) float32
    points: jnp.ndarray,    # (nq, npp, d) float32, npp % pt == 0
    valid: jnp.ndarray,     # (nq, npp) int32
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jnp.ndarray:
    """(nq,) in-window counts; each query scans its own gathered points."""
    nq, npp, d = points.shape
    assert npp % pt == 0, "pad the candidate axis to a tile multiple"
    grid = (nq, npp // pt)
    return pl.pallas_call(
        _gathered_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, pt, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, pt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(lo, hi, points, valid)
