"""Resilience primitives for the serving stack: retry, deadline, breaker.

The policies are deliberately small and injectable — every source of
nondeterminism (sleep, clock, jitter randomness) is a constructor
argument, so tests drive them with virtual clocks and zero-length sleeps
while production uses the real ones.

  * :class:`RetryPolicy` — bounded attempts with exponential backoff and
    seeded jitter.  Retries any exception in ``retry_on`` except the
    explicit ``no_retry`` types (a :class:`DeadlineExceeded` or an
    upstream ``ShardUnavailable`` must propagate, not burn attempts).
  * :class:`Deadline` — a per-batch time budget.  Backoff sleeps never
    overshoot it, and ``check()`` raises :class:`DeadlineExceeded` once
    it is spent, turning a slow failing dependency into a prompt
    degraded answer instead of an unbounded stall.
  * :class:`CircuitBreaker` — per-shard closed/open/half-open state.
    ``failure_threshold`` consecutive dispatch failures open the
    breaker; while open, calls fail fast (no device dispatch, no retry
    burn) until ``cooldown_s`` has elapsed, then a single half-open
    trial either closes it or re-opens it.  The serving layer keeps one
    breaker per shard so a dead shard degrades only its own subspace.
  * :class:`TableLock` — a writer-preferring readers-writer lock.  The
    async frontend races device dispatches (readers of the host
    ``NodeTable``) against adaptive refinement (``graft`` /
    ``apply_delta`` / ``compact`` — writers); the lock makes that safe
    while keeping the common read path concurrent.  Writer preference
    means a query storm cannot starve refinement.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
import zlib
from typing import Callable, Optional

import numpy as np

from ..analysis import runtime as _san


def _call_id(key) -> int:
    """Stable 32-bit id for a retry call site (pure function of the key)."""
    if key is None:
        return 0
    if isinstance(key, (int, np.integer)):
        return int(key) & 0xFFFFFFFF
    return zlib.crc32(repr(key).encode()) & 0xFFFFFFFF


class DeadlineExceeded(RuntimeError):
    """The per-batch time budget is spent."""


class RetryExhausted(RuntimeError):
    """Every attempt failed; ``__cause__`` is the last failure."""

    def __init__(self, attempts: int, last: BaseException):
        self.attempts = attempts
        super().__init__(
            f"all {attempts} attempts failed "
            f"(last: {type(last).__name__}: {last})"
        )


class Deadline:
    """Monotonic time budget; ``Deadline(None)`` never expires."""

    def __init__(self, seconds: Optional[float],
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.seconds = seconds
        self._t0 = clock()

    def remaining(self) -> float:
        if self.seconds is None:
            return float("inf")
        return self.seconds - (self.clock() - self._t0)

    @property
    def expired(self) -> bool:
        return self.remaining() <= 0.0

    def check(self) -> None:
        if self.expired:
            raise DeadlineExceeded(
                f"batch deadline of {self.seconds}s exceeded"
            )


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retries with exponential backoff + seeded jitter.

    Attempt ``i`` (0-based) sleeps ``base_delay_s * backoff**i`` scaled
    by a jitter factor drawn uniformly from ``[1 - jitter, 1 + jitter]``,
    capped at ``max_delay_s`` and at the deadline's remaining budget.
    ``max_attempts=1`` means no retries.

    The jitter draw is a *pure function* of ``(seed, call-id, attempt)``
    — there is no shared rng stream, so concurrent :meth:`call`\\ s from
    the async frontend's worker threads see the same delays no matter
    how the scheduler interleaves them.  Callers that run concurrently
    pass distinct ``call_key``\\ s (e.g. the shard id) to decorrelate
    their jitter; the key is hashed stably, never by ``id()``.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.0      # serving tests want zero-cost retries
    backoff: float = 2.0
    max_delay_s: float = 1.0
    jitter: float = 0.5
    seed: int = 0
    sleep: Callable[[float], None] = time.sleep

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")

    def delay(self, attempt: int, call_id: int = 0) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        raw = self.base_delay_s * (self.backoff ** (attempt - 1))
        if raw <= 0.0:
            return 0.0
        rng = np.random.default_rng([self.seed, call_id, attempt])
        factor = 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return float(min(raw * max(factor, 0.0), self.max_delay_s))

    def call(
        self,
        fn: Callable,
        *,
        retry_on: tuple = (Exception,),
        no_retry: tuple = (DeadlineExceeded,),
        deadline: Optional[Deadline] = None,
        on_retry: Optional[Callable[[int, BaseException], None]] = None,
        call_key=None,
    ):
        """Run ``fn`` under the policy; raises :class:`RetryExhausted`
        (with the last failure as ``__cause__``) when attempts run out,
        or :class:`DeadlineExceeded` when the budget is spent first."""
        cid = _call_id(call_key)
        last: Optional[BaseException] = None
        for attempt in range(1, self.max_attempts + 1):
            if deadline is not None:
                deadline.check()
            try:
                return fn()
            except no_retry:
                raise
            except retry_on as e:
                last = e
                if attempt == self.max_attempts:
                    break
                if on_retry is not None:
                    on_retry(attempt, e)
                pause = self.delay(attempt, cid)
                if deadline is not None:
                    deadline.check()
                    pause = min(pause, max(deadline.remaining(), 0.0))
                if pause > 0.0:
                    self.sleep(pause)
        raise RetryExhausted(self.max_attempts, last) from last


class CircuitBreaker:
    """Per-dependency closed / open / half-open gate.

    ``record_failure`` counts *consecutive* failures (each already
    retry-exhausted by the caller); at ``failure_threshold`` the breaker
    opens and :meth:`allow` fails fast until ``cooldown_s`` of the
    injected clock has passed, after which exactly one half-open trial
    is admitted — success closes the breaker, failure re-opens it for
    another cooldown.
    """

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.state = "closed"
        self.failures = 0            # consecutive
        self.opened_at: Optional[float] = None
        self.open_count = 0          # times the breaker tripped open

    def allow(self) -> bool:
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock() - self.opened_at >= self.cooldown_s:
                self.state = "half_open"
                return True
            return False
        return False  # half_open: the single trial is already in flight

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0
        self.opened_at = None

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.failure_threshold:
            if self.state != "open":
                self.open_count += 1
            self.state = "open"
            self.opened_at = self.clock()

    def reset(self) -> None:
        """Force-close (the repair path: the shard was just rebuilt)."""
        self.record_success()


class TableLock:
    """Writer-preferring readers-writer lock for the serving-time table.

    Device dispatches and cold-mask computations *read* the host
    ``NodeTable``; adaptive refinement (``graft``), delta uploads, shard
    re-exports, ``compact`` row remaps, and shard ``repair`` *write* it.
    Before this lock the adaptive path mutated the table with no
    synchronization at all — safe only because ``DeviceQueryServer`` was
    called from one thread; the async frontend overlaps a device worker
    with host refinement, so the races became real.

    Semantics: any number of concurrent readers, one writer, and a
    waiting writer blocks *new* readers (writer preference — a query
    storm cannot starve refinement).  Not reentrant: a thread must
    never nest acquisitions, which the serving code honors by releasing
    its read section before entering a write section.

    Under ``REPRO_SANITIZE=1`` every acquisition reports to
    :mod:`repro.analysis.runtime` *before blocking*: same-thread
    re-entry and cross-lock acquisition-order inversions raise instead
    of deadlocking, and :meth:`held_write` lets guarded mutators assert
    the writer section is really held by the calling thread.
    """

    def __init__(self, name: str = "table_lock"):
        self.name = name
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writer_thread = None
        self._writers_waiting = 0

    def held_write(self) -> bool:
        """True iff the *calling thread* holds the writer section."""
        return self._writer and self._writer_thread == threading.get_ident()

    @contextlib.contextmanager
    def read(self):
        _san.note_acquire(self, "read", self.name)
        try:
            with self._cond:
                while self._writer or self._writers_waiting:
                    self._cond.wait()
                self._readers += 1
            try:
                yield
            finally:
                with self._cond:
                    self._readers -= 1
                    if self._readers == 0:
                        self._cond.notify_all()
        finally:
            _san.note_release(self)

    @contextlib.contextmanager
    def write(self):
        _san.note_acquire(self, "write", self.name)
        try:
            with self._cond:
                self._writers_waiting += 1
                try:
                    while self._writer or self._readers:
                        self._cond.wait()
                finally:
                    self._writers_waiting -= 1
                self._writer = True
                self._writer_thread = threading.get_ident()
            try:
                yield
            finally:
                with self._cond:
                    self._writer = False
                    self._writer_thread = None
                    self._cond.notify_all()
        finally:
            _san.note_release(self)
