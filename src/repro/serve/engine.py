"""Serving engine: batched prefill/decode plus FMBI-backed kNN retrieval.

``LMServer`` is the generation path: continuous batched decode over a shared
cache pytree (prefill once, then step).  ``RetrievalServer`` serves batched
kNN/window queries over an FMBI ``JaxIndex``; in ``adaptive=True`` mode it
applies AMBI's residency policy — only leaves that the live query stream
touches are kept "hot" (the TPU analogue of the paper's buffer retention),
with hit statistics exposed for the workload-adaptation benchmark.
``DeviceQueryServer`` serves batched window and k-NN traffic straight off a
bulk-loaded ``NodeTable`` through the compiled ``queries_jax`` engine, with
microbatching so arbitrary client batch sizes reuse a bounded set of
compiled variants.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from ..core import jax_index
from ..kernels import ops as kops
from ..models import model as M
from ..models.sharding import MeshAxes


class LMServer:
    def __init__(self, cfg, params, axes: MeshAxes | None = None):
        self.cfg = cfg
        self.params = params
        self.axes = axes or MeshAxes()
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, self.axes)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos, self.axes)
        )

    def generate(self, tokens: np.ndarray, max_new: int,
                 cache_len: int | None = None) -> np.ndarray:
        """Greedy generation for a (B, S) prompt batch."""
        B, S = tokens.shape
        cache_len = cache_len or (S + max_new)
        lg, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        cache = jax.tree.map(
            lambda x: (
                jnp.concatenate(
                    [x, jnp.zeros(
                        x.shape[:2] + (cache_len - S,) + x.shape[3:], x.dtype
                    )], axis=2,
                )
                if x.ndim >= 3 and x.shape[2] == S
                else x
            ),
            cache,
        )
        out = [jnp.argmax(lg[:, -1], axis=-1)]
        for t in range(max_new - 1):
            pos = jnp.full((B,), S + t, jnp.int32)
            lg, cache = self._decode(
                self.params, out[-1][:, None].astype(jnp.int32), cache, pos
            )
            out.append(jnp.argmax(lg[:, 0], axis=-1))
        return np.stack([np.asarray(o) for o in out], axis=1)


@dataclasses.dataclass
class RetrievalStats:
    queries: int = 0
    hot_hits: int = 0
    cold_misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hot_hits + self.cold_misses
        return self.hot_hits / total if total else 0.0


class RetrievalServer:
    """Batched exact kNN over an FMBI JaxIndex (Pallas distance kernel).

    Two boot paths: build a balanced index from raw points (``__init__``),
    or bridge a bulk-loaded CPU ``NodeTable`` snapshot straight into the
    accelerator layout (``from_snapshot``) — no rebuild, no re-sort.
    """

    def __init__(self, points: np.ndarray, levels: int, *,
                 adaptive: bool = False, hot_capacity: int = 64):
        padded, ids = jax_index.pad_points(points.astype(np.float32), levels)
        self.index = jax_index.build(
            jnp.asarray(padded), levels, jnp.asarray(ids, jnp.int32)
        )
        self._routed = True  # built indexes carry split tables for route()
        self._init_serving(levels, adaptive, hot_capacity)

    @classmethod
    def from_snapshot(cls, path, *, adaptive: bool = False,
                      hot_capacity: int = 64) -> "RetrievalServer":
        """Boot from a ``NodeTable.save`` snapshot (``.npz`` with points).

        The snapshot's leaf-contiguous layout maps directly onto the
        ``JaxIndex`` grid via ``NodeTable.to_jax_index``; adaptive residency
        falls back to ``nearest_leaf`` because a bridged FMBI tree has no
        balanced split tables.
        """
        from ..core.nodetable import NodeTable

        table, _meta, points = NodeTable.load(path)
        if points is None:
            raise ValueError("snapshot was saved without points")
        self = cls.__new__(cls)
        self.index = table.to_jax_index(np.asarray(points))
        self._routed = False
        self._init_serving(self.index.levels, adaptive, hot_capacity)
        return self

    def _init_serving(self, levels: int, adaptive: bool,
                      hot_capacity: int) -> None:
        self.levels = levels
        self.adaptive = adaptive
        # leaf -> last-touch tick, insertion-ordered: recency order IS the
        # dict order (same structure as pagestore.LRUBuffer), so eviction is
        # popitem(last=False) instead of an O(capacity) min() scan per query
        self.hot: OrderedDict[int, int] = OrderedDict()
        self.hot_capacity = hot_capacity
        self.tick = 0
        self.stats = RetrievalStats()

    def knn(self, queries: np.ndarray, k: int, n_candidate_leaves: int = 8):
        rows, d2, exact = jax_index.knn(
            self.index, jnp.asarray(queries, jnp.float32), k,
            n_candidate_leaves=n_candidate_leaves,
        )
        if self.adaptive:
            locate = jax_index.route if self._routed else jax_index.nearest_leaf
            leaves = np.asarray(
                locate(self.index, jnp.asarray(queries, jnp.float32))
            )
            for leaf in leaves:
                self.tick += 1
                leaf = int(leaf)
                if leaf in self.hot:
                    self.stats.hot_hits += 1
                    self.hot.move_to_end(leaf)
                else:
                    self.stats.cold_misses += 1
                self.hot[leaf] = self.tick
                if len(self.hot) > self.hot_capacity:
                    self.hot.popitem(last=False)  # least recent first
            self.stats.queries += len(queries)
        return np.asarray(rows), np.asarray(d2), np.asarray(exact)

    def knn_kernel(self, queries: np.ndarray, k: int):
        """Direct Pallas-kernel path (distance tiles + top-k)."""
        idx, d2 = kops.knn_topk(
            jnp.asarray(queries, jnp.float32),
            self.index.points_sorted,
            k,
            valid=(self.index.row_ids >= 0).astype(jnp.int32),
        )
        return np.asarray(idx), np.asarray(d2)


@dataclasses.dataclass
class DeviceQueryStats:
    queries: int = 0
    microbatches: int = 0
    shards: int = 1
    hot_queries: int = 0       # answered entirely on the device
    cold_queries: int = 0      # reached unindexed space -> host + refine
    grafts: int = 0            # unrefined rows refined by the serving loop
    delta_refreshes: int = 0   # DeviceTable.apply_delta swaps
    shard_refreshes: int = 0   # shards re-exported by ShardedDeviceTable
    compactions: int = 0       # NodeTable.compact vacuums
    retries: int = 0           # dispatch/refine attempts beyond the first
    host_fallbacks: int = 0    # device outage answered by the host engine
    degraded_queries: int = 0  # answers returned with an incomplete cert
    journal_records: int = 0   # ops durably journaled before execution
    checkpoints: int = 0       # snapshot barriers written
    replayed_records: int = 0  # journal records replayed at recovery


class DeviceQueryServer:
    """Batched window/k-NN serving over a ``NodeTable`` via the compiled
    device engine (``core/queries_jax.py``).

    Boots from a built CPU index (or its ``.npz`` snapshot) by exporting
    the flat table to the device once; every query batch afterwards is one
    compiled dispatch.  Incoming traffic is split into ``microbatch``-sized
    chunks — each chunk pads to a power-of-two bucket inside the engine —
    so any client batch size is served by a bounded set of compiled
    variants instead of a fresh compilation per shape.  Exactness matches
    the NumPy engine (see the queries_jax parity contract); the simulated
    LRU I/O accounting stays with the CPU path.

    ``shards=m`` serves through the *sharded* engine instead
    (``core/distributed_jax.py``): the table partitions into m per-shard
    DeviceTables behind a subspace-MBB router, windows fan out only to
    qualified shards, and k-NN runs the two-round certified protocol —
    same results, distributed execution.

    ``adaptive=True`` (boot via :meth:`from_ambi`) serves an AMBI table
    that may be arbitrarily unrefined — down to the single-unrefined-root
    state, where the device holds nothing but the root's cold box:

      * the table is exported *partially* — unrefined rows ride along as
        cold boxes the compiled frontier traversal surfaces as a mask;
      * a query that never reaches cold space is answered entirely from
        the device (no simulated I/O, the hot path);
      * a cold query is answered by the host AMBI engine, whose refiner —
        carrying that query's context explicitly — charges the paper's
        I/O and grafts the touched subspaces;
      * after each microbatch the grafts are pushed to the device
        *incrementally*: ``DeviceTable.apply_delta`` uploads only the new
        leaf blocks into a double-buffered swap (sharded serving
        re-exports only the shards owning grafted subspaces), and
        ``NodeTable.compact`` vacuums dead perm segments once grafting
        has bloated the host table past ``compact_slack``.

    Under a focused workload the hot set converges and serving detaches
    from the host entirely — the paper's adaptivity argument carried onto
    the accelerator.
    """

    def __init__(self, table, points: np.ndarray, *,
                 microbatch: int = 64, use_kernel: bool | None = None,
                 compressed: bool = False,
                 shards: int | None = None, adaptive: bool = False,
                 ambi=None, compact_slack: float = 0.5,
                 fault_plan=None, retry=None, deadline_s: float | None = None,
                 breaker_threshold: int = 3, breaker_cooldown_s: float = 30.0,
                 clock=None,
                 journal_path=None, snapshot_path=None):
        import os

        from ..core.distributed_jax import ShardedDeviceTable
        from ..core.queries_jax import DeviceTable, UploadStats
        from .journal import GraftJournal
        from .resilience import RetryPolicy, TableLock

        if adaptive:
            if ambi is None:
                raise ValueError(
                    "adaptive serving needs the host AMBI engine — boot "
                    "with DeviceQueryServer.from_ambi(ambi)"
                )
            table, points = ambi.table, ambi.points
        points = np.asarray(points)
        # resilience plane: per-server policies, injectable for tests
        self.fault_plan = fault_plan
        self.retry = retry if retry is not None else RetryPolicy()
        self.deadline_s = deadline_s
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        self.clock = clock  # None -> time.monotonic inside the primitives
        self.breakers: dict = {}
        # table RW-lock: device dispatches and cold-mask computations read
        # the host table; adaptive refinement (graft/apply_delta/compact)
        # and shard repair write it.  The async frontend overlaps a device
        # worker with host refinement, so the lock is load-bearing there;
        # single-threaded callers pay two uncontended acquisitions.
        self.table_lock = TableLock()
        # per-server upload accounting (satellite: no cross-server leakage)
        self.upload_stats = UploadStats()
        if adaptive and fault_plan is not None and ambi is not None:
            ambi.store.fault_hook = fault_plan.pagestore_hook()
        if shards is not None and shards > 1:
            self.sdev = ShardedDeviceTable.from_table(
                table, points, shards, partial=adaptive,
                stats=self.upload_stats, compressed=compressed,
            )
            self.dev = None
            n_shards = self.sdev.m
        else:
            self.dev = DeviceTable.from_table(
                table, points, partial=adaptive, stats=self.upload_stats,
                compressed=compressed,
            )
            self.sdev = None
            n_shards = 1
        self.table = table
        self.requested_shards = shards if shards is not None else 1
        self.adaptive = adaptive
        self.ambi = ambi
        self.points = points
        self.dim = int(points.shape[1])
        self.compact_slack = float(compact_slack)
        self.microbatch = int(microbatch)
        self.use_kernel = use_kernel
        self.compressed = bool(compressed)
        self.stats = DeviceQueryStats(shards=n_shards)
        # durability plane (adaptive only): write-ahead graft journal +
        # snapshot barriers; recovery = snapshot + replay (see recover())
        self.journal = None
        self.snapshot_path = None
        if journal_path is not None or snapshot_path is not None:
            if not adaptive:
                raise ValueError(
                    "journaling/snapshots apply to adaptive serving — a "
                    "static table needs no recovery log"
                )
            if journal_path is None or snapshot_path is None:
                raise ValueError(
                    "durability needs BOTH journal_path and snapshot_path "
                    "(recovery replays the journal against the snapshot)"
                )
            self.snapshot_path = os.fspath(snapshot_path)
            if not self.snapshot_path.endswith(".npz"):
                self.snapshot_path += ".npz"
            self.journal = GraftJournal(journal_path, fault_plan=fault_plan)
            if not os.path.exists(self.snapshot_path):
                # boot barrier: capture the pre-serving adaptive state so a
                # crash before the first compaction is still recoverable
                self.checkpoint()

    @classmethod
    def from_index(cls, index, **kw) -> "DeviceQueryServer":
        """From a built ``core.fmbi.Index`` (or AMBI's ``.index``)."""
        return cls(index.table, index.points, **kw)

    @classmethod
    def from_ambi(cls, ambi, **kw) -> "DeviceQueryServer":
        """Adaptive serving over a host AMBI engine (any refinement state,
        including the freshly constructed single-unrefined-root table)."""
        return cls(ambi.table, ambi.points, adaptive=True, ambi=ambi, **kw)

    @classmethod
    def from_snapshot(cls, path, **kw) -> "DeviceQueryServer":
        """From a ``NodeTable.save``/``Index.save`` snapshot with points."""
        from ..core.nodetable import NodeTable

        table, _meta, points = NodeTable.load(path)
        if points is None:
            raise ValueError("snapshot was saved without points")
        return cls(table, points, **kw)

    def _chunks(self, n: int):
        for start in range(0, n, self.microbatch):
            yield start, min(start + self.microbatch, n)

    # -- resilience plane ----------------------------------------------------
    def _breaker(self, s: int):
        from .resilience import CircuitBreaker

        br = self.breakers.get(s)
        if br is None:
            kw = {} if self.clock is None else {"clock": self.clock}
            br = self.breakers[s] = CircuitBreaker(
                self.breaker_threshold, self.breaker_cooldown_s, **kw
            )
        return br

    def _deadline(self):
        from .resilience import Deadline

        kw = {} if self.clock is None else {"clock": self.clock}
        return Deadline(self.deadline_s, **kw)

    def _count_retry(self, attempt, exc) -> None:
        self.stats.retries += 1

    def _shard_runner(self, deadline):
        """The resilience hook the sharded protocols dispatch through:
        breaker fail-fast, then bounded retries (each attempt passing the
        shard's fault point), then breaker accounting.  A shard that
        exhausts its retries surfaces as :class:`ShardUnavailable` — the
        protocol's degraded-mode signal."""
        from ..core.distributed_jax import ShardUnavailable
        from .resilience import DeadlineExceeded, RetryExhausted

        def run(s: int, thunk):
            br = self._breaker(s)
            if not br.allow():
                raise ShardUnavailable(s, "circuit open")

            def attempt():
                if self.fault_plan is not None:
                    self.fault_plan.fire("shard_dispatch", shard=int(s))
                return thunk()

            try:
                res = self.retry.call(
                    attempt, deadline=deadline,
                    no_retry=(DeadlineExceeded, ShardUnavailable),
                    on_retry=self._count_retry, call_key=("shard", int(s)),
                )
            except (DeadlineExceeded, ShardUnavailable):
                raise
            except RetryExhausted as e:
                br.record_failure()
                raise ShardUnavailable(s, str(e)) from e
            br.record_success()
            return res

        return run

    def repair(self, shard_ids=None) -> list[int]:
        """Rebuild failed shards from the host ``NodeTable`` and close
        their breakers; with no argument, repairs every shard whose
        breaker is not closed.  Returns the repaired shard ids."""
        if shard_ids is None:
            shard_ids = [
                s for s, br in self.breakers.items() if br.state != "closed"
            ]
        shard_ids = sorted(int(s) for s in shard_ids)
        if not shard_ids:
            return []
        with self.table_lock.write():
            if self.sdev is not None:
                self.sdev.refresh(shard_ids)
                self.stats.shard_refreshes += len(shard_ids)
            else:
                from ..core.queries_jax import DeviceTable

                t = self.ambi.table if self.adaptive else self.table
                self.dev = DeviceTable.from_table(
                    t, self.points, partial=self.adaptive,
                    stats=self.upload_stats, compressed=self.compressed,
                )
        for s in shard_ids:
            self._breaker(s).reset()
        return shard_ids

    def _root_cert(self):
        """Degraded certificate for a whole-table outage (single-device
        serving): the entire root MBB is unanswered."""
        from ..core.distributed_jax import CompletenessCertificate

        t = self.ambi.table if self.adaptive else self.table
        return CompletenessCertificate(
            complete=False, certified_exact=False, missing_shards=(0,),
            missing_lo=np.asarray(t.mbb_lo[0], dtype=np.float32)[None],
            missing_hi=np.asarray(t.mbb_hi[0], dtype=np.float32)[None],
        )

    # -- input validation ----------------------------------------------------
    def _validate_batch(self, arr, name: str) -> np.ndarray:
        """API-boundary validation: precise errors here instead of cryptic
        jit/traversal failures deep in the engine."""
        a = np.asarray(arr)
        if a.dtype == object or not np.issubdtype(a.dtype, np.number):
            raise ValueError(
                f"{name}: expected a numeric array, got dtype {a.dtype}"
            )
        if np.issubdtype(a.dtype, np.complexfloating):
            raise ValueError(f"{name}: complex coordinates are not supported")
        a = np.atleast_2d(a.astype(np.float64, copy=False))
        if a.ndim != 2 or a.shape[1] != self.dim:
            raise ValueError(
                f"{name}: expected shape (Q, {self.dim}) to match the "
                f"{self.dim}-dimensional dataset, got {np.asarray(arr).shape}"
            )
        if np.isnan(a).any():
            bad = int(np.flatnonzero(np.isnan(a).any(axis=1))[0])
            raise ValueError(f"{name}: query {bad} contains NaN coordinates")
        return a

    def window(self, los: np.ndarray, his: np.ndarray, *,
               return_certs: bool = False, deadline=None) -> list[np.ndarray]:
        """Per-query dataset row ids inside each [lo, hi] box.

        ``return_certs=True`` opts into degraded serving: the return is
        ``(results, certs)`` and a shard outage (breaker open / retries
        exhausted) yields partial results whose
        ``CompletenessCertificate`` names the unanswered subspaces
        instead of raising.  Adaptive serving answers outages host-side,
        so its certificates are always intact.

        ``deadline`` overrides the server's own per-batch budget — the
        async frontend passes the admitted batch's remaining budget so a
        queued-then-dispatched request is bounded end to end.
        """
        from ..core.distributed_jax import (
            CompletenessCertificate,
            ShardUnavailable,
            window_query_batch_sharded,
        )
        from ..core.queries_jax import window_query_batch_jax

        los = self._validate_batch(los, "los")
        his = self._validate_batch(his, "his")
        if los.shape != his.shape:
            raise ValueError(
                f"los/his shape mismatch: {los.shape} vs {his.shape}"
            )
        if deadline is None:
            deadline = self._deadline()
        out: list[np.ndarray] = []
        certs: list = []
        for a, b in self._chunks(los.shape[0]):
            runner = self._shard_runner(deadline)
            if self.adaptive:
                out.extend(
                    self._window_adaptive(los[a:b], his[a:b], deadline)
                )
                certs.extend(
                    CompletenessCertificate.intact() for _ in range(b - a)
                )
            elif self.sdev is not None:
                with self.table_lock.read():
                    res = window_query_batch_sharded(
                        self.sdev, los[a:b], his[a:b],
                        use_kernel=self.use_kernel, runner=runner,
                        return_certs=return_certs,
                    )
                if return_certs:
                    res, cs = res
                    certs.extend(cs)
                out.extend(res)
            else:
                try:
                    with self.table_lock.read():
                        out.extend(runner(0, lambda a=a, b=b: (
                            window_query_batch_jax(
                                self.dev, los[a:b], his[a:b],
                                use_kernel=self.use_kernel,
                            )
                        )))
                    certs.extend(
                        CompletenessCertificate.intact()
                        for _ in range(b - a)
                    )
                except ShardUnavailable:
                    if not return_certs:
                        raise
                    out.extend(
                        np.zeros(0, dtype=np.int64) for _ in range(b - a)
                    )
                    certs.extend(self._root_cert() for _ in range(b - a))
            self.stats.microbatches += 1
        self.stats.queries += los.shape[0]
        if return_certs:
            self.stats.degraded_queries += sum(
                1 for c in certs if not c.complete
            )
            return out, certs
        return out

    def knn(self, qs: np.ndarray, k: int, *,
            return_certs: bool = False, deadline=None,
            max_rounds: int | None = None) -> list[np.ndarray]:
        """Per-query ascending-distance row ids (length min(k, n)).

        Degraded mode mirrors :meth:`window`; a k-NN certificate can be
        ``certified_exact`` even when shards were down (the pruning
        radius clears their subspaces — see the distributed protocol).

        ``max_rounds`` caps the device engine's budget-escalation rounds
        (the frontend's brownout tier).  A capped query returns its
        best-effort answer with ``certified_exact=False`` on its
        certificate.  The cap applies to the single-table compiled
        dispatch; the sharded two-round protocol and the adaptive host
        path keep their own exactness machinery and ignore it.
        """
        from ..core.distributed_jax import (
            CompletenessCertificate,
            ShardUnavailable,
            knn_query_batch_sharded,
        )
        from ..core.queries_jax import knn_query_batch_jax

        qs = self._validate_batch(qs, "qs")
        if not isinstance(k, (int, np.integer)) or int(k) < 1:
            raise ValueError(f"k must be a positive integer, got {k!r}")
        k = int(k)
        if deadline is None:
            deadline = self._deadline()
        out: list[np.ndarray] = []
        certs: list = []
        for a, b in self._chunks(qs.shape[0]):
            runner = self._shard_runner(deadline)
            if self.adaptive:
                out.extend(self._knn_adaptive(qs[a:b], k, deadline))
                certs.extend(
                    CompletenessCertificate.intact() for _ in range(b - a)
                )
            elif self.sdev is not None:
                with self.table_lock.read():
                    res = knn_query_batch_sharded(
                        self.sdev, qs[a:b], k, use_kernel=self.use_kernel,
                        runner=runner, return_certs=return_certs,
                    )
                if return_certs:
                    res, cs = res
                    certs.extend(cs)
                out.extend(res)
            else:
                try:
                    with self.table_lock.read():
                        res, exact = runner(0, lambda a=a, b=b: (
                            knn_query_batch_jax(
                                self.dev, qs[a:b], k,
                                use_kernel=self.use_kernel,
                                max_rounds=max_rounds, return_exact=True,
                            )
                        ))
                    out.extend(res)
                    certs.extend(
                        CompletenessCertificate.intact() if bool(e)
                        else CompletenessCertificate(
                            complete=True, certified_exact=False
                        )
                        for e in exact
                    )
                except ShardUnavailable:
                    if not return_certs:
                        raise
                    out.extend(
                        np.zeros(0, dtype=np.int64) for _ in range(b - a)
                    )
                    certs.extend(self._root_cert() for _ in range(b - a))
            self.stats.microbatches += 1
        self.stats.queries += qs.shape[0]
        if return_certs:
            self.stats.degraded_queries += sum(
                1 for c in certs if not c.complete
            )
            return out, certs
        return out

    def cold_window_mask(self, los: np.ndarray, his: np.ndarray) -> np.ndarray:
        """Which window queries reach unrefined (cold) space — the cheap
        host-side test the async frontend uses to split a microbatch into
        a device-lane hot part and a refine-lane cold part *before*
        dispatch, so host refinement overlaps device execution instead of
        serializing behind it.  Hit sets are downward-closed, so reaching
        an unrefined row equals intersecting its MBB.  Non-adaptive
        servers have no cold space: all-False."""
        los = np.atleast_2d(np.asarray(los, dtype=np.float64))
        his = np.atleast_2d(np.asarray(his, dtype=np.float64))
        if not self.adaptive:
            return np.zeros(los.shape[0], dtype=bool)
        with self.table_lock.read():
            return self._cold_mask_unlocked(los, his)

    # -- brownout tier: device-only answers, no host refinement --------------
    def _cold_boxes_cert(self, lo, hi):
        """Certificate for a cold query answered device-only: the unrefined
        subspaces intersecting the window are the unanswered region."""
        from ..core.distributed_jax import CompletenessCertificate
        from ..core.geometry import boxes_intersect_windows

        t = self.ambi.table
        unref = np.flatnonzero(t.unrefined)
        if len(unref):
            hit = boxes_intersect_windows(
                t.mbb_lo[unref], t.mbb_hi[unref], lo[None], hi[None]
            )[0]
            unref = unref[hit]
        if not len(unref):
            return CompletenessCertificate.intact()
        return CompletenessCertificate(
            complete=False, certified_exact=False, missing_shards=(),
            missing_lo=np.asarray(t.mbb_lo[unref], dtype=np.float32),
            missing_hi=np.asarray(t.mbb_hi[unref], dtype=np.float32),
        )

    def window_hot(self, los: np.ndarray, his: np.ndarray, *,
                   deadline=None):
        """Brownout-tier window serving: answer from the device's refined
        subset only — no host refinement, no grafting, no cold-path I/O.
        Returns ``(results, certs)``; a query reaching cold space comes
        back *partial* (its refined-subset hits) with the unrefined
        subspaces it touches listed as the certificate's missing boxes.
        Only meaningful on an adaptive server; a fully refined table makes
        this identical to :meth:`window`."""
        from ..core.distributed_jax import CompletenessCertificate
        from ..core.queries_jax import window_query_batch_jax

        if not self.adaptive:
            return self.window(los, his, return_certs=True,
                               deadline=deadline)
        los = self._validate_batch(los, "los")
        his = self._validate_batch(his, "his")
        if deadline is None:
            deadline = self._deadline()
        out: list[np.ndarray] = []
        certs: list = []
        for a, b in self._chunks(los.shape[0]):
            runner = self._shard_runner(deadline)
            with self.table_lock.read():
                cold_q = np.asarray(
                    self._cold_mask_unlocked(los[a:b], his[a:b])
                )
                if self.sdev is not None:
                    res = [np.zeros(0, dtype=np.int64)] * (b - a)
                    hot = np.flatnonzero(~cold_q)
                    if hot.size:
                        hres, hcs = self._sharded_window(
                            los[a:b][hot], his[a:b][hot], runner
                        )
                        for qi, ids in zip(hot, hres):
                            res[qi] = ids
                else:
                    res, cold = runner(0, lambda a=a, b=b: (
                        window_query_batch_jax(
                            self.dev, los[a:b], his[a:b],
                            use_kernel=self.use_kernel, return_cold=True,
                        )
                    ))
                    res = list(res)
                    cold_q = cold_q | np.asarray(cold).any(axis=1)
                for i in range(b - a):
                    certs.append(
                        self._cold_boxes_cert(los[a + i], his[a + i])
                        if cold_q[i]
                        else CompletenessCertificate.intact()
                    )
            out.extend(res)
            self.stats.microbatches += 1
            self.stats.hot_queries += int((~cold_q).sum())
            self.stats.cold_queries += int(cold_q.sum())
        self.stats.queries += los.shape[0]
        self.stats.degraded_queries += sum(1 for c in certs if not c.complete)
        return out, certs

    def knn_hot(self, qs: np.ndarray, k: int, *, deadline=None,
                max_rounds: int | None = None):
        """Brownout-tier k-NN: device-only, escalation capped, no host
        refinement.  Returns ``(results, certs)`` — a query whose answer
        a cold box could still beat (or whose escalation was capped)
        carries ``certified_exact=False``."""
        from ..core.distributed_jax import (
            CompletenessCertificate,
            knn_query_batch_sharded,
        )
        from ..core.queries_jax import knn_query_batch_jax

        if not self.adaptive:
            return self.knn(qs, k, return_certs=True, deadline=deadline,
                            max_rounds=max_rounds)
        qs = self._validate_batch(qs, "qs")
        k = int(k)
        if deadline is None:
            deadline = self._deadline()
        out: list[np.ndarray] = []
        certs: list = []
        for a, b in self._chunks(qs.shape[0]):
            runner = self._shard_runner(deadline)
            with self.table_lock.read():
                t = self.ambi.table
                if self.sdev is not None:
                    res, _cs = knn_query_batch_sharded(
                        self.sdev, qs[a:b], k, use_kernel=self.use_kernel,
                        runner=runner, return_certs=True,
                    )
                    res = list(res)
                    exact = np.ones(b - a, dtype=bool)
                else:
                    res, exact = runner(0, lambda a=a, b=b: (
                        knn_query_batch_jax(
                            self.dev, qs[a:b], k,
                            use_kernel=self.use_kernel,
                            max_rounds=max_rounds, return_exact=True,
                        )
                    ))
                    res = list(res)
                cold_q = self._knn_cold_mask(qs[a:b], res, k)
                unref = np.flatnonzero(t.unrefined)
                for i in range(b - a):
                    if not cold_q[i] and exact[i]:
                        certs.append(CompletenessCertificate.intact())
                    else:
                        certs.append(CompletenessCertificate(
                            complete=not cold_q[i],
                            certified_exact=False,
                            missing_shards=(),
                            missing_lo=np.asarray(
                                t.mbb_lo[unref], dtype=np.float32),
                            missing_hi=np.asarray(
                                t.mbb_hi[unref], dtype=np.float32),
                        ))
            out.extend(res)
            self.stats.microbatches += 1
            self.stats.hot_queries += int((~cold_q).sum())
            self.stats.cold_queries += int(cold_q.sum())
        self.stats.queries += qs.shape[0]
        self.stats.degraded_queries += sum(1 for c in certs if not c.complete)
        return out, certs

    def _cold_mask_unlocked(self, los, his) -> np.ndarray:
        """`cold_window_mask` body without the lock (callers hold read)."""
        from ..core.geometry import boxes_intersect_windows

        t = self.ambi.table
        unref = np.flatnonzero(t.unrefined)
        if not len(unref):
            return np.zeros(np.atleast_2d(los).shape[0], dtype=bool)
        return boxes_intersect_windows(
            t.mbb_lo[unref], t.mbb_hi[unref],
            np.asarray(los, dtype=np.float64),
            np.asarray(his, dtype=np.float64),
        ).any(axis=1)

    def _sharded_window(self, los, his, runner):
        from ..core.distributed_jax import window_query_batch_sharded

        return window_query_batch_sharded(
            self.sdev, los, his, use_kernel=self.use_kernel,
            runner=runner, return_certs=True,
        )

    # -- adaptive serving loop ----------------------------------------------
    # The host AMBI engine is authoritative over the full dataset, so the
    # adaptive server degrades *gracefully* under device outages: a failed
    # dispatch reroutes the affected queries down the (exact) host cold
    # path instead of returning partial answers — certificates stay intact.
    def _journal_op(self, op: str, **args) -> None:
        """Write-ahead: durably journal a cold host op before executing it
        (recovery replays exactly the journaled sequence).  An append that
        cannot be made durable fails the op — never execute unlogged."""
        if self.journal is None:
            return

        def attempt():
            return self.journal.append(op, **args)

        self.retry.call(
            attempt, on_retry=self._count_retry, call_key="journal"
        )
        self.stats.journal_records += 1

    def _host_window(self, lo, hi) -> np.ndarray:
        """Cold-path window: journal, then host-answer (+ refine) under
        retry.  Faults fire at entry, before any host mutation, so a
        retried attempt re-runs the op from scratch."""
        self._journal_op(
            "window", lo=[float(v) for v in lo], hi=[float(v) for v in hi]
        )

        def attempt():
            if self.fault_plan is not None:
                self.fault_plan.fire("host_refine", op="window")
            return self.ambi.window(lo, hi)

        ids, _ = self.retry.call(
            attempt, on_retry=self._count_retry, call_key="host_refine"
        )
        return ids

    def _host_knn(self, q, k: int) -> np.ndarray:
        self._journal_op("knn", q=[float(v) for v in q], k=int(k))

        def attempt():
            if self.fault_plan is not None:
                self.fault_plan.fire("host_refine", op="knn")
            return self.ambi.knn(q, k)

        ids, _ = self.retry.call(
            attempt, on_retry=self._count_retry, call_key="host_refine"
        )
        return ids

    def _window_adaptive(self, los, his, deadline=None) -> list[np.ndarray]:
        """One microbatch: device answers for hot queries, host answers
        (+ refinement + device refresh) for queries reaching cold space."""
        from ..core.distributed_jax import (
            ShardUnavailable,
            window_query_batch_sharded,
        )
        from ..core.geometry import boxes_intersect_windows
        from ..core.queries_jax import window_query_batch_jax

        runner = self._shard_runner(deadline)
        with self.table_lock.read():
            t = self.ambi.table
            unref = np.flatnonzero(t.unrefined)
            if self.sdev is not None:
                # reaching an unrefined row == intersecting its MBB (hit
                # sets are downward-closed), so the host-side router test
                # equals the frontier's cold mask without a cross-shard
                # gather — and, being known up front, lets the device
                # serve only the hot part
                cold_q = (
                    boxes_intersect_windows(
                        t.mbb_lo[unref], t.mbb_hi[unref],
                        np.asarray(los, dtype=np.float64),
                        np.asarray(his, dtype=np.float64),
                    ).any(axis=1)
                    if len(unref)
                    else np.zeros(los.shape[0], dtype=bool)
                )
                out: list = [None] * los.shape[0]
                hot = np.flatnonzero(~cold_q)
                if hot.size:
                    res, cs = window_query_batch_sharded(
                        self.sdev, los[hot], his[hot],
                        use_kernel=self.use_kernel, runner=runner,
                        return_certs=True,
                    )
                    for qi, ids, cert in zip(hot, res, cs):
                        if cert.complete:
                            out[qi] = ids
                        else:  # dead shard: exact host answer instead
                            cold_q[qi] = True
                            self.stats.host_fallbacks += 1
            else:
                try:
                    res, cold = runner(0, lambda: window_query_batch_jax(
                        self.dev, los, his,
                        use_kernel=self.use_kernel, return_cold=True,
                    ))
                    out = list(res)
                    cold_q = cold.any(axis=1)
                except ShardUnavailable:
                    # whole-device outage: host serves the full microbatch
                    out = [None] * los.shape[0]
                    cold_q = np.ones(los.shape[0], dtype=bool)
                    self.stats.host_fallbacks += los.shape[0]
        if cold_q.any():
            with self.table_lock.write():
                for i in np.flatnonzero(cold_q):
                    out[i] = self._host_window(los[i], his[i])
                self._after_refinement(unref)  # pre-serving unrefined rows
        self.stats.hot_queries += int((~cold_q).sum())
        self.stats.cold_queries += int(cold_q.sum())
        return out

    def _knn_adaptive(self, qs, k: int, deadline=None) -> list[np.ndarray]:
        from ..core.distributed_jax import (
            ShardUnavailable,
            knn_query_batch_sharded,
        )
        from ..core.queries_jax import knn_query_batch_jax

        runner = self._shard_runner(deadline)
        with self.table_lock.read():
            t = self.ambi.table
            degraded = np.zeros(qs.shape[0], dtype=bool)
            if self.sdev is not None:
                res, cs = knn_query_batch_sharded(
                    self.sdev, qs, k, use_kernel=self.use_kernel,
                    runner=runner, return_certs=True,
                )
                res = list(res)
                for i, cert in enumerate(cs):
                    if not cert.certified_exact:
                        degraded[i] = True
                        self.stats.host_fallbacks += 1
            else:
                try:
                    res = list(runner(0, lambda: knn_query_batch_jax(
                        self.dev, qs, k, use_kernel=self.use_kernel
                    )))
                except ShardUnavailable:
                    res = [np.zeros(0, dtype=np.int64)] * qs.shape[0]
                    degraded[:] = True
                    self.stats.host_fallbacks += qs.shape[0]
            out = list(res)
            cold_q = self._knn_cold_mask(qs, res, k) | degraded
            before_unref = np.flatnonzero(t.unrefined)
        if cold_q.any():
            with self.table_lock.write():
                for i in np.flatnonzero(cold_q):
                    out[i] = self._host_knn(qs[i], k)
                self._after_refinement(before_unref)
        self.stats.hot_queries += int((~cold_q).sum())
        self.stats.cold_queries += int(cold_q.sum())
        return out

    def _knn_cold_mask(self, qs, res, k: int) -> np.ndarray:
        """Which queries the device answer cannot certify: a cold box
        could hold a closer neighbor (mindist within the k-th distance,
        both exact float64 over the host data — ``<=`` keeps boundary
        ties host-side, matching what the host's own best-first refinement
        would expand), or the refined subset is short of k."""
        from ..core.geometry import boxes_mindist_sq

        t = self.ambi.table
        qs = np.asarray(qs, dtype=np.float64)
        cold = np.zeros(qs.shape[0], dtype=bool)
        unref = np.flatnonzero(t.unrefined)
        want = min(k, len(self.points))
        if not len(unref):
            return cold
        minds = boxes_mindist_sq(t.mbb_lo[unref], t.mbb_hi[unref], qs)
        for i, ids in enumerate(res):
            if len(ids) < want:
                cold[i] = True
                continue
            kth = float(
                np.max(np.sum((self.points[ids] - qs[i]) ** 2, axis=1))
            )
            cold[i] = bool(minds[i].min() <= kth)
        return cold

    def _after_refinement(self, before_unref: np.ndarray) -> None:
        """Push the microbatch's grafts to the device: incremental delta
        (single table) or per-changed-shard re-export (sharded), then
        vacuum the host table if grafting bloated it.

        The upload is retried under the ``apply_delta`` fault point (fired
        at entry — an injected upload fault never half-applies: the swap
        is double-buffered, the old export serves until the new one
        lands).  An upload that exhausts its retries leaves the device
        stale but the *host* current; the next cold answer/fallback is
        still exact, and the refresh is re-attempted after the next graft.
        """
        from .resilience import RetryExhausted

        t = self.ambi.table
        grafted = before_unref[~t.unrefined[before_unref]]
        if len(grafted) == 0:
            return
        self.stats.grafts += len(grafted)

        def upload():
            if self.fault_plan is not None:
                self.fault_plan.fire("apply_delta")
            if self.sdev is not None:
                if self.sdev.m < self.requested_shards:
                    # a boot from a barely refined table (ultimately the
                    # single-unrefined-root state, where the plan is [[0]])
                    # cannot cut m subspaces yet; re-plan once the grafts
                    # grow the tree far enough instead of full-re-exporting
                    # the one degenerate whole-table "shard" on every graft
                    sizes = t.subtree_points()
                    if len(t.shard_plan(
                        self.requested_shards, sizes
                    )) > self.sdev.m:
                        from ..core.distributed_jax import ShardedDeviceTable

                        self.sdev = ShardedDeviceTable.from_table(
                            t, self.points, self.requested_shards,
                            partial=True, stats=self.upload_stats,
                            compressed=self.compressed,
                        )
                        self.stats.shards = self.sdev.m
                        self.stats.shard_refreshes += self.sdev.m
                        return
                changed = self.sdev.shards_of_rows(grafted)
                self.sdev.refresh(changed)
                self.stats.shard_refreshes += len(changed)
            else:
                self.dev = self.dev.apply_delta(t, self.points)  # swap
                self.stats.delta_refreshes += 1

        try:
            self.retry.call(
                upload, on_retry=self._count_retry, call_key="apply_delta"
            )
        except RetryExhausted:
            pass  # device stale, host authoritative; retried next graft
        self._maybe_compact()

    def _maybe_compact(self) -> None:
        """Vacuum the host table once grafting bloated it, rebasing the
        device/shard scaffolding through the returned row remap.  With a
        journal, the vacuum is itself a journaled op (replay must compact
        at the same point to stay bit-identical) and doubles as the
        snapshot barrier: checkpoint, then truncate the folded journal."""
        from .resilience import RetryExhausted

        t = self.ambi.table
        if t.n_perm > (1.0 + self.compact_slack) * len(self.points):
            if self.journal is not None:
                try:
                    self._journal_op("compact")
                except RetryExhausted:
                    return  # not durably logged -> defer the vacuum
            remap = t.compact()
            if self.sdev is not None:
                self.sdev.remap_source_rows(remap)
            elif self.dev is not None:
                self.dev.remap_rows(remap)
            self.stats.compactions += 1
            if self.snapshot_path is not None:
                try:
                    self.checkpoint()
                except RetryExhausted:
                    pass  # barrier deferred; journal still holds the ops

    # -- durability: snapshot barriers + crash recovery ----------------------
    def checkpoint(self) -> None:
        """Durable snapshot barrier: atomically persist the table, the
        dataset, and the adaptive state (rng + page store), recording the
        journal's high-water ``seq``; then truncate the journal (its
        records are folded into the snapshot).  Crash-ordering: the
        snapshot lands via atomic rename *before* the truncate, and
        recovery skips records at or below the recorded seq — a kill
        between the two replays nothing twice."""
        if self.snapshot_path is None:
            raise ValueError("no snapshot_path configured")

        def attempt():
            if self.fault_plan is not None:
                self.fault_plan.fire("snapshot_save", path=self.snapshot_path)
            self.ambi.table.save(
                self.snapshot_path, points=self.points,
                extra={
                    "ambi_state": self.ambi.state_meta(),
                    "journal_seq": self.journal.seq if self.journal else 0,
                },
            )

        self.retry.call(
            attempt, on_retry=self._count_retry, call_key="snapshot"
        )
        if self.journal is not None:
            self.journal.truncate()
        self.stats.checkpoints += 1

    @staticmethod
    def _replay_op(ambi, rec: dict) -> None:
        from .journal import JournalError

        op = rec.get("op")
        if op == "window":
            ambi.window(
                np.asarray(rec["lo"], dtype=np.float64),
                np.asarray(rec["hi"], dtype=np.float64),
            )
        elif op == "knn":
            ambi.knn(np.asarray(rec["q"], dtype=np.float64), int(rec["k"]))
        elif op == "compact":
            ambi.table.compact()
        else:
            raise JournalError(f"unknown journal op {op!r} (seq {rec.get('seq')})")

    @classmethod
    def recover(cls, snapshot_path, journal_path, *,
                fault_plan=None, **kw) -> "DeviceQueryServer":
        """Reboot a killed adaptive server: load the snapshot, replay the
        journal's post-barrier records against the restored AMBI state
        (grafting is deterministic given the snapshot's rng + page-store
        state, so the table lands bit-identical to the uninterrupted
        server's), then resume serving with the same durability config.

        The fault plane is disarmed for the replay — recovery re-executes
        already-acknowledged ops and must not be re-faulted — and rearmed
        before the recovered server takes traffic."""
        import os

        from ..core.ambi import AMBI
        from ..core.nodetable import NodeTable
        from .journal import GraftJournal

        snapshot_path = os.fspath(snapshot_path)
        if not snapshot_path.endswith(".npz"):
            snapshot_path += ".npz"
        if fault_plan is not None:
            fault_plan.fire("snapshot_load", path=snapshot_path)
        table, meta, points = NodeTable.load(snapshot_path)
        if points is None or "ambi_state" not in meta:
            raise ValueError(
                "recovery snapshot must carry points and adaptive state "
                "(written by DeviceQueryServer.checkpoint)"
            )
        ambi = AMBI.from_table_state(
            np.asarray(points), table, str(meta["ambi_state"])
        )
        snap_seq = int(meta["journal_seq"])
        was_armed = fault_plan is not None and fault_plan.armed
        if was_armed:
            fault_plan.disarm()
        replayed = 0
        try:
            for rec in GraftJournal.read_records(
                journal_path, after_seq=snap_seq
            ):
                cls._replay_op(ambi, rec)
                replayed += 1
        finally:
            if was_armed:
                fault_plan.rearm()
        srv = cls.from_ambi(
            ambi, snapshot_path=snapshot_path, journal_path=journal_path,
            fault_plan=fault_plan, **kw,
        )
        srv.journal.seq = max(srv.journal.seq, snap_seq)
        srv.stats.replayed_records = replayed
        return srv
