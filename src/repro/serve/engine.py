"""Serving engine: batched prefill/decode plus FMBI-backed kNN retrieval.

``LMServer`` is the generation path: continuous batched decode over a shared
cache pytree (prefill once, then step).  ``RetrievalServer`` serves batched
kNN/window queries over an FMBI ``JaxIndex``; in ``adaptive=True`` mode it
applies AMBI's residency policy — only leaves that the live query stream
touches are kept "hot" (the TPU analogue of the paper's buffer retention),
with hit statistics exposed for the workload-adaptation benchmark.
``DeviceQueryServer`` serves batched window and k-NN traffic straight off a
bulk-loaded ``NodeTable`` through the compiled ``queries_jax`` engine, with
microbatching so arbitrary client batch sizes reuse a bounded set of
compiled variants.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import runtime as _san
from ..core import jax_index
from ..kernels import ops as kops
from ..models import model as M
from ..models.sharding import MeshAxes


class LMServer:
    def __init__(self, cfg, params, axes: MeshAxes | None = None):
        self.cfg = cfg
        self.params = params
        self.axes = axes or MeshAxes()
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, self.axes)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos, self.axes)
        )

    def generate(self, tokens: np.ndarray, max_new: int,
                 cache_len: int | None = None) -> np.ndarray:
        """Greedy generation for a (B, S) prompt batch."""
        B, S = tokens.shape
        cache_len = cache_len or (S + max_new)
        lg, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        cache = jax.tree.map(
            lambda x: (
                jnp.concatenate(
                    [x, jnp.zeros(
                        x.shape[:2] + (cache_len - S,) + x.shape[3:], x.dtype
                    )], axis=2,
                )
                if x.ndim >= 3 and x.shape[2] == S
                else x
            ),
            cache,
        )
        out = [jnp.argmax(lg[:, -1], axis=-1)]
        for t in range(max_new - 1):
            pos = jnp.full((B,), S + t, jnp.int32)
            lg, cache = self._decode(
                self.params, out[-1][:, None].astype(jnp.int32), cache, pos
            )
            out.append(jnp.argmax(lg[:, 0], axis=-1))
        return np.stack([np.asarray(o) for o in out], axis=1)


@dataclasses.dataclass
class RetrievalStats:
    queries: int = 0
    hot_hits: int = 0
    cold_misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hot_hits + self.cold_misses
        return self.hot_hits / total if total else 0.0


class RetrievalServer:
    """Batched exact kNN over an FMBI JaxIndex (Pallas distance kernel).

    Two boot paths: build a balanced index from raw points (``__init__``),
    or bridge a bulk-loaded CPU ``NodeTable`` snapshot straight into the
    accelerator layout (``from_snapshot``) — no rebuild, no re-sort.
    """

    def __init__(self, points: np.ndarray, levels: int, *,
                 adaptive: bool = False, hot_capacity: int = 64):
        padded, ids = jax_index.pad_points(points.astype(np.float32), levels)
        self.index = jax_index.build(
            jnp.asarray(padded), levels, jnp.asarray(ids, jnp.int32)
        )
        self._routed = True  # built indexes carry split tables for route()
        self._init_serving(levels, adaptive, hot_capacity)

    @classmethod
    def from_snapshot(cls, path, *, adaptive: bool = False,
                      hot_capacity: int = 64) -> "RetrievalServer":
        """Boot from a ``NodeTable.save`` snapshot (``.npz`` with points).

        The snapshot's leaf-contiguous layout maps directly onto the
        ``JaxIndex`` grid via ``NodeTable.to_jax_index``; adaptive residency
        falls back to ``nearest_leaf`` because a bridged FMBI tree has no
        balanced split tables.
        """
        from ..core.nodetable import NodeTable

        table, _meta, points = NodeTable.load(path)
        if points is None:
            raise ValueError("snapshot was saved without points")
        self = cls.__new__(cls)
        self.index = table.to_jax_index(np.asarray(points))
        self._routed = False
        self._init_serving(self.index.levels, adaptive, hot_capacity)
        return self

    def _init_serving(self, levels: int, adaptive: bool,
                      hot_capacity: int) -> None:
        self.levels = levels
        self.adaptive = adaptive
        # leaf -> last-touch tick, insertion-ordered: recency order IS the
        # dict order (same structure as pagestore.LRUBuffer), so eviction is
        # popitem(last=False) instead of an O(capacity) min() scan per query
        self.hot: OrderedDict[int, int] = OrderedDict()
        self.hot_capacity = hot_capacity
        self.tick = 0
        self.stats = RetrievalStats()

    def knn(self, queries: np.ndarray, k: int, n_candidate_leaves: int = 8):
        rows, d2, exact = jax_index.knn(
            self.index, jnp.asarray(queries, jnp.float32), k,
            n_candidate_leaves=n_candidate_leaves,
        )
        if self.adaptive:
            locate = jax_index.route if self._routed else jax_index.nearest_leaf
            leaves = np.asarray(
                locate(self.index, jnp.asarray(queries, jnp.float32))
            )
            for leaf in leaves:
                self.tick += 1
                leaf = int(leaf)
                if leaf in self.hot:
                    self.stats.hot_hits += 1
                    self.hot.move_to_end(leaf)
                else:
                    self.stats.cold_misses += 1
                self.hot[leaf] = self.tick
                if len(self.hot) > self.hot_capacity:
                    self.hot.popitem(last=False)  # least recent first
            self.stats.queries += len(queries)
        return np.asarray(rows), np.asarray(d2), np.asarray(exact)

    def knn_kernel(self, queries: np.ndarray, k: int):
        """Direct Pallas-kernel path (distance tiles + top-k)."""
        idx, d2 = kops.knn_topk(
            jnp.asarray(queries, jnp.float32),
            self.index.points_sorted,
            k,
            valid=(self.index.row_ids >= 0).astype(jnp.int32),
        )
        return np.asarray(idx), np.asarray(d2)


@dataclasses.dataclass
class DeviceQueryStats:
    queries: int = 0
    microbatches: int = 0
    shards: int = 1
    hot_queries: int = 0       # answered entirely on the device
    cold_queries: int = 0      # reached unindexed space -> host + refine
    grafts: int = 0            # unrefined rows refined by the serving loop
    delta_refreshes: int = 0   # DeviceTable.apply_delta swaps
    shard_refreshes: int = 0   # shards re-exported by ShardedDeviceTable
    compactions: int = 0       # NodeTable.compact vacuums
    retries: int = 0           # dispatch/refine attempts beyond the first
    host_fallbacks: int = 0    # device outage answered by the host engine
    degraded_queries: int = 0  # answers returned with an incomplete cert
    journal_records: int = 0   # ops durably journaled before execution
    checkpoints: int = 0       # snapshot barriers written
    replayed_records: int = 0  # journal records replayed at recovery
    inserts: int = 0           # streamed points ingested
    deletes: int = 0           # ids tombstoned
    stream_syncs: int = 0      # structural device syncs (flush/merge shipped)
    stream_reshards: int = 0   # full re-shard fallbacks (should stay 0)


class DeviceQueryServer:
    """Batched window/k-NN serving over a ``NodeTable`` via the compiled
    device engine (``core/queries_jax.py``).

    Boots from a built CPU index (or its ``.npz`` snapshot) by exporting
    the flat table to the device once; every query batch afterwards is one
    compiled dispatch.  Incoming traffic is split into ``microbatch``-sized
    chunks — each chunk pads to a power-of-two bucket inside the engine —
    so any client batch size is served by a bounded set of compiled
    variants instead of a fresh compilation per shape.  Exactness matches
    the NumPy engine (see the queries_jax parity contract); the simulated
    LRU I/O accounting stays with the CPU path.

    ``shards=m`` serves through the *sharded* engine instead
    (``core/distributed_jax.py``): the table partitions into m per-shard
    DeviceTables behind a subspace-MBB router, windows fan out only to
    qualified shards, and k-NN runs the two-round certified protocol —
    same results, distributed execution.

    ``adaptive=True`` (boot via :meth:`from_ambi`) serves an AMBI table
    that may be arbitrarily unrefined — down to the single-unrefined-root
    state, where the device holds nothing but the root's cold box:

      * the table is exported *partially* — unrefined rows ride along as
        cold boxes the compiled frontier traversal surfaces as a mask;
      * a query that never reaches cold space is answered entirely from
        the device (no simulated I/O, the hot path);
      * a cold query is answered by the host AMBI engine, whose refiner —
        carrying that query's context explicitly — charges the paper's
        I/O and grafts the touched subspaces;
      * after each microbatch the grafts are pushed to the device
        *incrementally*: ``DeviceTable.apply_delta`` uploads only the new
        leaf blocks into a double-buffered swap (sharded serving
        re-exports only the shards owning grafted subspaces), and
        ``NodeTable.compact`` vacuums dead perm segments once grafting
        has bloated the host table past ``compact_slack``.

    Under a focused workload the hot set converges and serving detaches
    from the host entirely — the paper's adaptivity argument carried onto
    the accelerator.
    """

    # overlay construction defaults — shared by the live ingest path and
    # journal replay, which must build the identical structure
    OVERLAY_KW = dict(delta_threshold=2048, delta_index_every=256,
                     size_ratio=4)

    def __init__(self, table, points: np.ndarray, *,
                 microbatch: int = 64, use_kernel: bool | None = None,
                 compressed: bool = False,
                 shards: int | None = None, adaptive: bool = False,
                 ambi=None, stream=None, compact_slack: float = 0.5,
                 fault_plan=None, retry=None, deadline_s: float | None = None,
                 breaker_threshold: int = 3, breaker_cooldown_s: float = 30.0,
                 clock=None,
                 journal_path=None, snapshot_path=None):
        import os

        from ..core.distributed_jax import ShardedDeviceTable
        from ..core.queries_jax import DeviceTable, UploadStats
        from .journal import GraftJournal
        from .resilience import RetryPolicy, TableLock

        if adaptive:
            if ambi is None:
                raise ValueError(
                    "adaptive serving needs the host AMBI engine — boot "
                    "with DeviceQueryServer.from_ambi(ambi)"
                )
            if stream is not None:
                raise ValueError(
                    "an adaptive server grows its streaming overlay on "
                    "insert(); do not pass stream="
                )
            table, points = ambi.table, ambi.points
        self.stream = stream
        self.mirror = None
        if stream is not None:
            from ..core.streaming import DeviceMirror

            if not stream.tiers:
                raise ValueError(
                    "streaming serving boots from a stream with at least "
                    "one tier — seed it with points or insert past the "
                    "flush threshold first"
                )
            self.mirror = DeviceMirror(stream)
            table = self.mirror.table
            points = stream.points
        points = np.asarray(points)
        # resilience plane: per-server policies, injectable for tests
        self.fault_plan = fault_plan
        self.retry = retry if retry is not None else RetryPolicy()
        self.deadline_s = deadline_s
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_s)
        self.clock = clock  # None -> time.monotonic inside the primitives
        self.breakers: dict = {}
        # table RW-lock: device dispatches and cold-mask computations read
        # the host table; adaptive refinement (graft/apply_delta/compact)
        # and shard repair write it.  The async frontend overlaps a device
        # worker with host refinement, so the lock is load-bearing there;
        # single-threaded callers pay two uncontended acquisitions.
        self.table_lock = TableLock()
        # per-server upload accounting (satellite: no cross-server leakage)
        self.upload_stats = UploadStats()
        if adaptive and fault_plan is not None and ambi is not None:
            ambi.store.fault_hook = fault_plan.pagestore_hook()
        if shards is not None and shards > 1:
            self.sdev = ShardedDeviceTable.from_table(
                table, points, shards, partial=adaptive,
                stats=self.upload_stats, compressed=compressed,
            )
            self.dev = None
            n_shards = self.sdev.m
        else:
            self.dev = DeviceTable.from_table(
                table, points, partial=adaptive, stats=self.upload_stats,
                compressed=compressed,
            )
            self.sdev = None
            n_shards = 1
        self.table = table
        self.requested_shards = shards if shards is not None else 1
        self.adaptive = adaptive
        self.ambi = ambi
        self._points = points
        self.dim = int(points.shape[1])
        # compaction epoch: bumped under the writer lock whenever compact()
        # moves rows, so a lock-split reader can detect that its captured
        # row indices went stale before it re-enters as a writer
        self._table_version = 0
        # sharded streaming: shards whose refresh exhausted its retries —
        # re-included in the next sync so the device converges
        self._stream_stale_shards: set[int] = set()
        # single-device streaming: a tier upload exhausted its retries —
        # queries serve host-side (exact) until the next sync re-uploads
        self._stream_device_stale = False
        self.compact_slack = float(compact_slack)
        self.microbatch = int(microbatch)
        self.use_kernel = use_kernel
        self.compressed = bool(compressed)
        self.stats = DeviceQueryStats(shards=n_shards)
        # durability plane (adaptive only): write-ahead graft journal +
        # snapshot barriers; recovery = snapshot + replay (see recover())
        self.journal = None
        self.snapshot_path = None
        if journal_path is not None or snapshot_path is not None:
            if not adaptive and stream is None:
                raise ValueError(
                    "journaling/snapshots apply to adaptive or streaming "
                    "serving — a static table needs no recovery log"
                )
            if journal_path is None or snapshot_path is None:
                raise ValueError(
                    "durability needs BOTH journal_path and snapshot_path "
                    "(recovery replays the journal against the snapshot)"
                )
            self.snapshot_path = os.fspath(snapshot_path)
            if not self.snapshot_path.endswith(".npz"):
                self.snapshot_path += ".npz"
            self.journal = GraftJournal(journal_path, fault_plan=fault_plan)
            if not os.path.exists(self.snapshot_path):
                # boot barrier: capture the pre-serving adaptive state so a
                # crash before the first compaction is still recoverable
                self.checkpoint()
        # REPRO_SANITIZE: bind every shared mutable object the serving
        # layer publishes to the writer lock that guards it.  Binding is
        # the LAST construction step — everything above runs unpublished
        # and single-threaded; everything after must hold the lock.
        self._bind_sanitizer()

    def _bind_sanitizer(self) -> None:
        for obj in (self.stream,
                    self.mirror,
                    self.mirror.table if self.mirror is not None else None,
                    self.ambi.table if self.ambi is not None else None):
            if obj is not None:
                _san.bind(obj, self.table_lock)

    @property
    def points(self) -> np.ndarray:
        """The served dataset.  A streaming (non-adaptive) server's point
        buffer grows in place, so this is the stream's live view; adaptive
        servers keep the AMBI base here (the overlay carries its own)."""
        if self.stream is not None and not self.adaptive:
            return self.stream.points
        return self._points

    @classmethod
    def from_index(cls, index, **kw) -> "DeviceQueryServer":
        """From a built ``core.fmbi.Index`` (or AMBI's ``.index``)."""
        return cls(index.table, index.points, **kw)

    @classmethod
    def from_streaming(cls, stream, **kw) -> "DeviceQueryServer":
        """Live serving over a :class:`~repro.core.streaming.StreamingIndex`:
        the server owns a :class:`DeviceMirror` of the stream's tiers,
        ``insert``/``delete`` route through the stream under the writer
        lock, and structural changes (flush/merge) ship to the device as
        deltas — never a full re-export after boot."""
        return cls(None, None, stream=stream, **kw)

    @classmethod
    def from_ambi(cls, ambi, **kw) -> "DeviceQueryServer":
        """Adaptive serving over a host AMBI engine (any refinement state,
        including the freshly constructed single-unrefined-root table)."""
        return cls(ambi.table, ambi.points, adaptive=True, ambi=ambi, **kw)

    @classmethod
    def from_snapshot(cls, path, **kw) -> "DeviceQueryServer":
        """From a ``NodeTable.save``/``Index.save`` snapshot with points."""
        from ..core.nodetable import NodeTable

        table, _meta, points = NodeTable.load(path)
        if points is None:
            raise ValueError("snapshot was saved without points")
        return cls(table, points, **kw)

    def _chunks(self, n: int):
        for start in range(0, n, self.microbatch):
            yield start, min(start + self.microbatch, n)

    # -- resilience plane ----------------------------------------------------
    def _breaker(self, s: int):
        from .resilience import CircuitBreaker

        br = self.breakers.get(s)
        if br is None:
            kw = {} if self.clock is None else {"clock": self.clock}
            # setdefault, not assignment: two lanes creating the breaker
            # concurrently must converge on ONE instance, or failure
            # counts split across copies and the breaker never opens
            br = self.breakers.setdefault(s, CircuitBreaker(
                self.breaker_threshold, self.breaker_cooldown_s, **kw
            ))
        return br

    def _deadline(self):
        from .resilience import Deadline

        kw = {} if self.clock is None else {"clock": self.clock}
        return Deadline(self.deadline_s, **kw)

    def _count_retry(self, attempt, exc) -> None:
        self.stats.retries += 1

    def _shard_runner(self, deadline):
        """The resilience hook the sharded protocols dispatch through:
        breaker fail-fast, then bounded retries (each attempt passing the
        shard's fault point), then breaker accounting.  A shard that
        exhausts its retries surfaces as :class:`ShardUnavailable` — the
        protocol's degraded-mode signal."""
        from ..core.distributed_jax import ShardUnavailable
        from .resilience import DeadlineExceeded, RetryExhausted

        def run(s: int, thunk):
            br = self._breaker(s)
            if not br.allow():
                raise ShardUnavailable(s, "circuit open")

            def attempt():
                if self.fault_plan is not None:
                    self.fault_plan.fire("shard_dispatch", shard=int(s))
                return thunk()

            try:
                res = self.retry.call(
                    attempt, deadline=deadline,
                    no_retry=(DeadlineExceeded, ShardUnavailable),
                    on_retry=self._count_retry, call_key=("shard", int(s)),
                )
            except (DeadlineExceeded, ShardUnavailable):
                raise
            except RetryExhausted as e:
                br.record_failure()
                raise ShardUnavailable(s, str(e)) from e
            br.record_success()
            return res

        return run

    def repair(self, shard_ids=None) -> list[int]:
        """Rebuild failed shards from the host ``NodeTable`` and close
        their breakers; with no argument, repairs every shard whose
        breaker is not closed.  Returns the repaired shard ids."""
        if shard_ids is None:
            shard_ids = [
                s for s, br in self.breakers.items() if br.state != "closed"
            ]
        shard_ids = sorted(int(s) for s in shard_ids)
        if not shard_ids:
            return []
        with self.table_lock.write():
            if self.sdev is not None:
                self.sdev.refresh(shard_ids)
                self.stats.shard_refreshes += len(shard_ids)
            else:
                from ..core.queries_jax import DeviceTable

                t = self.ambi.table if self.adaptive else self.table
                self.dev = DeviceTable.from_table(
                    t, self.points, partial=self.adaptive,
                    stats=self.upload_stats, compressed=self.compressed,
                )
        for s in shard_ids:
            self._breaker(s).reset()
        return shard_ids

    def _root_cert(self):
        """Degraded certificate for a whole-table outage (single-device
        serving): the entire root MBB is unanswered."""
        from ..core.distributed_jax import CompletenessCertificate

        t = self.ambi.table if self.adaptive else self.table
        return CompletenessCertificate(
            complete=False, certified_exact=False, missing_shards=(0,),
            missing_lo=np.asarray(t.mbb_lo[0], dtype=np.float32)[None],
            missing_hi=np.asarray(t.mbb_hi[0], dtype=np.float32)[None],
        )

    # -- input validation ----------------------------------------------------
    def _validate_batch(self, arr, name: str) -> np.ndarray:
        """API-boundary validation: precise errors here instead of cryptic
        jit/traversal failures deep in the engine."""
        a = np.asarray(arr)
        if a.dtype == object or not np.issubdtype(a.dtype, np.number):
            raise ValueError(
                f"{name}: expected a numeric array, got dtype {a.dtype}"
            )
        if np.issubdtype(a.dtype, np.complexfloating):
            raise ValueError(f"{name}: complex coordinates are not supported")
        a = np.atleast_2d(a.astype(np.float64, copy=False))
        if a.ndim != 2 or a.shape[1] != self.dim:
            raise ValueError(
                f"{name}: expected shape (Q, {self.dim}) to match the "
                f"{self.dim}-dimensional dataset, got {np.asarray(arr).shape}"
            )
        if np.isnan(a).any():
            bad = int(np.flatnonzero(np.isnan(a).any(axis=1))[0])
            raise ValueError(f"{name}: query {bad} contains NaN coordinates")
        return a

    def window(self, los: np.ndarray, his: np.ndarray, *,
               return_certs: bool = False, deadline=None) -> list[np.ndarray]:
        """Per-query dataset row ids inside each [lo, hi] box.

        ``return_certs=True`` opts into degraded serving: the return is
        ``(results, certs)`` and a shard outage (breaker open / retries
        exhausted) yields partial results whose
        ``CompletenessCertificate`` names the unanswered subspaces
        instead of raising.  Adaptive serving answers outages host-side,
        so its certificates are always intact.

        ``deadline`` overrides the server's own per-batch budget — the
        async frontend passes the admitted batch's remaining budget so a
        queued-then-dispatched request is bounded end to end.
        """
        from ..core.distributed_jax import (
            CompletenessCertificate,
            ShardUnavailable,
            window_query_batch_sharded,
        )
        from ..core.queries_jax import window_query_batch_jax

        los = self._validate_batch(los, "los")
        his = self._validate_batch(his, "his")
        if los.shape != his.shape:
            raise ValueError(
                f"los/his shape mismatch: {los.shape} vs {his.shape}"
            )
        if deadline is None:
            deadline = self._deadline()
        out: list[np.ndarray] = []
        certs: list = []
        for a, b in self._chunks(los.shape[0]):
            runner = self._shard_runner(deadline)
            if self.adaptive:
                res = self._window_adaptive(los[a:b], his[a:b], deadline)
                if self.stream is not None:
                    res = self._merge_overlay_window(res, los[a:b], his[a:b])
                out.extend(res)
                certs.extend(
                    CompletenessCertificate.intact() for _ in range(b - a)
                )
            elif self.stream is not None:
                res = self._window_streaming(
                    los[a:b], his[a:b], runner, return_certs=return_certs,
                )
                if return_certs:
                    res, cs = res
                    certs.extend(cs)
                out.extend(res)
            elif self.sdev is not None:
                with self.table_lock.read():
                    res = window_query_batch_sharded(
                        self.sdev, los[a:b], his[a:b],
                        use_kernel=self.use_kernel, runner=runner,
                        return_certs=return_certs,
                    )
                if return_certs:
                    res, cs = res
                    certs.extend(cs)
                out.extend(res)
            else:
                try:
                    with self.table_lock.read():
                        out.extend(runner(0, lambda a=a, b=b: (
                            window_query_batch_jax(
                                self.dev, los[a:b], his[a:b],
                                use_kernel=self.use_kernel,
                            )
                        )))
                    certs.extend(
                        CompletenessCertificate.intact()
                        for _ in range(b - a)
                    )
                except ShardUnavailable:
                    if not return_certs:
                        raise
                    out.extend(
                        np.zeros(0, dtype=np.int64) for _ in range(b - a)
                    )
                    certs.extend(self._root_cert() for _ in range(b - a))
            self.stats.microbatches += 1
        self.stats.queries += los.shape[0]
        if return_certs:
            self.stats.degraded_queries += sum(
                1 for c in certs if not c.complete
            )
            return out, certs
        return out

    def knn(self, qs: np.ndarray, k: int, *,
            return_certs: bool = False, deadline=None,
            max_rounds: int | None = None) -> list[np.ndarray]:
        """Per-query ascending-distance row ids (length min(k, n)).

        Degraded mode mirrors :meth:`window`; a k-NN certificate can be
        ``certified_exact`` even when shards were down (the pruning
        radius clears their subspaces — see the distributed protocol).

        ``max_rounds`` caps the device engine's budget-escalation rounds
        (the frontend's brownout tier).  A capped query returns its
        best-effort answer with ``certified_exact=False`` on its
        certificate.  The cap applies to the single-table compiled
        dispatch; the sharded two-round protocol and the adaptive host
        path keep their own exactness machinery and ignore it.
        """
        from ..core.distributed_jax import (
            CompletenessCertificate,
            ShardUnavailable,
            knn_query_batch_sharded,
        )
        from ..core.queries_jax import knn_query_batch_jax

        qs = self._validate_batch(qs, "qs")
        if not isinstance(k, (int, np.integer)) or int(k) < 1:
            raise ValueError(f"k must be a positive integer, got {k!r}")
        k = int(k)
        if deadline is None:
            deadline = self._deadline()
        out: list[np.ndarray] = []
        certs: list = []
        for a, b in self._chunks(qs.shape[0]):
            runner = self._shard_runner(deadline)
            if self.adaptive:
                if self.stream is not None:
                    k_eff = self._k_eff(k)
                    res = self._knn_adaptive(qs[a:b], k_eff, deadline)
                    res = self._merge_overlay_knn(res, qs[a:b], k)
                else:
                    res = self._knn_adaptive(qs[a:b], k, deadline)
                out.extend(res)
                certs.extend(
                    CompletenessCertificate.intact() for _ in range(b - a)
                )
            elif self.stream is not None:
                res = self._knn_streaming(
                    qs[a:b], k, runner, return_certs=return_certs,
                )
                if return_certs:
                    res, cs = res
                    certs.extend(cs)
                out.extend(res)
            elif self.sdev is not None:
                with self.table_lock.read():
                    res = knn_query_batch_sharded(
                        self.sdev, qs[a:b], k, use_kernel=self.use_kernel,
                        runner=runner, return_certs=return_certs,
                    )
                if return_certs:
                    res, cs = res
                    certs.extend(cs)
                out.extend(res)
            else:
                try:
                    with self.table_lock.read():
                        res, exact = runner(0, lambda a=a, b=b: (
                            knn_query_batch_jax(
                                self.dev, qs[a:b], k,
                                use_kernel=self.use_kernel,
                                max_rounds=max_rounds, return_exact=True,
                            )
                        ))
                    out.extend(res)
                    certs.extend(
                        CompletenessCertificate.intact() if bool(e)
                        else CompletenessCertificate(
                            complete=True, certified_exact=False
                        )
                        for e in exact
                    )
                except ShardUnavailable:
                    if not return_certs:
                        raise
                    out.extend(
                        np.zeros(0, dtype=np.int64) for _ in range(b - a)
                    )
                    certs.extend(self._root_cert() for _ in range(b - a))
            self.stats.microbatches += 1
        self.stats.queries += qs.shape[0]
        if return_certs:
            self.stats.degraded_queries += sum(
                1 for c in certs if not c.complete
            )
            return out, certs
        return out

    def cold_window_mask(self, los: np.ndarray, his: np.ndarray) -> np.ndarray:
        """Which window queries reach unrefined (cold) space — the cheap
        host-side test the async frontend uses to split a microbatch into
        a device-lane hot part and a refine-lane cold part *before*
        dispatch, so host refinement overlaps device execution instead of
        serializing behind it.  Hit sets are downward-closed, so reaching
        an unrefined row equals intersecting its MBB.  Non-adaptive
        servers have no cold space: all-False."""
        los = np.atleast_2d(np.asarray(los, dtype=np.float64))
        his = np.atleast_2d(np.asarray(his, dtype=np.float64))
        if not self.adaptive:
            return np.zeros(los.shape[0], dtype=bool)
        with self.table_lock.read():
            return self._cold_mask_unlocked(los, his)

    # -- brownout tier: device-only answers, no host refinement --------------
    def _cold_boxes_cert(self, lo, hi):
        """Certificate for a cold query answered device-only: the unrefined
        subspaces intersecting the window are the unanswered region."""
        from ..core.distributed_jax import CompletenessCertificate
        from ..core.geometry import boxes_intersect_windows

        t = self.ambi.table
        unref = np.flatnonzero(t.unrefined)
        if len(unref):
            hit = boxes_intersect_windows(
                t.mbb_lo[unref], t.mbb_hi[unref], lo[None], hi[None]
            )[0]
            unref = unref[hit]
        if not len(unref):
            return CompletenessCertificate.intact()
        return CompletenessCertificate(
            complete=False, certified_exact=False, missing_shards=(),
            missing_lo=np.asarray(t.mbb_lo[unref], dtype=np.float32),
            missing_hi=np.asarray(t.mbb_hi[unref], dtype=np.float32),
        )

    def window_hot(self, los: np.ndarray, his: np.ndarray, *,
                   deadline=None):
        """Brownout-tier window serving: answer from the device's refined
        subset only — no host refinement, no grafting, no cold-path I/O.
        Returns ``(results, certs)``; a query reaching cold space comes
        back *partial* (its refined-subset hits) with the unrefined
        subspaces it touches listed as the certificate's missing boxes.
        Only meaningful on an adaptive server; a fully refined table makes
        this identical to :meth:`window`."""
        from ..core.distributed_jax import CompletenessCertificate
        from ..core.queries_jax import window_query_batch_jax

        if not self.adaptive:
            return self.window(los, his, return_certs=True,
                               deadline=deadline)
        los = self._validate_batch(los, "los")
        his = self._validate_batch(his, "his")
        if deadline is None:
            deadline = self._deadline()
        out: list[np.ndarray] = []
        certs: list = []
        for a, b in self._chunks(los.shape[0]):
            runner = self._shard_runner(deadline)
            with self.table_lock.read():
                cold_q = np.asarray(
                    self._cold_mask_unlocked(los[a:b], his[a:b])
                )
                if self.sdev is not None:
                    res = [np.zeros(0, dtype=np.int64)] * (b - a)
                    hot = np.flatnonzero(~cold_q)
                    if hot.size:
                        hres, hcs = self._sharded_window(
                            los[a:b][hot], his[a:b][hot], runner
                        )
                        for qi, ids in zip(hot, hres):
                            res[qi] = ids
                else:
                    res, cold = runner(0, lambda a=a, b=b: (
                        window_query_batch_jax(
                            self.dev, los[a:b], his[a:b],
                            use_kernel=self.use_kernel, return_cold=True,
                        )
                    ))
                    res = list(res)
                    cold_q = cold_q | np.asarray(cold).any(axis=1)
                for i in range(b - a):
                    certs.append(
                        self._cold_boxes_cert(los[a + i], his[a + i])
                        if cold_q[i]
                        else CompletenessCertificate.intact()
                    )
            out.extend(res)
            self.stats.microbatches += 1
            self.stats.hot_queries += int((~cold_q).sum())
            self.stats.cold_queries += int(cold_q.sum())
        self.stats.queries += los.shape[0]
        self.stats.degraded_queries += sum(1 for c in certs if not c.complete)
        return out, certs

    def knn_hot(self, qs: np.ndarray, k: int, *, deadline=None,
                max_rounds: int | None = None):
        """Brownout-tier k-NN: device-only, escalation capped, no host
        refinement.  Returns ``(results, certs)`` — a query whose answer
        a cold box could still beat (or whose escalation was capped)
        carries ``certified_exact=False``."""
        from ..core.distributed_jax import (
            CompletenessCertificate,
            knn_query_batch_sharded,
        )
        from ..core.queries_jax import knn_query_batch_jax

        if not self.adaptive:
            return self.knn(qs, k, return_certs=True, deadline=deadline,
                            max_rounds=max_rounds)
        qs = self._validate_batch(qs, "qs")
        k = int(k)
        if deadline is None:
            deadline = self._deadline()
        out: list[np.ndarray] = []
        certs: list = []
        for a, b in self._chunks(qs.shape[0]):
            runner = self._shard_runner(deadline)
            with self.table_lock.read():
                t = self.ambi.table
                if self.sdev is not None:
                    res, _cs = knn_query_batch_sharded(
                        self.sdev, qs[a:b], k, use_kernel=self.use_kernel,
                        runner=runner, return_certs=True,
                    )
                    res = list(res)
                    exact = np.ones(b - a, dtype=bool)
                else:
                    res, exact = runner(0, lambda a=a, b=b: (
                        knn_query_batch_jax(
                            self.dev, qs[a:b], k,
                            use_kernel=self.use_kernel,
                            max_rounds=max_rounds, return_exact=True,
                        )
                    ))
                    res = list(res)
                cold_q = self._knn_cold_mask(qs[a:b], res, k)
                unref = np.flatnonzero(t.unrefined)
                for i in range(b - a):
                    if not cold_q[i] and exact[i]:
                        certs.append(CompletenessCertificate.intact())
                    else:
                        certs.append(CompletenessCertificate(
                            complete=not cold_q[i],
                            certified_exact=False,
                            missing_shards=(),
                            missing_lo=np.asarray(
                                t.mbb_lo[unref], dtype=np.float32),
                            missing_hi=np.asarray(
                                t.mbb_hi[unref], dtype=np.float32),
                        ))
            out.extend(res)
            self.stats.microbatches += 1
            self.stats.hot_queries += int((~cold_q).sum())
            self.stats.cold_queries += int(cold_q.sum())
        self.stats.queries += qs.shape[0]
        self.stats.degraded_queries += sum(1 for c in certs if not c.complete)
        return out, certs

    def _cold_mask_unlocked(self, los, his) -> np.ndarray:
        """`cold_window_mask` body without the lock (callers hold read)."""
        from ..core.geometry import boxes_intersect_windows

        t = self.ambi.table
        unref = np.flatnonzero(t.unrefined)
        if not len(unref):
            return np.zeros(np.atleast_2d(los).shape[0], dtype=bool)
        return boxes_intersect_windows(
            t.mbb_lo[unref], t.mbb_hi[unref],
            np.asarray(los, dtype=np.float64),
            np.asarray(his, dtype=np.float64),
        ).any(axis=1)

    def _sharded_window(self, los, his, runner):
        from ..core.distributed_jax import window_query_batch_sharded

        return window_query_batch_sharded(
            self.sdev, los, his, use_kernel=self.use_kernel,
            runner=runner, return_certs=True,
        )

    # -- adaptive serving loop ----------------------------------------------
    # The host AMBI engine is authoritative over the full dataset, so the
    # adaptive server degrades *gracefully* under device outages: a failed
    # dispatch reroutes the affected queries down the (exact) host cold
    # path instead of returning partial answers — certificates stay intact.
    def _journal_op(self, op: str, **args) -> None:  # analysis: caller-holds-write
        """Write-ahead: durably journal a cold host op before executing it
        (recovery replays exactly the journaled sequence).  An append that
        cannot be made durable fails the op — never execute unlogged.
        Callers hold the writer lock: journal seq must equal application
        order, so append and apply are one atomic writer section."""
        if self.journal is None:
            return

        def attempt():
            return self.journal.append(op, **args)

        self.retry.call(
            attempt, on_retry=self._count_retry, call_key="journal"
        )
        self.stats.journal_records += 1

    def _host_window(self, lo, hi) -> np.ndarray:  # analysis: caller-holds-write
        """Cold-path window: journal, then host-answer (+ refine) under
        retry.  Faults fire at entry, before any host mutation, so a
        retried attempt re-runs the op from scratch."""
        self._journal_op(
            "window", lo=[float(v) for v in lo], hi=[float(v) for v in hi]
        )

        def attempt():
            if self.fault_plan is not None:
                self.fault_plan.fire("host_refine", op="window")
            return self.ambi.window(lo, hi)

        ids, _ = self.retry.call(
            attempt, on_retry=self._count_retry, call_key="host_refine"
        )
        return ids

    def _host_knn(self, q, k: int) -> np.ndarray:  # analysis: caller-holds-write
        self._journal_op("knn", q=[float(v) for v in q], k=int(k))

        def attempt():
            if self.fault_plan is not None:
                self.fault_plan.fire("host_refine", op="knn")
            return self.ambi.knn(q, k)

        ids, _ = self.retry.call(
            attempt, on_retry=self._count_retry, call_key="host_refine"
        )
        return ids

    def _window_adaptive(self, los, his, deadline=None) -> list[np.ndarray]:
        """One microbatch: device answers for hot queries, host answers
        (+ refinement + device refresh) for queries reaching cold space."""
        from ..core.distributed_jax import (
            ShardUnavailable,
            window_query_batch_sharded,
        )
        from ..core.geometry import boxes_intersect_windows
        from ..core.queries_jax import window_query_batch_jax

        runner = self._shard_runner(deadline)
        with self.table_lock.read():
            t = self.ambi.table
            unref = np.flatnonzero(t.unrefined)
            version = self._table_version
            if self.sdev is not None:
                # reaching an unrefined row == intersecting its MBB (hit
                # sets are downward-closed), so the host-side router test
                # equals the frontier's cold mask without a cross-shard
                # gather — and, being known up front, lets the device
                # serve only the hot part
                cold_q = (
                    boxes_intersect_windows(
                        t.mbb_lo[unref], t.mbb_hi[unref],
                        np.asarray(los, dtype=np.float64),
                        np.asarray(his, dtype=np.float64),
                    ).any(axis=1)
                    if len(unref)
                    else np.zeros(los.shape[0], dtype=bool)
                )
                out: list = [None] * los.shape[0]
                hot = np.flatnonzero(~cold_q)
                if hot.size:
                    res, cs = window_query_batch_sharded(
                        self.sdev, los[hot], his[hot],
                        use_kernel=self.use_kernel, runner=runner,
                        return_certs=True,
                    )
                    for qi, ids, cert in zip(hot, res, cs):
                        if cert.complete:
                            out[qi] = ids
                        else:  # dead shard: exact host answer instead
                            cold_q[qi] = True
                            self.stats.host_fallbacks += 1
            else:
                try:
                    res, cold = runner(0, lambda: window_query_batch_jax(
                        self.dev, los, his,
                        use_kernel=self.use_kernel, return_cold=True,
                    ))
                    out = list(res)
                    cold_q = cold.any(axis=1)
                except ShardUnavailable:
                    # whole-device outage: host serves the full microbatch
                    out = [None] * los.shape[0]
                    cold_q = np.ones(los.shape[0], dtype=bool)
                    self.stats.host_fallbacks += los.shape[0]
        if cold_q.any():
            with self.table_lock.write():
                if self._table_version != version:
                    # a writer compacted between our read and write
                    # sections: the captured row indices are stale
                    unref = np.flatnonzero(t.unrefined)
                for i in np.flatnonzero(cold_q):
                    out[i] = self._host_window(los[i], his[i])
                self._after_refinement(unref)  # pre-serving unrefined rows
        self.stats.hot_queries += int((~cold_q).sum())
        self.stats.cold_queries += int(cold_q.sum())
        return out

    def _knn_adaptive(self, qs, k: int, deadline=None) -> list[np.ndarray]:
        from ..core.distributed_jax import (
            ShardUnavailable,
            knn_query_batch_sharded,
        )
        from ..core.queries_jax import knn_query_batch_jax

        runner = self._shard_runner(deadline)
        with self.table_lock.read():
            t = self.ambi.table
            degraded = np.zeros(qs.shape[0], dtype=bool)
            if self.sdev is not None:
                res, cs = knn_query_batch_sharded(
                    self.sdev, qs, k, use_kernel=self.use_kernel,
                    runner=runner, return_certs=True,
                )
                res = list(res)
                for i, cert in enumerate(cs):
                    if not cert.certified_exact:
                        degraded[i] = True
                        self.stats.host_fallbacks += 1
            else:
                try:
                    res = list(runner(0, lambda: knn_query_batch_jax(
                        self.dev, qs, k, use_kernel=self.use_kernel
                    )))
                except ShardUnavailable:
                    res = [np.zeros(0, dtype=np.int64)] * qs.shape[0]
                    degraded[:] = True
                    self.stats.host_fallbacks += qs.shape[0]
            out = list(res)
            cold_q = self._knn_cold_mask(qs, res, k) | degraded
            before_unref = np.flatnonzero(t.unrefined)
            version = self._table_version
        if cold_q.any():
            with self.table_lock.write():
                if self._table_version != version:
                    before_unref = np.flatnonzero(t.unrefined)
                for i in np.flatnonzero(cold_q):
                    out[i] = self._host_knn(qs[i], k)
                self._after_refinement(before_unref)
        self.stats.hot_queries += int((~cold_q).sum())
        self.stats.cold_queries += int(cold_q.sum())
        return out

    def _knn_cold_mask(self, qs, res, k: int) -> np.ndarray:
        """Which queries the device answer cannot certify: a cold box
        could hold a closer neighbor (mindist within the k-th distance,
        both exact float64 over the host data — ``<=`` keeps boundary
        ties host-side, matching what the host's own best-first refinement
        would expand), or the refined subset is short of k."""
        from ..core.geometry import boxes_mindist_sq

        t = self.ambi.table
        qs = np.asarray(qs, dtype=np.float64)
        cold = np.zeros(qs.shape[0], dtype=bool)
        unref = np.flatnonzero(t.unrefined)
        want = min(k, len(self.points))
        if not len(unref):
            return cold
        minds = boxes_mindist_sq(t.mbb_lo[unref], t.mbb_hi[unref], qs)
        for i, ids in enumerate(res):
            if len(ids) < want:
                cold[i] = True
                continue
            kth = float(
                np.max(np.sum((self.points[ids] - qs[i]) ** 2, axis=1))
            )
            cold[i] = bool(minds[i].min() <= kth)
        return cold

    # -- streaming ingest ----------------------------------------------------
    # The stream (host LSM tiers + delta) is authoritative; the device
    # serves the mirror of its tiers, tombstones filter host-side, and the
    # not-yet-flushed delta rows are unioned in by brute force (they are
    # few by construction: at most delta_threshold).
    def _ensure_stream(self):  # analysis: caller-holds-write
        if self.stream is None:
            if not self.adaptive:
                raise ValueError(
                    "ingest needs a streaming or adaptive server — boot "
                    "with from_streaming(...) or from_ambi(...)"
                )
            from ..core.streaming import StreamingIndex

            # adaptive overlay: the AMBI rows stay where they are (ids
            # [0, n) keep meaning buffer rows); only new points get tiered
            self.stream = StreamingIndex(
                self._points, store=self.ambi.store, base_external=True,
                **self.OVERLAY_KW,
            )
            _san.bind(self.stream, self.table_lock)
        return self.stream

    def insert(self, pts) -> np.ndarray:
        """Ingest points; returns their assigned ids.  Journaled (when
        durable), applied under the writer lock, and any tier flush/merge
        it triggers ships to the device before the lock drops."""
        pts = self._validate_batch(pts, "pts")
        if self.stream is None and not self.adaptive:
            raise ValueError(
                "this server is static — boot with from_streaming(...) "
                "or from_ambi(...) to ingest"
            )
        with self.table_lock.write():
            stream = self._ensure_stream()
            # journal inside the writer section: journal seq must match
            # application order or replay assigns different ids than the
            # live run acknowledged to clients
            self._journal_op(
                "insert", pts=[[float(v) for v in p] for p in pts]
            )
            ids = stream.insert(pts)
            self._sync_stream_device()
        self.stats.inserts += len(pts)
        return ids

    def delete(self, ids) -> int:
        """Tombstone ids; returns how many were newly deleted.  The points
        stay physically present until a merge rewrites their tier — queries
        filter them immediately."""
        ids = np.unique(np.asarray(ids, dtype=np.int64).ravel())
        if self.stream is None and not self.adaptive:
            raise ValueError(
                "this server is static — boot with from_streaming(...) "
                "or from_ambi(...) to ingest"
            )
        with self.table_lock.write():
            stream = self._ensure_stream()
            # validate before journaling (and journal under the lock, in
            # application order): a durable record that deterministically
            # raises would make every subsequent recover() fail
            if len(ids) and (ids[0] < 0 or ids[-1] >= stream.n_ids):
                raise IndexError("delete id out of range")
            self._journal_op("delete", ids=[int(i) for i in ids])
            n = stream.delete(ids)
            self._sync_stream_device()
        self.stats.deletes += n
        return n

    def _sync_stream_device(self) -> None:  # analysis: caller-holds-write
        """Ship the stream's structural events (tier attach/merge) to the
        device.  Caller holds the writer lock.  Single device: one
        ``apply_delta`` (only new leaf blocks upload).  Sharded: plan
        surgery + per-changed-shard refresh.  The adaptive overlay has no
        mirror — its tiers serve host-side."""
        if self.mirror is None:
            return
        from .resilience import RetryExhausted

        info = self.mirror.sync()
        if (info is None and not self._stream_stale_shards
                and not self._stream_device_stale):
            return
        self.stats.stream_syncs += 1

        def upload():
            if self.fault_plan is not None:
                self.fault_plan.fire("apply_delta")
            if self.sdev is not None:
                self._stream_refresh_shards(info)
            else:
                self.dev = self.dev.apply_delta(
                    self.mirror.table, self.stream.points
                )
                self._stream_device_stale = False
                self.stats.delta_refreshes += 1

        try:
            self.retry.call(
                upload, on_retry=self._count_retry, call_key="apply_delta"
            )
        except RetryExhausted:
            # device stale, host authoritative: streaming queries serve
            # host-side until a later sync lands the upload.  Sharded
            # keeps the failed set in _stream_stale_shards; the single
            # device records a whole-table stale flag — both re-enter
            # upload on the next sync even if it carries no new events.
            if self.sdev is None:
                self._stream_device_stale = True

    def _stream_refresh_shards(self, info) -> None:  # analysis: caller-holds-write
        """Rewrite the shard plans through the mirror's sync summary and
        re-export only the shards whose content changed.

        Root copies that merely *moved* (the per-sync root-block rebuild,
        fusion adopting old roots) are remapped in the plan without a
        refresh — their subtree content is identical.  Shards lose plan
        entries when a rebuild-merge retires their tiers and gain the
        merged/attached roots back, preferring empty shards then the
        smallest."""
        sdev = self.sdev
        # the stream's buffer reallocates as it grows; refresh gathers
        # coordinates through source_points, so rebind the live view
        sdev.source_points = self.stream.points
        changed = set(self._stream_stale_shards)
        self._stream_stale_shards = set()
        if info is not None:
            remap = info["remap"]
            retired = info["retired"]
            plans = sdev.shard_roots
            for s in range(sdev.m):
                new_plan = []
                for r in plans[s]:
                    r = int(remap.get(int(r), int(r)))
                    if any(lo <= r < hi for lo, hi in retired):
                        changed.add(s)
                        continue
                    if r not in new_plan:
                        new_plan.append(r)
                plans[s] = new_plan
            placed = {r for p in plans for r in p}
            pool = [int(r) for r in info["add_rows"] if r not in placed]
            n_empty = sum(1 for p in plans if not p)
            if pool and len(pool) < n_empty:
                # a cascade merged everything a shard owned into one tier:
                # expand the widest new root into its child rows (the same
                # frontier move shard_plan makes at boot) until every
                # shard can keep a subspace
                t = self.mirror.table
                sizes = t.subtree_points()
                while len(pool) < n_empty:
                    exp = [r for r in pool if t.child_count[r] > 0]
                    if not exp:
                        break
                    r = max(exp, key=lambda r: int(sizes[r]))
                    pool.remove(r)
                    fc, cc = int(t.first_child[r]), int(t.child_count[r])
                    pool.extend(range(fc, fc + cc))
            for r in pool:
                empties = [s for s in range(sdev.m) if not plans[s]]
                s = (empties[0] if empties else
                     min(range(sdev.m),
                         key=lambda s: int(sdev.shards[s].n_points)))
                plans[s].append(int(r))
                changed.add(s)
            for s in range(sdev.m):
                if plans[s]:
                    continue
                donors = [d for d in range(sdev.m) if len(plans[d]) > 1]
                if donors:
                    d = max(donors,
                            key=lambda d: int(sdev.shards[d].n_points))
                    plans[s].append(plans[d].pop())
                    changed.update((s, d))
                else:
                    # cannot keep m nonempty subspaces: full re-shard
                    # (the delta-only acceptance counter pins this to 0)
                    from ..core.distributed_jax import ShardedDeviceTable

                    self.sdev = ShardedDeviceTable.from_table(
                        self.mirror.table, self.stream.points,
                        self.requested_shards, stats=self.upload_stats,
                        compressed=self.compressed,
                    )
                    self.stats.stream_reshards += 1
                    return
        if changed:
            try:
                sdev.refresh(sorted(changed))
            except Exception:
                self._stream_stale_shards = changed
                raise
            self.stats.shard_refreshes += len(changed)

    def _k_eff(self, k: int) -> int:
        """k-NN over-fetch for tombstones: each component's top-(k+shadow)
        must contain its k best live rows.  Bucketed to the next power of
        two so a drifting shadow count reuses compiled k-variants."""
        shadow = self.stream.shadow if self.stream is not None else 0
        if shadow == 0:
            return k
        return max(k, 1 << (k + shadow - 1).bit_length())

    def _stream_is_stale(self) -> bool:
        """Device copies known to be missing just-flushed tier rows (a
        failed upload): the host stream answers exactly until the next
        sync converges the device."""
        return self._stream_device_stale or bool(self._stream_stale_shards)

    def _window_streaming(self, los, his, runner, *,
                          return_certs: bool = False):
        """Streaming window: device fan-out + tombstone filter + delta
        union.  A stale device or a single-device outage falls back to
        the authoritative host stream (exact, intact certificates); a
        sharded outage under ``return_certs`` serves degraded with the
        protocol's real per-shard certificates."""
        from ..core.distributed_jax import (
            CompletenessCertificate,
            ShardUnavailable,
            window_query_batch_sharded,
        )
        from ..core.queries_jax import window_query_batch_jax

        with self.table_lock.read():
            stream = self.stream
            certs = [CompletenessCertificate.intact() for _ in los]
            if self._stream_is_stale():
                out = stream.window(los, his)
                return (out, certs) if return_certs else out
            if self.sdev is not None:
                res = window_query_batch_sharded(
                    self.sdev, los, his, use_kernel=self.use_kernel,
                    runner=runner, return_certs=return_certs,
                )
                if return_certs:
                    res, certs = res
            else:
                try:
                    res = runner(0, lambda: window_query_batch_jax(
                        self.dev, los, his, use_kernel=self.use_kernel,
                    ))
                except ShardUnavailable:
                    out = stream.window(los, his)
                    return (out, certs) if return_certs else out
            pend = stream.delta_live_rows()
            if len(pend):
                p = stream.points[pend]
                inside = ((p[None, :, :] >= los[:, None, :])
                          & (p[None, :, :] <= his[:, None, :])).all(axis=2)
            out = []
            for i, ids in enumerate(res):
                ids = stream.filter_live(np.asarray(ids, dtype=np.int64))
                if len(pend):
                    ids = np.concatenate([ids, pend[inside[i]]])
                out.append(np.sort(ids))
        return (out, certs) if return_certs else out

    def _knn_streaming(self, qs, k: int, runner, *,
                       return_certs: bool = False):
        from ..core.distributed_jax import (
            CompletenessCertificate,
            ShardUnavailable,
            knn_query_batch_sharded,
        )
        from ..core.queries_jax import knn_query_batch_jax

        with self.table_lock.read():
            stream = self.stream
            certs = [CompletenessCertificate.intact() for _ in qs]
            if self._stream_is_stale():
                out = stream.knn(qs, k)
                return (out, certs) if return_certs else out
            n_phys = int(self.sdev.n_points if self.sdev is not None
                         else self.dev.live_points())
            k_eff = min(self._k_eff(k), n_phys)
            res = [np.empty(0, dtype=np.int64)] * len(qs)
            if k_eff > 0:
                if self.sdev is not None:
                    res = knn_query_batch_sharded(
                        self.sdev, qs, k_eff, use_kernel=self.use_kernel,
                        runner=runner, return_certs=return_certs,
                    )
                    if return_certs:
                        res, certs = res
                else:
                    try:
                        res = runner(0, lambda: knn_query_batch_jax(
                            self.dev, qs, k_eff, use_kernel=self.use_kernel,
                        ))
                    except ShardUnavailable:
                        out = stream.knn(qs, k)
                        return (out, certs) if return_certs else out
            pend = stream.delta_live_rows()
            pts = stream.points
            out = []
            for i in range(len(qs)):
                ids = stream.filter_live(np.asarray(res[i], dtype=np.int64))
                if len(pend):
                    ids = np.concatenate([ids, pend])
                ids = np.unique(ids)
                d2 = np.sum((pts[ids] - qs[i]) ** 2, axis=1)
                out.append(ids[np.lexsort((ids, d2))[:k]])
        return (out, certs) if return_certs else out

    def _merge_overlay_window(self, res, los, his) -> list[np.ndarray]:
        """Union an adaptive microbatch's base answers with the streaming
        overlay's, filtering base rows tombstoned by delete()."""
        with self.table_lock.read():
            s = self.stream
            over = s.window(los, his)
            out = []
            for base_ids, ov in zip(res, over):
                ids = s.filter_live(np.asarray(base_ids, dtype=np.int64))
                out.append(np.sort(np.concatenate([ids, ov])))
        return out

    def _merge_overlay_knn(self, res, qs, k: int) -> list[np.ndarray]:
        """Two-level top-k: the base path served top-k_eff physical rows
        (enough to survive tombstone filtering), the overlay serves its
        own top-k live; rank the union by exact f64 distance."""
        with self.table_lock.read():
            s = self.stream
            over = s.knn(qs, k)
            pts = s.points
            out = []
            for i, (base_ids, ov) in enumerate(zip(res, over)):
                ids = s.filter_live(np.asarray(base_ids, dtype=np.int64))
                ids = np.unique(np.concatenate([ids, ov]))
                d2 = np.sum((pts[ids] - qs[i]) ** 2, axis=1)
                out.append(ids[np.lexsort((ids, d2))[:k]])
        return out

    def _after_refinement(self, before_unref: np.ndarray) -> None:  # analysis: caller-holds-write
        """Push the microbatch's grafts to the device: incremental delta
        (single table) or per-changed-shard re-export (sharded), then
        vacuum the host table if grafting bloated it.

        The upload is retried under the ``apply_delta`` fault point (fired
        at entry — an injected upload fault never half-applies: the swap
        is double-buffered, the old export serves until the new one
        lands).  An upload that exhausts its retries leaves the device
        stale but the *host* current; the next cold answer/fallback is
        still exact, and the refresh is re-attempted after the next graft.
        """
        from .resilience import RetryExhausted

        t = self.ambi.table
        grafted = before_unref[~t.unrefined[before_unref]]
        if len(grafted) == 0:
            return
        self.stats.grafts += len(grafted)

        def upload():
            if self.fault_plan is not None:
                self.fault_plan.fire("apply_delta")
            if self.sdev is not None:
                if self.sdev.m < self.requested_shards:
                    # a boot from a barely refined table (ultimately the
                    # single-unrefined-root state, where the plan is [[0]])
                    # cannot cut m subspaces yet; re-plan once the grafts
                    # grow the tree far enough instead of full-re-exporting
                    # the one degenerate whole-table "shard" on every graft
                    sizes = t.subtree_points()
                    if len(t.shard_plan(
                        self.requested_shards, sizes
                    )) > self.sdev.m:
                        from ..core.distributed_jax import ShardedDeviceTable

                        self.sdev = ShardedDeviceTable.from_table(
                            t, self.points, self.requested_shards,
                            partial=True, stats=self.upload_stats,
                            compressed=self.compressed,
                        )
                        self.stats.shards = self.sdev.m
                        self.stats.shard_refreshes += self.sdev.m
                        return
                changed = self.sdev.shards_of_rows(grafted)
                self.sdev.refresh(changed)
                self.stats.shard_refreshes += len(changed)
            else:
                self.dev = self.dev.apply_delta(t, self.points)  # swap
                self.stats.delta_refreshes += 1

        try:
            self.retry.call(
                upload, on_retry=self._count_retry, call_key="apply_delta"
            )
        except RetryExhausted:
            pass  # device stale, host authoritative; retried next graft
        self._maybe_compact()

    def _maybe_compact(self) -> None:  # analysis: caller-holds-write
        """Vacuum the host table once grafting bloated it, rebasing the
        device/shard scaffolding through the returned row remap.  With a
        journal, the vacuum is itself a journaled op (replay must compact
        at the same point to stay bit-identical) and doubles as the
        snapshot barrier: checkpoint, then truncate the folded journal."""
        from .resilience import RetryExhausted

        t = self.ambi.table
        if t.n_perm > (1.0 + self.compact_slack) * len(self.points):
            # the compact() row remap and the device/shard rebase must be
            # one atomic writer section: a concurrent apply_delta swap (or
            # reader capturing row indices) between them would observe a
            # half-rebased slot map.  Callers enter through the adaptive
            # write sections; this pins the invariant for new call sites.
            assert self.table_lock.held_write(), (
                "_maybe_compact requires the TableLock writer section"
            )
            if self.journal is not None:
                try:
                    self._journal_op("compact")
                except RetryExhausted:
                    return  # not durably logged -> defer the vacuum
            remap = t.compact()
            if self.sdev is not None:
                self.sdev.remap_source_rows(remap)
            elif self.dev is not None:
                self.dev.remap_rows(remap)
            self._table_version += 1
            self.stats.compactions += 1
            if self.snapshot_path is not None:
                try:
                    self._checkpoint_locked()
                except RetryExhausted:
                    pass  # barrier deferred; journal still holds the ops

    # -- durability: snapshot barriers + crash recovery ----------------------
    def checkpoint(self) -> None:
        """Durable snapshot barrier: atomically persist the table, the
        dataset, and the adaptive state (rng + page store), recording the
        journal's high-water ``seq``; then truncate the journal (its
        records are folded into the snapshot).  Crash-ordering: the
        snapshot lands via atomic rename *before* the truncate, and
        recovery skips records at or below the recorded seq — a kill
        between the two replays nothing twice.

        Takes the writer lock: the snapshot must capture a quiesced
        state, and the captured seq, the saved bytes, and the truncate
        must not interleave with a concurrent writer (a journal record
        folded into no snapshot but truncated anyway would be lost).
        ``_maybe_compact`` calls :meth:`_checkpoint_locked` directly —
        it already holds the writer section (TableLock is not
        reentrant)."""
        if self.snapshot_path is None:
            raise ValueError("no snapshot_path configured")
        with self.table_lock.write():
            self._checkpoint_locked()

    def _checkpoint_locked(self) -> None:  # analysis: caller-holds-write
        if self.snapshot_path is None:
            raise ValueError("no snapshot_path configured")

        def attempt():
            if self.fault_plan is not None:
                self.fault_plan.fire("snapshot_save", path=self.snapshot_path)
            seq = self.journal.seq if self.journal else 0
            if self.stream is not None and not self.adaptive:
                # streaming barrier: the stream IS the authoritative state
                # (points, tombstones, tiers, store); the mirror is derived
                # and rebuilt at boot
                self.stream.save(self.snapshot_path,
                                 extra={"journal_seq": seq})
                return
            self.ambi.table.save(
                self.snapshot_path, points=self._points,
                extra={
                    "ambi_state": self.ambi.state_meta(),
                    "journal_seq": seq,
                },
            )
            if self.stream is not None:
                # adaptive overlay rides along as a sidecar in the same
                # barrier.  The two saves are not atomic as a pair: a
                # crash in between leaves the old sidecar next to the new
                # base, so recovery replays ingest from the sidecar's OWN
                # recorded seq, not the base's (see recover())
                self.stream.save(self._overlay_sidecar(),
                                 extra={"journal_seq": seq})

        self.retry.call(
            attempt, on_retry=self._count_retry, call_key="snapshot"
        )
        if self.journal is not None:
            self.journal.truncate()
        self.stats.checkpoints += 1

    def _overlay_sidecar(self) -> str:
        return self.snapshot_path[:-len(".npz")] + ".stream.npz"

    @staticmethod
    def _replay_op(ambi, rec: dict) -> None:  # analysis: single-threaded(boot-time replay precedes serving)
        from .journal import JournalError

        op = rec.get("op")
        if op == "window":
            ambi.window(
                np.asarray(rec["lo"], dtype=np.float64),
                np.asarray(rec["hi"], dtype=np.float64),
            )
        elif op == "knn":
            ambi.knn(np.asarray(rec["q"], dtype=np.float64), int(rec["k"]))
        elif op == "compact":
            ambi.table.compact()
        else:
            raise JournalError(f"unknown journal op {op!r} (seq {rec.get('seq')})")

    @classmethod
    def recover(cls, snapshot_path, journal_path, *,  # analysis: single-threaded(recovery runs before the server takes traffic)
                fault_plan=None, **kw) -> "DeviceQueryServer":
        """Reboot a killed adaptive server: load the snapshot, replay the
        journal's post-barrier records against the restored AMBI state
        (grafting is deterministic given the snapshot's rng + page-store
        state, so the table lands bit-identical to the uninterrupted
        server's), then resume serving with the same durability config.

        The fault plane is disarmed for the replay — recovery re-executes
        already-acknowledged ops and must not be re-faulted — and rearmed
        before the recovered server takes traffic."""
        import os

        from ..core.ambi import AMBI
        from ..core.nodetable import NodeTable
        from ..core.streaming import StreamingIndex
        from .journal import GraftJournal

        snapshot_path = os.fspath(snapshot_path)
        if not snapshot_path.endswith(".npz"):
            snapshot_path += ".npz"
        if fault_plan is not None:
            fault_plan.fire("snapshot_load", path=snapshot_path)
        if StreamingIndex.is_stream_snapshot(snapshot_path):
            # streaming server: restore the stream, replay post-barrier
            # ingest on the host, then boot (the mirror and device exports
            # are derived state, rebuilt fresh from the restored tiers)
            stream, meta = StreamingIndex.load(snapshot_path)
            snap_seq = int(meta["journal_seq"])
            was_armed = fault_plan is not None and fault_plan.armed
            if was_armed:
                fault_plan.disarm()
            replayed = 0
            try:
                for rec in GraftJournal.read_records(
                    journal_path, after_seq=snap_seq
                ):
                    cls._replay_ingest(stream, rec)
                    replayed += 1
            finally:
                if was_armed:
                    fault_plan.rearm()
            srv = cls.from_streaming(
                stream, snapshot_path=snapshot_path,
                journal_path=journal_path, fault_plan=fault_plan, **kw,
            )
            srv.journal.seq = max(srv.journal.seq, snap_seq)
            srv.stats.replayed_records = replayed
            return srv
        table, meta, points = NodeTable.load(snapshot_path)
        if points is None or "ambi_state" not in meta:
            raise ValueError(
                "recovery snapshot must carry points and adaptive state "
                "(written by DeviceQueryServer.checkpoint)"
            )
        ambi = AMBI.from_table_state(
            np.asarray(points), table, str(meta["ambi_state"])
        )
        snap_seq = int(meta["journal_seq"])
        # the base snapshot and the overlay sidecar are two files written
        # in sequence — a crash between them leaves the sidecar at the
        # *previous* barrier's seq.  Each file keeps its own replay
        # cursor: ambi ops resume after the base's seq, ingest ops after
        # the sidecar's own recorded seq (0 when no sidecar exists — no
        # ingest was ever folded, so every journaled ingest op replays).
        overlay = None
        overlay_seq = 0
        sidecar = snapshot_path[:-len(".npz")] + ".stream.npz"
        if os.path.exists(sidecar):
            overlay, ometa = StreamingIndex.load(sidecar)
            overlay_seq = int(ometa["journal_seq"])
        was_armed = fault_plan is not None and fault_plan.armed
        if was_armed:
            fault_plan.disarm()
        replayed = 0
        try:
            for rec in GraftJournal.read_records(
                journal_path, after_seq=min(snap_seq, overlay_seq)
            ):
                if rec.get("op") in ("insert", "delete"):
                    if int(rec.get("seq", 0)) <= overlay_seq:
                        continue  # already folded into the sidecar
                    if overlay is None:
                        overlay = StreamingIndex(
                            np.asarray(points), store=ambi.store,
                            base_external=True, **cls.OVERLAY_KW,
                        )
                    cls._replay_ingest(overlay, rec)
                else:
                    if int(rec.get("seq", 0)) <= snap_seq:
                        continue  # already folded into the base snapshot
                    cls._replay_op(ambi, rec)
                replayed += 1
        finally:
            if was_armed:
                fault_plan.rearm()
        srv = cls.from_ambi(
            ambi, snapshot_path=snapshot_path, journal_path=journal_path,
            fault_plan=fault_plan, **kw,
        )
        srv.stream = overlay
        if overlay is not None:
            _san.bind(overlay, srv.table_lock)
        srv.journal.seq = max(srv.journal.seq, snap_seq)
        srv.stats.replayed_records = replayed
        return srv

    @staticmethod
    def _replay_ingest(stream, rec: dict) -> None:  # analysis: single-threaded(boot-time replay precedes serving)
        from .journal import JournalError

        op = rec.get("op")
        if op == "insert":
            stream.insert(np.asarray(rec["pts"], dtype=np.float64))
        elif op == "delete":
            stream.delete(np.asarray(rec["ids"], dtype=np.int64))
        else:
            raise JournalError(
                f"unknown journal op {op!r} (seq {rec.get('seq')})"
            )
