"""Serving engine: batched prefill/decode plus FMBI-backed kNN retrieval.

``LMServer`` is the generation path: continuous batched decode over a shared
cache pytree (prefill once, then step).  ``RetrievalServer`` serves batched
kNN/window queries over an FMBI ``JaxIndex``; in ``adaptive=True`` mode it
applies AMBI's residency policy — only leaves that the live query stream
touches are kept "hot" (the TPU analogue of the paper's buffer retention),
with hit statistics exposed for the workload-adaptation benchmark.
``DeviceQueryServer`` serves batched window and k-NN traffic straight off a
bulk-loaded ``NodeTable`` through the compiled ``queries_jax`` engine, with
microbatching so arbitrary client batch sizes reuse a bounded set of
compiled variants.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from ..core import jax_index
from ..kernels import ops as kops
from ..models import model as M
from ..models.sharding import MeshAxes


class LMServer:
    def __init__(self, cfg, params, axes: MeshAxes | None = None):
        self.cfg = cfg
        self.params = params
        self.axes = axes or MeshAxes()
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, self.axes)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos, self.axes)
        )

    def generate(self, tokens: np.ndarray, max_new: int,
                 cache_len: int | None = None) -> np.ndarray:
        """Greedy generation for a (B, S) prompt batch."""
        B, S = tokens.shape
        cache_len = cache_len or (S + max_new)
        lg, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        cache = jax.tree.map(
            lambda x: (
                jnp.concatenate(
                    [x, jnp.zeros(
                        x.shape[:2] + (cache_len - S,) + x.shape[3:], x.dtype
                    )], axis=2,
                )
                if x.ndim >= 3 and x.shape[2] == S
                else x
            ),
            cache,
        )
        out = [jnp.argmax(lg[:, -1], axis=-1)]
        for t in range(max_new - 1):
            pos = jnp.full((B,), S + t, jnp.int32)
            lg, cache = self._decode(
                self.params, out[-1][:, None].astype(jnp.int32), cache, pos
            )
            out.append(jnp.argmax(lg[:, 0], axis=-1))
        return np.stack([np.asarray(o) for o in out], axis=1)


@dataclasses.dataclass
class RetrievalStats:
    queries: int = 0
    hot_hits: int = 0
    cold_misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hot_hits + self.cold_misses
        return self.hot_hits / total if total else 0.0


class RetrievalServer:
    """Batched exact kNN over an FMBI JaxIndex (Pallas distance kernel).

    Two boot paths: build a balanced index from raw points (``__init__``),
    or bridge a bulk-loaded CPU ``NodeTable`` snapshot straight into the
    accelerator layout (``from_snapshot``) — no rebuild, no re-sort.
    """

    def __init__(self, points: np.ndarray, levels: int, *,
                 adaptive: bool = False, hot_capacity: int = 64):
        padded, ids = jax_index.pad_points(points.astype(np.float32), levels)
        self.index = jax_index.build(
            jnp.asarray(padded), levels, jnp.asarray(ids, jnp.int32)
        )
        self._routed = True  # built indexes carry split tables for route()
        self._init_serving(levels, adaptive, hot_capacity)

    @classmethod
    def from_snapshot(cls, path, *, adaptive: bool = False,
                      hot_capacity: int = 64) -> "RetrievalServer":
        """Boot from a ``NodeTable.save`` snapshot (``.npz`` with points).

        The snapshot's leaf-contiguous layout maps directly onto the
        ``JaxIndex`` grid via ``NodeTable.to_jax_index``; adaptive residency
        falls back to ``nearest_leaf`` because a bridged FMBI tree has no
        balanced split tables.
        """
        from ..core.nodetable import NodeTable

        table, _meta, points = NodeTable.load(path)
        if points is None:
            raise ValueError("snapshot was saved without points")
        self = cls.__new__(cls)
        self.index = table.to_jax_index(np.asarray(points))
        self._routed = False
        self._init_serving(self.index.levels, adaptive, hot_capacity)
        return self

    def _init_serving(self, levels: int, adaptive: bool,
                      hot_capacity: int) -> None:
        self.levels = levels
        self.adaptive = adaptive
        # leaf -> last-touch tick, insertion-ordered: recency order IS the
        # dict order (same structure as pagestore.LRUBuffer), so eviction is
        # popitem(last=False) instead of an O(capacity) min() scan per query
        self.hot: OrderedDict[int, int] = OrderedDict()
        self.hot_capacity = hot_capacity
        self.tick = 0
        self.stats = RetrievalStats()

    def knn(self, queries: np.ndarray, k: int, n_candidate_leaves: int = 8):
        rows, d2, exact = jax_index.knn(
            self.index, jnp.asarray(queries, jnp.float32), k,
            n_candidate_leaves=n_candidate_leaves,
        )
        if self.adaptive:
            locate = jax_index.route if self._routed else jax_index.nearest_leaf
            leaves = np.asarray(
                locate(self.index, jnp.asarray(queries, jnp.float32))
            )
            for leaf in leaves:
                self.tick += 1
                leaf = int(leaf)
                if leaf in self.hot:
                    self.stats.hot_hits += 1
                    self.hot.move_to_end(leaf)
                else:
                    self.stats.cold_misses += 1
                self.hot[leaf] = self.tick
                if len(self.hot) > self.hot_capacity:
                    self.hot.popitem(last=False)  # least recent first
            self.stats.queries += len(queries)
        return np.asarray(rows), np.asarray(d2), np.asarray(exact)

    def knn_kernel(self, queries: np.ndarray, k: int):
        """Direct Pallas-kernel path (distance tiles + top-k)."""
        idx, d2 = kops.knn_topk(
            jnp.asarray(queries, jnp.float32),
            self.index.points_sorted,
            k,
            valid=(self.index.row_ids >= 0).astype(jnp.int32),
        )
        return np.asarray(idx), np.asarray(d2)


@dataclasses.dataclass
class DeviceQueryStats:
    queries: int = 0
    microbatches: int = 0
    shards: int = 1
    hot_queries: int = 0       # answered entirely on the device
    cold_queries: int = 0      # reached unindexed space -> host + refine
    grafts: int = 0            # unrefined rows refined by the serving loop
    delta_refreshes: int = 0   # DeviceTable.apply_delta swaps
    shard_refreshes: int = 0   # shards re-exported by ShardedDeviceTable
    compactions: int = 0       # NodeTable.compact vacuums


class DeviceQueryServer:
    """Batched window/k-NN serving over a ``NodeTable`` via the compiled
    device engine (``core/queries_jax.py``).

    Boots from a built CPU index (or its ``.npz`` snapshot) by exporting
    the flat table to the device once; every query batch afterwards is one
    compiled dispatch.  Incoming traffic is split into ``microbatch``-sized
    chunks — each chunk pads to a power-of-two bucket inside the engine —
    so any client batch size is served by a bounded set of compiled
    variants instead of a fresh compilation per shape.  Exactness matches
    the NumPy engine (see the queries_jax parity contract); the simulated
    LRU I/O accounting stays with the CPU path.

    ``shards=m`` serves through the *sharded* engine instead
    (``core/distributed_jax.py``): the table partitions into m per-shard
    DeviceTables behind a subspace-MBB router, windows fan out only to
    qualified shards, and k-NN runs the two-round certified protocol —
    same results, distributed execution.

    ``adaptive=True`` (boot via :meth:`from_ambi`) serves an AMBI table
    that may be arbitrarily unrefined — down to the single-unrefined-root
    state, where the device holds nothing but the root's cold box:

      * the table is exported *partially* — unrefined rows ride along as
        cold boxes the compiled frontier traversal surfaces as a mask;
      * a query that never reaches cold space is answered entirely from
        the device (no simulated I/O, the hot path);
      * a cold query is answered by the host AMBI engine, whose refiner —
        carrying that query's context explicitly — charges the paper's
        I/O and grafts the touched subspaces;
      * after each microbatch the grafts are pushed to the device
        *incrementally*: ``DeviceTable.apply_delta`` uploads only the new
        leaf blocks into a double-buffered swap (sharded serving
        re-exports only the shards owning grafted subspaces), and
        ``NodeTable.compact`` vacuums dead perm segments once grafting
        has bloated the host table past ``compact_slack``.

    Under a focused workload the hot set converges and serving detaches
    from the host entirely — the paper's adaptivity argument carried onto
    the accelerator.
    """

    def __init__(self, table, points: np.ndarray, *,
                 microbatch: int = 64, use_kernel: bool | None = None,
                 shards: int | None = None, adaptive: bool = False,
                 ambi=None, compact_slack: float = 0.5):
        from ..core.distributed_jax import ShardedDeviceTable
        from ..core.queries_jax import DeviceTable

        if adaptive:
            if ambi is None:
                raise ValueError(
                    "adaptive serving needs the host AMBI engine — boot "
                    "with DeviceQueryServer.from_ambi(ambi)"
                )
            table, points = ambi.table, ambi.points
        points = np.asarray(points)
        if shards is not None and shards > 1:
            self.sdev = ShardedDeviceTable.from_table(
                table, points, shards, partial=adaptive
            )
            self.dev = None
            n_shards = self.sdev.m
        else:
            self.dev = DeviceTable.from_table(table, points, partial=adaptive)
            self.sdev = None
            n_shards = 1
        self.requested_shards = shards if shards is not None else 1
        self.adaptive = adaptive
        self.ambi = ambi
        self.points = points
        self.compact_slack = float(compact_slack)
        self.microbatch = int(microbatch)
        self.use_kernel = use_kernel
        self.stats = DeviceQueryStats(shards=n_shards)

    @classmethod
    def from_index(cls, index, **kw) -> "DeviceQueryServer":
        """From a built ``core.fmbi.Index`` (or AMBI's ``.index``)."""
        return cls(index.table, index.points, **kw)

    @classmethod
    def from_ambi(cls, ambi, **kw) -> "DeviceQueryServer":
        """Adaptive serving over a host AMBI engine (any refinement state,
        including the freshly constructed single-unrefined-root table)."""
        return cls(ambi.table, ambi.points, adaptive=True, ambi=ambi, **kw)

    @classmethod
    def from_snapshot(cls, path, **kw) -> "DeviceQueryServer":
        """From a ``NodeTable.save``/``Index.save`` snapshot with points."""
        from ..core.nodetable import NodeTable

        table, _meta, points = NodeTable.load(path)
        if points is None:
            raise ValueError("snapshot was saved without points")
        return cls(table, points, **kw)

    def _chunks(self, n: int):
        for start in range(0, n, self.microbatch):
            yield start, min(start + self.microbatch, n)

    def window(self, los: np.ndarray, his: np.ndarray) -> list[np.ndarray]:
        """Per-query dataset row ids inside each [lo, hi] box."""
        from ..core.distributed_jax import window_query_batch_sharded
        from ..core.queries_jax import window_query_batch_jax

        los = np.atleast_2d(np.asarray(los))
        his = np.atleast_2d(np.asarray(his))
        out: list[np.ndarray] = []
        for a, b in self._chunks(los.shape[0]):
            if self.adaptive:
                out.extend(self._window_adaptive(los[a:b], his[a:b]))
            elif self.sdev is not None:
                out.extend(window_query_batch_sharded(
                    self.sdev, los[a:b], his[a:b],
                    use_kernel=self.use_kernel,
                ))
            else:
                out.extend(window_query_batch_jax(
                    self.dev, los[a:b], his[a:b], use_kernel=self.use_kernel
                ))
            self.stats.microbatches += 1
        self.stats.queries += los.shape[0]
        return out

    def knn(self, qs: np.ndarray, k: int) -> list[np.ndarray]:
        """Per-query ascending-distance row ids (length min(k, n))."""
        from ..core.distributed_jax import knn_query_batch_sharded
        from ..core.queries_jax import knn_query_batch_jax

        qs = np.atleast_2d(np.asarray(qs))
        out: list[np.ndarray] = []
        for a, b in self._chunks(qs.shape[0]):
            if self.adaptive:
                out.extend(self._knn_adaptive(qs[a:b], k))
            elif self.sdev is not None:
                out.extend(knn_query_batch_sharded(
                    self.sdev, qs[a:b], k, use_kernel=self.use_kernel
                ))
            else:
                out.extend(knn_query_batch_jax(
                    self.dev, qs[a:b], k, use_kernel=self.use_kernel
                ))
            self.stats.microbatches += 1
        self.stats.queries += qs.shape[0]
        return out

    # -- adaptive serving loop ----------------------------------------------
    def _window_adaptive(self, los, his) -> list[np.ndarray]:
        """One microbatch: device answers for hot queries, host answers
        (+ refinement + device refresh) for queries reaching cold space."""
        from ..core.distributed_jax import window_query_batch_sharded
        from ..core.geometry import boxes_intersect_windows
        from ..core.queries_jax import window_query_batch_jax

        t = self.ambi.table
        unref = np.flatnonzero(t.unrefined)
        if self.sdev is not None:
            # reaching an unrefined row == intersecting its MBB (hit sets
            # are downward-closed), so the host-side router test equals
            # the frontier's cold mask without a cross-shard gather — and,
            # being known up front, lets the device serve only the hot part
            cold_q = (
                boxes_intersect_windows(
                    t.mbb_lo[unref], t.mbb_hi[unref],
                    np.asarray(los, dtype=np.float64),
                    np.asarray(his, dtype=np.float64),
                ).any(axis=1)
                if len(unref)
                else np.zeros(los.shape[0], dtype=bool)
            )
            out: list = [None] * los.shape[0]
            hot = np.flatnonzero(~cold_q)
            if hot.size:
                for qi, ids in zip(hot, window_query_batch_sharded(
                    self.sdev, los[hot], his[hot],
                    use_kernel=self.use_kernel,
                )):
                    out[qi] = ids
        else:
            res, cold = window_query_batch_jax(
                self.dev, los, his,
                use_kernel=self.use_kernel, return_cold=True,
            )
            out = list(res)
            cold_q = cold.any(axis=1)
        if cold_q.any():
            for i in np.flatnonzero(cold_q):
                ids, _ = self.ambi.window(los[i], his[i])
                out[i] = ids
            self._after_refinement(unref)  # the pre-serving unrefined rows
        self.stats.hot_queries += int((~cold_q).sum())
        self.stats.cold_queries += int(cold_q.sum())
        return out

    def _knn_adaptive(self, qs, k: int) -> list[np.ndarray]:
        from ..core.distributed_jax import knn_query_batch_sharded
        from ..core.queries_jax import knn_query_batch_jax

        t = self.ambi.table
        if self.sdev is not None:
            res = knn_query_batch_sharded(
                self.sdev, qs, k, use_kernel=self.use_kernel
            )
        else:
            res = knn_query_batch_jax(
                self.dev, qs, k, use_kernel=self.use_kernel
            )
        out = list(res)
        cold_q = self._knn_cold_mask(qs, res, k)
        if cold_q.any():
            before_unref = np.flatnonzero(t.unrefined)
            for i in np.flatnonzero(cold_q):
                ids, _ = self.ambi.knn(qs[i], k)
                out[i] = ids
            self._after_refinement(before_unref)
        self.stats.hot_queries += int((~cold_q).sum())
        self.stats.cold_queries += int(cold_q.sum())
        return out

    def _knn_cold_mask(self, qs, res, k: int) -> np.ndarray:
        """Which queries the device answer cannot certify: a cold box
        could hold a closer neighbor (mindist within the k-th distance,
        both exact float64 over the host data — ``<=`` keeps boundary
        ties host-side, matching what the host's own best-first refinement
        would expand), or the refined subset is short of k."""
        from ..core.geometry import boxes_mindist_sq

        t = self.ambi.table
        qs = np.asarray(qs, dtype=np.float64)
        cold = np.zeros(qs.shape[0], dtype=bool)
        unref = np.flatnonzero(t.unrefined)
        want = min(k, len(self.points))
        if not len(unref):
            return cold
        minds = boxes_mindist_sq(t.mbb_lo[unref], t.mbb_hi[unref], qs)
        for i, ids in enumerate(res):
            if len(ids) < want:
                cold[i] = True
                continue
            kth = float(
                np.max(np.sum((self.points[ids] - qs[i]) ** 2, axis=1))
            )
            cold[i] = bool(minds[i].min() <= kth)
        return cold

    def _after_refinement(self, before_unref: np.ndarray) -> None:
        """Push the microbatch's grafts to the device: incremental delta
        (single table) or per-changed-shard re-export (sharded), then
        vacuum the host table if grafting bloated it."""
        t = self.ambi.table
        grafted = before_unref[~t.unrefined[before_unref]]
        if len(grafted) == 0:
            return
        self.stats.grafts += len(grafted)
        if self.sdev is not None:
            if self.sdev.m < self.requested_shards:
                # a boot from a barely refined table (ultimately the
                # single-unrefined-root state, where the plan is [[0]])
                # cannot cut m subspaces yet; re-plan once the grafts grow
                # the tree far enough instead of full-re-exporting the one
                # degenerate whole-table "shard" on every graft
                sizes = t.subtree_points()
                if len(t.shard_plan(self.requested_shards, sizes)) > self.sdev.m:
                    from ..core.distributed_jax import ShardedDeviceTable

                    self.sdev = ShardedDeviceTable.from_table(
                        t, self.points, self.requested_shards, partial=True
                    )
                    self.stats.shards = self.sdev.m
                    self.stats.shard_refreshes += self.sdev.m
                    self._maybe_compact()
                    return
            changed = self.sdev.shards_of_rows(grafted)
            self.sdev.refresh(changed)
            self.stats.shard_refreshes += len(changed)
        else:
            self.dev = self.dev.apply_delta(t, self.points)  # buffer swap
            self.stats.delta_refreshes += 1
        self._maybe_compact()

    def _maybe_compact(self) -> None:
        """Vacuum the host table once grafting bloated it, rebasing the
        device/shard scaffolding through the returned row remap."""
        t = self.ambi.table
        if t.n_perm > (1.0 + self.compact_slack) * len(self.points):
            remap = t.compact()
            if self.sdev is not None:
                self.sdev.remap_source_rows(remap)
            else:
                self.dev.remap_rows(remap)
            self.stats.compactions += 1
