"""Serving engine: batched prefill/decode plus FMBI-backed kNN retrieval.

``LMServer`` is the generation path: continuous batched decode over a shared
cache pytree (prefill once, then step).  ``RetrievalServer`` serves batched
kNN/window queries over an FMBI ``JaxIndex``; in ``adaptive=True`` mode it
applies AMBI's residency policy — only leaves that the live query stream
touches are kept "hot" (the TPU analogue of the paper's buffer retention),
with hit statistics exposed for the workload-adaptation benchmark.
``DeviceQueryServer`` serves batched window and k-NN traffic straight off a
bulk-loaded ``NodeTable`` through the compiled ``queries_jax`` engine, with
microbatching so arbitrary client batch sizes reuse a bounded set of
compiled variants.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import jax_index
from ..kernels import ops as kops
from ..models import model as M
from ..models.sharding import MeshAxes


class LMServer:
    def __init__(self, cfg, params, axes: MeshAxes | None = None):
        self.cfg = cfg
        self.params = params
        self.axes = axes or MeshAxes()
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, self.axes)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos, self.axes)
        )

    def generate(self, tokens: np.ndarray, max_new: int,
                 cache_len: int | None = None) -> np.ndarray:
        """Greedy generation for a (B, S) prompt batch."""
        B, S = tokens.shape
        cache_len = cache_len or (S + max_new)
        lg, cache = self._prefill(self.params, {"tokens": jnp.asarray(tokens)})
        cache = jax.tree.map(
            lambda x: (
                jnp.concatenate(
                    [x, jnp.zeros(
                        x.shape[:2] + (cache_len - S,) + x.shape[3:], x.dtype
                    )], axis=2,
                )
                if x.ndim >= 3 and x.shape[2] == S
                else x
            ),
            cache,
        )
        out = [jnp.argmax(lg[:, -1], axis=-1)]
        for t in range(max_new - 1):
            pos = jnp.full((B,), S + t, jnp.int32)
            lg, cache = self._decode(
                self.params, out[-1][:, None].astype(jnp.int32), cache, pos
            )
            out.append(jnp.argmax(lg[:, 0], axis=-1))
        return np.stack([np.asarray(o) for o in out], axis=1)


@dataclasses.dataclass
class RetrievalStats:
    queries: int = 0
    hot_hits: int = 0
    cold_misses: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hot_hits + self.cold_misses
        return self.hot_hits / total if total else 0.0


class RetrievalServer:
    """Batched exact kNN over an FMBI JaxIndex (Pallas distance kernel).

    Two boot paths: build a balanced index from raw points (``__init__``),
    or bridge a bulk-loaded CPU ``NodeTable`` snapshot straight into the
    accelerator layout (``from_snapshot``) — no rebuild, no re-sort.
    """

    def __init__(self, points: np.ndarray, levels: int, *,
                 adaptive: bool = False, hot_capacity: int = 64):
        padded, ids = jax_index.pad_points(points.astype(np.float32), levels)
        self.index = jax_index.build(
            jnp.asarray(padded), levels, jnp.asarray(ids, jnp.int32)
        )
        self._routed = True  # built indexes carry split tables for route()
        self._init_serving(levels, adaptive, hot_capacity)

    @classmethod
    def from_snapshot(cls, path, *, adaptive: bool = False,
                      hot_capacity: int = 64) -> "RetrievalServer":
        """Boot from a ``NodeTable.save`` snapshot (``.npz`` with points).

        The snapshot's leaf-contiguous layout maps directly onto the
        ``JaxIndex`` grid via ``NodeTable.to_jax_index``; adaptive residency
        falls back to ``nearest_leaf`` because a bridged FMBI tree has no
        balanced split tables.
        """
        from ..core.nodetable import NodeTable

        table, _meta, points = NodeTable.load(path)
        if points is None:
            raise ValueError("snapshot was saved without points")
        self = cls.__new__(cls)
        self.index = table.to_jax_index(np.asarray(points))
        self._routed = False
        self._init_serving(self.index.levels, adaptive, hot_capacity)
        return self

    def _init_serving(self, levels: int, adaptive: bool,
                      hot_capacity: int) -> None:
        self.levels = levels
        self.adaptive = adaptive
        self.hot: dict[int, int] = {}  # leaf -> last-touch tick (AMBI policy)
        self.hot_capacity = hot_capacity
        self.tick = 0
        self.stats = RetrievalStats()

    def knn(self, queries: np.ndarray, k: int, n_candidate_leaves: int = 8):
        rows, d2, exact = jax_index.knn(
            self.index, jnp.asarray(queries, jnp.float32), k,
            n_candidate_leaves=n_candidate_leaves,
        )
        if self.adaptive:
            locate = jax_index.route if self._routed else jax_index.nearest_leaf
            leaves = np.asarray(
                locate(self.index, jnp.asarray(queries, jnp.float32))
            )
            for leaf in leaves:
                self.tick += 1
                if int(leaf) in self.hot:
                    self.stats.hot_hits += 1
                else:
                    self.stats.cold_misses += 1
                self.hot[int(leaf)] = self.tick
                if len(self.hot) > self.hot_capacity:
                    coldest = min(self.hot, key=self.hot.get)
                    del self.hot[coldest]
            self.stats.queries += len(queries)
        return np.asarray(rows), np.asarray(d2), np.asarray(exact)

    def knn_kernel(self, queries: np.ndarray, k: int):
        """Direct Pallas-kernel path (distance tiles + top-k)."""
        idx, d2 = kops.knn_topk(
            jnp.asarray(queries, jnp.float32),
            self.index.points_sorted,
            k,
            valid=(self.index.row_ids >= 0).astype(jnp.int32),
        )
        return np.asarray(idx), np.asarray(d2)


@dataclasses.dataclass
class DeviceQueryStats:
    queries: int = 0
    microbatches: int = 0
    shards: int = 1


class DeviceQueryServer:
    """Batched window/k-NN serving over a ``NodeTable`` via the compiled
    device engine (``core/queries_jax.py``).

    Boots from a built CPU index (or its ``.npz`` snapshot) by exporting
    the flat table to the device once; every query batch afterwards is one
    compiled dispatch.  Incoming traffic is split into ``microbatch``-sized
    chunks — each chunk pads to a power-of-two bucket inside the engine —
    so any client batch size is served by a bounded set of compiled
    variants instead of a fresh compilation per shape.  Exactness matches
    the NumPy engine (see the queries_jax parity contract); the simulated
    LRU I/O accounting stays with the CPU path.

    ``shards=m`` serves through the *sharded* engine instead
    (``core/distributed_jax.py``): the table partitions into m per-shard
    DeviceTables behind a subspace-MBB router, windows fan out only to
    qualified shards, and k-NN runs the two-round certified protocol —
    same results, distributed execution.
    """

    def __init__(self, table, points: np.ndarray, *,
                 microbatch: int = 64, use_kernel: bool | None = None,
                 shards: int | None = None):
        from ..core.distributed_jax import ShardedDeviceTable
        from ..core.queries_jax import DeviceTable

        points = np.asarray(points)
        if shards is not None and shards > 1:
            self.sdev = ShardedDeviceTable.from_table(table, points, shards)
            self.dev = None
            n_shards = self.sdev.m
        else:
            self.dev = DeviceTable.from_table(table, points)
            self.sdev = None
            n_shards = 1
        self.microbatch = int(microbatch)
        self.use_kernel = use_kernel
        self.stats = DeviceQueryStats(shards=n_shards)

    @classmethod
    def from_index(cls, index, **kw) -> "DeviceQueryServer":
        """From a built ``core.fmbi.Index`` (or AMBI's ``.index``)."""
        return cls(index.table, index.points, **kw)

    @classmethod
    def from_snapshot(cls, path, **kw) -> "DeviceQueryServer":
        """From a ``NodeTable.save``/``Index.save`` snapshot with points."""
        from ..core.nodetable import NodeTable

        table, _meta, points = NodeTable.load(path)
        if points is None:
            raise ValueError("snapshot was saved without points")
        return cls(table, points, **kw)

    def _chunks(self, n: int):
        for start in range(0, n, self.microbatch):
            yield start, min(start + self.microbatch, n)

    def window(self, los: np.ndarray, his: np.ndarray) -> list[np.ndarray]:
        """Per-query dataset row ids inside each [lo, hi] box."""
        from ..core.distributed_jax import window_query_batch_sharded
        from ..core.queries_jax import window_query_batch_jax

        los = np.atleast_2d(np.asarray(los))
        his = np.atleast_2d(np.asarray(his))
        out: list[np.ndarray] = []
        for a, b in self._chunks(los.shape[0]):
            if self.sdev is not None:
                out.extend(window_query_batch_sharded(
                    self.sdev, los[a:b], his[a:b],
                    use_kernel=self.use_kernel,
                ))
            else:
                out.extend(window_query_batch_jax(
                    self.dev, los[a:b], his[a:b], use_kernel=self.use_kernel
                ))
            self.stats.microbatches += 1
        self.stats.queries += los.shape[0]
        return out

    def knn(self, qs: np.ndarray, k: int) -> list[np.ndarray]:
        """Per-query ascending-distance row ids (length min(k, n))."""
        from ..core.distributed_jax import knn_query_batch_sharded
        from ..core.queries_jax import knn_query_batch_jax

        qs = np.atleast_2d(np.asarray(qs))
        out: list[np.ndarray] = []
        for a, b in self._chunks(qs.shape[0]):
            if self.sdev is not None:
                out.extend(knn_query_batch_sharded(
                    self.sdev, qs[a:b], k, use_kernel=self.use_kernel
                ))
            else:
                out.extend(knn_query_batch_jax(
                    self.dev, qs[a:b], k, use_kernel=self.use_kernel
                ))
            self.stats.microbatches += 1
        self.stats.queries += qs.shape[0]
        return out
