"""Deterministic, seeded fault-injection plane for the serving stack.

Production serving (ROADMAP north star) means partial failure is the
normal case: a shard dispatch times out, a host/device upload is
interrupted, the cold-path refiner's backing store hiccups, a snapshot
write is torn mid-file.  This module makes those failures *injectable,
reproducible events* so every chaos run is replayable in CI: a
:class:`FaultPlan` is armed at named failure points across the stack and
fires :class:`FaultError` on a schedule that is a pure function of
``(seed, rule, call index)`` — never of wall clock, never of interleaving
across points.

Failure points (the names the serving stack fires; see
``serve/engine.py`` for where each is armed):

  * ``shard_dispatch``   — per-shard compiled query dispatch (ctx: shard)
  * ``apply_delta``      — host -> device leaf-block upload / shard refresh
  * ``host_refine``      — the cold-path host AMBI engine call
  * ``pagestore_read``   — simulated disk reads (``PageStore.fault_hook``)
  * ``snapshot_save``    — durable snapshot barrier write
  * ``snapshot_load``    — snapshot read at recovery time
  * ``journal_append``   — graft-journal record append
  * ``admission``        — async-frontend request admission (ctx: kind)
  * ``batch_close``      — async-frontend microbatch close/dispatch

A plan can schedule faults two ways, per rule: an explicit ``at_calls``
set (fire on exactly those 1-based call indices at the point — the
boundary-sweep tests use this) or a seeded Bernoulli ``rate`` (each
matching call draws from a per-rule ``np.random.default_rng([seed, rule])``
stream — the chaos parity run uses this).  Every fired fault is recorded
in :attr:`FaultPlan.log` so a failing chaos run prints the exact schedule
that produced it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

FAILURE_POINTS = (
    "shard_dispatch",
    "apply_delta",
    "host_refine",
    "pagestore_read",
    "snapshot_save",
    "snapshot_load",
    "journal_append",
    "admission",
    "batch_close",
)


class FaultError(RuntimeError):
    """An injected (transient) fault.  The resilience layer treats it like
    any other dispatch failure: retried, then breaker-counted."""

    def __init__(self, point: str, call_no: int, ctx: dict):
        self.point = point
        self.call_no = call_no
        self.ctx = dict(ctx)
        super().__init__(
            f"injected fault at {point!r} (call #{call_no}"
            + (f", ctx={ctx}" if ctx else "")
            + ")"
        )


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One scheduled failure source at one failure point.

    ``at_calls`` fires on exactly those 1-based *matching-call* indices;
    otherwise ``rate`` is a per-call Bernoulli drawn from the rule's own
    seeded stream.  ``match`` restricts the rule to calls whose context
    contains the given items (e.g. ``{"shard": 2}`` fails one shard only);
    non-matching calls neither fire nor advance the rule's counters.
    ``max_fires`` caps total fires — the standard way to build a fault a
    bounded retry policy is guaranteed to outlast.
    """

    point: str
    at_calls: Optional[frozenset] = None
    rate: float = 0.0
    match: Optional[tuple] = None  # ((key, value), ...) context filter
    max_fires: Optional[int] = None

    def __post_init__(self):
        if self.point not in FAILURE_POINTS:
            raise ValueError(
                f"unknown failure point {self.point!r}; "
                f"expected one of {FAILURE_POINTS}"
            )
        if self.at_calls is not None:
            object.__setattr__(self, "at_calls", frozenset(
                int(c) for c in self.at_calls
            ))
        if self.match is not None:
            object.__setattr__(
                self, "match", tuple(sorted(dict(self.match).items()))
            )

    def matches(self, ctx: dict) -> bool:
        if self.match is None:
            return True
        return all(ctx.get(k) == v for k, v in self.match)


class FaultPlan:
    """A seeded, replayable schedule of injected faults.

    Construction is cheap and stateless-looking: all mutable state is the
    per-rule matching-call counters, so re-running the *same* serving
    sequence against a fresh plan with the same seed reproduces the same
    faults bit for bit.  ``fire(point, **ctx)`` is the single hook the
    stack calls; it raises :class:`FaultError` when any armed rule is
    scheduled for this call.

    ``disarm()``/``rearm()`` gate the whole plane (recovery replay runs
    with the plane disarmed so replay is never re-faulted), and
    ``pagestore_hook()`` adapts the plane onto
    ``PageStore.fault_hook``'s ``(op, n)`` calling convention.
    """

    def __init__(self, rules=(), *, seed: int = 0):
        self.seed = int(seed)
        self.rules = tuple(rules)
        self._calls = [0] * len(self.rules)          # matching calls seen
        self._fires = [0] * len(self.rules)
        self._rngs = [
            np.random.default_rng([self.seed, i])
            for i in range(len(self.rules))
        ]
        self.log: list[tuple[str, int, dict]] = []   # fired faults, in order
        self.armed = True

    # -- convenience constructors ------------------------------------------
    @classmethod
    def single(cls, point: str, at_call: int = 1, **kw) -> "FaultPlan":
        """Fire once, on the ``at_call``-th call at ``point``."""
        return cls([FaultRule(point, at_calls=frozenset([at_call]))], **kw)

    @classmethod
    def storm(cls, points, rate: float, *, seed: int = 0,
              max_fires_per_point: Optional[int] = None) -> "FaultPlan":
        """Seeded Bernoulli faults at several points at once (chaos runs)."""
        return cls(
            [
                FaultRule(p, rate=rate, max_fires=max_fires_per_point)
                for p in points
            ],
            seed=seed,
        )

    # -- arming -------------------------------------------------------------
    def disarm(self) -> None:
        self.armed = False

    def rearm(self) -> None:
        self.armed = True

    @property
    def total_fires(self) -> int:
        return len(self.log)

    def fires_at(self, point: str) -> int:
        return sum(1 for p, _, _ in self.log if p == point)

    # -- the hook ------------------------------------------------------------
    def fire(self, point: str, **ctx) -> None:
        """Advance every matching rule's schedule; raise if one is due.

        Counters advance even when the plan is disarmed *only* for armed
        plans — a disarmed plan is inert, so recovery replay neither
        faults nor perturbs the schedule the live path will see.
        """
        if not self.armed:
            return
        due = None
        for i, rule in enumerate(self.rules):
            if rule.point != point or not rule.matches(ctx):
                continue
            self._calls[i] += 1
            if rule.max_fires is not None and self._fires[i] >= rule.max_fires:
                continue
            if rule.at_calls is not None:
                hit = self._calls[i] in rule.at_calls
            else:
                hit = bool(rule.rate) and (
                    self._rngs[i].random() < rule.rate
                )
            if hit:
                self._fires[i] += 1
                due = (point, self._calls[i], ctx)
        if due is not None:
            self.log.append(due)
            raise FaultError(*due)

    def pagestore_hook(self):
        """Adapter for ``PageStore.fault_hook``: fires ``pagestore_read``
        for read-side ops before any I/O is accounted."""

        def hook(op: str, n: int) -> None:
            if op.startswith("read"):
                self.fire("pagestore_read", op=op, pages=int(n))

        return hook
