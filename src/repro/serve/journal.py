"""Append-only graft journal: crash recovery for adaptive serving.

The adaptive server's device table is a pure function of the boot-time
AMBI state and the *sequence of cold queries* it refined (grafting in
``NodeTable`` is deterministic: ``_adaptive_build`` consumes the index's
own seeded rng and the ``PageStore`` id counter, both of which are part
of the snapshot).  So the journal is **logical**: each record is one
cold host-path operation (``window`` or ``knn``) with a monotonically
increasing ``seq``.  Replaying the journal against the snapshot's AMBI
state re-executes exactly those refinements and lands on the
bit-identical table — there is no physical page image to log.

Record framing (binary, little-endian)::

    [u32 payload_len][u32 crc32(payload)][payload: JSON utf-8]

Appends are flushed and ``fsync``'d before the caller's operation is
acknowledged.  On read:

  * a **torn tail** (fewer bytes than a full header+payload at EOF —
    the crash interrupted the final append) is tolerated and dropped:
    the op was never acknowledged, so dropping it is correct;
  * a **complete record with a bad checksum** means real corruption and
    raises :class:`JournalError` instead of replaying garbage;
  * a **seq at or below the snapshot barrier** is skipped — this closes
    the crash window between "snapshot written" and "journal truncated"
    during compaction (records already folded into the snapshot must not
    be replayed twice).

Compaction writes a fresh snapshot (recording ``last_seq``) and then
truncates the journal via a create-new + ``os.replace`` so there is no
moment where neither a valid snapshot nor a valid journal exists.

JSON carries float64 coordinates via ``repr``-style shortest-roundtrip
encoding, which is exact for binary64 — replayed queries are
bit-identical to the originals.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Iterator

_HEADER = struct.Struct("<II")  # payload_len, crc32


class JournalError(RuntimeError):
    """The journal is corrupt (complete record, bad checksum / framing)."""


class GraftJournal:
    """Append-only fsync'd record log of cold-path serving ops.

    Opening an existing journal scans it (validating checksums) and
    continues the ``seq`` counter after the last intact record, so a
    recovered server keeps journaling where the dead one stopped.
    """

    def __init__(self, path, *, fault_plan=None):
        self.path = os.fspath(path)
        self.fault_plan = fault_plan
        last = 0
        if os.path.exists(self.path):
            for rec in self.read_records(self.path):
                last = rec["seq"]
        self.seq = last
        self._f = open(self.path, "ab")

    # -- writing ------------------------------------------------------------
    def append(self, op: str, **args) -> int:
        """Durably log one op; returns its seq.  The fault point fires
        *before* any bytes are written, so an injected append fault never
        leaves a torn record behind."""
        if self.fault_plan is not None:
            self.fault_plan.fire("journal_append", op=op)
        self.seq += 1
        payload = json.dumps(
            {"seq": self.seq, "op": op, **args}, sort_keys=True
        ).encode("utf-8")
        self._f.write(_HEADER.pack(len(payload), zlib.crc32(payload)))
        self._f.write(payload)
        self._f.flush()
        os.fsync(self._f.fileno())
        return self.seq

    def truncate(self) -> None:
        """Empty the journal (compaction barrier): atomic swap-in of a
        fresh empty file, never an in-place truncation of live records."""
        self._f.close()
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self._f = open(self.path, "ab")

    def close(self) -> None:
        self._f.close()

    # -- reading ------------------------------------------------------------
    @staticmethod
    def read_records(path, *, after_seq: int = 0) -> Iterator[dict]:
        """Yield intact records with ``seq > after_seq``.

        Tolerates a torn final record (unacknowledged op); raises
        :class:`JournalError` on a checksum mismatch in a complete one.
        """
        path = os.fspath(path)
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            buf = f.read()
        off, end = 0, len(buf)
        while off < end:
            if end - off < _HEADER.size:
                break  # torn header at tail
            length, crc = _HEADER.unpack_from(buf, off)
            start = off + _HEADER.size
            if end - start < length:
                break  # torn payload at tail
            payload = buf[start:start + length]
            if zlib.crc32(payload) != crc:
                raise JournalError(
                    f"journal {path!r}: checksum mismatch at byte {off} "
                    f"(record is complete — this is corruption, not a torn "
                    f"tail); refusing to replay"
                )
            try:
                rec = json.loads(payload.decode("utf-8"))
            except ValueError as e:
                raise JournalError(
                    f"journal {path!r}: undecodable record at byte {off}"
                ) from e
            off = start + length
            if rec.get("seq", 0) > after_seq:
                yield rec

    @staticmethod
    def last_seq(path) -> int:
        """Seq of the last intact record (0 for empty/missing journal)."""
        last = 0
        for rec in GraftJournal.read_records(path):
            last = rec["seq"]
        return last
