"""Async serving frontend: admission control, deadline-aware microbatching,
load shedding, and certified brownout in front of ``DeviceQueryServer``.

PR 6 made the server survive *failures*; this layer makes it survive
*overload* — the other half of production robustness.  The shape follows
the contention analysis of *Main Memory Adaptive Indexing for Multi-core
Systems* (PAPERS.md): the device hot path and the host cold path
(adaptive refinement) are different resources, so the frontend overlaps
them instead of serializing one behind the other.

The pipeline, request by request:

  * **Admission** — a *bounded* queue.  A submit that would exceed
    ``queue_bound`` is rejected immediately with a reason and a
    root-MBB :class:`CompletenessCertificate` (the honest "we answered
    nothing" answer) — the queue can never grow without bound, so an
    overloaded server degrades with certificates instead of OOMing or
    stalling every client behind an unbounded backlog.
  * **Batch forming** — per lane (windows; k-NN per ``k``), a microbatch
    closes at ``batch_max`` queued requests *or* once the oldest member
    has waited ``batch_window_s``, whichever comes first.  Closed
    batches go to the device worker as one dispatch; the engine pads
    them to the pow2 bucket shapes it already compiles for, so drifting
    batch sizes reuse a bounded set of compiled variants.
  * **Deadlines** — each request may carry a deadline; one expired in
    the queue is shed (with a certificate) at batch close, and the
    dispatched batch carries a :class:`Deadline` equal to the tightest
    member's remaining budget, threading into the engine's existing
    retry/breaker machinery.
  * **Brownout** — when queue depth crosses ``brownout_high`` the
    frontend degrades: k-NN escalation is capped at
    ``brownout_knn_rounds`` (best-effort answers marked
    ``certified_exact=False``), dispatch optionally reroutes to a
    compressed/fused ``brownout_server`` twin, and an adaptive server
    answers device-only (``window_hot``/``knn_hot``): cold queries get
    their refined-subset hits plus a certificate naming the unrefined
    subspaces instead of a multi-ms host refinement.  Depth back under
    ``brownout_low`` exits brownout — the watermark gap is the
    hysteresis that keeps the tier from flapping.
  * **Overlap** — outside brownout an adaptive window batch is split by
    the cheap host-side cold test (``cold_window_mask``): the hot part
    runs on the device lane while the cold part refines on the refine
    lane concurrently, both behind the server's table RW-lock.

Everything nondeterministic is injectable: the clock (``VirtualClock``
for saturation tests — the same burst replays bit-identically), the
executors (``InlineExecutor`` runs lanes synchronously on the pump
thread; ``WorkerExecutor`` is the production daemon-thread lane), and
the fault plane (``admission`` / ``batch_close`` failure points).  In
real-time mode :meth:`start` owns a dispatcher thread that forms and
dispatches batches; in virtual mode the test (or the open-loop load
generator) drives :meth:`pump` explicitly.
"""
from __future__ import annotations

import dataclasses
import queue as _queue
import threading
import time
from collections import OrderedDict
from typing import Callable, Optional

import numpy as np

from .resilience import Deadline, DeadlineExceeded, RetryExhausted


class VirtualClock:
    """Injectable deterministic clock: saturation tests replay exactly."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("clocks only move forward")
        self.t += float(dt)


class InlineExecutor:
    """Deterministic executor: runs each task immediately on the caller's
    thread, in submission order.  The virtual-clock tests use this for
    both lanes, so a pump() is one deterministic sequence of work."""

    def submit(self, fn: Callable[[], None]) -> None:
        fn()

    def stop(self) -> None:
        pass


class WorkerExecutor:
    """One daemon worker thread draining a FIFO task queue — the
    production lane.  ``stop()`` drains outstanding tasks, then joins."""

    def __init__(self, name: str = "frontend-lane"):
        self._q: _queue.Queue = _queue.Queue()
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            fn = self._q.get()
            if fn is None:
                return
            try:
                fn()
            finally:
                self._q.task_done()

    def submit(self, fn: Callable[[], None]) -> None:
        self._q.put(fn)

    def stop(self) -> None:
        self._q.put(None)
        self._thread.join(timeout=30.0)


@dataclasses.dataclass
class FrontendStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0         # served (possibly brownout-degraded)
    rejected: int = 0          # admission control bounced it (queue full)
    timed_out: int = 0         # deadline expired before service
    shed: int = 0              # dispatch failure turned into certified shed
    batches: int = 0
    brownout_batches: int = 0
    refine_batches: int = 0    # cold sub-batches overlapped on refine lane
    brownout_enters: int = 0
    brownout_exits: int = 0
    depth_peak: int = 0

    @property
    def dropped(self) -> int:
        return self.rejected + self.timed_out + self.shed


class Request:
    """One admitted (or bounced) query and its eventual reply.

    ``status`` lifecycle: ``queued`` -> one of ``ok`` (served; check
    ``cert`` for brownout degradation), ``rejected`` (admission),
    ``timeout`` (deadline expired), ``shed`` (dispatch failed after
    retries).  Every terminal state carries a certificate; only ``ok``
    carries ids.  ``wait()`` blocks (real mode) or returns immediately
    after the pump served it (virtual mode)."""

    __slots__ = ("kind", "payload", "t_submit", "deadline", "seq",
                 "status", "reason", "ids", "cert", "brownout",
                 "t_done", "_event")

    def __init__(self, kind, payload, t_submit, deadline, seq):
        self.kind = kind
        self.payload = payload
        self.t_submit = t_submit
        self.deadline = deadline
        self.seq = seq
        self.status = "queued"
        self.reason: Optional[str] = None
        self.ids: Optional[np.ndarray] = None
        self.cert = None
        self.brownout = False
        self.t_done: Optional[float] = None
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    @property
    def latency(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit


class Frontend:
    """The async admission/batching pipeline in front of a
    :class:`~repro.serve.engine.DeviceQueryServer` (see module docstring).

    Two drive modes share one code path:

      * **real time** — ``start()`` spawns the dispatcher thread (it owns
        every device dispatch) and, for adaptive servers, a refine-lane
        worker; ``submit_*`` may be called from any thread and
        ``Request.wait()`` blocks until served.  ``stop()`` drains.
      * **virtual time** — construct with ``clock=VirtualClock()`` (and
        the default ``InlineExecutor`` lanes), never call ``start``;
        drive ``pump()``/``drain()`` explicitly.  Identical inputs give
        identical statuses, results, and certificates on every replay.
    """

    def __init__(self, server, *,
                 clock: Optional[Callable[[], float]] = None,
                 queue_bound: int = 256,
                 batch_max: Optional[int] = None,
                 batch_window_s: float = 0.002,
                 default_deadline_s: Optional[float] = None,
                 brownout_high: Optional[int] = None,
                 brownout_low: Optional[int] = None,
                 brownout_knn_rounds: int = 0,
                 brownout_server=None,
                 overlap_refine: bool = True,
                 executor=None, refine_executor=None,
                 fault_plan=None):
        if queue_bound < 1:
            raise ValueError("queue_bound must be >= 1")
        self.server = server
        self.clock = clock if clock is not None else time.monotonic
        self._virtual = clock is not None
        self.queue_bound = int(queue_bound)
        self.batch_max = int(batch_max if batch_max is not None
                             else server.microbatch)
        self.batch_window_s = float(batch_window_s)
        self.default_deadline_s = default_deadline_s
        if brownout_high is not None:
            if brownout_high > queue_bound:
                raise ValueError("brownout_high must be <= queue_bound")
            if brownout_low is None:
                brownout_low = max(brownout_high // 4, 0)
            if brownout_low >= brownout_high:
                raise ValueError(
                    "hysteresis needs brownout_low < brownout_high"
                )
        self.brownout_high = brownout_high
        self.brownout_low = brownout_low
        self.brownout_knn_rounds = int(brownout_knn_rounds)
        self.brownout_server = brownout_server
        self.overlap_refine = bool(overlap_refine)
        self.fault_plan = fault_plan
        self.stats = FrontendStats()
        self.brownout = False
        # lanes: injected executors win; else the device lane runs inline
        # on whoever pumps (the dispatcher thread in real mode) and the
        # refine lane gets its own worker under the real clock
        self._executor = executor if executor is not None else InlineExecutor()
        self._refine = refine_executor
        if self._refine is None:
            self._refine = (InlineExecutor() if self._virtual
                            else WorkerExecutor("frontend-refine"))
        # admission state, all guarded by one mutex
        self._mu = threading.Condition()
        self._queues: "OrderedDict[tuple, list]" = OrderedDict()
        self._depth = 0
        self._seq = 0
        self._stopping = False
        self._dispatcher: Optional[threading.Thread] = None

    # -- admission -----------------------------------------------------------
    def submit_window(self, lo, hi, *, deadline_s: Optional[float] = None):
        lo = np.asarray(lo, dtype=np.float64).reshape(-1)
        hi = np.asarray(hi, dtype=np.float64).reshape(-1)
        self.server._validate_batch(lo[None], "lo")
        self.server._validate_batch(hi[None], "hi")
        return self._submit("window", (lo, hi), ("window",), deadline_s)

    def submit_knn(self, q, k: int, *, deadline_s: Optional[float] = None):
        q = np.asarray(q, dtype=np.float64).reshape(-1)
        self.server._validate_batch(q[None], "q")
        if not isinstance(k, (int, np.integer)) or int(k) < 1:
            raise ValueError(f"k must be a positive integer, got {k!r}")
        return self._submit("knn", (q, int(k)), ("knn", int(k)), deadline_s)

    def _submit(self, kind, payload, lane, deadline_s):
        now = self.clock()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline = None if deadline_s is None else now + float(deadline_s)
        with self._mu:
            self._seq += 1
            req = Request(kind, payload, now, deadline, self._seq)
            self.stats.submitted += 1
            if self._stopping:
                self._reject(req, "frontend stopped")
                return req
            if self.fault_plan is not None:
                from .faults import FaultError

                try:
                    self.fault_plan.fire("admission", kind=kind)
                except FaultError as e:
                    self._reject(req, f"admission fault injected: {e}")
                    return req
            if self._depth >= self.queue_bound:
                self._reject(
                    req,
                    f"queue full (depth={self._depth}, "
                    f"bound={self.queue_bound})",
                )
                return req
            self.stats.admitted += 1
            self._queues.setdefault(lane, []).append(req)
            self._depth += 1
            self.stats.depth_peak = max(self.stats.depth_peak, self._depth)
            self._update_brownout()
            self._mu.notify_all()
        return req

    def _reject(self, req, reason: str) -> None:
        self._finish_dropped(req, "rejected", reason, stat="rejected")

    def _finish_dropped(self, req, status: str, reason: str,
                        stat: Optional[str] = None) -> bool:
        """Terminal no-answer state: empty ids, root certificate.

        The done-check, the field writes, and the stat bump are one
        atomic section under ``_mu`` (a reentrant Condition — admission
        paths already holding it nest safely): the device lane and the
        refine lane can race to finish the same request when a retried
        dispatch overlaps refinement, and the first to claim it here
        wins — the loser neither tears the terminal state nor
        double-counts the SLO stat.  Returns whether this call won."""
        with self._mu:
            if req.done:
                return False
            if stat is not None:
                setattr(self.stats, stat, getattr(self.stats, stat) + 1)
            req.status = status
            req.reason = reason
            req.ids = np.zeros(0, dtype=np.int64)
            req.cert = self.server._root_cert()
            req.t_done = self.clock()
            req._event.set()
        return True

    @property
    def depth(self) -> int:
        with self._mu:
            return self._depth

    def _update_brownout(self) -> None:  # analysis: caller-holds-write
        """Watermark hysteresis (holding ``_mu``): enter at >= high, exit
        at <= low — depths between the watermarks keep the current tier,
        so oscillation around one threshold cannot flap the mode."""
        if self.brownout_high is None:
            return
        if not self.brownout and self._depth >= self.brownout_high:
            self.brownout = True
            self.stats.brownout_enters += 1
        elif self.brownout and self._depth <= self.brownout_low:
            self.brownout = False
            self.stats.brownout_exits += 1

    # -- batch forming -------------------------------------------------------
    def _due_lanes(self, now: float, flush: bool) -> list:
        due = []
        for lane, q in self._queues.items():
            if not q:
                continue
            if (flush or len(q) >= self.batch_max
                    or now - q[0].t_submit >= self.batch_window_s
                    or (q[0].deadline is not None
                        and now >= q[0].deadline)):
                due.append(lane)
        return due

    def _next_due(self, now: float) -> Optional[float]:
        """Earliest future instant any lane's batch will close by age."""
        nxt = None
        for q in self._queues.values():
            if not q:
                continue
            t = q[0].t_submit + self.batch_window_s
            if q[0].deadline is not None:
                t = min(t, q[0].deadline)
            nxt = t if nxt is None else min(nxt, t)
        return nxt

    def _close_batch(self, lane) -> list:  # analysis: caller-holds-write
        q = self._queues[lane]
        batch, rest = q[:self.batch_max], q[self.batch_max:]
        self._queues[lane] = rest
        self._depth -= len(batch)
        self._update_brownout()
        return batch

    # -- dispatch ------------------------------------------------------------
    def pump(self, flush: bool = False) -> int:
        """Form and dispatch every due microbatch; returns how many.

        The virtual-time drive loop: tests/load rigs interleave
        ``submit_*``, ``clock.advance``, and ``pump`` and observe a fully
        deterministic schedule.  The real-time dispatcher thread calls
        this too — same code path, real clock."""
        dispatched = 0
        while True:
            with self._mu:
                now = self.clock()
                due = self._due_lanes(now, flush)
                if not due:
                    return dispatched
                # tier decision happens at close time, while the members
                # still count toward the depth that justified degrading
                brown = self.brownout
                batches = [(lane, self._close_batch(lane)) for lane in due]
            for lane, reqs in batches:
                self._executor.submit(
                    lambda lane=lane, reqs=reqs, brown=brown: (
                        self._dispatch(lane, reqs, brown)
                    )
                )
                dispatched += 1

    def drain(self) -> None:
        """Flush every queued request through dispatch (virtual mode)."""
        while self.pump(flush=True):
            pass

    def _dispatch(self, lane, reqs: list, brown: bool) -> None:
        now = self.clock()
        live = []
        for r in reqs:
            if r.deadline is not None and now >= r.deadline:
                self._finish_dropped(
                    r, "timeout", "deadline expired in queue",
                    stat="timed_out",
                )
            else:
                live.append(r)
        if not live:
            return
        with self._mu:
            self.stats.batches += 1
            if brown:
                self.stats.brownout_batches += 1
        budgets = [r.deadline - now for r in live if r.deadline is not None]
        deadline = Deadline(min(budgets) if budgets else None,
                            clock=self.clock)

        def attempt():
            if self.fault_plan is not None:
                self.fault_plan.fire("batch_close", kind=lane[0])
            return self._execute(lane, live, deadline, brown)

        try:
            self.server.retry.call(
                attempt, no_retry=(DeadlineExceeded,),
                call_key=("batch_close", lane),
            )
        except DeadlineExceeded:
            for r in live:
                self._finish_dropped(
                    r, "timeout", "deadline exceeded during dispatch",
                    stat="timed_out",
                )
        except RetryExhausted as e:
            for r in live:
                self._finish_dropped(r, "shed", f"dispatch failed: {e}",
                                     stat="shed")

    def _execute(self, lane, reqs: list, deadline, brown: bool) -> None:
        """One formed microbatch against the engine.  Raises to signal a
        retryable dispatch failure; on success every request is done."""
        kind = lane[0]
        srv = self.server
        if brown and self.brownout_server is not None and not srv.adaptive:
            srv = self.brownout_server
        if kind == "window":
            los = np.stack([r.payload[0] for r in reqs])
            his = np.stack([r.payload[1] for r in reqs])
            if brown and srv.adaptive:
                res, certs = srv.window_hot(los, his, deadline=deadline)
                self._finish_batch(reqs, res, certs, brown)
            elif srv.adaptive and self.overlap_refine:
                self._execute_window_overlap(srv, reqs, los, his, deadline)
            else:
                res, certs = srv.window(los, his, return_certs=True,
                                        deadline=deadline)
                self._finish_batch(reqs, res, certs, brown)
        else:
            k = lane[1]
            qs = np.stack([r.payload[0] for r in reqs])
            if brown:
                res, certs = srv.knn_hot(
                    qs, k, deadline=deadline,
                    max_rounds=self.brownout_knn_rounds,
                )
            else:
                res, certs = srv.knn(qs, k, return_certs=True,
                                     deadline=deadline)
            self._finish_batch(reqs, res, certs, brown)

    def _execute_window_overlap(self, srv, reqs, los, his, deadline):
        """Split by the cheap host-side cold test: the hot part answers on
        this (device) lane now; the cold part refines on the refine lane,
        overlapping the next device batches instead of blocking them."""
        cold = srv.cold_window_mask(los, his)
        hot_i = np.flatnonzero(~cold)
        cold_i = np.flatnonzero(cold)
        if cold_i.size:
            cold_reqs = [reqs[i] for i in cold_i]
            with self._mu:
                self.stats.refine_batches += 1
            self._refine.submit(
                lambda: self._run_refine(srv, cold_reqs, deadline)
            )
        if hot_i.size:
            res, certs = srv.window(los[hot_i], his[hot_i],
                                    return_certs=True, deadline=deadline)
            self._finish_batch([reqs[i] for i in hot_i], res, certs, False)

    def _run_refine(self, srv, reqs, deadline) -> None:
        """Refine-lane task: host cold path for one cold sub-batch."""
        live = []
        for r in reqs:
            if r.done:
                continue  # a retried dispatch re-submitted this sub-batch
            if r.deadline is not None and self.clock() >= r.deadline:
                self._finish_dropped(
                    r, "timeout", "deadline expired before refinement",
                    stat="timed_out",
                )
            else:
                live.append(r)
        if not live:
            return
        try:
            los = np.stack([r.payload[0] for r in live])
            his = np.stack([r.payload[1] for r in live])
            res, certs = srv.window(los, his, return_certs=True,
                                    deadline=deadline)
        except DeadlineExceeded:
            for r in live:
                self._finish_dropped(
                    r, "timeout", "deadline exceeded during refinement",
                    stat="timed_out",
                )
            return
        except Exception as e:
            for r in live:
                self._finish_dropped(r, "shed", f"refinement failed: {e}",
                                     stat="shed")
            return
        self._finish_batch(live, res, certs, False)

    def _finish_batch(self, reqs, res, certs, brown: bool) -> None:
        t = self.clock()
        with self._mu:
            # claim-or-skip under _mu, like _finish_dropped: the device
            # and refine lanes may both carry a request after a retried
            # dispatch, and only the first finisher may write its
            # terminal state
            for r, ids, cert in zip(reqs, res, certs):
                if r.done:
                    continue
                r.status = "ok"
                r.ids = np.asarray(ids)
                r.cert = cert
                r.brownout = brown
                r.t_done = t
                self.stats.completed += 1
                r._event.set()

    # -- real-time dispatcher -------------------------------------------------
    def start(self) -> "Frontend":
        """Spawn the dispatcher thread (real-time mode).  It owns every
        device dispatch: batches form on the shared clock and execute on
        this one thread, so the device never sees concurrent dispatches
        while refinement overlaps on its own lane."""
        if self._virtual:
            raise RuntimeError(
                "start() is for the real clock; under a VirtualClock "
                "drive pump()/drain() explicitly"
            )
        if self._dispatcher is not None:
            raise RuntimeError("frontend already started")
        self._dispatcher = threading.Thread(
            target=self._loop, name="frontend-dispatch", daemon=True
        )
        self._dispatcher.start()
        return self

    def _loop(self) -> None:
        while True:
            with self._mu:
                while True:
                    if self._stopping:
                        break
                    now = self.clock()
                    if self._due_lanes(now, False):
                        break
                    nxt = self._next_due(now)
                    self._mu.wait(
                        None if nxt is None else max(nxt - now, 0.0)
                    )
                if self._stopping and self._depth == 0:
                    return
            self.pump(flush=self._stopping)

    def stop(self, drain: bool = True) -> None:
        """Stop serving.  ``drain=True`` flushes queued requests through
        dispatch first; either way every still-queued request reaches a
        terminal state before return."""
        with self._mu:
            self._stopping = True
            self._mu.notify_all()
        if self._dispatcher is not None:
            self._dispatcher.join(timeout=30.0)
            self._dispatcher = None
        if drain:
            self.drain()
        else:
            with self._mu:
                leftovers = [r for q in self._queues.values() for r in q]
                self._queues.clear()
                self._depth = 0
            for r in leftovers:
                self._finish_dropped(r, "shed", "frontend stopped",
                                     stat="shed")
        self._executor.stop()
        self._refine.stop()
