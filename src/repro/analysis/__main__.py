"""CLI: ``python -m repro.analysis [paths...] [--tests-dir DIR]``.

Runs every applicable checker over the given paths (default ``src/``),
prints findings as ``path:line: [checker] message``, and exits nonzero
when any finding survives.  This is what the CI ``lint`` job runs.
"""

from __future__ import annotations

import argparse
import sys

from .common import analyze_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific invariant lint for the serving spine "
                    "(lock discipline, journal ordering, jit/Pallas "
                    "purity, fault-point coverage)",
    )
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--tests-dir", default="tests",
                    help="test tree for coverage/ref-twin checks "
                         "(default: tests; pass '' to skip)")
    ns = ap.parse_args(argv)
    findings = analyze_paths(ns.paths or ["src"],
                             tests_dir=ns.tests_dir or None)
    for f in findings:
        print(f.render())
    if findings:
        print(f"\n{len(findings)} finding(s).", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
