"""The machine-checked mutable-state inventory of the serving spine.

This is the single source of truth the lock-discipline and
journal-ordering checkers consume, and the list DESIGN_PERF.md's
"Concurrency invariants" section documents.  Three categories:

* **containment** classes (``StreamingIndex``, ``DeviceMirror``) own no
  lock; their contract is "not internally locked — the serving layer
  serializes writers through its TableLock".  The checker proves their
  state attributes are only written inside their declared mutator
  methods (inventory drift shows up as a finding), and the *call sites*
  of those mutators in lock-owning files must be writer sections.
* **domination** classes (``DeviceQueryServer``, ``Frontend``) own a
  guard (``table_lock`` / ``_mu``); every mutation of their guarded
  attributes and every call to an inventoried mutator must sit inside a
  ``with ...write():`` (resp. ``with self._mu:``) section.  Reads of
  serving state need at least a ``.read()`` section.
* **relaxed** attributes (telemetry counters) tolerate benign lost
  updates by policy; they are listed so the exemption is explicit, not
  accidental.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ClassInventory:
    name: str
    kind: str                      # 'containment' | 'domination'
    state_attrs: frozenset = frozenset()
    mutators: frozenset = frozenset()     # methods allowed to write state_attrs
    relaxed_attrs: frozenset = frozenset()


# -- containment classes (core/streaming.py) --------------------------------

STREAMING_INDEX = ClassInventory(
    name="StreamingIndex",
    kind="containment",
    state_attrs=frozenset({
        "_pts", "_tomb", "_n", "_delta", "_delta_n", "_delta_indexed",
        "_delta_table", "tiers", "_next_tid", "_shadow", "base_n",
    }),
    mutators=frozenset({
        "insert", "delete", "_ensure_points", "_reindex_delta", "_flush",
        "_maybe_merge", "_merge_last_two", "_alloc_tid",
    }),
    relaxed_attrs=frozenset({"_events", "track_events"}),
)

DEVICE_MIRROR = ClassInventory(
    name="DeviceMirror",
    kind="containment",
    state_attrs=frozenset({
        "table", "spans", "root_rows", "_remap", "_retired",
    }),
    mutators=frozenset({
        "sync", "_attach", "_fuse", "_retire", "_rebuild_root",
    }),
)

# -- domination classes -----------------------------------------------------

# DeviceQueryServer: serving state republished under table_lock.write().
DEVICE_QUERY_SERVER = ClassInventory(
    name="DeviceQueryServer",
    kind="domination",
    state_attrs=frozenset({
        "dev", "sdev", "stream", "mirror", "ambi", "_table_version",
        "_stream_stale_shards", "_stream_device_stale",
    }),
    relaxed_attrs=frozenset({
        # Telemetry: monotone counters where a lost increment skews a
        # metric but cannot corrupt serving state (policy: relaxed).
        "stats", "breakers",
    }),
)

# Frontend: admission queues, request terminal states, and SLO counters
# all serialize through the reentrant Condition self._mu.
FRONTEND = ClassInventory(
    name="Frontend",
    kind="domination",
    state_attrs=frozenset({
        # admission + brownout state
        "_queues", "_depth", "_seq", "_stopping", "brownout",
        # Request terminal-state fields (the double-finish race surface)
        "status", "reason", "ids", "cert", "t_done",
        # FrontendStats fields — SLO counters feed shed/brownout
        # decisions and bench gates, so they are guarded, not relaxed
        "submitted", "admitted", "completed", "rejected", "timed_out",
        "shed", "batches", "brownout_batches", "refine_batches",
        "brownout_enters", "brownout_exits", "depth_peak",
    }),
)

INVENTORY: dict[str, ClassInventory] = {
    c.name: c for c in (
        STREAMING_INDEX, DEVICE_MIRROR, DEVICE_QUERY_SERVER, FRONTEND,
    )
}

# -- cross-file mutator call sites ------------------------------------------

# Method names that mutate inventoried state no matter which object the
# receiver resolves to; a call must be dominated by a writer section.
WRITE_CALLS = frozenset({
    # StreamingIndex / DeviceMirror
    "insert", "delete", "sync", "load_state",
    # NodeTable post-boot mutators (guarded at runtime by the sanitizer)
    "graft", "append_subtree", "append_row_copies", "set_root_children",
    "append_branch", "neutralize_rows", "compact",
    # device republish + journal truncation
    "apply_delta", "truncate",
})

# Receivers that make a WRITE_CALLS method name unambiguous.  A call is
# flagged when the method name is in WRITE_CALLS *and* the receiver's
# final segment is one of these (or starts with them), keeping generic
# names like ``list.insert`` out of scope.
WRITE_CALL_RECEIVERS = frozenset({
    "stream", "mirror", "table", "tbl", "ambi", "journal", "_journal",
    "t", "self",
})

# Read-path entry points: must hold at least table_lock.read().
READ_CALLS = frozenset({
    "window_query_batch_jax", "window_query_batch_jax_sharded",
    "knn_query_batch_jax", "knn_query_batch_jax_sharded",
    "filter_live", "delta_live_rows", "live_points",
})

# -- journal ordering -------------------------------------------------------

# A call whose receiver chain ends in one of these attrs with method
# 'append', or a call to one of JOURNAL_METHODS, counts as a journal
# write (Rule B: must be inside a writer section).
JOURNAL_RECEIVERS = frozenset({"journal", "_journal"})
JOURNAL_METHODS = frozenset({"_journal_op"})

# Within one writer section, the first journal write must precede the
# first of these journaled state mutations (Rule A).
JOURNALED_MUTATIONS = frozenset({"insert", "delete"})
JOURNALED_MUTATION_RECEIVERS = frozenset({"stream", "ambi", "self"})
