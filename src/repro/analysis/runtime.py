"""The ``REPRO_SANITIZE=1`` concurrency sanitizer (dynamic side).

Three runtime checks, all zero-cost when disabled (one module-level
boolean test per hook):

* **guarded mutators** — the serving layer binds its shared mutable
  objects (``NodeTable``, ``StreamingIndex``, ``DeviceMirror``) to the
  server's ``TableLock`` via :func:`bind`; their mutator entry points
  call :func:`check_write`, which raises :class:`SanitizerError` when
  the current thread does not hold the writer lock.  This is the
  dynamic completion of the static lock checker: closures and
  cross-file call chains the AST pass cannot follow are caught here.
* **held-state tracking** — ``TableLock`` reports acquisitions to
  :func:`note_acquire` / :func:`note_release` *before blocking*, so a
  same-thread re-acquisition (TableLock is not reentrant — nesting
  self-deadlocks) raises :class:`LockOrderError` instead of hanging the
  suite.
* **lock-order graph** — every acquisition records held-lock → new-lock
  edges in a global directed graph; acquiring L while holding H when the
  graph already shows a path L → H is a potential deadlock (some thread
  took the locks in the opposite order) and raises
  :class:`LockOrderError` naming both locks.

Enable with ``REPRO_SANITIZE=1`` in the environment, or
programmatically via :func:`enable` / :func:`disable` in tests.
"""

from __future__ import annotations

import os
import threading
from typing import Optional

__all__ = [
    "SanitizerError", "LockOrderError", "enabled", "enable", "disable",
    "reset", "bind", "check_write", "note_acquire", "note_release",
]


class SanitizerError(AssertionError):
    """A guarded mutator ran without the writer lock held."""


class LockOrderError(AssertionError):
    """Same-lock re-entry or a lock-acquisition-order inversion."""


_enabled = os.environ.get("REPRO_SANITIZE", "0") not in ("", "0", "false")

_tls = threading.local()            # .held: list of (lock_id, mode, name)
_graph_mu = threading.Lock()
# lock_id -> {successor_lock_id: (held_name, acquired_name)}
_edges: dict[int, dict[int, tuple]] = {}


def enabled() -> bool:
    return _enabled


def enable() -> bool:
    """Turn the sanitizer on (tests); returns the previous state."""
    global _enabled
    prev, _enabled = _enabled, True
    return prev


def disable() -> bool:
    global _enabled
    prev, _enabled = _enabled, False
    return prev


def reset() -> None:
    """Clear the lock-order graph and this thread's held list (tests)."""
    with _graph_mu:
        _edges.clear()
    _tls.held = []


def _held() -> list:
    held = getattr(_tls, "held", None)
    if held is None:
        held = _tls.held = []
    return held


def _reaches(src: int, dst: int) -> bool:
    """DFS: does the recorded graph contain a path src -> dst?"""
    seen = {src}
    stack = [src]
    while stack:
        cur = stack.pop()
        if cur == dst:
            return True
        for nxt in _edges.get(cur, ()):
            if nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return False


def note_acquire(lock, mode: str, name: Optional[str] = None) -> None:
    """Called by the lock *before it blocks*.  Raises instead of letting
    the thread deadlock."""
    if not _enabled:
        return
    name = name or getattr(lock, "name", None) or type(lock).__name__
    held = _held()
    for lid, _m, nm in held:
        if lid == id(lock):
            raise LockOrderError(
                f"re-entrant acquisition of non-reentrant lock '{name}' "
                f"(mode={mode}) — already held by this thread; this "
                f"self-deadlocks without the sanitizer")
    if held:
        with _graph_mu:
            new_id = id(lock)
            for lid, _m, nm in held:
                # inversion: some earlier acquisition recorded new -> held
                if _reaches(new_id, lid):
                    raise LockOrderError(
                        f"lock-order inversion: acquiring '{name}' while "
                        f"holding '{nm}', but the acquisition graph "
                        f"already orders '{name}' before '{nm}' — "
                        f"potential deadlock")
            for lid, _m, nm in held:
                _edges.setdefault(lid, {})[new_id] = (nm, name)
    held.append((id(lock), mode, name))


def note_release(lock) -> None:
    if not _enabled:
        return
    held = _held()
    for i in range(len(held) - 1, -1, -1):
        if held[i][0] == id(lock):
            del held[i]
            return


def bind(obj, lock) -> None:
    """Associate a shared mutable object with its guarding TableLock.
    Objects without a ``_san_lock`` slot are skipped silently."""
    try:
        obj._san_lock = lock
    except AttributeError:
        pass


def check_write(obj, op: str) -> None:
    """Assert the current thread holds the writer lock the object was
    bound to.  No-op when the sanitizer is off or the object is unbound
    (boot-time construction happens before publication)."""
    if not _enabled:
        return
    lock = getattr(obj, "_san_lock", None)
    if lock is None:
        return
    if not lock.held_write():
        raise SanitizerError(
            f"{type(obj).__name__}.{op}() mutated shared state without "
            f"the writer lock held (REPRO_SANITIZE) — serialize through "
            f"'with table_lock.write():'")
