"""repro.analysis — repo-specific invariant lint + concurrency sanitizer.

Static side (stdlib ``ast`` only, no runtime deps):

- :mod:`repro.analysis.locks` — lock-discipline / static race detector.
  Every mutation of inventoried serving-spine state must be dominated by a
  ``with ...table_lock.write():`` section (reads by at least ``.read()``).
- :mod:`repro.analysis.ordering` — journal-ordering checker.  Inside a
  writer section that both journals and mutates, the journal append must
  precede the first state mutation, and every journal append must itself
  sit inside a writer section (the PR-9 bug class).
- :mod:`repro.analysis.purity` — jit/Pallas purity lint.  No host syncs
  (``.item()`` / ``float()`` / ``int()`` / ``np.asarray``) on traced values
  in jit-reachable functions, no Python ``if`` on tracers inside Pallas
  kernel bodies, and every public kernel wrapper must have a ``ref.py``
  twin referenced by a test.
- :mod:`repro.analysis.coverage` — fault-point coverage checker.  Every
  name in ``serve/faults.py``'s ``FAILURE_POINTS`` must appear in at least
  one test file.

Dynamic side:

- :mod:`repro.analysis.runtime` — the ``REPRO_SANITIZE=1`` sanitizer:
  per-thread lock held-state, guarded mutator assertions on
  ``NodeTable`` / ``StreamingIndex`` / ``DeviceMirror``, and a
  lock-acquisition-order graph that reports potential deadlocks.

Run the static pass with ``python -m repro.analysis src/``.  Escape
hatches (all require a reason):

- ``# analysis: unlocked-ok(reason)`` — suppress lock findings on a line.
- ``# analysis: caller-holds-write`` on a ``def`` line — the body is
  treated as a writer section; intra-file callers are checked instead.
- ``# analysis: single-threaded(reason)`` on a ``def`` line — boot /
  recovery code exempt from lock discipline.
- ``# analysis: host-ok(reason)`` — suppress purity findings on a line.
"""

from .common import Finding, analyze_paths, iter_py_files

__all__ = ["Finding", "analyze_paths", "iter_py_files"]
