"""Fault-point coverage checker.

``serve/faults.py`` registers the named failure points the chaos plane
can fire (``FAILURE_POINTS``).  A failure point nobody injects in a test
is a recovery path that has never executed — this checker fails the
build until every registered name appears in at least one test file
under ``tests/``.  Registering a new fault point therefore *requires*
shipping a test that exercises it in the same change.
"""

from __future__ import annotations

import ast
import re
from typing import Optional

from .common import Finding, SourceFile, tests_corpus

CHECKER = "fault-coverage"


def _failure_points(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, ast.Assign):
            names = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if "FAILURE_POINTS" not in names:
                continue
            if isinstance(node.value, (ast.Tuple, ast.List, ast.Set)):
                for elt in node.value.elts:
                    if isinstance(elt, ast.Constant) \
                            and isinstance(elt.value, str):
                        yield elt.value, elt.lineno


def check(src: SourceFile, tests_dir: Optional[str] = "tests") -> list[Finding]:
    points = list(_failure_points(src.tree))
    if not points:
        return []
    corpus = tests_corpus(tests_dir)
    if not corpus:
        return [Finding(src.path, points[0][1], CHECKER,
                        f"FAILURE_POINTS registered but no tests found "
                        f"under {tests_dir!r}")]
    findings = []
    for name, line in points:
        if not re.search(rf"[\"']{re.escape(name)}[\"']", corpus):
            findings.append(Finding(
                src.path, line, CHECKER,
                f"failure point '{name}' is not exercised by any test "
                f"under {tests_dir}/ — every registered fault needs an "
                f"injection test"))
    return findings
