"""jit/Pallas purity lint.

Three sub-checks, path-scoped so the broader repo (models, launch,
benchmarks — which legitimately mix host and device code) stays quiet:

* **jit host-sync lint** — in ``core/queries_jax.py`` (and any file
  carrying a ``# analysis: jit-strict`` marker), functions decorated
  with ``jax.jit`` / ``partial(jax.jit, ...)`` *and everything they call
  intra-file* must not force a host sync: no ``.item()``, no
  ``np.asarray``/``np.array``/``jax.device_get``/``block_until_ready``,
  and no ``float(...)``/``int(...)``/``bool(...)`` on values that are
  not statically derivable (shapes, dtypes, lengths, constants are
  fine).  A host sync inside a jit-reachable function either fails at
  trace time in the best case or silently retraces/blocks in the worst.
* **kernel branch lint** — in ``kernels/*.py`` (except ``ref.py``),
  Pallas kernel bodies (functions taking ``*_ref`` params or named
  ``*_kernel``) must not branch with Python ``if``/``while``/ternary on
  traced values (loads from refs, ``pl.load``, ``pl.program_id``, and
  anything derived from them).  Structural tests (``x is None``,
  ``.shape``/``.dtype``/``len()`` comparisons) are static and allowed;
  predication belongs in ``pl.when``/``jnp.where``.
* **ref-twin check** — every public Pallas wrapper in ``kernels/ops.py``
  must have a ``ref.py`` oracle twin (``<wrapper>_ref``, prefix-matched
  so ``leaf_mindist_tiled`` pairs with ``leaf_mindist_ref``) that some
  test under ``tests/`` references by name.

``# analysis: host-ok(reason)`` on the offending line suppresses the
host-sync and branch lints.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Optional

from .common import Finding, SourceFile, attr_chain, module_functions, tests_corpus

CHECKER = "jit-purity"

_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "itemsize"}
_NUMPY_NAMES = {"np", "onp", "numpy"}
_TRACED_SOURCES = {"load", "program_id", "num_programs"}  # pl.<...>


def _is_jit_decorator(dec: ast.expr) -> bool:
    chain = attr_chain(dec)
    if chain and chain[-1] == "jit":
        return True
    if isinstance(dec, ast.Call):
        fchain = attr_chain(dec.func)
        if fchain and fchain[-1] == "jit":
            return True
        if fchain and fchain[-1] == "partial":
            return any(_is_jit_decorator(a) for a in dec.args)
    return False


def _jit_roots(tree: ast.Module) -> set[str]:
    roots = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                roots.add(node.name)
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            # fn = jax.jit(fn) re-binding form
            if _is_jit_decorator(node.value.func) or (
                    attr_chain(node.value.func)[-1:] == ["jit"]):
                for a in node.value.args:
                    if isinstance(a, ast.Name):
                        roots.add(a.id)
    return roots


def _reachable(tree: ast.Module, roots: set[str]) -> set[str]:
    funcs = module_functions(tree)
    calls: dict[str, set[str]] = {}
    for name, fn in funcs.items():
        out = set()
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name):
                if sub.func.id in funcs:
                    out.add(sub.func.id)
        calls[name] = out
    seen = set(r for r in roots if r in funcs)
    frontier = list(seen)
    while frontier:
        cur = frontier.pop()
        for nxt in calls.get(cur, ()):
            if nxt not in seen:
                seen.add(nxt)
                frontier.append(nxt)
    return seen


def _static_expr(e: ast.expr) -> bool:
    """Conservatively true when the expression is statically derivable
    under jit (shapes, dtypes, lengths, constants, arithmetic thereof)."""
    if isinstance(e, ast.Constant):
        return True
    if isinstance(e, ast.Attribute):
        return e.attr in _STATIC_ATTRS
    if isinstance(e, ast.Subscript):
        return _static_expr(e.value)
    if isinstance(e, ast.Call):
        chain = attr_chain(e.func)
        if chain in (["len"], ["min"], ["max"], ["abs"], ["round"]):
            return all(_static_expr(a) for a in e.args)
        return False
    if isinstance(e, ast.BinOp):
        return _static_expr(e.left) and _static_expr(e.right)
    if isinstance(e, ast.UnaryOp):
        return _static_expr(e.operand)
    if isinstance(e, ast.IfExp):
        return all(_static_expr(x) for x in (e.test, e.body, e.orelse))
    return False


def _check_jit_purity(src: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    roots = _jit_roots(src.tree)
    reachable = _reachable(src.tree, roots)
    funcs = module_functions(src.tree)
    for name in sorted(reachable):
        fn = funcs[name]
        for sub in ast.walk(fn):
            if not isinstance(sub, ast.Call):
                continue
            msg = None
            chain = attr_chain(sub.func)
            if chain and chain[-1] == "item":
                msg = ".item() forces a host sync"
            elif chain and chain[-1] in ("asarray", "array") \
                    and chain[0] in _NUMPY_NAMES:
                msg = f"{'.'.join(chain)}() pulls a traced value to host"
            elif chain and chain[-1] in ("device_get", "block_until_ready"):
                msg = f"{'.'.join(chain)}() forces a host sync"
            elif chain in (["float"], ["int"], ["bool"]) and sub.args \
                    and not all(_static_expr(a) for a in sub.args):
                msg = (f"{chain[0]}() on a non-static value concretizes "
                       f"a tracer")
            if msg is None:
                continue
            if src.annotation(sub, "host-ok") is not None:
                continue
            findings.append(Finding(
                src.path, sub.lineno, CHECKER,
                f"{msg} in jit-reachable function {name}() "
                f"(reached from @jax.jit root{'s' if len(roots) > 1 else ''} "
                f"{', '.join(sorted(roots & reachable or roots)[:3])})"))
    return findings


# -- kernel branch lint ------------------------------------------------------

def _kernel_fns(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            params = [a.arg for a in node.args.args]
            if node.name.endswith("_kernel") \
                    or any(p.endswith("_ref") for p in params):
                yield node


def _tainted(e: ast.expr, taint: set[str], refs: set[str]) -> bool:
    if isinstance(e, ast.Constant):
        return False
    if isinstance(e, ast.Name):
        return e.id in taint or e.id in refs
    if isinstance(e, ast.Attribute):
        if e.attr in _STATIC_ATTRS:
            return False
        return _tainted(e.value, taint, refs)
    if isinstance(e, ast.Subscript):
        if isinstance(e.value, ast.Name) and e.value.id in refs:
            return True  # a load from a ref is a traced value
        return (_tainted(e.value, taint, refs)
                or _tainted(e.slice, taint, refs))
    if isinstance(e, ast.Call):
        chain = attr_chain(e.func)
        if len(chain) >= 2 and chain[-1] in _TRACED_SOURCES \
                and chain[-2] == "pl":
            return True
        if chain == ["len"]:
            return False
        return any(_tainted(a, taint, refs) for a in e.args)
    if isinstance(e, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops):
            return False  # identity tests are static (the `acc is None` idiom)
        return (_tainted(e.left, taint, refs)
                or any(_tainted(c, taint, refs) for c in e.comparators))
    return any(_tainted(c, taint, refs)
               for c in ast.iter_child_nodes(e)
               if isinstance(c, ast.expr))


def _check_kernel_branches(src: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    for fn in _kernel_fns(src.tree):
        refs = {a.arg for a in fn.args.args if a.arg.endswith("_ref")}
        taint: set[str] = set()
        for _ in range(8):  # taint propagation to fixpoint
            before = len(taint)
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Assign) \
                        and _tainted(sub.value, taint, refs):
                    for tgt in sub.targets:
                        for n in ast.walk(tgt):
                            if isinstance(n, ast.Name):
                                taint.add(n.id)
                elif isinstance(sub, ast.AugAssign) \
                        and isinstance(sub.target, ast.Name) \
                        and _tainted(sub.value, taint, refs):
                    taint.add(sub.target.id)
            if len(taint) == before:
                break
        for sub in ast.walk(fn):
            test = None
            kind = None
            if isinstance(sub, (ast.If, ast.While)):
                test, kind = sub.test, type(sub).__name__.lower()
            elif isinstance(sub, ast.IfExp):
                test, kind = sub.test, "ternary"
            if test is None or not _tainted(test, taint, refs):
                continue
            if src.annotation(sub, "host-ok") is not None:
                continue
            findings.append(Finding(
                src.path, sub.lineno, CHECKER,
                f"Python {kind} on a traced value in Pallas kernel "
                f"{fn.name}() — use pl.when / jnp.where predication"))
    return findings


# -- ref-twin check ----------------------------------------------------------

def _imports_pallas(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and (
                (node.module and "pallas" in node.module)
                or any("pallas" in a.name for a in node.names)):
            return True
        if isinstance(node, ast.Import):
            if any("pallas" in a.name for a in node.names):
                return True
    return False


def _check_ref_twins(src: SourceFile, tests_dir: Optional[str]) -> list[Finding]:
    ref_path = os.path.join(os.path.dirname(src.path), "ref.py")
    if not os.path.exists(ref_path):
        return [Finding(src.path, 1, CHECKER,
                        "kernels/ops.py has no sibling ref.py oracle module")]
    with open(ref_path, "r", encoding="utf-8") as f:
        ref_tree = ast.parse(f.read(), filename=ref_path)
    refs = sorted(n.name for n in ref_tree.body
                  if isinstance(n, ast.FunctionDef)
                  and n.name.endswith("_ref"))

    funcs = module_functions(src.tree)
    # kernel-module aliases: ``from . import knn_topk as _knn`` etc. —
    # ops.py wrappers dispatch through these (ref re-exports excluded)
    kernel_mods = set()
    for node in src.tree.body:
        if isinstance(node, ast.ImportFrom) and node.level >= 1 \
                and not node.module:
            for a in node.names:
                if a.name != "ref":
                    kernel_mods.add(a.asname or a.name)
    pallas_direct = {
        name for name, fn in funcs.items()
        if any(isinstance(s, ast.Call)
               and attr_chain(s.func)[-1:] == ["pallas_call"]
               for s in ast.walk(fn))
    }
    # a wrapper calls pallas_call directly, reaches it through a local
    # helper, or dispatches into an imported kernel module
    wrappers = set()
    for name, fn in funcs.items():
        if name.startswith("_"):
            continue
        called_names = set()
        called_mods = set()
        for s in ast.walk(fn):
            if not isinstance(s, ast.Call):
                continue
            if isinstance(s.func, ast.Name):
                called_names.add(s.func.id)
            chain = attr_chain(s.func)
            if len(chain) >= 2 and chain[0] in kernel_mods:
                called_mods.add(chain[0])
        if name in pallas_direct or (called_names & pallas_direct) \
                or called_mods:
            wrappers.add(name)

    corpus = tests_corpus(tests_dir)
    findings: list[Finding] = []
    for w in sorted(wrappers):
        twins = [r for r in refs
                 if r == w + "_ref" or w.startswith(r[:-len("_ref")])]
        if not twins:
            findings.append(Finding(
                src.path, funcs[w].lineno, CHECKER,
                f"Pallas wrapper {w}() has no ref.py twin "
                f"(expected {w}_ref or a prefix match)"))
            continue
        if corpus and not any(
                re.search(rf"\b{re.escape(r)}\b", corpus) for r in twins):
            findings.append(Finding(
                src.path, funcs[w].lineno, CHECKER,
                f"ref twin {twins[0]}() of Pallas wrapper {w}() is not "
                f"referenced by any test under {tests_dir}/"))
    return findings


def check(src: SourceFile, tests_dir: Optional[str] = "tests") -> list[Finding]:
    findings: list[Finding] = []
    norm = src.path.replace(os.sep, "/")
    base = os.path.basename(norm)
    if base == "queries_jax.py" or src.has_marker("jit-strict"):
        findings.extend(_check_jit_purity(src))
    if "/kernels/" in norm or norm.startswith("kernels/"):
        if base != "ref.py" and _imports_pallas(src.tree):
            findings.extend(_check_kernel_branches(src))
        if base == "ops.py":
            findings.extend(_check_ref_twins(src, tests_dir))
    return findings
