"""Shared infrastructure for the repro.analysis static checkers.

Everything here is stdlib-only (``ast``, ``re``, ``os``).  The central
abstraction is :func:`iter_with_context`: a walk over a module's
statements that tracks, for every node, which class/method encloses it,
whether a ``with ...table_lock.write():`` (or ``.read()``, or Frontend's
``with self._mu:``) section dominates it, and which escape-hatch
annotations apply.

Soundness caveats (documented, deliberate):

- Nested ``def`` closures inherit the lock context of their definition
  site (the retry ``attempt()`` / ``upload()`` idiom in the engine).  A
  closure stored and invoked later outside the section would be missed;
  the runtime sanitizer covers that case.
- Lock context is tracked per-file.  Cross-file call chains are handled
  by the ``# analysis: caller-holds-write`` contract: the annotated
  function's body is treated as a writer section, and its intra-file
  call sites are checked instead.
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Iterator, Optional

# ``# analysis: tag`` or ``# analysis: tag(reason)``; several may share a line.
_ANNOT_RE = re.compile(r"#\s*analysis:\s*([a-z-]+)(?:\(([^)]*)\))?")

# Annotations that require a reason string to be accepted.
_REASON_REQUIRED = {"unlocked-ok", "single-threaded", "host-ok"}


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    checker: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.checker}] {self.message}"


class SourceFile:
    """A parsed module plus its line-level ``# analysis:`` annotations."""

    def __init__(self, path: str, text: Optional[str] = None):
        self.path = path
        if text is None:
            with open(path, "r", encoding="utf-8") as f:
                text = f.read()
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        # line number -> {tag: reason-or-""}
        self.annotations: dict[int, dict[str, str]] = {}
        self.bad_annotations: list[Finding] = []
        for i, line in enumerate(self.lines, start=1):
            for m in _ANNOT_RE.finditer(line):
                tag, reason = m.group(1), (m.group(2) or "").strip()
                if tag in _REASON_REQUIRED and not reason:
                    self.bad_annotations.append(
                        Finding(path, i, "annotation",
                                f"'# analysis: {tag}(...)' requires a reason")
                    )
                self.annotations.setdefault(i, {})[tag] = reason

    def annotation(self, node: ast.AST, tag: str) -> Optional[str]:
        """Reason string if ``tag`` annotates any line the node's header
        spans (def line through first body line for defs; the node's own
        line span otherwise).  Returns None when absent."""
        first = getattr(node, "lineno", None)
        if first is None:
            return None
        last = getattr(node, "end_lineno", first)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node.body:
            first = min(d.lineno for d in node.decorator_list) if node.decorator_list else first
            last = node.body[0].lineno - 1
            last = max(last, node.lineno)
        for ln in range(first, last + 1):
            tags = self.annotations.get(ln)
            if tags is not None and tag in tags:
                return tags[tag]
        return None

    def has_marker(self, tag: str) -> bool:
        return any(tag in tags for tags in self.annotations.values())


def attr_chain(node: ast.AST) -> list[str]:
    """``self.table_lock.write`` -> ["self", "table_lock", "write"].
    Returns [] for expressions that are not simple dotted names."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def guard_mode(item: ast.withitem) -> Optional[str]:
    """Classify a with-item as a 'write' or 'read' lock section.

    Recognized guards:
      - ``with <...>.table_lock.write():``  -> write
      - ``with <...>.table_lock.read():``   -> read
      - ``with <...>._mu:``                 -> write (Frontend's Condition)
    """
    e = item.context_expr
    if isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute):
        chain = attr_chain(e.func)
        if "table_lock" in chain or any(c.endswith("_lock") for c in chain[:-1]):
            if e.func.attr == "write":
                return "write"
            if e.func.attr == "read":
                return "read"
    if isinstance(e, ast.Attribute) and e.attr == "_mu":
        return "write"
    if isinstance(e, ast.Name) and e.id == "_mu":
        return "write"
    return None


# Methods whose bodies are exempt from lock discipline by default:
# object construction happens before the instance is published.
CONSTRUCTOR_EXEMPT = {"__init__", "__new__", "__post_init__"}


@dataclass
class Ctx:
    """Static context at a visited node."""
    class_name: Optional[str] = None
    func_name: Optional[str] = None
    func_node: Optional[ast.AST] = None
    lock: Optional[str] = None        # 'write' | 'read' | None
    lock_node: Optional[ast.AST] = None  # the With/def that took the lock
    exempt: Optional[str] = None      # reason the whole scope is exempt
    with_stack: tuple = field(default_factory=tuple)  # enclosing With nodes

    def dominated(self, need: str) -> bool:
        if self.exempt is not None:
            return True
        if need == "read":
            return self.lock in ("read", "write")
        return self.lock == "write"


def iter_with_context(src: SourceFile) -> Iterator[tuple[ast.stmt, Ctx]]:
    """Yield every statement in the module with its :class:`Ctx`.

    Function bodies annotated ``# analysis: caller-holds-write`` are
    walked with ``lock='write'``; ``# analysis: single-threaded(...)``
    and constructors are walked with ``exempt`` set.  Nested closures
    inherit their definition site's context.
    """

    def walk(stmts, ctx: Ctx):
        for node in stmts:
            yield node, ctx
            if isinstance(node, ast.ClassDef):
                yield from walk(node.body, Ctx(class_name=node.name))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                sub = Ctx(class_name=ctx.class_name, func_name=node.name,
                          func_node=node, lock=ctx.lock,
                          lock_node=ctx.lock_node, exempt=ctx.exempt,
                          with_stack=ctx.with_stack)
                if node.name in CONSTRUCTOR_EXEMPT:
                    sub.exempt = "constructor"
                if src.annotation(node, "single-threaded") is not None:
                    sub.exempt = "single-threaded"
                if src.annotation(node, "caller-holds-write") is not None:
                    sub.lock = "write"
                    sub.lock_node = node
                yield from walk(node.body, sub)
            elif isinstance(node, (ast.With, ast.AsyncWith)):
                mode = None
                for item in node.items:
                    m = guard_mode(item)
                    if m == "write":
                        mode = "write"
                    elif m == "read" and mode is None:
                        mode = "read"
                sub = Ctx(**{**ctx.__dict__})
                if mode == "write":
                    sub.lock = "write"
                    sub.lock_node = node
                elif mode == "read" and ctx.lock != "write":
                    sub.lock = "read"
                sub.with_stack = ctx.with_stack + (node,)
                yield from walk(node.body, sub)
            elif isinstance(node, (ast.If, ast.For, ast.AsyncFor, ast.While)):
                yield from walk(node.body, ctx)
                yield from walk(node.orelse, ctx)
            elif isinstance(node, ast.Try):
                yield from walk(node.body, ctx)
                for h in node.handlers:
                    yield from walk(h.body, ctx)
                yield from walk(node.orelse, ctx)
                yield from walk(node.finalbody, ctx)
            elif isinstance(node, ast.Match):
                for case in node.cases:
                    yield from walk(case.body, ctx)

    yield from walk(src.tree.body, Ctx())


def defined_classes(src: SourceFile) -> set[str]:
    return {n.name for n in src.tree.body if isinstance(n, ast.ClassDef)}


def module_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def iter_py_files(paths: list[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d not in
                                 ("__pycache__", ".git", ".venv", "node_modules"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


_CORPUS_CACHE: dict[str, str] = {}


def tests_corpus(tests_dir: Optional[str]) -> str:
    """Concatenated text of every test file under ``tests_dir`` (cached);
    empty string when the directory is absent."""
    if not tests_dir or not os.path.isdir(tests_dir):
        return ""
    key = os.path.abspath(tests_dir)
    if key not in _CORPUS_CACHE:
        parts = []
        for path in iter_py_files([tests_dir]):
            try:
                with open(path, "r", encoding="utf-8") as f:
                    parts.append(f.read())
            except OSError:
                continue
        _CORPUS_CACHE[key] = "\n".join(parts)
    return _CORPUS_CACHE[key]


def analyze_paths(paths: list[str], tests_dir: Optional[str] = "tests") -> list[Finding]:
    """Run every applicable checker over ``paths``; returns all findings."""
    # Imported here so ``from repro.analysis.common import Finding`` stays
    # cheap and cycle-free for the runtime sanitizer.
    from . import coverage, locks, ordering, purity

    findings: list[Finding] = []
    for path in iter_py_files(paths):
        try:
            src = SourceFile(path)
        except SyntaxError as e:
            findings.append(Finding(path, e.lineno or 0, "parse", str(e.msg)))
            continue
        findings.extend(src.bad_annotations)
        findings.extend(locks.check(src))
        findings.extend(ordering.check(src))
        findings.extend(purity.check(src, tests_dir=tests_dir))
        findings.extend(coverage.check(src, tests_dir=tests_dir))
    findings.sort(key=lambda f: (f.path, f.line))
    return findings
