"""Journal-ordering checker — makes the PR-9 review bug class
unrepresentable.

Rule B (journal inside lock): every journal write — a call to
``*_journal*.append(...)`` / ``...journal.append(...)`` or to the
server's ``self._journal_op(...)`` — must be dominated by a writer
section.  The PR-9 bug was a journal append *outside* the writer
section, which let a concurrent writer interleave and record operations
out of application order.

Rule A (journal before mutation): within one writer section (a
``with ...write():`` block, or the whole body of a
``# analysis: caller-holds-write`` function) that both journals and
applies a journaled mutation (``stream.insert/delete``,
``self.insert/delete``, ``ambi``-receiver ops), the first journal call
must precede the first mutation in source order.  Journal-then-apply is
what makes the journal a write-ahead log: a crash between the two
replays the op; the reverse order loses it.

``# analysis: unlocked-ok(reason)`` suppresses Rule B on a line (e.g.
single-threaded recovery paths already annotated at the def level are
exempt wholesale).  Rule A has no escape hatch by design.
"""

from __future__ import annotations

import ast

from .common import Finding, SourceFile, attr_chain, iter_with_context
from .inventory import (
    JOURNAL_METHODS,
    JOURNAL_RECEIVERS,
    JOURNALED_MUTATION_RECEIVERS,
    JOURNALED_MUTATIONS,
)
from .locks import _call_sites, _classes
from .inventory import INVENTORY

CHECKER = "journal-ordering"


def _is_journal_call(call: ast.Call) -> bool:
    if not isinstance(call.func, ast.Attribute):
        return False
    chain = attr_chain(call.func)
    if not chain:
        return False
    meth = chain[-1]
    if meth in JOURNAL_METHODS:
        return True
    if meth == "append" and len(chain) >= 2:
        recv = chain[-2]
        return recv in JOURNAL_RECEIVERS or recv.endswith("journal")
    return False


def _is_journaled_mutation(call: ast.Call) -> bool:
    if not isinstance(call.func, ast.Attribute):
        return False
    chain = attr_chain(call.func)
    if len(chain) < 2 or chain[-1] not in JOURNALED_MUTATIONS:
        return False
    recv = chain[-2]
    return (recv in JOURNALED_MUTATION_RECEIVERS
            or recv.endswith("stream") or recv.endswith("ambi"))


def check(src: SourceFile) -> list[Finding]:
    if not (_classes(src) & set(INVENTORY)):
        return []
    findings: list[Finding] = []

    # section key -> [first_journal_line, first_mutation_line, func_name]
    # A section is the innermost writer With block if any, else the
    # enclosing caller-holds-write/exempt-writer function body.
    sections: dict[int, list] = {}

    for node, ctx in iter_with_context(src):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for call in _call_sites(node):
            journal = _is_journal_call(call)
            mutation = _is_journaled_mutation(call)
            if not journal and not mutation:
                continue
            if journal and not ctx.dominated("write"):
                if src.annotation(node, "unlocked-ok") is None:
                    findings.append(Finding(
                        src.path, node.lineno, CHECKER,
                        "journal write outside a writer section — a "
                        "concurrent writer can interleave and break "
                        "journal/application order "
                        f"(in {ctx.func_name or '<module>'})"))
                continue
            if ctx.lock != "write" and ctx.exempt is None:
                continue  # mutation outside writer ctx: lock checker's job
            key = id(ctx.lock_node if ctx.lock_node is not None
                     else ctx.func_node)
            rec = sections.setdefault(key, [None, None, ctx.func_name])
            if journal and rec[0] is None:
                rec[0] = node.lineno
            if mutation and rec[1] is None:
                rec[1] = (node.lineno, ast.unparse(call.func))

    for first_journal, first_mut, func in sections.values():
        if first_journal is None or first_mut is None:
            continue
        mut_line, mut_expr = first_mut
        if first_journal > mut_line:
            findings.append(Finding(
                src.path, mut_line, CHECKER,
                f"state mutation '{mut_expr}()' precedes the journal "
                f"append at line {first_journal} inside the same writer "
                f"section (in {func or '<module>'}) — journal first, "
                f"then apply"))
    return findings
