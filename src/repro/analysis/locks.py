"""Lock-discipline / static race detector.

Two passes, driven by which inventoried classes a file defines:

* **containment** — inside a file defining ``StreamingIndex`` /
  ``DeviceMirror``, every assignment to an inventoried state attribute
  (``self._pts``, ``self.tiers``, ...) must occur inside one of that
  class's declared mutator methods.  New mutation sites outside the
  inventory are findings (inventory drift), so the runtime sanitizer's
  guard list cannot silently fall behind the code.
* **domination** — inside a file defining ``DeviceQueryServer`` /
  ``Frontend``, every assignment to a guarded attribute and every call
  to an inventoried mutator (``stream.insert``, ``mirror.sync``,
  ``table.graft``, ``journal.truncate``, ...) must be dominated by a
  ``with ...table_lock.write():`` (Frontend: ``with self._mu:``)
  section; inventoried read entry points need at least ``.read()``.

Escape hatches: ``# analysis: unlocked-ok(reason)`` on the line,
``# analysis: caller-holds-write`` / ``# analysis: single-threaded(...)``
on the enclosing ``def`` (see :mod:`repro.analysis.common`).  A
``caller-holds-write`` function's intra-file call sites are themselves
checked: each must already be in a writer section.
"""

from __future__ import annotations

import ast

from .common import Finding, SourceFile, attr_chain, iter_with_context
from .inventory import (
    INVENTORY,
    READ_CALLS,
    WRITE_CALLS,
    WRITE_CALL_RECEIVERS,
)

CHECKER = "lock-discipline"


def _assign_targets(node: ast.stmt):
    if isinstance(node, ast.Assign):
        return node.targets
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        return [node.target]
    return []


def _flag(src: SourceFile, node: ast.AST, msg: str,
          findings: list[Finding]) -> None:
    if src.annotation(node, "unlocked-ok") is not None:
        return
    findings.append(Finding(src.path, node.lineno, CHECKER, msg))


_SIMPLE_STMTS = (ast.Expr, ast.Assign, ast.AugAssign, ast.AnnAssign,
                 ast.Return, ast.Raise, ast.Assert, ast.Delete)


def _iter_calls(node: ast.AST):
    """Call expressions in a subtree, pruning nested defs and lambdas
    (their bodies run later, under their own — separately walked or
    deliberately deferred — context)."""
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        return
    if isinstance(node, ast.Call):
        yield node
    for child in ast.iter_child_nodes(node):
        yield from _iter_calls(child)


def _call_sites(node: ast.stmt):
    """Calls that execute *at this statement's context*: the whole body
    of simple statements, only the header expressions of compound ones
    (their bodies are yielded separately with the inner context)."""
    if isinstance(node, _SIMPLE_STMTS):
        yield from _iter_calls(node)
    elif isinstance(node, (ast.If, ast.While)):
        yield from _iter_calls(node.test)
    elif isinstance(node, (ast.For, ast.AsyncFor)):
        yield from _iter_calls(node.iter)
    elif isinstance(node, (ast.With, ast.AsyncWith)):
        for item in node.items:
            yield from _iter_calls(item.context_expr)


def check(src: SourceFile) -> list[Finding]:
    local = {name: inv for name, inv in INVENTORY.items()
             if name in _classes(src)}
    if not local:
        return []

    containment = [inv for inv in local.values() if inv.kind == "containment"]
    domination = [inv for inv in local.values() if inv.kind == "domination"]
    findings: list[Finding] = []

    guarded_attrs = frozenset().union(
        *(inv.state_attrs for inv in domination)) if domination else frozenset()
    # containment mutators: calls inside them are the callee side of the
    # contract — the *caller* holds the lock — so skip domination there.
    containment_methods = {
        (inv.name, m) for inv in containment for m in inv.mutators
    }
    # pre-pass: collect caller-holds-write defs so call sites that appear
    # earlier in the file than the def are still checked
    chw_funcs: dict[str, int] = {}
    for sub in ast.walk(src.tree):
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and src.annotation(sub, "caller-holds-write") is not None:
            chw_funcs.setdefault(sub.name, sub.lineno)
    chw_called_in_write: dict[str, bool] = {}

    for node, ctx in iter_with_context(src):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue

        in_containment_mutator = (
            (ctx.class_name, ctx.func_name) in containment_methods
        )

        # -- containment: state attrs only written inside declared mutators
        for inv in containment:
            if ctx.class_name != inv.name:
                continue
            for tgt in _assign_targets(node):
                chain = attr_chain(tgt)
                if len(chain) == 2 and chain[0] == "self" \
                        and chain[1] in inv.state_attrs:
                    if ctx.exempt is not None:
                        continue
                    if ctx.func_name not in inv.mutators:
                        _flag(src, node,
                              f"{inv.name}.{chain[1]} written in "
                              f"{ctx.func_name or '<module>'}(), which is not "
                              f"a declared mutator of {inv.name} — add it to "
                              f"the inventory (and the sanitizer guard) or "
                              f"move the write", findings)

        # -- domination: guarded attr writes need a writer section
        if domination and not in_containment_mutator:
            for tgt in _assign_targets(node):
                chain = attr_chain(tgt)
                if len(chain) >= 2 and chain[-1] in guarded_attrs:
                    if not ctx.dominated("write"):
                        _flag(src, node,
                              f"write to guarded attribute "
                              f"'{'.'.join(chain)}' outside a writer section "
                              f"(in {ctx.func_name or '<module>'})", findings)

        # -- domination: mutator / read-path calls
        if not in_containment_mutator:
            for call in _call_sites(node):
                if not isinstance(call.func, ast.Attribute):
                    # bare call: check caller-holds-write contract below
                    if isinstance(call.func, ast.Name) \
                            and call.func.id in chw_funcs:
                        ok = ctx.dominated("write")
                        prev = chw_called_in_write.get(call.func.id, True)
                        chw_called_in_write[call.func.id] = prev and ok
                        if not ok:
                            _flag(src, node,
                                  f"call to caller-holds-write function "
                                  f"{call.func.id}() outside a writer section",
                                  findings)
                    continue
                meth = call.func.attr
                chain = attr_chain(call.func)
                recv = chain[-2] if len(chain) >= 2 else ""
                if domination and meth in WRITE_CALLS and (
                        recv in WRITE_CALL_RECEIVERS
                        or any(recv.startswith(r) for r in
                               WRITE_CALL_RECEIVERS if r != "t")):
                    if not ctx.dominated("write"):
                        _flag(src, node,
                              f"mutating call '{'.'.join(chain)}()' outside "
                              f"a writer section "
                              f"(in {ctx.func_name or '<module>'})", findings)
                elif domination and meth in READ_CALLS:
                    if not ctx.dominated("read"):
                        _flag(src, node,
                              f"serving read '{'.'.join(chain)}()' outside "
                              f"a read (or write) section "
                              f"(in {ctx.func_name or '<module>'})", findings)
                if meth in chw_funcs and recv == "self":
                    ok = ctx.dominated("write")
                    prev = chw_called_in_write.get(meth, True)
                    chw_called_in_write[meth] = prev and ok
                    if not ok:
                        _flag(src, node,
                              f"call to caller-holds-write method "
                              f"self.{meth}() outside a writer section",
                              findings)

    return findings


def _classes(src: SourceFile) -> set[str]:
    return {n.name for n in src.tree.body if isinstance(n, ast.ClassDef)}
