"""Data pipeline with FMBI spatial sharding (the paper as a data substrate).

Distributed training wants balanced, locality-preserving shards.  Documents
carry multidimensional keys (here: synthetic (length-score, domain-embedding)
coordinates); the paper's parallel bulk loader (Section 5) partitions them
across data-parallel workers with its balanced median SplitTree — max/mean
shard load ~1.06 in the paper, which is exactly the straggler-avoidance
property a pipeline needs (every DP worker finishes its epoch slice at the
same time).

The pipeline is deterministic and checkpointable: its state is
(epoch, cursor, seed), saved alongside model checkpoints.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.splittree import build_group_median_tree


@dataclasses.dataclass
class PipelineState:
    epoch: int = 0
    cursor: int = 0
    seed: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


class TokenPipeline:
    """Synthetic-corpus pipeline: documents -> fixed-length token batches.

    ``n_shards`` data-parallel workers each stream only their FMBI-assigned
    document shard; ``shard_balance()`` reports the max/mean load.
    """

    def __init__(self, vocab: int, seq_len: int, n_docs: int = 2048,
                 n_shards: int = 1, seed: int = 0, doc_len_range=(64, 512)):
        self.vocab = vocab
        self.seq_len = seq_len
        self.n_shards = n_shards
        rng = np.random.default_rng(seed)
        lens = rng.integers(*doc_len_range, n_docs)
        # multidimensional document keys: (normalized length, 2-D embedding)
        keys = np.stack(
            [
                lens / doc_len_range[1],
                rng.random(n_docs),
                rng.random(n_docs),
            ],
            axis=1,
        ).astype(np.float64)
        if n_shards > 1:
            # paper Section 5: m-way SplitTree partition of the key space
            group = max(len(keys) // (n_shards * 8), 1)
            trim = n_shards * group * 8
            tree, _, assign = build_group_median_tree(
                keys[:trim], n_shards, group, 8
            )
            rest = tree.route(keys[trim:]) if trim < len(keys) else np.zeros(
                0, np.int32
            )
            self.shard_of = np.concatenate([assign, rest])
        else:
            self.shard_of = np.zeros(n_docs, dtype=np.int32)
        self.docs = [
            rng.integers(0, vocab, l).astype(np.int32) for l in lens
        ]
        self.state = PipelineState(seed=seed)

    def shard_balance(self) -> float:
        counts = np.bincount(self.shard_of, minlength=self.n_shards)
        return float(counts.max() / counts.mean())

    def _shard_tokens(self, shard: int) -> np.ndarray:
        docs = [d for d, s in zip(self.docs, self.shard_of) if s == shard]
        return (
            np.concatenate(docs) if docs else np.zeros(0, np.int32)
        )

    def next_batch(self, batch_per_shard: int, shard: int = 0) -> dict:
        """(batch_per_shard, seq_len) token/label arrays for one DP shard."""
        stream = self._shard_tokens(shard)
        need = batch_per_shard * self.seq_len
        out = np.empty(need, np.int32)
        got = 0
        cur = self.state.cursor
        while got < need:
            take = min(need - got, len(stream) - cur)
            if take <= 0:
                cur = 0
                self.state.epoch += 1
                continue
            out[got : got + take] = stream[cur : cur + take]
            got += take
            cur += take
        self.state.cursor = cur
        chunk = out.reshape(batch_per_shard, self.seq_len)
        # loss_fn shifts internally: labels == tokens stream
        return {"tokens": chunk, "labels": chunk.copy()}

    def global_batch(self, global_batch: int) -> dict:
        """Concatenated per-shard batches in shard order (DP layout)."""
        per = global_batch // self.n_shards
        parts = [self.next_batch(per, s) for s in range(self.n_shards)]
        return {
            k: np.concatenate([p[k] for p in parts]) for k in parts[0]
        }
