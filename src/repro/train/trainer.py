"""Training step factory: microbatched grad accumulation + optimizer fusion.

``make_train_step`` builds the jittable update used by both the real
training loop (``launch/train.py``) and the multi-pod dry-run.  Gradient
accumulation runs as a ``lax.scan`` over microbatches (keeps live activation
memory to one microbatch — the knob that fits 32k-token-per-device shapes in
16 GB HBM), accumulating float32 gradients sharded like the parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import model as M
from .optimizer import Optimizer


def make_train_step(cfg, axes, optimizer: Optimizer, n_micro: int = 1,
                    accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics)."""

    def loss_of(params, mb):
        return M.loss_fn(params, cfg, mb, axes)

    def train_step(params, opt_state, batch, step):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]),
                batch,
            )
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )

            def body(acc, mb):
                l_acc, g_acc = acc
                l, g = jax.value_and_grad(loss_of)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g
                )
                return (l_acc + l, g_acc), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), micro
            )
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def pick_microbatches(cfg, shape, n_dp: int) -> int:
    """Keep ~<=8k tokens per device per microbatch (activation budget)."""
    tokens_per_dev = shape.seq_len * shape.global_batch // max(n_dp, 1)
    n = max(1, tokens_per_dev // 8192)
    # must divide the per-step batch count
    while shape.global_batch % (n or 1):
        n -= 1
    return max(n, 1)
