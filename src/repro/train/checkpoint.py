"""Checkpointing: atomic step snapshots, async save, restart, elastic re-shard.

Layout:  <dir>/step_<n>/
            manifest.json          flat-key -> {file, shape, dtype}
            arrays/<i>.npy         one file per leaf (host-gathered)
            .complete              commit marker (atomic rename-last)

Fault-tolerance contract:
  * saves are crash-safe: a snapshot without ``.complete`` is ignored by
    ``latest_step`` (a died writer never corrupts restart);
  * ``save_async`` snapshots device arrays to host immediately and writes on
    a worker thread — training continues during the write;
  * ``restore`` re-shards every leaf onto the *current* mesh via
    ``jax.device_put``: restarting on a different device count (elastic
    scaling after losing a pod) needs no converter pass;
  * data-pipeline state (step, shard cursor, rng) rides in the same manifest.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}#/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix_keys(node):
        if isinstance(node, dict):
            out = {}
            lst = node and all(k.endswith("#") for k in node)
            if lst:
                return [
                    fix_keys(node[k])
                    for k in sorted(node, key=lambda s: int(s[:-1]))
                ]
            for k, v in node.items():
                out[k] = fix_keys(v)
            return out
        return node

    return fix_keys(root)


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- discovery ---------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / ".complete").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None) -> None:
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self._write(step, host, extra or {})

    def save_async(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, extra: dict) -> None:
        path = self.dir / f"step_{step}"
        tmp = self.dir / f".tmp_step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        (tmp / "arrays").mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for i, (key, arr) in enumerate(host.items()):
            np.save(tmp / "arrays" / f"{i}.npy", arr)
            manifest["leaves"][key] = {
                "file": f"arrays/{i}.npy",
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if path.exists():
            shutil.rmtree(path)
        tmp.rename(path)
        (path / ".complete").touch()  # commit marker
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def restore(self, step: int | None = None, shardings=None):
        """Returns (tree, extra).  ``shardings``: optional same-structure tree
        of Shardings — leaves are device_put onto them (elastic re-shard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = self.dir / f"step_{step}"
        manifest = json.loads((path / "manifest.json").read_text())
        flat = {}
        for key, meta in manifest["leaves"].items():
            flat[key] = np.load(path / meta["file"])
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten(shardings)
            flat_tr = _flatten(tree)
            placed = {
                k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                for k, v in flat_tr.items()
            }
            tree = _unflatten(placed)
        return tree, manifest["extra"]
