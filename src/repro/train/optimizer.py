"""Optimizers in pure JAX: AdamW and Adafactor (factored second moments).

Adafactor is the memory-sane choice for the >40B architectures (arctic,
qwen3-moe, jamba): second moments factor into row/col running means over the
last two axes, so optimizer state is O(sum of dims) instead of O(params).
Both optimizers expose the same (init, update) pair and a ``state_specs``
helper that derives PartitionSpecs for their state from the parameter specs
(FSDP-sharded exactly like the parameters they track).

Optimizer state is a *list of per-leaf dicts* in the parameters' canonical
flatten order — structure-agnostic, checkpoint-friendly, and immune to
tree-prefix pitfalls.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable            # params -> state
    update: Callable          # (grads, state, params, step) -> (params, state)
    state_specs: Callable     # param_specs tree -> state specs (list)


def _split(pairs, treedef):
    newp = treedef.unflatten([a for a, _ in pairs])
    news = [b for _, b in pairs]
    return newp, news


def adamw(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, wd: float = 0.01) -> Optimizer:
    def init(params):
        leaves = jax.tree.leaves(params)
        return [
            {"m": jnp.zeros(p.shape, jnp.float32),
             "v": jnp.zeros(p.shape, jnp.float32)}
            for p in leaves
        ]

    def update(grads, state, params, step):
        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = jax.tree.leaves(params)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd_one(g, s, p):
            g = g.astype(jnp.float32)
            m = b1 * s["m"] + (1 - b1) * g
            v = b2 * s["v"] + (1 - b2) * g * g
            stp = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            newp = p.astype(jnp.float32) - stp - lr * wd * p.astype(
                jnp.float32
            )
            return newp.astype(p.dtype), {"m": m, "v": v}

        pairs = [
            upd_one(g, s, p)
            for g, s, p in zip(g_leaves, state, p_leaves)
        ]
        return _split(pairs, treedef)

    def state_specs(pspecs):
        return [{"m": s, "v": s} for s in jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P))]

    return Optimizer(init, update, state_specs)


def adafactor(lr: float = 1e-4, decay: float = 0.99,
              eps: float = 1e-30, clip: float = 1.0) -> Optimizer:
    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        out = []
        for p in jax.tree.leaves(params):
            if factored(p):
                out.append({
                    "row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                     jnp.float32),
                })
            else:
                out.append({"v": jnp.zeros(p.shape, jnp.float32)})
        return out

    def _upd_one(g, s, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if factored(p):
            row = decay * s["row"] + (1 - decay) * g2.mean(-1)
            col = decay * s["col"] + (1 - decay) * g2.mean(-2)
            rfac = row / jnp.clip(row.mean(-1, keepdims=True), min=eps)
            v = rfac[..., None] * col[..., None, :]
            new_s = {"row": row, "col": col}
        else:
            v = decay * s["v"] + (1 - decay) * g2
            new_s = {"v": v}
        u = g * jax.lax.rsqrt(v + eps)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip)  # update clipping
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

    def update(grads, state, params, step):
        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = jax.tree.leaves(params)
        pairs = [
            _upd_one(g, s, p)
            for g, s, p in zip(g_leaves, state, p_leaves)
        ]
        return _split(pairs, treedef)

    def state_specs(pspecs):
        out = []
        for s in jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)):
            st = tuple(s)
            if len(st) >= 2:
                out.append({"row": P(*st[:-1]),
                            "col": P(*(st[:-2] + st[-1:]))})
            else:
                out.append({"v": P(*st)})
        return out

    return Optimizer(init, update, state_specs)


def get_optimizer(name: str, lr: float = 1e-4) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr)
    if name == "adafactor":
        return adafactor(lr=lr)
    raise ValueError(name)


def pick_for(cfg) -> str:
    """Adafactor above ~40B total params (HBM headroom), AdamW otherwise."""
    total, _ = cfg.params_count()
    return "adafactor" if total > 40e9 else "adamw"
