"""Serve batched k-NN queries from an FMBI index (paper as a serving
substrate): exact tree-pruned search, the Pallas distance-kernel path,
AMBI-style adaptive residency for a focused query stream, and booting a
server from a bulk-loaded NodeTable snapshot without rebuilding.

    PYTHONPATH=src python examples/knn_serving.py
"""
import pathlib
import tempfile
import time

import numpy as np

from repro.core import PageStore, bulk_load
from repro.core.datasets import nycyt_like
from repro.serve.engine import RetrievalServer


def main():
    print("indexing 200k 5-D trip records (NYCYT-like)...")
    points = nycyt_like(200_000, d=5, seed=0)
    server = RetrievalServer(points, levels=8)

    rng = np.random.default_rng(1)
    queries = rng.random((64, 5)).astype(np.float32)

    t0 = time.time()
    rows, d2, exact = server.knn(queries, k=16, n_candidate_leaves=16)
    print(f"batch of 64 16-NN queries: {time.time()-t0:.3f}s "
          f"(exact certificates: {np.mean(exact):.0%})")

    t0 = time.time()
    _, d2k = server.knn_kernel(queries, k=16)
    print(f"Pallas kernel path (interpret mode on CPU): {time.time()-t0:.3f}s")
    agree = np.allclose(np.sort(d2[exact], axis=1),
                        np.sort(d2k[exact], axis=1), rtol=1e-3, atol=1e-5)
    print(f"tree-pruned vs kernel distances agree: {agree}")

    # ---- snapshot boot: CPU bulk load -> .npz -> accelerator serving ------
    print("\nboot from a NodeTable snapshot (no rebuild):")
    idx = bulk_load(points.astype(np.float64), 400, PageStore(400))
    with tempfile.TemporaryDirectory() as tmp:
        snap = pathlib.Path(tmp) / "index.npz"
        idx.save(snap)
        t0 = time.time()
        snap_server = RetrievalServer.from_snapshot(snap)
        boot = time.time() - t0
        rows_s, d2_s, exact_s = snap_server.knn(queries, k=16,
                                                n_candidate_leaves=16)
        print(f"  bridged {idx.table.n_nodes}-row table in {boot:.3f}s; "
              f"exact certificates: {np.mean(exact_s):.0%}")

    # ---- adaptive serving: AMBI residency policy --------------------------
    print("\nadaptive residency (focused stream over one city):")
    adaptive = RetrievalServer(points, levels=8, adaptive=True,
                               hot_capacity=32)
    for step in range(20):
        qs = (rng.random((32, 5)) * 0.1 + 0.45).astype(np.float32)
        adaptive.knn(qs, k=8)
        if step in (0, 4, 19):
            print(f"  after {adaptive.stats.queries:4d} queries: "
                  f"hot-leaf hit rate {adaptive.stats.hit_rate:.0%}")


if __name__ == "__main__":
    main()
