"""Serve batched k-NN queries from an FMBI index (paper as a serving
substrate): exact tree-pruned search, the Pallas distance-kernel path,
AMBI-style adaptive residency for a focused query stream, booting a
server from a bulk-loaded NodeTable snapshot without rebuilding, the
compiled device query engine (bulk load on CPU, serve windows + k-NN
through jit-compiled traversal with id-identical results), and sharded
serving (paper Section 5): the table partitions into m DeviceTables
behind a subspace-MBB router, windows fan out only to qualified shards,
and k-NN runs the certified two-round protocol.  The last two sections
exercise the fault-tolerance layer: degraded serving with completeness
certificates when a seeded fault kills a shard (then repair), and graft
journal crash recovery rebooting an adaptive server from snapshot +
replay to the bit-identical table.

    PYTHONPATH=src python examples/knn_serving.py
"""
import pathlib
import tempfile
import time

import numpy as np

from repro.core import PageStore, bulk_load, knn_query_batch, window_query_batch
from repro.core.datasets import nycyt_like
from repro.serve.engine import DeviceQueryServer, RetrievalServer


def main():
    print("indexing 200k 5-D trip records (NYCYT-like)...")
    # float32-representable coordinates: the device engine's exact-parity
    # contract (see core/queries_jax.py) holds bit-for-bit
    points = nycyt_like(200_000, d=5, seed=0).astype(np.float32).astype(
        np.float64)
    server = RetrievalServer(points, levels=8)

    rng = np.random.default_rng(1)
    queries = rng.random((64, 5)).astype(np.float32)

    t0 = time.time()
    rows, d2, exact = server.knn(queries, k=16, n_candidate_leaves=16)
    print(f"batch of 64 16-NN queries: {time.time()-t0:.3f}s "
          f"(exact certificates: {np.mean(exact):.0%})")

    t0 = time.time()
    _, d2k = server.knn_kernel(queries, k=16)
    print(f"Pallas kernel path (interpret mode on CPU): {time.time()-t0:.3f}s")
    agree = np.allclose(np.sort(d2[exact], axis=1),
                        np.sort(d2k[exact], axis=1), rtol=1e-3, atol=1e-5)
    print(f"tree-pruned vs kernel distances agree: {agree}")

    # ---- snapshot boot: CPU bulk load -> .npz -> accelerator serving ------
    print("\nboot from a NodeTable snapshot (no rebuild):")
    idx = bulk_load(points.astype(np.float64), 400, PageStore(400))
    with tempfile.TemporaryDirectory() as tmp:
        snap = pathlib.Path(tmp) / "index.npz"
        idx.save(snap)
        t0 = time.time()
        snap_server = RetrievalServer.from_snapshot(snap)
        boot = time.time() - t0
        rows_s, d2_s, exact_s = snap_server.knn(queries, k=16,
                                                n_candidate_leaves=16)
        print(f"  bridged {idx.table.n_nodes}-row table in {boot:.3f}s; "
              f"exact certificates: {np.mean(exact_s):.0%}")

    # ---- compiled device engine: NodeTable -> DeviceTable -----------------
    print("\ncompiled device query engine (microbatched, id-identical):")
    dev_srv = DeviceQueryServer.from_index(idx, microbatch=64)
    los = queries[:, :] - 0.03
    his = queries[:, :] + 0.03
    dev_srv.window(los, his)          # compile once
    dev_srv.knn(queries, 16)
    t0 = time.time()
    dev_windows = dev_srv.window(los, his)
    t_w = time.time() - t0
    t0 = time.time()
    dev_knn = dev_srv.knn(queries, 16)
    t_k = time.time() - t0
    cpu_windows, _ = window_query_batch(idx, los.astype(np.float64),
                                        his.astype(np.float64))
    cpu_knn, _ = knn_query_batch(idx, queries.astype(np.float64), 16)
    w_ok = all(np.array_equal(np.sort(a), np.sort(b))
               for a, b in zip(dev_windows, cpu_windows))
    k_ok = all(np.array_equal(a, b) for a, b in zip(dev_knn, cpu_knn))
    print(f"  64 windows {t_w*1e3:.1f} ms, 64 16-NN {t_k*1e3:.1f} ms "
          f"({dev_srv.stats.microbatches} microbatches)")
    print(f"  id-parity vs NumPy engine: windows {w_ok}, knn {k_ok}")

    # ---- sharded serving: m DeviceTables behind the subspace router -------
    print("\nsharded serving (4 shards, two-round certified k-NN):")
    shard_srv = DeviceQueryServer.from_index(idx, microbatch=64, shards=4)
    shard_srv.window(los, his)        # compile once per shard shape
    shard_srv.knn(queries, 16)
    t0 = time.time()
    sh_windows = shard_srv.window(los, his)
    t_w = time.time() - t0
    t0 = time.time()
    sh_knn = shard_srv.knn(queries, 16)
    t_k = time.time() - t0
    w_ok = all(np.array_equal(np.sort(a), np.sort(b))
               for a, b in zip(sh_windows, dev_windows))
    k_ok = all(np.array_equal(a, b) for a, b in zip(sh_knn, dev_knn))
    print(f"  {shard_srv.stats.shards} shards: 64 windows {t_w*1e3:.1f} ms, "
          f"64 16-NN {t_k*1e3:.1f} ms")
    print(f"  id-parity vs single-table engine: windows {w_ok}, knn {k_ok}")

    # ---- adaptive serving: AMBI residency policy --------------------------
    print("\nadaptive residency (focused stream over one city):")
    adaptive = RetrievalServer(points, levels=8, adaptive=True,
                               hot_capacity=32)
    for step in range(20):
        qs = (rng.random((32, 5)) * 0.1 + 0.45).astype(np.float32)
        adaptive.knn(qs, k=8)
        if step in (0, 4, 19):
            print(f"  after {adaptive.stats.queries:4d} queries: "
                  f"hot-leaf hit rate {adaptive.stats.hit_rate:.0%}")

    # ---- adaptive DEVICE serving: AMBI behind the compiled engine ---------
    # boot from the single-unrefined-root state: nothing is indexed yet.
    # Cold queries are answered by the host AMBI engine (charging the
    # paper's I/O and grafting the touched subspaces); each graft streams
    # to the device as an incremental delta, and the pinned hotspot goes
    # fully device-resident — no host I/O at steady state.
    print("\nadaptive device serving (partial index, incremental refresh):")
    from repro.core import AMBI
    from repro.core import queries_jax as QJ

    ambi = AMBI(points.astype(np.float64), 400)
    adaptive_dev = DeviceQueryServer.from_ambi(ambi, microbatch=64)
    hot_c = (rng.random((64, 5)) * 0.08 + 0.45).astype(np.float32)
    hot_lo, hot_hi = hot_c - 0.02, hot_c + 0.02
    t0 = time.time()
    adaptive_dev.window(hot_lo, hot_hi)
    print(f"  first hotspot batch (host refine + delta upload): "
          f"{time.time()-t0:.3f}s, grafts={adaptive_dev.stats.grafts}")
    t0 = time.time()
    adaptive_dev.window(hot_lo, hot_hi)
    s = adaptive_dev.stats
    print(f"  steady-state batch (device only): {time.time()-t0:.3f}s — "
          f"hot {s.hot_queries}, cold {s.cold_queries}, "
          f"delta refreshes {s.delta_refreshes}, "
          f"partial: {not ambi.is_fully_refined()}")
    u = adaptive_dev.upload_stats  # per-server accounting, no module state
    print(f"  uploads: {u['full_exports']} full export (the boot), "
          f"{u['delta_refreshes']} deltas, "
          f"{u['uploaded_leaf_blocks']} leaf blocks total "
          f"(= {adaptive_dev.dev.n_leaves} resident leaves)")

    # ---- degraded serving: a dead shard with completeness certificates ----
    # an unbounded fault kills shard 2; retries exhaust, its breaker opens,
    # and queries opting into `return_certs` get partial answers whose
    # certificate names the unanswered subspace — k-NN answers whose
    # pruning radius provably clears the dead shard stay certified-exact
    print("\ndegraded serving (seeded fault kills shard 2):")
    from repro.serve.faults import FaultPlan, FaultRule
    from repro.serve.resilience import RetryPolicy

    plan = FaultPlan(
        [FaultRule("shard_dispatch", rate=1.0, match={"shard": 2})], seed=0
    )
    deg_srv = DeviceQueryServer.from_index(
        idx, microbatch=64, shards=4, fault_plan=plan,
        retry=RetryPolicy(max_attempts=2, sleep=lambda s: None),
        breaker_threshold=1,
    )
    res, certs = deg_srv.window(los, his, return_certs=True)
    down = [c for c in certs if not c.complete]
    print(f"  {len(res) - len(down)}/{len(res)} windows complete; "
          f"{len(down)} partial, each certifying shard "
          f"{down[0].missing_shards} / MBB {down[0].missing_lo[0].round(2)}"
          f"..{down[0].missing_hi[0].round(2)} unanswered")
    kres, kcerts = deg_srv.knn(queries, 16, return_certs=True)
    n_exact = sum(c.certified_exact for c in kcerts)
    # a k-NN answer stays certified-exact under the outage only when the
    # pruning radius clears the dead shard's MBB; in 5-D the subspace
    # boxes overlap heavily, so expect honest partials here
    print(f"  k-NN: {n_exact}/{len(kcerts)} certified exact, "
          f"{sum(not c.complete for c in kcerts)} honestly partial "
          f"(exact over the 3 alive shards)")
    plan.disarm()  # the operator fixed the fault...
    repaired = deg_srv.repair()  # ...and rebuilt the shard from the host
    res2, certs2 = deg_srv.window(los, his, return_certs=True)
    print(f"  repaired shards {repaired}: "
          f"{sum(c.complete for c in certs2)}/{len(certs2)} complete again")

    # ---- crash recovery: graft journal + snapshot barrier -----------------
    # a durable adaptive server write-ahead journals every cold op; killing
    # it and rebooting from snapshot + replay lands on the bit-identical
    # table (grafting is deterministic given the snapshotted rng/page-store
    # state), so the recovered server serves exactly like the dead one
    print("\ncrash recovery (journaled adaptive serving):")
    with tempfile.TemporaryDirectory() as tmp:
        tmp = pathlib.Path(tmp)
        durable = DeviceQueryServer.from_ambi(
            AMBI(points.astype(np.float64), 400), microbatch=64,
            journal_path=tmp / "grafts.journal",
            snapshot_path=tmp / "snapshot.npz",
            compact_slack=5.0,  # keep the ops in the journal for the demo
            # (a compaction barrier would fold them into the snapshot)
        )
        durable.window(hot_lo, hot_hi)
        print(f"  served 1 hotspot batch: {durable.stats.journal_records} "
              f"journaled cold ops after {durable.stats.checkpoints} "
              f"snapshot barrier (boot)")
        t0 = time.time()  # kill -9 here; the reboot path is:
        recovered = DeviceQueryServer.recover(
            tmp / "snapshot.npz", tmp / "grafts.journal", microbatch=64
        )
        boot = time.time() - t0
        identical = recovered.ambi.table.equals(durable.ambi.table)
        print(f"  recovered in {boot:.3f}s: replayed "
              f"{recovered.stats.replayed_records} records -> "
              f"bit-identical table: {identical}")
        a = recovered.window(hot_lo, hot_hi)
        b = durable.window(hot_lo, hot_hi)
        same = all(np.array_equal(x, y) for x, y in zip(a, b))
        print(f"  post-recovery serving identical to the never-killed "
              f"twin: {same}")

    # ---- async frontend: a burst against the bounded admission queue ------
    # the PR-8 serving pipeline: requests enter a *bounded* queue, a
    # dispatcher thread closes microbatches at size N or age T, expired
    # deadlines and overflow turn into certified drops (never unbounded
    # queueing), and a depth watermark degrades k-NN to capped-escalation
    # brownout answers until the backlog clears
    print("\nasync frontend (burst at a queue bound of 96):")
    from repro.serve.frontend import Frontend

    fe = Frontend(
        dev_srv, queue_bound=96, batch_max=64, batch_window_s=0.002,
        default_deadline_s=5.0, brownout_high=64, brownout_low=16,
        brownout_knn_rounds=1,
    ).start()
    burst = []
    for i in range(256):  # ~2.7x the queue bound, submitted full throttle
        if i % 4 == 3:
            burst.append(fe.submit_knn(rng.random(5), 16))
        else:
            c = rng.random(5) * 0.9
            burst.append(fe.submit_window(c - 0.03, c + 0.03))
    for r in burst:
        r.wait(30.0)
    fe.stop()
    st = fe.stats
    ok = [r for r in burst if r.status == "ok"]
    lat = np.array([r.latency for r in ok])
    print(f"  served {st.completed}/{st.submitted} "
          f"(rejected {st.rejected}, timed out {st.timed_out}, "
          f"shed {st.shed}); peak queue depth {st.depth_peak} <= 96")
    print(f"  p50 {np.percentile(lat, 50)*1e3:.1f} ms, "
          f"p99 {np.percentile(lat, 99)*1e3:.1f} ms over "
          f"{st.batches} microbatches ({st.brownout_batches} brownout)")
    dropped = [r for r in burst if r.status != "ok"]
    certified = all(r.cert is not None and not r.cert.complete
                    for r in dropped)
    print(f"  every dropped request carries a completeness certificate: "
          f"{certified}")
    sample = [r for r in ok if r.kind == "window"][:8]
    ref = dev_srv.window(np.stack([r.payload[0] for r in sample]),
                         np.stack([r.payload[1] for r in sample]))
    exact = all(np.array_equal(np.sort(r.ids), np.sort(e))
                for r, e in zip(sample, ref))
    print(f"  admitted answers id-identical to the offline engine: {exact}")


if __name__ == "__main__":
    main()
