"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the FMBI-sharded synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # seconds-scale demo
"""
import argparse
import dataclasses
import sys

from repro.configs.base import get_config
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.tiny:
        argv = [
            "--arch", "qwen3-0.6b", "--steps", str(args.steps or 30),
            "--batch", "8", "--seq", "128", "--reduced",
            "--ckpt-dir", "/tmp/repro_train_lm_tiny", "--lr", "1e-3",
        ]
    else:
        # ~100M params: qwen3 wiring at d_model=768, 12 layers
        cfg = get_config("qwen3-0.6b")
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=32768, dtype="float32",
            chunk_q=256,
        )
        total, _ = cfg.params_count()
        print(f"training {cfg.name}-100m: {total/1e6:.0f}M params")
        from repro.configs import base as cfg_base

        cfg_base.register(dataclasses.replace(cfg, name="qwen3-100m"))
        argv = [
            "--arch", "qwen3-100m", "--steps", str(args.steps or 200),
            "--batch", "4", "--seq", "512",
            "--ckpt-dir", "/tmp/repro_train_lm_100m", "--lr", "3e-4",
            "--micro", "2",
        ]
    losses = train_mod.main(argv)
    if losses and losses[-1] < losses[0]:
        print("training signal confirmed: loss decreased")
    return 0


if __name__ == "__main__":
    sys.exit(main())
