"""AMBI under a drifting workload: the index refines only where queries go
(paper Figures 6+8), then converges to FMBI once the workload covers space.

    PYTHONPATH=src python examples/adaptive_workload.py
"""
import numpy as np

from repro.core import AMBI, PageStore, bulk_load
from repro.core.datasets import osm_like


def count_unrefined(ambi):
    n = 0
    stack = [ambi.root]
    while stack:
        node = stack.pop()
        if node.is_unrefined:
            n += 1
        elif node.children:
            stack.extend(node.children)
    return n


def main():
    points = osm_like(400_000, seed=0)
    M = 400
    ambi = AMBI(points, M)
    rng = np.random.default_rng(2)

    phases = [
        ("Germany-ish dense cluster", lambda: rng.random(2) * 0.06 + 0.60),
        ("second city",               lambda: rng.random(2) * 0.06 + 0.25),
        ("uniform everywhere",        lambda: rng.random(2) * 0.9 + 0.05),
    ]
    cum = 0
    for name, gen in phases:
        for _ in range(60):
            c = gen()
            _, io = ambi.window(c - 0.02, c + 0.02)
            cum += io.total
        print(f"after '{name}': cumulative I/O {cum:6d}, "
              f"unrefined regions left: {count_unrefined(ambi):3d}, "
              f"fully refined: {ambi.is_fully_refined()}")

    store = PageStore(M)
    bulk_load(points, M, store)
    print(f"\n(for scale: one-shot FMBI build costs {store.stats.total} I/Os)")

    # full coverage converges to the complete index
    for x in np.linspace(0.05, 0.95, 9):
        for y in np.linspace(0.05, 0.95, 9):
            ambi.window(np.array([x - 0.07, y - 0.07]),
                        np.array([x + 0.07, y + 0.07]))
    print(f"after covering sweep: fully refined = {ambi.is_fully_refined()} "
          f"(AMBI -> FMBI, paper Fig 6c)")


if __name__ == "__main__":
    main()
