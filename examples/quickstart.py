"""Quickstart: bulk load FMBI, query it, compare against the sort-based
competitors, and peek at the adaptive variant.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import tempfile

import numpy as np

from repro.core import (
    ALL_LOADERS,
    AMBI,
    Index,
    PageStore,
    bulk_load,
    knn_query,
    leaf_stats,
    window_query,
    window_query_batch,
)
from repro.core.datasets import osm_like


def main():
    print("generating an OSM-like dataset (dense cities, empty oceans)...")
    points = osm_like(300_000, seed=0)
    buffer_pages = 400  # ~4.5% of the dataset's 880 pages

    # ---- full bulk loading (paper Section 3) ----------------------------
    store = PageStore(buffer_pages)
    index = bulk_load(points, buffer_pages, store)
    stats = leaf_stats(index)
    print(f"\nFMBI built with {store.stats.total} page I/Os "
          f"({store.stats.reads} reads / {store.stats.writes} writes)")
    print(f"  leaves={stats.count}  fill={stats.avg_fill:.2f}  "
          f"area={stats.total_area:.4f}  balance={stats.max_over_mean:.3f}")

    # ---- queries ---------------------------------------------------------
    res, io = window_query(index, np.array([0.6, 0.6]),
                           np.array([0.63, 0.63]))
    print(f"\nwindow [0.60,0.63]^2 -> {len(res)} points, {io.total} page I/Os")
    rows, io = knn_query(index, np.array([0.5, 0.5]), 16)
    print(f"16-NN of (0.5,0.5) -> {io.total} page I/Os")

    # ---- vs sort-based competitors ---------------------------------------
    print("\nconstruction cost (page I/O):")
    for name, loader in sorted(ALL_LOADERS.items()):
        st = PageStore(buffer_pages)
        loader(points, buffer_pages, st)
        print(f"  {name:8s} {st.stats.total:7d}")

    # ---- batched queries over the flat node table ------------------------
    rng = np.random.default_rng(1)
    centers = rng.random((32, 2)) * 0.9
    res_b, io_b = window_query_batch(index, centers - 0.02, centers + 0.02)
    print(f"\n32-window batch (one frontier traversal) -> "
          f"{sum(len(r) for r in res_b)} points, {io_b.total} page I/Os")

    # ---- snapshot the flat index (single .npz), reload, query ------------
    t = index.table
    print(f"\nflat node table: {t.n_nodes} rows, {t.n_perm} perm entries")
    with tempfile.TemporaryDirectory() as tmp:
        snap = pathlib.Path(tmp) / "fmbi.npz"
        index.save(snap)
        loaded = Index.load(snap)
        res2, _ = window_query(loaded, np.array([0.6, 0.6]),
                               np.array([0.63, 0.63]))
        same = sorted(res2.tolist()) == sorted(res.tolist())
        print(f"snapshot -> {snap.stat().st_size/1e6:.1f} MB; reloaded index "
              f"answers identically: {same}")

    # ---- adaptive bulk loading (paper Section 4) -------------------------
    ambi = AMBI(points, buffer_pages)
    cum = 0
    for i in range(10):
        c = rng.random(2) * 0.08 + 0.55
        _, io = ambi.window(c - 0.02, c + 0.02)
        cum += io.total
    print(f"\nAMBI: 10 focused windows cost {cum} page I/Os total "
          f"(vs {store.stats.total} for the full FMBI build alone); "
          f"fully refined: {ambi.is_fully_refined()}")


if __name__ == "__main__":
    main()
