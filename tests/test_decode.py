"""Decode == train-forward consistency per family (the serving contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import model as M
from repro.models.sharding import MeshAxes

B, S, TAIL = 2, 32, 4


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def _grow(cache, s0):
    def f(x):
        if x.ndim >= 3 and x.shape[2] == s0:
            pad = jnp.zeros(x.shape[:2] + (TAIL,) + x.shape[3:], x.dtype)
            return jnp.concatenate([x, pad], axis=2)
        if x.ndim >= 2 and x.shape[1] == s0:
            pad = jnp.zeros((x.shape[0], TAIL) + x.shape[2:], x.dtype)
            return jnp.concatenate([x, pad], axis=1)
        return x

    return jax.tree.map(f, cache)


def _check(cfg, mesh, tol=2e-3):
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    params = M.init_params(cfg, jax.random.key(1), jnp.float32)
    axes = MeshAxes()
    with use_mesh(mesh):
        lg_full, _ = M.forward(params, cfg, {"tokens": toks}, axes,
                               mode="train")
        s0 = S - TAIL
        lg_pre, cache = M.prefill(params, cfg, {"tokens": toks[:, :s0]}, axes)
        cache = _grow(cache, s0)
        errs = [float(jnp.max(jnp.abs(lg_pre[:, -1] - lg_full[:, s0 - 1])))]
        for t in range(s0, S):
            lg_t, cache = M.decode_step(
                params, cfg, toks[:, t : t + 1], cache,
                jnp.full((B,), t, jnp.int32), axes,
            )
            errs.append(float(jnp.max(jnp.abs(lg_t[:, 0] - lg_full[:, t]))))
    assert max(errs) < tol, (cfg.name, errs)


def test_dense_gqa_qknorm(mesh):
    _check(ModelConfig(
        name="t-dense", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=100, qk_norm=True, dtype="float32",
        chunk_q=16,
    ), mesh)


def test_local_global_ring_cache(mesh):
    _check(ModelConfig(
        name="t-gemma", family="dense", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=100, local_window=8,
        local_per_global=2, dtype="float32", chunk_q=16,
    ), mesh)


def test_rwkv_state_decode(mesh):
    _check(ModelConfig(
        name="t-rwkv", family="rwkv", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=100, head_dim=16, rwkv_head_dim=16,
        dtype="float32", la_chunk=4,
    ), mesh)


def test_hybrid_mamba_attn_moe(mesh):
    _check(ModelConfig(
        name="t-jamba", family="hybrid", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=100, n_experts=4, moe_top_k=2,
        moe_dff=128, moe_every=2, attn_every=4, mamba_d_state=8,
        mamba_head_dim=16, dtype="float32", la_chunk=4, chunk_q=16,
        capacity_factor=8.0,  # no capacity drops: decode must equal train
    ), mesh)


def test_encdec_decode_with_cross_cache(mesh):
    cfg = ModelConfig(
        name="t-encdec", family="encdec", n_layers=2, encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=100,
        dtype="float32", chunk_q=16, frontend="audio_stub",
    )
    rng = np.random.default_rng(3)
    frames = jnp.asarray(rng.normal(0, 1, (B, S, 64)), jnp.float32)
    dec = jnp.asarray(rng.integers(0, 100, (B, 16)), jnp.int32)
    params = M.init_params(cfg, jax.random.key(2), jnp.float32)
    axes = MeshAxes()
    with use_mesh(mesh):
        lg_full, _ = M.forward(
            params, cfg, {"frames": frames, "tokens": dec}, axes,
            mode="train",
        )
        s0 = 12
        lg_pre, cache = M.prefill(
            params, cfg, {"frames": frames, "tokens": dec[:, :s0]}, axes
        )
        cache = _grow(cache, s0)
        errs = [float(jnp.max(jnp.abs(lg_pre[:, -1] - lg_full[:, s0 - 1])))]
        for t in range(s0, 16):
            lg_t, cache = M.decode_step(
                params, cfg, dec[:, t : t + 1], cache,
                jnp.full((B,), t, jnp.int32), axes,
            )
            errs.append(float(jnp.max(jnp.abs(lg_t[:, 0] - lg_full[:, t]))))
    assert max(errs) < 2e-3, errs
