import numpy as np
import pytest

from repro.core.pagestore import (
    IOStats,
    LRUBuffer,
    PageStore,
    branch_capacity,
    leaf_capacity,
)


def test_paper_capacities_2d():
    # the paper's exact arithmetic for 4 KiB pages, d=2
    assert leaf_capacity(2) == 341
    assert branch_capacity(2) == 204


def test_capacities_monotone_in_d():
    for d in range(2, 8):
        assert leaf_capacity(d) > branch_capacity(d)
        assert leaf_capacity(d + 1) < leaf_capacity(d)


def test_lru_buffer_hits_and_eviction():
    buf = LRUBuffer(2)
    assert not buf.touch(1)
    assert not buf.touch(2)
    assert buf.touch(1)          # hit
    assert not buf.touch(3)      # evicts 2 (LRU)
    assert not buf.touch(2)      # miss again
    assert 1 not in buf          # 1 was evicted when 2 came back


def test_store_counts_reads_writes():
    st = PageStore(buffer_pages=2)
    st.read(10)
    st.read(10)  # buffered: free
    st.write(11)
    assert st.stats.reads == 1
    assert st.stats.writes == 1
    st.read(11)  # freshly written page is resident
    assert st.stats.reads == 1


def test_external_sort_cost_regimes():
    st = PageStore(buffer_pages=100)
    small = st.external_sort_cost(50, 100)     # fits in buffer
    assert small.writes == 0 and small.reads == 50
    big = st.external_sort_cost(10_000, 100)
    # run formation + >=1 merge pass
    assert big.reads >= 2 * 10_000 and big.writes >= 2 * 10_000
    bigger = st.external_sort_cost(1_000_000, 100)
    assert bigger.total > big.total


def test_iostats_algebra():
    a, b = IOStats(1, 2), IOStats(3, 4)
    c = a + b
    assert (c.reads, c.writes, c.total) == (4, 6, 10)
    snap = c.snapshot()
    c.reads += 5
    assert c.delta(snap).reads == 5


# --------------------------------------------------------------------------
# run fast paths: same accounting as the per-page touch loop
# --------------------------------------------------------------------------
def _reference_write_seq(store, first_id, n_pages):
    store.stats.writes += n_pages
    for pid in range(first_id, first_id + n_pages):
        store.buffer.touch(pid)


def _reference_read_many(store, ids):
    for pid in ids:
        store.read(int(pid))


def _buffer_state(store):
    return list(store.buffer._pages.keys())


@pytest.mark.parametrize("cap,n", [(8, 3), (8, 8), (8, 30), (64, 200), (3, 4)])
def test_write_seq_fast_path_matches_reference(cap, n):
    a, b = PageStore(cap), PageStore(cap)
    for st_ in (a, b):  # pre-warm with some resident pages, incl. run overlap
        st_.buffer.touch(2)
        st_.buffer.touch(5)
        st_.buffer.touch(100)
    a.write_seq(4, n)
    _reference_write_seq(b, 4, n)
    assert a.stats.writes == b.stats.writes
    assert _buffer_state(a) == _buffer_state(b)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_read_many_fast_path_matches_reference(seed):
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(4, 32))
    n = int(rng.integers(cap + 1, 6 * cap))
    ids = rng.permutation(10 * cap)[:n]  # distinct, arbitrary order
    warm = rng.integers(0, 10 * cap, 5)
    a, b = PageStore(cap), PageStore(cap)
    for st_ in (a, b):
        for w in warm:
            st_.buffer.touch(int(w))
    a.read_many(ids)
    _reference_read_many(b, ids)
    assert a.stats.reads == b.stats.reads
    assert _buffer_state(a) == _buffer_state(b)


def test_read_many_duplicate_ids_fall_back_to_exact_loop():
    cap = 4
    ids = [1, 2, 3, 4, 5, 1, 2, 9, 9, 1]  # repeats: hits depend on order
    a, b = PageStore(cap), PageStore(cap)
    a.read_many(ids)
    _reference_read_many(b, ids)
    assert a.stats.reads == b.stats.reads
    assert _buffer_state(a) == _buffer_state(b)


# --------------------------------------------------------------------------
# free-list recycling (PR-9 satellite): tier retirement must not leak ids
# --------------------------------------------------------------------------
def test_free_list_first_fit_reuse_and_coalescing():
    st = PageStore(8)
    a = st.alloc(4)          # [0, 4)
    st.alloc(4)              # [4, 8)
    c = st.alloc(2)          # [8, 10)
    st.free_range(a, 4)
    st.free_range(c, 2)
    assert st.free_page_count == 6
    assert st.allocated_pages == 10
    assert st.live_pages == 4
    assert st.alloc(3) == 0  # first fit inside the [0, 4) run
    assert st.alloc(1) == 3  # its remainder
    assert st.alloc(2) == 8  # next fitting run
    assert st.allocated_pages == 10, "high-water advanced despite free pages"
    st.free_range(4, 2)
    st.free_range(6, 2)      # adjacent runs coalesce
    assert st._free == [[4, 4]]
    assert st.alloc(4) == 4
    assert st.free_page_count == 0


def test_recycled_page_ids_charge_like_fresh_ids():
    """IOStats parity: a store that frees and re-allocates the same ids must
    charge exactly what a store using only fresh ids charges — freeing
    evicts the pages, so a recycled id's first read is a miss, never a
    buffer hit inherited from the retired owner."""
    recycled, fresh = PageStore(8), PageStore(8)
    a = recycled.alloc(3)
    recycled.read_many(range(a, a + 3))
    recycled.free_range(a, 3)
    a2 = recycled.alloc(3)
    assert a2 == a           # ids really were recycled
    recycled.read_many(range(a2, a2 + 3))

    f1 = fresh.alloc(3)
    fresh.read_many(range(f1, f1 + 3))
    f2 = fresh.alloc(3)      # distinct ids: misses by construction
    fresh.read_many(range(f2, f2 + 3))

    assert recycled.stats.reads == fresh.stats.reads == 6
    assert recycled.stats.writes == fresh.stats.writes


def test_state_dict_roundtrip_preserves_free_runs():
    st = PageStore(4)
    st.alloc(6)
    st.free_range(1, 2)
    st.free_range(4, 1)
    st2 = PageStore(1)
    st2.load_state(st.state_dict())
    assert st2._free == st._free == [[1, 2], [4, 1]]
    assert st2.free_page_count == 3
    assert st2.alloc(2) == 1  # allocator behaviour survives the round-trip
    # legacy snapshots without the key load with an empty free list
    legacy = st.state_dict()
    legacy.pop("free_runs")
    st3 = PageStore(1)
    st3.load_state(legacy)
    assert st3.free_page_count == 0
