import numpy as np
import pytest

from repro.core.pagestore import (
    IOStats,
    LRUBuffer,
    PageStore,
    branch_capacity,
    leaf_capacity,
)


def test_paper_capacities_2d():
    # the paper's exact arithmetic for 4 KiB pages, d=2
    assert leaf_capacity(2) == 341
    assert branch_capacity(2) == 204


def test_capacities_monotone_in_d():
    for d in range(2, 8):
        assert leaf_capacity(d) > branch_capacity(d)
        assert leaf_capacity(d + 1) < leaf_capacity(d)


def test_lru_buffer_hits_and_eviction():
    buf = LRUBuffer(2)
    assert not buf.touch(1)
    assert not buf.touch(2)
    assert buf.touch(1)          # hit
    assert not buf.touch(3)      # evicts 2 (LRU)
    assert not buf.touch(2)      # miss again
    assert 1 not in buf          # 1 was evicted when 2 came back


def test_store_counts_reads_writes():
    st = PageStore(buffer_pages=2)
    st.read(10)
    st.read(10)  # buffered: free
    st.write(11)
    assert st.stats.reads == 1
    assert st.stats.writes == 1
    st.read(11)  # freshly written page is resident
    assert st.stats.reads == 1


def test_external_sort_cost_regimes():
    st = PageStore(buffer_pages=100)
    small = st.external_sort_cost(50, 100)     # fits in buffer
    assert small.writes == 0 and small.reads == 50
    big = st.external_sort_cost(10_000, 100)
    # run formation + >=1 merge pass
    assert big.reads >= 2 * 10_000 and big.writes >= 2 * 10_000
    bigger = st.external_sort_cost(1_000_000, 100)
    assert bigger.total > big.total


def test_iostats_algebra():
    a, b = IOStats(1, 2), IOStats(3, 4)
    c = a + b
    assert (c.reads, c.writes, c.total) == (4, 6, 10)
    snap = c.snapshot()
    c.reads += 5
    assert c.delta(snap).reads == 5
