import numpy as np
import pytest

from repro.core.pagestore import (
    IOStats,
    LRUBuffer,
    PageStore,
    branch_capacity,
    leaf_capacity,
)


def test_paper_capacities_2d():
    # the paper's exact arithmetic for 4 KiB pages, d=2
    assert leaf_capacity(2) == 341
    assert branch_capacity(2) == 204


def test_capacities_monotone_in_d():
    for d in range(2, 8):
        assert leaf_capacity(d) > branch_capacity(d)
        assert leaf_capacity(d + 1) < leaf_capacity(d)


def test_lru_buffer_hits_and_eviction():
    buf = LRUBuffer(2)
    assert not buf.touch(1)
    assert not buf.touch(2)
    assert buf.touch(1)          # hit
    assert not buf.touch(3)      # evicts 2 (LRU)
    assert not buf.touch(2)      # miss again
    assert 1 not in buf          # 1 was evicted when 2 came back


def test_store_counts_reads_writes():
    st = PageStore(buffer_pages=2)
    st.read(10)
    st.read(10)  # buffered: free
    st.write(11)
    assert st.stats.reads == 1
    assert st.stats.writes == 1
    st.read(11)  # freshly written page is resident
    assert st.stats.reads == 1


def test_external_sort_cost_regimes():
    st = PageStore(buffer_pages=100)
    small = st.external_sort_cost(50, 100)     # fits in buffer
    assert small.writes == 0 and small.reads == 50
    big = st.external_sort_cost(10_000, 100)
    # run formation + >=1 merge pass
    assert big.reads >= 2 * 10_000 and big.writes >= 2 * 10_000
    bigger = st.external_sort_cost(1_000_000, 100)
    assert bigger.total > big.total


def test_iostats_algebra():
    a, b = IOStats(1, 2), IOStats(3, 4)
    c = a + b
    assert (c.reads, c.writes, c.total) == (4, 6, 10)
    snap = c.snapshot()
    c.reads += 5
    assert c.delta(snap).reads == 5


# --------------------------------------------------------------------------
# run fast paths: same accounting as the per-page touch loop
# --------------------------------------------------------------------------
def _reference_write_seq(store, first_id, n_pages):
    store.stats.writes += n_pages
    for pid in range(first_id, first_id + n_pages):
        store.buffer.touch(pid)


def _reference_read_many(store, ids):
    for pid in ids:
        store.read(int(pid))


def _buffer_state(store):
    return list(store.buffer._pages.keys())


@pytest.mark.parametrize("cap,n", [(8, 3), (8, 8), (8, 30), (64, 200), (3, 4)])
def test_write_seq_fast_path_matches_reference(cap, n):
    a, b = PageStore(cap), PageStore(cap)
    for st_ in (a, b):  # pre-warm with some resident pages, incl. run overlap
        st_.buffer.touch(2)
        st_.buffer.touch(5)
        st_.buffer.touch(100)
    a.write_seq(4, n)
    _reference_write_seq(b, 4, n)
    assert a.stats.writes == b.stats.writes
    assert _buffer_state(a) == _buffer_state(b)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_read_many_fast_path_matches_reference(seed):
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(4, 32))
    n = int(rng.integers(cap + 1, 6 * cap))
    ids = rng.permutation(10 * cap)[:n]  # distinct, arbitrary order
    warm = rng.integers(0, 10 * cap, 5)
    a, b = PageStore(cap), PageStore(cap)
    for st_ in (a, b):
        for w in warm:
            st_.buffer.touch(int(w))
    a.read_many(ids)
    _reference_read_many(b, ids)
    assert a.stats.reads == b.stats.reads
    assert _buffer_state(a) == _buffer_state(b)


def test_read_many_duplicate_ids_fall_back_to_exact_loop():
    cap = 4
    ids = [1, 2, 3, 4, 5, 1, 2, 9, 9, 1]  # repeats: hits depend on order
    a, b = PageStore(cap), PageStore(cap)
    a.read_many(ids)
    _reference_read_many(b, ids)
    assert a.stats.reads == b.stats.reads
    assert _buffer_state(a) == _buffer_state(b)
