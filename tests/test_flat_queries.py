"""Flat (NodeTable) query engines vs the PR-1 object-graph references.

The PR-2 query layer traverses the flat node table (level-synchronous
frontiers, DFS-order read replay).  These tests retain the PR-1 object-graph
implementations verbatim — they run unchanged over the read-only ``NodeView``
graph — and assert the flat engines return identical results AND charge
bit-identical ``IOStats`` per query, window and k-NN, single and batched.
Two identically seeded builds are used so both sides start from identical
LRU buffer states.
"""
import heapq
import itertools

import numpy as np
import pytest

from repro.core import (
    PageStore,
    bulk_load,
    knn_query,
    knn_query_batch,
    window_query,
    window_query_batch,
)
from repro.core.datasets import gaussian, osm_like
from repro.core.queries import _merge_topk, mbb_intersects, mindist_sq


# --------------------------------------------------------------------------
# PR-1 reference implementations (object-graph traversal, verbatim)
# --------------------------------------------------------------------------
def window_ref(index, lo, hi):
    store = index.store
    before = store.stats.snapshot()
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    out = []
    stack = [index.root]
    while stack:
        node = stack.pop()
        if not mbb_intersects(node.mbb, lo, hi):
            continue
        store.read(node.page_id)
        if node.is_leaf:
            pts = index.points[node.point_idx]
            mask = np.all((pts >= lo) & (pts <= hi), axis=1)
            if mask.any():
                out.append(node.point_idx[mask])
        else:
            stack.extend(node.children)
    res = np.concatenate(out) if out else np.zeros(0, dtype=np.int64)
    return res, store.stats.delta(before)


def window_batch_ref(index, los, his):
    store = index.store
    before = store.stats.snapshot()
    los = np.atleast_2d(np.asarray(los, dtype=np.float64))
    his = np.atleast_2d(np.asarray(his, dtype=np.float64))
    nq = los.shape[0]
    out = [[] for _ in range(nq)]
    stack = [(index.root, np.arange(nq))]
    while stack:
        node, qids = stack.pop()
        hit = np.all(node.mbb[0] <= his[qids], axis=1) & np.all(
            node.mbb[1] >= los[qids], axis=1
        )
        if not hit.any():
            continue
        qids = qids[hit]
        store.read(node.page_id)
        if node.is_leaf:
            pts = index.points[node.point_idx]
            inside = np.all(
                (pts[None, :, :] >= los[qids, None, :])
                & (pts[None, :, :] <= his[qids, None, :]),
                axis=2,
            )
            for qi, m in zip(qids, inside):
                if m.any():
                    out[qi].append(node.point_idx[m])
        else:
            stack.extend((c, qids) for c in node.children)
    res = [np.concatenate(o) if o else np.zeros(0, dtype=np.int64) for o in out]
    return res, store.stats.delta(before)


def knn_ref(index, q, k):
    store = index.store
    before = store.stats.snapshot()
    q = np.asarray(q, dtype=np.float64)
    counter = itertools.count()
    heap = [(0.0, next(counter), index.root)]
    best_d = np.full(0, np.inf)
    best_r = np.zeros(0, dtype=np.int64)
    while heap:
        dist, _, node = heapq.heappop(heap)
        kth = best_d.max() if len(best_d) == k else np.inf
        if dist > kth:
            break
        store.read(node.page_id)
        if node.is_leaf:
            pts = index.points[node.point_idx]
            d2 = np.sum((pts - q) ** 2, axis=1)
            best_d, best_r = _merge_topk(best_d, best_r, d2, node.point_idx, k)
        else:
            kth = best_d.max() if len(best_d) == k else np.inf
            for c in node.children:
                md = mindist_sq(c.mbb, q)
                if md <= kth:
                    heapq.heappush(heap, (md, next(counter), c))
    order = np.argsort(best_d, kind="stable")
    return best_r[order], store.stats.delta(before)


def knn_batch_ref(index, qs, k):
    store = index.store
    before = store.stats.snapshot()
    qs = np.atleast_2d(np.asarray(qs, dtype=np.float64))
    leaves = []
    stack = [index.root]
    while stack:
        node = stack.pop()
        if node.is_leaf:
            leaves.append(node)
        else:
            store.read(node.page_id)
            stack.extend(node.children)
    leaf_lo = np.stack([l.mbb[0] for l in leaves])
    leaf_hi = np.stack([l.mbb[1] for l in leaves])
    results = []
    for q in qs:
        gap = np.maximum(leaf_lo - q, 0.0) + np.maximum(q - leaf_hi, 0.0)
        mind = np.sum(gap * gap, axis=1)
        order = np.argsort(mind, kind="stable")
        best_d = np.full(0, np.inf)
        best_r = np.zeros(0, dtype=np.int64)
        for li in order:
            if len(best_d) == k and mind[li] > best_d.max():
                break
            leaf = leaves[li]
            store.read(leaf.page_id)
            pts = index.points[leaf.point_idx]
            d2 = np.sum((pts - q) ** 2, axis=1)
            best_d, best_r = _merge_topk(best_d, best_r, d2, leaf.point_idx, k)
        results.append(best_r[np.argsort(best_d, kind="stable")])
    return results, store.stats.delta(before)


# --------------------------------------------------------------------------
# fixtures: two identically built indexes -> identical starting LRU states
# --------------------------------------------------------------------------
def _pair(dataset, M):
    pts = dataset()
    return pts, bulk_load(pts, M, PageStore(M)), bulk_load(pts, M, PageStore(M))


@pytest.fixture(scope="module", params=["osm", "gauss-dense"])
def pair(request):
    if request.param == "osm":
        return _pair(lambda: osm_like(80_000, seed=9), 250)
    # tiny buffer forces the Step-5 dense recursion: a deeper, messier tree
    return _pair(lambda: gaussian(60_000, 2, seed=5), 230)


def _io(io):
    return (io.reads, io.writes)


def test_window_flat_matches_reference_io(pair):
    pts, a, b = pair
    rng = np.random.default_rng(4)
    for _ in range(30):
        c = rng.random(2)
        w = rng.uniform(0.005, 0.1)
        res_r, io_r = window_ref(a, c - w, c + w)
        res_f, io_f = window_query(b, c - w, c + w)
        assert sorted(res_r.tolist()) == sorted(res_f.tolist())
        assert _io(io_r) == _io(io_f)


def test_window_batch_flat_matches_reference_io(pair):
    pts, a, b = pair
    rng = np.random.default_rng(5)
    for _ in range(4):
        c = rng.random((16, 2)) * 0.9
        w = rng.uniform(0.01, 0.06, (16, 1))
        res_r, io_r = window_batch_ref(a, c - w, c + w)
        res_f, io_f = window_query_batch(b, c - w, c + w)
        for x, y in zip(res_r, res_f):
            assert sorted(x.tolist()) == sorted(y.tolist())
        assert _io(io_r) == _io(io_f)


def test_knn_flat_matches_reference_io(pair):
    pts, a, b = pair
    rng = np.random.default_rng(6)
    for k in (1, 8, 32):
        for _ in range(8):
            q = rng.random(2)
            res_r, io_r = knn_ref(a, q, k)
            res_f, io_f = knn_query(b, q, k)
            np.testing.assert_array_equal(res_r, res_f)
            assert _io(io_r) == _io(io_f)


def test_knn_batch_flat_matches_reference_io(pair):
    pts, a, b = pair
    rng = np.random.default_rng(7)
    qs = rng.random((12, 2))
    for k in (1, 16):
        res_r, io_r = knn_batch_ref(a, qs, k)
        res_f, io_f = knn_query_batch(b, qs, k)
        for x, y in zip(res_r, res_f):
            np.testing.assert_array_equal(x, y)
        assert _io(io_r) == _io(io_f)


def test_mixed_stream_keeps_lru_in_lockstep(pair):
    """Interleaved windows and k-NNs share one evolving LRU buffer; the
    engines must stay I/O-identical across the whole stream, not just on a
    cold cache."""
    pts, a, b = pair
    rng = np.random.default_rng(8)
    for i in range(40):
        if i % 2 == 0:
            c = rng.random(2)
            _, io_r = window_ref(a, c - 0.03, c + 0.03)
            _, io_f = window_query(b, c - 0.03, c + 0.03)
        else:
            q = rng.random(2)
            _, io_r = knn_ref(a, q, 16)
            _, io_f = knn_query(b, q, 16)
        assert _io(io_r) == _io(io_f)
