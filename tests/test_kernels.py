"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jax_index
from repro.kernels import ops


def _index(n, d, levels, seed):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, d)).astype(np.float32)
    padded, ids = jax_index.pad_points(pts, levels)
    return pts, jax_index.build(
        jnp.asarray(padded), levels, jnp.asarray(ids, jnp.int32)
    )


@pytest.mark.parametrize("d", [2, 3, 5])
@pytest.mark.parametrize("levels", [3, 6])
@pytest.mark.parametrize("tile", [64, 256])
def test_partition_assign_matches_ref(d, levels, tile):
    pts, idx = _index(1 << (levels + 3), d, levels, seed=d * 10 + levels)
    rng = np.random.default_rng(99)
    q = rng.random((777, d)).astype(np.float32)  # ragged: exercises padding
    got = ops.partition_assign(
        q, idx.split_dim, idx.split_val, levels=levels, tile=tile
    )
    want = ops.partition_assign_ref(
        jnp.asarray(q), idx.split_dim, idx.split_val, levels=levels
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d", [2, 4, 8])
@pytest.mark.parametrize("qt,pt", [(64, 128), (128, 512)])
def test_pairwise_dist2_matches_ref(d, qt, pt):
    rng = np.random.default_rng(d)
    q = rng.normal(0, 1, (200, d)).astype(np.float32)
    p = rng.normal(0, 1, (900, d)).astype(np.float32)
    valid = (rng.random(900) > 0.1).astype(np.int32)
    got = ops.pairwise_dist2(q, p, valid, qt=qt, pt=pt)
    want = ops.pairwise_dist2_ref(
        jnp.asarray(q), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("k", [1, 8, 33])
def test_knn_topk_matches_ref(k):
    rng = np.random.default_rng(k)
    q = rng.normal(0, 1, (64, 3)).astype(np.float32)
    p = rng.normal(0, 1, (512, 3)).astype(np.float32)
    valid = np.ones(512, np.int32)
    valid[500:] = 0
    gi, gd = ops.knn_topk(q, p, k, valid=valid, qt=64, pt=128)
    ri, rd = ops.knn_topk_ref(
        jnp.asarray(q), jnp.asarray(p), jnp.asarray(valid), k
    )
    np.testing.assert_allclose(
        np.sort(np.asarray(gd)), np.sort(np.asarray(rd)), rtol=1e-4,
        atol=1e-6,
    )
    # masked points never appear
    assert np.all(np.asarray(gi) < 500)


def test_kernel_route_agrees_with_index_route():
    pts, idx = _index(2048, 3, 5, seed=4)
    q = np.random.default_rng(1).random((512, 3)).astype(np.float32)
    a = ops.partition_assign(q, idx.split_dim, idx.split_val, levels=5)
    b = jax_index.route(idx, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("d", [2, 4])
@pytest.mark.parametrize("qt,pt", [(64, 128), (128, 512)])
def test_window_count_tiles_matches_ref(d, qt, pt):
    rng = np.random.default_rng(d * 7 + qt)
    lo = rng.random((150, d)).astype(np.float32) * 0.8  # ragged: padding
    hi = lo + rng.uniform(0.05, 0.4, (150, d)).astype(np.float32)
    p = rng.random((900, d)).astype(np.float32)
    valid = (rng.random(900) > 0.15).astype(np.int32)
    got = ops.window_count(lo, hi, p, valid, qt=qt, pt=pt)
    want = ops.window_count_ref(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).sum() > 0  # non-degenerate case


@pytest.mark.parametrize("pt", [128, 512])
def test_window_count_gathered_matches_ref(pt):
    rng = np.random.default_rng(pt)
    nq, npp, d = 13, 300, 3  # ragged candidate axis: exercises padding
    lo = rng.random((nq, d)).astype(np.float32) * 0.7
    hi = lo + 0.3
    p = rng.random((nq, npp, d)).astype(np.float32)
    valid = (rng.random((nq, npp)) > 0.1).astype(np.int32)
    got = ops.window_count_gathered(lo, hi, p, valid, pt=pt)
    want = ops.window_count_gathered_ref(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("pt", [128, 512])
def test_window_mask_gathered_matches_ref(pt):
    """Collection variant: the per-candidate mask, not just its sum."""
    rng = np.random.default_rng(pt + 1)
    nq, npp, d = 11, 300, 2  # ragged candidate axis: exercises padding
    lo = rng.random((nq, d)).astype(np.float32) * 0.7
    hi = lo + 0.3
    p = rng.random((nq, npp, d)).astype(np.float32)
    valid = (rng.random((nq, npp)) > 0.1).astype(np.int32)
    got = ops.window_mask_gathered(lo, hi, p, valid, pt=pt)
    want = ops.window_mask_gathered_ref(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # mask sums agree with the counting kernel
    cnt = ops.window_count_gathered(lo, hi, p, valid, pt=pt)
    np.testing.assert_array_equal(
        np.asarray(got).sum(axis=1), np.asarray(cnt)
    )


@pytest.mark.parametrize("pt", [128, 512])
@pytest.mark.parametrize("d", [2, 5])
def test_gathered_dist2_matches_ref(pt, d):
    rng = np.random.default_rng(pt * 3 + d)
    nq, npp = 9, 275  # ragged candidate axis: exercises padding
    q = rng.normal(0, 1, (nq, d)).astype(np.float32)
    p = rng.normal(0, 1, (nq, npp, d)).astype(np.float32)
    valid = (rng.random((nq, npp)) > 0.2).astype(np.int32)
    got = ops.gathered_dist2(q, p, valid, pt=pt)
    want = ops.gathered_dist2_ref(
        jnp.asarray(q), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )
    big = np.finfo(np.float32).max
    assert np.all(np.asarray(got)[valid == 0] == big)


def test_knn_topk_query_chunking_matches_unchunked():
    """The memory-capped (chunked) path returns the unchunked answer."""
    rng = np.random.default_rng(3)
    q = rng.normal(0, 1, (70, 3)).astype(np.float32)
    p = rng.normal(0, 1, (256, 3)).astype(np.float32)
    gi, gd = ops.knn_topk(q, p, 5, qt=64, pt=128)
    ci, cd = ops.knn_topk(q, p, 5, qt=64, pt=128, query_chunk=16)
    np.testing.assert_allclose(np.asarray(cd), np.asarray(gd), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(gi))


def test_dist2_dtype_f32_output_for_bf16_inputs():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (64, 4)), jnp.bfloat16)
    p = jnp.asarray(rng.normal(0, 1, (128, 4)), jnp.bfloat16)
    out = ops.pairwise_dist2(q, p, qt=64, pt=128)
    assert out.dtype == jnp.float32
