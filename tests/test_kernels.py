"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jax_index
from repro.kernels import ops


def _index(n, d, levels, seed):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, d)).astype(np.float32)
    padded, ids = jax_index.pad_points(pts, levels)
    return pts, jax_index.build(
        jnp.asarray(padded), levels, jnp.asarray(ids, jnp.int32)
    )


@pytest.mark.parametrize("d", [2, 3, 5])
@pytest.mark.parametrize("levels", [3, 6])
@pytest.mark.parametrize("tile", [64, 256])
def test_partition_assign_matches_ref(d, levels, tile):
    pts, idx = _index(1 << (levels + 3), d, levels, seed=d * 10 + levels)
    rng = np.random.default_rng(99)
    q = rng.random((777, d)).astype(np.float32)  # ragged: exercises padding
    got = ops.partition_assign(
        q, idx.split_dim, idx.split_val, levels=levels, tile=tile
    )
    want = ops.partition_assign_ref(
        jnp.asarray(q), idx.split_dim, idx.split_val, levels=levels
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d", [2, 4, 8])
@pytest.mark.parametrize("qt,pt", [(64, 128), (128, 512)])
def test_pairwise_dist2_matches_ref(d, qt, pt):
    rng = np.random.default_rng(d)
    q = rng.normal(0, 1, (200, d)).astype(np.float32)
    p = rng.normal(0, 1, (900, d)).astype(np.float32)
    valid = (rng.random(900) > 0.1).astype(np.int32)
    got = ops.pairwise_dist2(q, p, valid, qt=qt, pt=pt)
    want = ops.pairwise_dist2_ref(
        jnp.asarray(q), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("k", [1, 8, 33])
def test_knn_topk_matches_ref(k):
    rng = np.random.default_rng(k)
    q = rng.normal(0, 1, (64, 3)).astype(np.float32)
    p = rng.normal(0, 1, (512, 3)).astype(np.float32)
    valid = np.ones(512, np.int32)
    valid[500:] = 0
    gi, gd = ops.knn_topk(q, p, k, valid=valid, qt=64, pt=128)
    ri, rd = ops.knn_topk_ref(
        jnp.asarray(q), jnp.asarray(p), jnp.asarray(valid), k
    )
    np.testing.assert_allclose(
        np.sort(np.asarray(gd)), np.sort(np.asarray(rd)), rtol=1e-4,
        atol=1e-6,
    )
    # masked points never appear
    assert np.all(np.asarray(gi) < 500)


def test_kernel_route_agrees_with_index_route():
    pts, idx = _index(2048, 3, 5, seed=4)
    q = np.random.default_rng(1).random((512, 3)).astype(np.float32)
    a = ops.partition_assign(q, idx.split_dim, idx.split_val, levels=5)
    b = jax_index.route(idx, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("d", [2, 4])
@pytest.mark.parametrize("qt,pt", [(64, 128), (128, 512)])
def test_window_count_tiles_matches_ref(d, qt, pt):
    rng = np.random.default_rng(d * 7 + qt)
    lo = rng.random((150, d)).astype(np.float32) * 0.8  # ragged: padding
    hi = lo + rng.uniform(0.05, 0.4, (150, d)).astype(np.float32)
    p = rng.random((900, d)).astype(np.float32)
    valid = (rng.random(900) > 0.15).astype(np.int32)
    got = ops.window_count(lo, hi, p, valid, qt=qt, pt=pt)
    want = ops.window_count_ref(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).sum() > 0  # non-degenerate case


@pytest.mark.parametrize("pt", [128, 512])
def test_window_count_gathered_matches_ref(pt):
    rng = np.random.default_rng(pt)
    nq, npp, d = 13, 300, 3  # ragged candidate axis: exercises padding
    lo = rng.random((nq, d)).astype(np.float32) * 0.7
    hi = lo + 0.3
    p = rng.random((nq, npp, d)).astype(np.float32)
    valid = (rng.random((nq, npp)) > 0.1).astype(np.int32)
    got = ops.window_count_gathered(lo, hi, p, valid, pt=pt)
    want = ops.window_count_gathered_ref(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("pt", [128, 512])
def test_window_mask_gathered_matches_ref(pt):
    """Collection variant: the per-candidate mask, not just its sum."""
    rng = np.random.default_rng(pt + 1)
    nq, npp, d = 11, 300, 2  # ragged candidate axis: exercises padding
    lo = rng.random((nq, d)).astype(np.float32) * 0.7
    hi = lo + 0.3
    p = rng.random((nq, npp, d)).astype(np.float32)
    valid = (rng.random((nq, npp)) > 0.1).astype(np.int32)
    got = ops.window_mask_gathered(lo, hi, p, valid, pt=pt)
    want = ops.window_mask_gathered_ref(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # mask sums agree with the counting kernel
    cnt = ops.window_count_gathered(lo, hi, p, valid, pt=pt)
    np.testing.assert_array_equal(
        np.asarray(got).sum(axis=1), np.asarray(cnt)
    )


@pytest.mark.parametrize("pt", [128, 512])
@pytest.mark.parametrize("d", [2, 5])
def test_gathered_dist2_matches_ref(pt, d):
    rng = np.random.default_rng(pt * 3 + d)
    nq, npp = 9, 275  # ragged candidate axis: exercises padding
    q = rng.normal(0, 1, (nq, d)).astype(np.float32)
    p = rng.normal(0, 1, (nq, npp, d)).astype(np.float32)
    valid = (rng.random((nq, npp)) > 0.2).astype(np.int32)
    got = ops.gathered_dist2(q, p, valid, pt=pt)
    want = ops.gathered_dist2_ref(
        jnp.asarray(q), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )
    big = np.finfo(np.float32).max
    assert np.all(np.asarray(got)[valid == 0] == big)


def test_knn_topk_query_chunking_matches_unchunked():
    """The memory-capped (chunked) path returns the unchunked answer."""
    rng = np.random.default_rng(3)
    q = rng.normal(0, 1, (70, 3)).astype(np.float32)
    p = rng.normal(0, 1, (256, 3)).astype(np.float32)
    gi, gd = ops.knn_topk(q, p, 5, qt=64, pt=128)
    ci, cd = ops.knn_topk(q, p, 5, qt=64, pt=128, query_chunk=16)
    np.testing.assert_allclose(np.asarray(cd), np.asarray(gd), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(gi))


def test_dist2_dtype_f32_output_for_bf16_inputs():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (64, 4)), jnp.bfloat16)
    p = jnp.asarray(rng.normal(0, 1, (128, 4)), jnp.bfloat16)
    out = ops.pairwise_dist2(q, p, qt=64, pt=128)
    assert out.dtype == jnp.float32


# --------------------------------------------------------------------------
# PR-7 fused tiled kernels: frontier box test + pair-scan family
# --------------------------------------------------------------------------
@pytest.mark.parametrize("d", [2, 4])
@pytest.mark.parametrize("box_dtype", [jnp.float32, jnp.bfloat16])
def test_box_hits_tiled_matches_ref(d, box_dtype):
    rng = np.random.default_rng(d * 13)
    n, nq = 150, 77  # both axes ragged: exercises inverted-box padding
    lo = rng.random((n, d)).astype(np.float32) * 0.8
    hi = lo + rng.uniform(0.02, 0.3, (n, d)).astype(np.float32)
    qlo = rng.random((nq, d)).astype(np.float32) * 0.8
    qhi = qlo + rng.uniform(0.02, 0.3, (nq, d)).astype(np.float32)
    lo_c, hi_c = jnp.asarray(lo, box_dtype), jnp.asarray(hi, box_dtype)
    got = ops.box_hits_tiled(lo_c, hi_c, qlo, qhi)
    want = ops.box_hits_tiled_ref(
        lo_c, hi_c, jnp.asarray(qlo), jnp.asarray(qhi)
    )
    assert got.shape == (n, nq)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).sum() > 0


def _pair_workload(seed, p=37, n_l=12, s=64, d=3):
    """A (query, leaf) pair workload with ragged leaves and padding pairs."""
    rng = np.random.default_rng(seed)
    leaf_pts = rng.random((n_l, s, d)).astype(np.float32)
    leaf_counts = rng.integers(1, s + 1, n_l).astype(np.int32)
    big = np.finfo(np.float32).max
    for l in range(n_l):  # dead slots: sentinel coords + id -1
        leaf_pts[l, leaf_counts[l]:] = big
    leaf_ids = np.arange(n_l * s, dtype=np.int32).reshape(n_l, s)
    leaf_ids[np.arange(s)[None, :] >= leaf_counts[:, None]] = -1
    leaf_lo = leaf_pts.min(axis=1)
    leaf_hi = np.where(
        np.arange(s)[None, :, None] < leaf_counts[:, None, None],
        leaf_pts, -big,
    ).max(axis=1)
    nq = 9
    qlo = rng.random((nq, d)).astype(np.float32) * 0.6
    qhi = qlo + 0.35
    q_idx = rng.integers(0, nq, p).astype(np.int32)
    leaf_idx = rng.integers(0, n_l, p).astype(np.int32)
    pair_valid = (rng.random(p) > 0.2).astype(np.int32)
    return (qlo, qhi, leaf_lo, leaf_hi, leaf_pts, leaf_ids, leaf_counts,
            q_idx, leaf_idx, pair_valid)


@pytest.mark.parametrize("seed", [0, 7])
def test_pair_window_ids_matches_ref(seed):
    w = _pair_workload(seed)
    gi, gc = ops.pair_window_ids(*[jnp.asarray(x) for x in w])
    ri, rc = ops.pair_window_ids_ref(*[jnp.asarray(x) for x in w])
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(rc))
    # invalid pairs contribute nothing
    pv = w[-1]
    assert np.all(np.asarray(gi)[pv == 0] == -1)
    assert np.all(np.asarray(gc)[pv == 0] == 0)
    # counts agree with the id matrix
    np.testing.assert_array_equal(
        (np.asarray(gi) >= 0).sum(axis=1), np.asarray(gc)
    )


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("box_dtype", [jnp.float32, jnp.bfloat16])
def test_leaf_mindist_tiled_matches_ref(d, box_dtype):
    rng = np.random.default_rng(d * 31)
    nq, n_l = 21, 90  # ragged axes: degenerate far-box padding
    q = rng.random((nq, d)).astype(np.float32)
    lo = rng.random((n_l, d)).astype(np.float32) * 0.8
    hi = lo + rng.uniform(0.02, 0.2, (n_l, d)).astype(np.float32)
    lo_c, hi_c = jnp.asarray(lo, box_dtype), jnp.asarray(hi, box_dtype)
    got = ops.leaf_mindist_tiled(q, lo_c, hi_c)
    want = ops.leaf_mindist_ref(jnp.asarray(q), lo_c, hi_c)
    assert got.shape == (nq, n_l)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-6, atol=0
    )
    # inside-the-box queries have exactly zero mindist
    assert (np.asarray(got) == 0).any()


@pytest.mark.parametrize("seed", [1, 5])
def test_pair_dist2_matches_ref(seed):
    (qlo, _, _, _, leaf_pts, _, leaf_counts, q_idx, leaf_idx,
     _) = _pair_workload(seed)
    q = qlo  # any query coordinates do
    got = ops.pair_dist2(q, leaf_pts, leaf_counts, q_idx, leaf_idx)
    want = ops.pair_dist2_ref(
        jnp.asarray(q), jnp.asarray(leaf_pts), jnp.asarray(leaf_counts),
        jnp.asarray(q_idx), jnp.asarray(leaf_idx),
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-6, atol=0
    )
    # dead slots carry the f32-max sentinel, never a finite distance
    s = leaf_pts.shape[1]
    dead = np.arange(s)[None, :] >= leaf_counts[leaf_idx][:, None]
    assert np.all(np.asarray(got)[dead] == np.finfo(np.float32).max)


def test_box_hits_tiled_compiled_matches_interpret():
    """Interpret mode is the oracle everywhere; on a TPU backend the
    compiled (Mosaic) lowering must agree with it bit-for-bit.  On CPU
    the compiled leg is a no-op and the interpret-vs-ref assertion
    carries the test."""
    rng = np.random.default_rng(0)
    lo = rng.random((200, 3)).astype(np.float32) * 0.8
    hi = lo + 0.1
    qlo = rng.random((64, 3)).astype(np.float32) * 0.8
    qhi = qlo + 0.1
    b = ops.box_hits_tiled(lo, hi, qlo, qhi, interpret=True)
    want = ops.box_hits_tiled_ref(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(qlo),
        jnp.asarray(qhi),
    )
    np.testing.assert_array_equal(np.asarray(b), np.asarray(want))
    if ops.compiled_supported():
        a = ops.box_hits_tiled(lo, hi, qlo, qhi, interpret=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_vmem_tiles_respect_budget():
    from repro.kernels.window_filter import VMEM_TILE_BUDGET, vmem_tiles

    for n, q, d, b in [(100_000, 64, 2, 4), (5000, 1024, 16, 4),
                       (128, 8, 3, 2)]:
        nt, qt = vmem_tiles(n, q, d, in_bytes=b)
        assert nt >= 8 and qt >= 8
        block = 2 * nt * d * b + 2 * qt * d * 4 + nt * qt * 4
        assert block <= VMEM_TILE_BUDGET or (nt, qt) == (8, 8)
