"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jax_index
from repro.kernels import ops


def _index(n, d, levels, seed):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, d)).astype(np.float32)
    padded, ids = jax_index.pad_points(pts, levels)
    return pts, jax_index.build(
        jnp.asarray(padded), levels, jnp.asarray(ids, jnp.int32)
    )


@pytest.mark.parametrize("d", [2, 3, 5])
@pytest.mark.parametrize("levels", [3, 6])
@pytest.mark.parametrize("tile", [64, 256])
def test_partition_assign_matches_ref(d, levels, tile):
    pts, idx = _index(1 << (levels + 3), d, levels, seed=d * 10 + levels)
    rng = np.random.default_rng(99)
    q = rng.random((777, d)).astype(np.float32)  # ragged: exercises padding
    got = ops.partition_assign(
        q, idx.split_dim, idx.split_val, levels=levels, tile=tile
    )
    want = ops.partition_assign_ref(
        jnp.asarray(q), idx.split_dim, idx.split_val, levels=levels
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("d", [2, 4, 8])
@pytest.mark.parametrize("qt,pt", [(64, 128), (128, 512)])
def test_pairwise_dist2_matches_ref(d, qt, pt):
    rng = np.random.default_rng(d)
    q = rng.normal(0, 1, (200, d)).astype(np.float32)
    p = rng.normal(0, 1, (900, d)).astype(np.float32)
    valid = (rng.random(900) > 0.1).astype(np.int32)
    got = ops.pairwise_dist2(q, p, valid, qt=qt, pt=pt)
    want = ops.pairwise_dist2_ref(
        jnp.asarray(q), jnp.asarray(p), jnp.asarray(valid)
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("k", [1, 8, 33])
def test_knn_topk_matches_ref(k):
    rng = np.random.default_rng(k)
    q = rng.normal(0, 1, (64, 3)).astype(np.float32)
    p = rng.normal(0, 1, (512, 3)).astype(np.float32)
    valid = np.ones(512, np.int32)
    valid[500:] = 0
    gi, gd = ops.knn_topk(q, p, k, valid=valid, qt=64, pt=128)
    ri, rd = ops.knn_topk_ref(
        jnp.asarray(q), jnp.asarray(p), jnp.asarray(valid), k
    )
    np.testing.assert_allclose(
        np.sort(np.asarray(gd)), np.sort(np.asarray(rd)), rtol=1e-4,
        atol=1e-6,
    )
    # masked points never appear
    assert np.all(np.asarray(gi) < 500)


def test_kernel_route_agrees_with_index_route():
    pts, idx = _index(2048, 3, 5, seed=4)
    q = np.random.default_rng(1).random((512, 3)).astype(np.float32)
    a = ops.partition_assign(q, idx.split_dim, idx.split_val, levels=5)
    b = jax_index.route(idx, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dist2_dtype_f32_output_for_bf16_inputs():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (64, 4)), jnp.bfloat16)
    p = jnp.asarray(rng.normal(0, 1, (128, 4)), jnp.bfloat16)
    out = ops.pairwise_dist2(q, p, qt=64, pt=128)
    assert out.dtype == jnp.float32
