"""Shared box-geometry helpers (`core/geometry.py`): the single home the
per-file copies in queries/ambi/distributed were folded into."""
import numpy as np

from repro.core.geometry import (
    boxes_intersect_windows,
    boxes_mindist_sq,
    mbb_intersects,
    mindist_box_sq,
    mindist_sq,
)


def _mbb(lo, hi):
    return np.stack([np.asarray(lo, float), np.asarray(hi, float)])


def test_mbb_intersects():
    box = _mbb([0.0, 0.0], [1.0, 1.0])
    assert mbb_intersects(box, np.array([0.5, 0.5]), np.array([2.0, 2.0]))
    # closed intervals: touching at a face/corner counts
    assert mbb_intersects(box, np.array([1.0, 1.0]), np.array([2.0, 2.0]))
    assert not mbb_intersects(box, np.array([1.1, 0.0]), np.array([2.0, 1.0]))
    # disjoint in one dimension only is still disjoint
    assert not mbb_intersects(box, np.array([0.0, 1.5]), np.array([1.0, 2.0]))


def test_mindist_sq():
    box = _mbb([0.0, 0.0], [1.0, 1.0])
    assert mindist_sq(box, np.array([0.5, 0.5])) == 0.0  # inside
    assert mindist_sq(box, np.array([1.0, 1.0])) == 0.0  # on the boundary
    assert mindist_sq(box, np.array([2.0, 1.0])) == 1.0  # face distance
    np.testing.assert_allclose(
        mindist_sq(box, np.array([2.0, 2.0])), 2.0  # corner distance
    )


def test_mindist_box_sq():
    box = _mbb([0.0, 0.0], [1.0, 1.0])
    assert mindist_box_sq(box, np.array([0.5, 0.5]), np.array([2.0, 2.0])) == 0.0
    assert mindist_box_sq(box, np.array([1.0, 0.0]), np.array([2.0, 1.0])) == 0.0
    assert mindist_box_sq(box, np.array([3.0, 0.0]), np.array([4.0, 1.0])) == 4.0
    np.testing.assert_allclose(
        mindist_box_sq(box, np.array([2.0, 2.0]), np.array([3.0, 3.0])), 2.0
    )


def test_batched_forms_match_scalar_forms():
    rng = np.random.default_rng(0)
    m, q, d = 7, 13, 3
    lo = rng.random((m, d))
    hi = lo + rng.random((m, d))
    los = rng.random((q, d)) * 1.5 - 0.2
    his = los + rng.random((q, d)) * 0.5
    qs = rng.random((q, d)) * 2 - 0.5

    inter = boxes_intersect_windows(lo, hi, los, his)
    mind = boxes_mindist_sq(lo, hi, qs)
    assert inter.shape == (q, m) and mind.shape == (q, m)
    for i in range(q):
        for j in range(m):
            box = _mbb(lo[j], hi[j])
            assert inter[i, j] == mbb_intersects(box, los[i], his[i])
            np.testing.assert_allclose(mind[i, j], mindist_sq(box, qs[i]))


def test_legacy_import_location_still_works():
    """queries.py re-exports the scalar helpers (its historical home)."""
    from repro.core.queries import mbb_intersects as mi, mindist_sq as ms

    assert mi is mbb_intersects
    assert ms is mindist_sq
