"""Async serving frontend (PR-8): admission, batching, shedding, brownout.

Saturation behavior is pinned under a virtual clock — the same burst
replays bit-identically — and the robustness contract is two-sided, like
the chaos tests: overload must surface as *honest* degradation (bounded
queue, certificates on every dropped or degraded answer), while every
admitted answer stays id-identical to the NumPy oracle.
"""
import threading

import numpy as np
import pytest

from repro.serve.engine import DeviceQueryServer
from repro.serve.faults import FaultPlan, FaultRule
from repro.serve.frontend import Frontend, VirtualClock
from repro.serve.resilience import RetryPolicy

from engines import NumpyEngine, build_fmbi, f32_points

K = 5


@pytest.fixture(scope="module")
def setup():
    pts = f32_points(1500, 2, seed=21)
    index = build_fmbi(pts, M=64)
    return pts, index


def _server(index, **kw):
    kw.setdefault("microbatch", 16)
    return DeviceQueryServer.from_index(index, **kw)


def _stream(n, d, seed):
    """Deterministic mixed stream of (kind, *payload) items."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        c = rng.random(d) * 0.9
        if i % 3 == 2:
            out.append(("knn", np.clip(c, 0, 1)))
        else:
            out.append(("window", np.clip(c - 0.08, 0, 1),
                        np.clip(c + 0.08, 0, 1)))
    return out


def _submit(fe, item):
    if item[0] == "window":
        return fe.submit_window(item[1], item[2])
    return fe.submit_knn(item[1], K)


# --------------------------------------------------------------------------
# admission: the queue bound is an invariant, not a hint
# --------------------------------------------------------------------------
def test_queue_depth_never_exceeds_bound(setup):
    _, index = setup
    srv = _server(index)
    clock = VirtualClock()
    fe = Frontend(srv, clock=clock, queue_bound=8, batch_max=4,
                  batch_window_s=0.01)
    reqs = []
    for item in _stream(40, 2, seed=1):
        reqs.append(_submit(fe, item))
        assert fe.depth <= 8
        if len(reqs) % 13 == 0:
            clock.advance(0.02)
            fe.pump()
            assert fe.depth <= 8
    fe.drain()
    assert fe.stats.depth_peak <= 8
    assert fe.stats.rejected > 0, "overflow must reject, not queue"
    for r in reqs:
        assert r.done
        if r.status == "rejected":
            assert "queue full" in r.reason
            assert r.cert is not None and not r.cert.complete
            assert r.ids.size == 0


def test_rejected_after_stop(setup):
    _, index = setup
    fe = Frontend(_server(index), clock=VirtualClock(), queue_bound=8)
    fe.stop()
    r = fe.submit_window([0.1, 0.1], [0.2, 0.2])
    assert r.status == "rejected" and "stopped" in r.reason


# --------------------------------------------------------------------------
# saturation: 2x burst sheds with certificates, admitted answers exact
# --------------------------------------------------------------------------
def test_burst_sheds_excess_with_certs_admitted_stay_exact(setup):
    pts, index = setup
    srv = _server(index)
    oracle = NumpyEngine(index)
    clock = VirtualClock()
    bound = 16
    fe = Frontend(srv, clock=clock, queue_bound=bound, batch_max=8,
                  batch_window_s=0.001)
    stream = _stream(2 * bound, 2, seed=7)  # 2x the queue capacity, no pumps
    reqs = [_submit(fe, it) for it in stream]
    fe.drain()

    dropped = [r for r in reqs if r.status != "ok"]
    served = [(r, it) for r, it in zip(reqs, stream) if r.status == "ok"]
    assert dropped, "a 2x-capacity burst must shed"
    assert len(served) + len(dropped) == len(reqs)
    for r in dropped:
        assert r.status == "rejected"
        assert r.cert is not None and not r.cert.complete
    # admitted answers: id-identical to the NumPy oracle
    w = [(r, it) for r, it in served if it[0] == "window"]
    los = np.stack([it[1] for _, it in w])
    his = np.stack([it[2] for _, it in w])
    for (r, _), ref in zip(w, oracle.window(los, his)):
        assert np.array_equal(np.sort(r.ids), np.sort(ref))
    kq = [(r, it) for r, it in served if it[0] == "knn"]
    qs = np.stack([it[1] for _, it in kq])
    for (r, _), ref in zip(kq, oracle.knn(qs, K)):
        assert np.array_equal(r.ids, ref)


# --------------------------------------------------------------------------
# batch former: closes at size N or age T, whichever first
# --------------------------------------------------------------------------
def test_batch_closes_at_size_or_age(setup):
    _, index = setup
    srv = _server(index)
    clock = VirtualClock()
    fe = Frontend(srv, clock=clock, queue_bound=64, batch_max=4,
                  batch_window_s=0.01)
    # size trigger: the 4th submit makes the lane due with no time passing
    reqs = [fe.submit_window([0.1, 0.1], [0.3, 0.3]) for _ in range(4)]
    assert fe.pump() == 1
    assert all(r.status == "ok" for r in reqs)
    # age trigger: one lone request closes only once the window elapses
    r = fe.submit_window([0.1, 0.1], [0.3, 0.3])
    assert fe.pump() == 0 and not r.done
    clock.advance(0.009)
    assert fe.pump() == 0 and not r.done
    clock.advance(0.002)
    assert fe.pump() == 1 and r.status == "ok"
    # lanes are independent: knn with different k never share a batch
    a = fe.submit_knn([0.5, 0.5], 2)
    b = fe.submit_knn([0.5, 0.5], 3)
    clock.advance(0.02)
    assert fe.pump() == 2
    assert a.ids.size == 2 and b.ids.size == 3


# --------------------------------------------------------------------------
# deadlines: expired requests are certified timeouts, never silent stalls
# --------------------------------------------------------------------------
def test_deadline_expired_in_queue_times_out_with_cert(setup):
    _, index = setup
    clock = VirtualClock()
    fe = Frontend(_server(index), clock=clock, queue_bound=16,
                  batch_max=100, batch_window_s=10.0,
                  default_deadline_s=0.05)
    r1 = fe.submit_window([0.1, 0.1], [0.3, 0.3])
    r2 = fe.submit_window([0.1, 0.1], [0.3, 0.3], deadline_s=1.0)
    clock.advance(0.1)  # past r1's deadline; the lane is now due
    fe.pump()
    assert r1.status == "timeout"
    assert r1.cert is not None and not r1.cert.complete
    assert r2.status == "ok", "a live member still gets served"
    st = fe.stats
    assert st.timed_out == 1 and st.completed == 1


# --------------------------------------------------------------------------
# brownout: watermark hysteresis, no flapping, certified degradation
# --------------------------------------------------------------------------
def test_brownout_hysteresis_does_not_flap(setup):
    _, index = setup
    srv = _server(index)
    clock = VirtualClock()
    fe = Frontend(srv, clock=clock, queue_bound=64, batch_max=999,
                  batch_window_s=0.005, brownout_high=16, brownout_low=4)
    # four independent knn lanes, staggered in time for one-lane stepping
    for k in (1, 2, 3, 4):
        for _ in range(4):
            fe.submit_knn([0.5, 0.5], k)
        clock.advance(0.001)
    assert fe.brownout and fe.stats.brownout_enters == 1
    # drain lane by lane: depths 12 and 8 sit between the watermarks and
    # must neither exit nor re-enter
    clock.advance(0.0015)  # lane k=1 is 5.5ms old; k=2 only 4.5ms
    assert fe.pump() == 1
    assert fe.depth == 12 and fe.brownout and fe.stats.brownout_exits == 0
    clock.advance(0.001)
    assert fe.pump() == 1
    assert fe.depth == 8 and fe.brownout and fe.stats.brownout_exits == 0
    clock.advance(0.001)
    assert fe.pump() == 1
    assert fe.depth == 4 and not fe.brownout  # at the low watermark: exit
    assert fe.stats.brownout_exits == 1
    # climbing back to just under high must not re-enter
    for _ in range(11):
        fe.submit_knn([0.5, 0.5], 5)
    assert fe.depth == 15 and not fe.brownout
    assert fe.stats.brownout_enters == 1
    fe.submit_knn([0.5, 0.5], 5)
    assert fe.brownout and fe.stats.brownout_enters == 2
    fe.drain()


def test_brownout_caps_knn_and_marks_requests(setup):
    _, index = setup
    srv = _server(index)
    clock = VirtualClock()
    fe = Frontend(srv, clock=clock, queue_bound=32, batch_max=4,
                  batch_window_s=10.0, brownout_high=6, brownout_low=1,
                  brownout_knn_rounds=0)
    reqs = [fe.submit_knn(np.random.default_rng(i).random(2), K)
            for i in range(8)]
    assert fe.brownout
    fe.drain()
    assert all(r.status == "ok" for r in reqs)
    assert any(r.brownout for r in reqs)
    assert fe.stats.brownout_batches > 0
    for r in reqs:
        assert r.cert is not None  # capped answers still carry provenance


# --------------------------------------------------------------------------
# determinism: identical schedule -> identical outcome, twice
# --------------------------------------------------------------------------
def _run_schedule(index):
    srv = _server(index)
    clock = VirtualClock()
    fe = Frontend(srv, clock=clock, queue_bound=12, batch_max=4,
                  batch_window_s=0.01, default_deadline_s=0.5,
                  brownout_high=8, brownout_low=2)
    reqs = []
    for i, item in enumerate(_stream(30, 2, seed=13)):
        reqs.append(_submit(fe, item))
        if i % 5 == 4:
            clock.advance(0.004)
            fe.pump()
    clock.advance(1.0)
    fe.drain()
    trace = [(r.status, r.reason,
              tuple(np.sort(r.ids).tolist()) if r.ids is not None else None,
              r.brownout, r.t_done)
             for r in reqs]
    return trace, fe.stats


def test_virtual_clock_replay_is_bit_identical(setup):
    _, index = setup
    t1, s1 = _run_schedule(index)
    t2, s2 = _run_schedule(index)
    assert t1 == t2
    assert s1 == s2


# --------------------------------------------------------------------------
# fault points: admission + batch_close wired into the seeded fault plane
# --------------------------------------------------------------------------
def test_admission_fault_point_rejects(setup):
    _, index = setup
    plan = FaultPlan([FaultRule("admission", rate=1.0, max_fires=2)],
                     seed=5)
    fe = Frontend(_server(index), clock=VirtualClock(), queue_bound=16,
                  fault_plan=plan)
    r1 = fe.submit_window([0.1, 0.1], [0.2, 0.2])
    r2 = fe.submit_knn([0.5, 0.5], K)
    r3 = fe.submit_window([0.1, 0.1], [0.2, 0.2])
    assert r1.status == "rejected" and "fault" in r1.reason
    assert r2.status == "rejected" and r2.cert is not None
    assert r3.status == "queued"  # max_fires spent; admission recovers
    fe.drain()
    assert r3.status == "ok"


def test_batch_close_fault_retries_then_serves(setup):
    _, index = setup
    # one injected close failure; the server's retry policy outlasts it
    plan = FaultPlan([FaultRule("batch_close", at_calls={1})], seed=5)
    srv = _server(index, retry=RetryPolicy(max_attempts=2,
                                           sleep=lambda s: None))
    fe = Frontend(srv, clock=VirtualClock(), queue_bound=16,
                  batch_max=2, batch_window_s=0.001, fault_plan=plan)
    r1 = fe.submit_window([0.1, 0.1], [0.4, 0.4])
    r2 = fe.submit_window([0.2, 0.2], [0.5, 0.5])
    fe.drain()
    assert r1.status == "ok" and r2.status == "ok"


def test_batch_close_fault_exhausting_retries_sheds_with_certs(setup):
    _, index = setup
    plan = FaultPlan([FaultRule("batch_close", rate=1.0)], seed=5)
    srv = _server(index, retry=RetryPolicy(max_attempts=2,
                                           sleep=lambda s: None))
    fe = Frontend(srv, clock=VirtualClock(), queue_bound=16,
                  batch_max=2, batch_window_s=0.001, fault_plan=plan)
    reqs = [fe.submit_window([0.1, 0.1], [0.4, 0.4]) for _ in range(4)]
    fe.drain()
    for r in reqs:
        assert r.status == "shed"
        assert r.cert is not None and not r.cert.complete
        assert "dispatch failed" in r.reason
    assert fe.stats.shed == 4


# --------------------------------------------------------------------------
# real-time mode: dispatcher + refine threads, same contract
# --------------------------------------------------------------------------
def test_realtime_dispatcher_serves_and_drains(setup):
    pts, index = setup
    srv = _server(index)
    oracle = NumpyEngine(index)
    fe = Frontend(srv, queue_bound=256, batch_max=8,
                  batch_window_s=0.001).start()
    stream = _stream(40, 2, seed=3)
    reqs = [_submit(fe, it) for it in stream]
    for r in reqs:
        assert r.wait(30.0), "request never reached a terminal state"
    fe.stop()
    served = [(r, it) for r, it in zip(reqs, stream) if r.status == "ok"]
    assert served, "an unsaturated run must serve"
    w = [(r, it) for r, it in served if it[0] == "window"]
    los = np.stack([it[1] for _, it in w])
    his = np.stack([it[2] for _, it in w])
    for (r, _), ref in zip(w, oracle.window(los, his)):
        assert np.array_equal(np.sort(r.ids), np.sort(ref))


def test_virtual_mode_rejects_start(setup):
    _, index = setup
    fe = Frontend(_server(index), clock=VirtualClock())
    with pytest.raises(RuntimeError, match="VirtualClock"):
        fe.start()


# --------------------------------------------------------------------------
# adaptive serving through the frontend: overlap + device-only brownout
# --------------------------------------------------------------------------
def _adaptive_server(pts, M=64, **kw):
    from repro.core import AMBI

    kw.setdefault("microbatch", 16)
    return DeviceQueryServer.from_ambi(AMBI(pts, M), **kw)


def _brute_window(pts, lo, hi):
    return np.sort(np.flatnonzero(
        (pts >= lo).all(axis=1) & (pts <= hi).all(axis=1)
    ))


def test_adaptive_overlap_refines_on_second_lane_and_stays_exact(setup):
    pts, _ = setup
    srv = _adaptive_server(pts)
    clock = VirtualClock()
    fe = Frontend(srv, clock=clock, queue_bound=64, batch_max=8,
                  batch_window_s=0.001)
    rng = np.random.default_rng(11)
    reqs = []
    for _ in range(16):
        c = rng.random(2) * 0.9
        reqs.append(fe.submit_window(np.clip(c - 0.06, 0, 1),
                                     np.clip(c + 0.06, 0, 1)))
    clock.advance(0.01)
    fe.pump()
    fe.drain()
    assert fe.stats.refine_batches > 0, "cold sub-batches use the refine lane"
    for r in reqs:
        assert r.status == "ok"
        lo, hi = r.payload
        assert np.array_equal(np.sort(r.ids), _brute_window(pts, lo, hi))


def test_adaptive_brownout_serves_device_only_with_certs(setup):
    pts, _ = setup
    srv = _adaptive_server(pts)
    clock = VirtualClock()
    fe = Frontend(srv, clock=clock, queue_bound=64, batch_max=4,
                  batch_window_s=10.0, brownout_high=6, brownout_low=1)
    rng = np.random.default_rng(12)
    reqs = []
    for _ in range(12):
        c = rng.random(2) * 0.9
        reqs.append(fe.submit_window(np.clip(c - 0.06, 0, 1),
                                     np.clip(c + 0.06, 0, 1)))
    assert fe.brownout
    grafts_before = srv.stats.grafts
    fe.drain()
    brown = [r for r in reqs if r.brownout]
    assert brown, "the flooded tail must be served in brownout"
    assert srv.stats.grafts == grafts_before, \
        "brownout must not pay for host refinement"
    # a fresh AMBI is all-cold: the degraded answers must say so honestly
    degraded = [r for r in brown if not r.cert.complete]
    assert degraded
    for r in degraded:
        assert r.cert.missing_lo is not None and len(r.cert.missing_lo) > 0
        # the returned ids never lie outside the requested window
        lo, hi = r.payload
        if r.ids.size:
            assert ((pts[r.ids] >= lo) & (pts[r.ids] <= hi)).all()


# --------------------------------------------------------------------------
# table RW-lock regression: queries racing refinement stay exact
# --------------------------------------------------------------------------
def test_table_lock_queries_racing_refinement_stay_exact():
    pts = f32_points(4000, 2, seed=33)
    srv = _adaptive_server(pts, M=64)
    rngs = [np.random.default_rng(s) for s in (1, 2, 3)]
    errors = []

    def worker(rng):
        try:
            for _ in range(12):
                c = rng.random((8, 2)) * 0.9
                los, his = np.clip(c - 0.05, 0, 1), np.clip(c + 0.05, 0, 1)
                for lo, hi, ids in zip(los, his, srv.window(los, his)):
                    expect = _brute_window(pts, lo, hi)
                    if not np.array_equal(np.sort(ids), expect):
                        errors.append((lo, hi))
                        return
        except Exception as e:  # pragma: no cover - the regression itself
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(r,)) for r in rngs]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    assert not errors, f"racing refinement corrupted answers: {errors[:2]}"
    assert srv.ambi.is_fully_refined() or srv.stats.grafts > 0
