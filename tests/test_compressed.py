"""bf16 compressed-MBB certificate: property tests + engine parity.

The compressed layout stores outward-rounded bfloat16 copies of the box
columns (``lo`` toward -inf, ``hi`` toward +inf).  Three properties make it
safe to traverse against:

  * containment — every compressed box contains its f32 box, so a window
    intersecting the f32 box always intersects the compressed one: the
    frontier can *over*-collect but never miss (no false negatives);
  * mindist under-estimation — the squared mindist to a compressed box
    never exceeds the f32 mindist, so the k-NN exactness certificate
    (k-th distance <= closest unscanned mindist) only gets *harder* to
    pass, never wrongly certifies;
  * certified f32 re-check — the pair-scan stage tests point containment
    against the exact f32 columns, so query results are id-identical to
    the NumPy engine despite the lossy traversal bounds.

Hypothesis drives the rounding properties over adversarial floats (ulp
boundaries, subnormals, huge magnitudes); the parity suite pins the
end-to-end guarantee over FMBI and grafted-AMBI tables.
"""
import numpy as np
import pytest

from repro.core import knn_query_batch, window_query_batch
from repro.core.nodetable import _bf16_outward, compress_boxes_bf16
from repro.core.queries_jax import (
    DeviceTable,
    knn_query_batch_jax,
    window_query_batch_jax,
)

from engines import build_fmbi, build_grafted_ambi, f32_points

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except Exception:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


finite_f32 = st.floats(
    min_value=-3.4e38, max_value=3.4e38, allow_nan=False,
    allow_infinity=False, width=32,
) if HAVE_HYPOTHESIS else None


# --------------------------------------------------------------------------
# rounding direction: the bit-level property everything rests on
# --------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @given(finite_f32)
    @settings(max_examples=300, deadline=None)
    def test_outward_rounding_direction(x):
        lo = np.float32(_bf16_outward(np.float32(x), up=False))
        hi = np.float32(_bf16_outward(np.float32(x), up=True))
        assert lo <= np.float32(x) <= hi

    @given(finite_f32)
    @settings(max_examples=300, deadline=None)
    def test_outward_rounding_is_tight(x):
        """At most one bf16 ulp of slack: the next representable value
        toward the rounding direction would cross ``x``."""
        import ml_dtypes

        x = np.float32(x)
        lo = _bf16_outward(x, up=False)
        hi = _bf16_outward(x, up=True)
        # nextafter in bf16 space: bump the bit pattern by one
        for v, up in ((lo, False), (hi, True)):
            f32 = np.float32(v)
            if f32 == x or not np.isfinite(f32):
                continue
            u = np.frombuffer(
                np.asarray(v, dtype=ml_dtypes.bfloat16).tobytes(),
                dtype=np.uint16,
            )[0]
            # stepping one ulp back toward x must overshoot it
            stepped = np.frombuffer(
                np.uint16(u + (1 if (f32 < x) == (not up) else -1))
                .tobytes(), dtype=ml_dtypes.bfloat16,
            )[0]
            back = np.float32(stepped)
            if np.isfinite(back):
                assert (back > x) if not up else (back < x)

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=300, deadline=None)
    def test_outward_rounding_bit_patterns(bits):
        """Every finite f32 bit pattern rounds outward (exhaustive-style:
        arbitrary sign/exponent/mantissa combinations, incl. subnormals)."""
        x = np.uint32(bits).view(np.float32)
        if not np.isfinite(x):
            return
        lo = np.float32(_bf16_outward(x, up=False))
        hi = np.float32(_bf16_outward(x, up=True))
        assert lo <= x <= hi

    @given(st.lists(finite_f32, min_size=2, max_size=16))
    @settings(max_examples=200, deadline=None)
    def test_compressed_box_contains_f32_box(vals):
        """compress_boxes_bf16 output contains the input box, so every
        window intersecting the f32 box intersects the compressed box."""
        v = np.asarray(vals, dtype=np.float32)
        lo = np.full(4, v.min(), dtype=np.float32)
        hi = np.full(4, v.max(), dtype=np.float32)
        lo_c, hi_c = compress_boxes_bf16(lo[None], hi[None])
        assert np.all(np.asarray(lo_c, np.float32) <= lo)
        assert np.all(np.asarray(hi_c, np.float32) >= hi)
        # mindist under-estimation: for any query point, the compressed
        # box is closer (gap shrinks when bounds move outward)
        q = np.float32(vals[0])
        g32 = np.maximum(lo - q, 0) + np.maximum(q - hi, 0)
        gc = (np.maximum(np.asarray(lo_c[0], np.float32) - q, 0)
              + np.maximum(q - np.asarray(hi_c[0], np.float32), 0))
        assert np.all(gc <= g32)


def test_outward_rounding_bit_sweep_fixed():
    """Deterministic stand-in for the hypothesis rounding properties
    (always runs): 200k pseudo-random f32 bit patterns — every exponent
    band, subnormals, both signs — must round outward in both directions,
    and exact bf16 values must round to themselves."""
    rng = np.random.default_rng(12345)
    bits = rng.integers(0, 2**32, 200_000, dtype=np.uint64).astype(np.uint32)
    x = bits.view(np.float32)
    x = x[np.isfinite(x)]
    lo = np.asarray(_bf16_outward(x, up=False), np.float32)
    hi = np.asarray(_bf16_outward(x, up=True), np.float32)
    assert np.all(lo <= x) and np.all(hi >= x)
    # exact bf16 values are fixed points of both roundings
    exact = (x.view(np.uint32) & np.uint32(0xFFFF)) == 0
    assert np.array_equal(lo[exact], x[exact])
    assert np.array_equal(hi[exact], x[exact])
    # slack is at most one bf16 ulp: re-rounding the rounded value is a
    # no-op (idempotence), so the result is the adjacent representable
    assert np.array_equal(
        np.asarray(_bf16_outward(lo, up=False), np.float32), lo
    )
    assert np.array_equal(
        np.asarray(_bf16_outward(hi, up=True), np.float32), hi
    )


def test_no_false_negative_fixed_sweep():
    """Dense deterministic sweep (runs with or without hypothesis): every
    f32 window/box intersection survives compression."""
    rng = np.random.default_rng(0)
    lo = rng.random((500, 3)).astype(np.float32)
    hi = lo + rng.uniform(0, 0.2, (500, 3)).astype(np.float32)
    lo_c, hi_c = compress_boxes_bf16(lo, hi)
    qlo = rng.random((64, 3)).astype(np.float32)
    qhi = qlo + rng.uniform(0, 0.3, (64, 3)).astype(np.float32)
    hit32 = np.all(
        (lo[:, None, :] <= qhi[None]) & (hi[:, None, :] >= qlo[None]), axis=2
    )
    hit_c = np.all(
        (np.asarray(lo_c, np.float32)[:, None, :] <= qhi[None])
        & (np.asarray(hi_c, np.float32)[:, None, :] >= qlo[None]), axis=2
    )
    assert np.all(hit_c | ~hit32)  # compressed hits are a superset


# --------------------------------------------------------------------------
# end-to-end: the f32 re-check pins id-identical results vs NumPy
# --------------------------------------------------------------------------
@pytest.mark.parametrize("builder", [build_fmbi, build_grafted_ambi])
@pytest.mark.parametrize("kind", ["uniform", "skew", "grid"])
def test_compressed_engine_window_id_identical(builder, kind):
    pts = f32_points(3000, 3, seed=17, kind=kind)
    idx = builder(pts)
    rng = np.random.default_rng(3)
    ctr = rng.random((24, 3))
    w = 0.05 + 0.1 * rng.random((24, 1))
    los, his = ctr - w, ctr + w
    ref, _ = window_query_batch(idx, los, his)
    dev = DeviceTable.from_index(idx, compressed=True)
    assert dev.compressed
    for fused in (False, True):
        got = window_query_batch_jax(dev, los, his, fused=fused)
        for a, b in zip(ref, got):
            assert set(np.asarray(a).tolist()) == set(
                np.asarray(b).tolist()
            )


@pytest.mark.parametrize("builder", [build_fmbi, build_grafted_ambi])
def test_compressed_engine_knn_id_identical(builder):
    pts = f32_points(3000, 3, seed=23)  # continuous: unique distances
    idx = builder(pts)
    rng = np.random.default_rng(5)
    qs = rng.random((24, 3))
    ref, _ = knn_query_batch(idx, qs, 11)
    dev = DeviceTable.from_index(idx, compressed=True)
    for fused in (False, True):
        got = knn_query_batch_jax(dev, qs, 11, fused=fused)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a starved budget escalates to the same answer under bf16 bounds
    got = knn_query_batch_jax(dev, qs, 11, fused=True,
                              n_candidate_leaves=1)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compressed_layout_roundtrip_and_delta():
    """apply_delta preserves compression: the refreshed table still
    carries bf16 columns and still answers id-identically."""
    from repro.core import AMBI

    pts = f32_points(2500, 2, seed=31)
    ambi = AMBI(pts, 250)
    dev = DeviceTable.from_table(ambi.table, ambi.points, partial=True,
                                 compressed=True)
    ambi.window(np.zeros(2), np.ones(2))  # refine everything
    dev = dev.apply_delta(ambi.table, ambi.points)
    assert dev.compressed and dev.leaf_lo_c is not None
    rng = np.random.default_rng(7)
    ctr = rng.random((8, 2))
    los, his = ctr - 0.05, ctr + 0.05
    ref, _ = window_query_batch(ambi.index, los, his)
    got = window_query_batch_jax(dev, los, his, fused=True)
    for a, b in zip(ref, got):
        assert set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())


def test_compressed_halves_box_bytes():
    pts = f32_points(3000, 3, seed=41)
    idx = build_fmbi(pts)
    dev = DeviceTable.from_index(idx, compressed=True)
    assert dev.leaf_lo_c.dtype.itemsize == 2
    assert dev.leaf_lo.dtype.itemsize == 4
    for (lo_c, hi_c), (lo, hi, _, _) in zip(dev.levels_c, dev.levels):
        assert lo_c.dtype.itemsize == 2 and lo_c.shape == lo.shape
        # containment holds level by level on-device too
        assert np.all(np.asarray(lo_c, np.float32) <= np.asarray(lo))
        assert np.all(np.asarray(hi_c, np.float32) >= np.asarray(hi))
