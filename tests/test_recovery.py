"""Graft-journal crash recovery (PR-6).

The adaptive server's table is a pure function of the boot AMBI state
and the sequence of cold ops it served (grafting consumes the index's
own seeded rng + the page-store allocator, both snapshotted).  So a
killed server must reboot from snapshot + journal replay to the
*bit-identical* table — verified here by killing at every journal
record boundary and comparing against an uninterrupted twin that
executed the same op prefix from scratch.
"""
import os
import shutil
import struct

import numpy as np
import pytest

from repro.core.ambi import AMBI
from repro.core.nodetable import NodeTable
from repro.serve.engine import DeviceQueryServer
from repro.serve.faults import FaultError, FaultPlan, FaultRule
from repro.serve.journal import GraftJournal, JournalError
from repro.serve.resilience import RetryExhausted, RetryPolicy

from engines import f32_points

_HEADER = struct.Struct("<II")


def _workload(d=2, seed=3, n=10, r=0.03):
    rng = np.random.default_rng(seed)
    c = rng.random((n, d))
    los = np.clip(c - r, 0, 1)
    his = np.clip(c + r, 0, 1)
    qs = rng.random((n, d))
    return los, his, qs


# 36 data pages >> M=24: the root is dense, so refinement is *incremental*
# (each cold query grafts only its own subspaces and journals one record;
# a shallow table would fully refine on the first touch and leave nothing
# for the boundary sweep to kill between)
_N, _M = 12_000, 24


def _drive(srv, los, his, qs, k=4):
    out = []
    for i in range(len(los)):
        out.extend(srv.window(los[i:i + 1], his[i:i + 1]))
        out.extend(srv.knn(qs[i:i + 1], k))
    return out


def _record_boundaries(journal_bytes):
    """Byte offsets after each complete record (0 included)."""
    offs = [0]
    off = 0
    while off + _HEADER.size <= len(journal_bytes):
        length, _ = _HEADER.unpack_from(journal_bytes, off)
        off += _HEADER.size + length
        offs.append(off)
    assert offs[-1] == len(journal_bytes)
    return offs


# --------------------------------------------------------------------------
# journal unit behaviour
# --------------------------------------------------------------------------
def test_journal_roundtrip_and_seq_continuity(tmp_path):
    path = tmp_path / "ops.journal"
    j = GraftJournal(path)
    assert j.append("window", lo=[0.0], hi=[1.0]) == 1
    assert j.append("knn", q=[0.5], k=3) == 2
    j.close()
    recs = list(GraftJournal.read_records(path))
    assert [r["seq"] for r in recs] == [1, 2]
    assert recs[0]["op"] == "window" and recs[1]["k"] == 3
    assert GraftJournal.last_seq(path) == 2
    # reopening scans and continues the sequence
    j2 = GraftJournal(path)
    assert j2.append("compact") == 3
    # truncation empties the file but the counter stays monotonic
    j2.truncate()
    assert list(GraftJournal.read_records(path)) == []
    assert j2.append("window", lo=[0.0], hi=[0.5]) == 4
    j2.close()
    assert GraftJournal.last_seq(path) == 4


def test_journal_coordinates_roundtrip_exactly(tmp_path):
    path = tmp_path / "ops.journal"
    # adversarial float64s: JSON shortest-roundtrip must be bit-exact
    vals = [0.1, 1 / 3, np.nextafter(0.7, 1.0), 1e-308, 12345.6789012345]
    j = GraftJournal(path)
    j.append("window", lo=vals, hi=vals)
    j.close()
    rec = next(GraftJournal.read_records(path))
    got = np.asarray(rec["lo"], dtype=np.float64)
    assert np.array_equal(got, np.asarray(vals, dtype=np.float64))


def test_journal_torn_tail_tolerated_corruption_fatal(tmp_path):
    path = tmp_path / "ops.journal"
    j = GraftJournal(path)
    for i in range(3):
        j.append("knn", q=[float(i)], k=1)
    j.close()
    blob = path.read_bytes()
    offs = _record_boundaries(blob)
    # torn payload (crash mid-append of record 3): dropped, not fatal
    path.write_bytes(blob[:offs[3] - 1])
    assert [r["seq"] for r in GraftJournal.read_records(path)] == [1, 2]
    # torn header at the tail: same
    path.write_bytes(blob[:offs[2] + 3])
    assert [r["seq"] for r in GraftJournal.read_records(path)] == [1, 2]
    # a COMPLETE record with a flipped payload byte is corruption
    bad = bytearray(blob)
    bad[offs[1] + _HEADER.size + 2] ^= 0xFF
    path.write_bytes(bytes(bad))
    with pytest.raises(JournalError, match="checksum mismatch"):
        list(GraftJournal.read_records(path))
    # opening a corrupt journal for append refuses too (scan validates)
    with pytest.raises(JournalError):
        GraftJournal(path)


def test_snapshot_save_is_atomic(tmp_path):
    pts = f32_points(300, 2, seed=1)
    ambi = AMBI(pts, 64)
    ambi.window(np.zeros(2), np.ones(2))
    path = str(tmp_path / "snap.npz")
    # a stale temp file from a previous crashed save must be harmless
    with open(path + ".tmp", "wb") as f:
        f.write(b"garbage from a torn write")
    ambi.table.save(path, points=pts, extra={"v": 1})
    assert not os.path.exists(path + ".tmp")  # replaced, not left behind
    table, meta, loaded = NodeTable.load(path)
    assert table.equals(ambi.table)
    assert np.array_equal(loaded, pts)
    # an interrupted overwrite (fault before the write) leaves the old
    # snapshot fully intact: the tmp-then-rename never touched it
    blob = open(path, "rb").read()
    plan = FaultPlan.single("snapshot_save", at_call=1)
    try:
        plan.fire("snapshot_save")
    except FaultError:
        pass
    assert open(path, "rb").read() == blob


# --------------------------------------------------------------------------
# write-ahead discipline
# --------------------------------------------------------------------------
def test_journal_append_failure_fails_the_op(tmp_path):
    pts = f32_points(400, 2, seed=2)
    ambi = AMBI(pts, 64)
    plan = FaultPlan([FaultRule("journal_append", rate=1.0)])
    srv = DeviceQueryServer.from_ambi(
        ambi, microbatch=8,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
        fault_plan=plan,
        retry=RetryPolicy(max_attempts=2, sleep=lambda s: None),
    )
    unref_before = ambi.table.unrefined.copy()
    with pytest.raises(RetryExhausted):
        srv.window(np.zeros((1, 2)), np.ones((1, 2)))
    # never execute unlogged: the journal is empty and the host table
    # saw no refinement from the failed op
    assert GraftJournal.last_seq(tmp_path / "ops.journal") == 0
    assert np.array_equal(ambi.table.unrefined, unref_before)
    # once the plane is quiet the same op succeeds and is journaled
    # (the file itself may already be re-truncated by a compaction
    # barrier — the monotonic seq and the counter prove the append)
    plan.disarm()
    srv.window(np.zeros((1, 2)), np.ones((1, 2)))
    assert srv.journal.seq >= 1
    assert srv.stats.journal_records >= 1


# --------------------------------------------------------------------------
# kill-restart: every journal record boundary
# --------------------------------------------------------------------------
def _twin_after(pts, M, ops):
    """The uninterrupted twin: a fresh AMBI that executed exactly ``ops``."""
    twin = AMBI(pts, M)
    for rec in ops:
        DeviceQueryServer._replay_op(twin, rec)
    return twin


def test_kill_at_every_record_boundary(tmp_path):
    pts = f32_points(_N, 2, seed=7)
    M = _M
    los, his, qs = _workload(n=8)
    live = tmp_path / "live"
    live.mkdir()
    srv = DeviceQueryServer.from_ambi(
        AMBI(pts, M), microbatch=8, compact_slack=1e9,  # no mid-run barrier
        journal_path=live / "ops.journal", snapshot_path=live / "snap.npz",
    )
    _drive(srv, los, his, qs)
    blob = (live / "ops.journal").read_bytes()
    offs = _record_boundaries(blob)
    ops = list(GraftJournal.read_records(live / "ops.journal"))
    assert len(ops) == len(offs) - 1 and len(ops) >= 6
    assert srv.stats.journal_records == len(ops)

    kill = tmp_path / "kill"
    for b in range(len(offs)):
        if kill.exists():
            shutil.rmtree(kill)
        kill.mkdir()
        shutil.copy(live / "snap.npz", kill / "snap.npz")
        (kill / "ops.journal").write_bytes(blob[:offs[b]])
        rec = DeviceQueryServer.recover(
            kill / "snap.npz", kill / "ops.journal",
            microbatch=8, compact_slack=1e9,
        )
        twin = _twin_after(pts, M, ops[:b])
        assert rec.stats.replayed_records == b
        assert rec.ambi.table.equals(twin.table), f"boundary {b}"
        # the FULL adaptive state matches: rng stream + page store
        assert rec.ambi.state_meta() == twin.state_meta(), f"boundary {b}"
        # a torn tail past the boundary recovers to the same state
        if b < len(offs) - 1:
            (kill / "ops.journal").write_bytes(blob[:offs[b] + 3])
            rec2 = DeviceQueryServer.recover(
                kill / "snap.npz", kill / "ops.journal",
                microbatch=8, compact_slack=1e9,
            )
            assert rec2.stats.replayed_records == b
            assert rec2.ambi.table.equals(twin.table)


def test_recovered_server_serves_identically(tmp_path):
    """Post-recovery, the rebooted server and the never-killed twin serve
    the same traffic with identical results AND identical upload-counter
    deltas (the device sync behaviour, not just the answers)."""
    pts = f32_points(_N, 2, seed=7)
    M = _M
    los, his, qs = _workload(n=8)

    def boot(d):
        d.mkdir()
        return DeviceQueryServer.from_ambi(
            AMBI(pts, M), microbatch=8, compact_slack=1e9,
            journal_path=d / "ops.journal", snapshot_path=d / "snap.npz",
        )

    twin = boot(tmp_path / "twin")
    dead = boot(tmp_path / "dead")
    warm = list(zip(_drive(twin, los, his, qs), _drive(dead, los, his, qs)))
    for a, b in warm:
        assert np.array_equal(a, b)
    # kill `dead` (drop it mid-flight) and reboot from its files
    rec = DeviceQueryServer.recover(
        tmp_path / "dead" / "snap.npz", tmp_path / "dead" / "ops.journal",
        microbatch=8, compact_slack=1e9,
    )
    assert rec.ambi.table.equals(twin.ambi.table)
    # journaling resumes after the dead server's last acknowledged seq
    assert rec.journal.seq == twin.journal.seq
    # fresh traffic: some cold (new region), some hot (warm region)
    los2, his2, qs2 = _workload(seed=12, n=6)
    base_rec = rec.upload_stats.as_dict()
    base_twin = twin.upload_stats.as_dict()
    for a, b in zip(_drive(rec, los2, his2, qs2),
                    _drive(twin, los2, his2, qs2)):
        assert np.array_equal(a, b)
    delta_rec = {
        k: v - base_rec[k] for k, v in rec.upload_stats.as_dict().items()
    }
    delta_twin = {
        k: v - base_twin[k] for k, v in twin.upload_stats.as_dict().items()
    }
    assert delta_rec == delta_twin
    assert rec.ambi.table.equals(twin.ambi.table)


# --------------------------------------------------------------------------
# compaction barriers and the snapshot/truncate crash window
# --------------------------------------------------------------------------
def test_compaction_checkpoint_folds_journal(tmp_path):
    pts = f32_points(_N, 2, seed=9)
    srv = DeviceQueryServer.from_ambi(
        AMBI(pts, _M), microbatch=8, compact_slack=0.05,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
    )
    los, his, qs = _workload(seed=5, n=10)
    _drive(srv, los, his, qs)
    if srv.stats.compactions == 0:
        _drive(srv, *_workload(seed=6, n=10))
    assert srv.stats.compactions >= 1
    assert srv.stats.checkpoints >= 2  # boot barrier + compaction barrier
    # the barrier folded the journal: far fewer live records than ops
    live = GraftJournal.last_seq(tmp_path / "ops.journal")
    assert srv.journal.seq > 0
    # recovery from barrier + tail lands on the live server's exact table
    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal",
        microbatch=8, compact_slack=0.05,
    )
    assert rec.ambi.table.equals(srv.ambi.table)
    assert rec.ambi.state_meta() == srv.ambi.state_meta()
    assert rec.journal.seq == srv.journal.seq
    assert live >= rec.stats.replayed_records


def test_crash_between_snapshot_and_truncate_replays_nothing_twice(tmp_path):
    pts = f32_points(_N, 2, seed=11)
    srv = DeviceQueryServer.from_ambi(
        AMBI(pts, _M), microbatch=8, compact_slack=1e9,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
    )
    los, his, qs = _workload(seed=8, n=6)
    _drive(srv, los, his, qs)
    pre_truncate = (tmp_path / "ops.journal").read_bytes()
    assert len(pre_truncate) > 0
    srv.checkpoint()  # snapshot written, then journal truncated
    # simulate the kill BETWEEN the two: restore the stale journal
    (tmp_path / "ops.journal").write_bytes(pre_truncate)
    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal",
        microbatch=8, compact_slack=1e9,
    )
    # every stale record's seq is at or below the snapshot barrier
    assert rec.stats.replayed_records == 0
    assert rec.ambi.table.equals(srv.ambi.table)
    assert rec.journal.seq == srv.journal.seq


def test_deferred_checkpoint_keeps_compact_in_journal(tmp_path):
    """When the snapshot barrier itself fails, the vacuum stays journaled
    and replay compacts at the same point — tables still bit-identical."""
    pts = f32_points(_N, 2, seed=13)
    plan = FaultPlan([FaultRule("snapshot_save", rate=1.0)])
    plan.disarm()  # let the boot barrier through
    srv = DeviceQueryServer.from_ambi(
        AMBI(pts, _M), microbatch=8, compact_slack=0.05,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
        fault_plan=plan,
        retry=RetryPolicy(max_attempts=2, sleep=lambda s: None),
    )
    plan.rearm()  # every post-boot snapshot save now fails -> deferred
    los, his, qs = _workload(seed=5, n=10)
    _drive(srv, los, his, qs)
    if srv.stats.compactions == 0:
        _drive(srv, *_workload(seed=6, n=10))
    assert srv.stats.compactions >= 1
    assert srv.stats.checkpoints == 1  # only the boot barrier landed
    ops = list(GraftJournal.read_records(tmp_path / "ops.journal"))
    assert any(r["op"] == "compact" for r in ops)
    plan.disarm()
    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal",
        microbatch=8, compact_slack=0.05,
    )
    assert rec.ambi.table.equals(srv.ambi.table)
    assert rec.ambi.state_meta() == srv.ambi.state_meta()


def test_recovery_replay_runs_disarmed(tmp_path):
    pts = f32_points(400, 2, seed=4)
    srv = DeviceQueryServer.from_ambi(
        AMBI(pts, 64), microbatch=8, compact_slack=1e9,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
    )
    srv.window(np.zeros((1, 2)), np.ones((1, 2)))
    assert srv.journal.seq >= 1
    # a plane that would fault every host op must NOT fault the replay
    plan = FaultPlan([
        FaultRule("host_refine", rate=1.0),
        FaultRule("pagestore_read", rate=1.0),
    ])
    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal",
        microbatch=8, compact_slack=1e9, fault_plan=plan,
    )
    assert rec.stats.replayed_records >= 1
    assert plan.total_fires == 0  # replay was never faulted
    assert plan.armed  # ...and the plane is rearmed for live traffic
    assert rec.ambi.table.equals(srv.ambi.table)


def test_recovery_snapshot_load_fault_is_injectable(tmp_path):
    pts = f32_points(300, 2, seed=6)
    srv = DeviceQueryServer.from_ambi(
        AMBI(pts, 64), microbatch=8,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
    )
    srv.window(np.zeros((1, 2)), np.ones((1, 2)))
    plan = FaultPlan.single("snapshot_load", at_call=1)
    with pytest.raises(FaultError):
        DeviceQueryServer.recover(
            tmp_path / "snap.npz", tmp_path / "ops.journal",
            fault_plan=plan,
        )
    # the supervisor's retry of the whole reboot then succeeds
    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal", fault_plan=plan,
    )
    assert rec.ambi.table.equals(srv.ambi.table)


# --------------------------------------------------------------------------
# property-based kill-restart (optional dev dependency)
# --------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency (see requirements-dev.txt)
    given = None

if given is not None:

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), frac=st.floats(0.0, 1.0))
    def test_kill_restart_property(tmp_path_factory, seed, frac):
        tmp = tmp_path_factory.mktemp("prop")
        pts = f32_points(_N, 2, seed=17)
        M = _M
        los, his, qs = _workload(seed=seed, n=5)
        srv = DeviceQueryServer.from_ambi(
            AMBI(pts, M), microbatch=8, compact_slack=1e9,
            journal_path=tmp / "ops.journal",
            snapshot_path=tmp / "snap.npz",
        )
        _drive(srv, los, his, qs)
        blob = (tmp / "ops.journal").read_bytes()
        offs = _record_boundaries(blob)
        ops = list(GraftJournal.read_records(tmp / "ops.journal"))
        b = int(round(frac * (len(offs) - 1)))
        (tmp / "ops.journal").write_bytes(blob[:offs[b]])
        rec = DeviceQueryServer.recover(
            tmp / "snap.npz", tmp / "ops.journal",
            microbatch=8, compact_slack=1e9,
        )
        twin = _twin_after(pts, M, ops[:b])
        assert rec.ambi.table.equals(twin.table)
        assert rec.ambi.state_meta() == twin.state_meta()

else:

    @pytest.mark.skip(reason="hypothesis not installed (optional dev dep)")
    def test_kill_restart_property():
        pass
