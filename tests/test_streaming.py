"""Streaming ingest (PR-9): LSM tiers, tombstones, delta-only device refresh.

The contract under test, on every engine path: an interleaved stream of
inserts, deletes and queries must be id-identical to throwing the index away
and bulk-loading it from scratch over the live points — tombstoned ids never
resurface, merges and tier retirements never change answers, and the device
mirror refreshes incrementally (upload counters prove no full re-export).
"""
import threading

import numpy as np
import pytest

from repro.core import DeviceMirror, PageStore, StreamingIndex, bulk_load
from repro.serve.engine import DeviceQueryServer

from engines import (
    STREAM_KW,
    OverlayServerEngine,
    RebuildOracle,
    StreamingHostEngine,
    StreamingServerEngine,
    f32_points,
    ingest_suite,
)

try:  # optional dev dependency (see requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------
# the interleaving driver: one op schedule, every engine, oracle parity
# --------------------------------------------------------------------------
def _drive_interleaved(engines, seed, steps=18, max_ins=150, check_every=3):
    """Apply an identical insert/delete schedule to every engine and assert
    window + k-NN parity against ``engines[0]`` (the rebuild oracle) at
    checkpoints.  Returns the number of ids ever allocated."""
    # decorrelate from f32_points(seed): replaying the base generator's
    # stream would insert exact duplicate coordinates (k-boundary ties)
    rng = np.random.default_rng(seed + 7919)
    n_ids = len(engines[0].pts)
    for step in range(steps):
        ins = rng.random((int(rng.integers(1, max_ins)), 2))
        ins = ins.astype(np.float32).astype(np.float64)
        ids = [e.insert(ins) for e in engines]
        for got in ids[1:]:  # id assignment itself must be identical
            np.testing.assert_array_equal(got, ids[0])
        n_ids += len(ins)
        if step % 2 == 0:
            dels = rng.integers(0, n_ids, size=int(rng.integers(1, 30)))
            counts = [e.delete(dels) for e in engines]
            assert counts[1:] == [counts[0]] * (len(engines) - 1)
        if step % check_every == check_every - 1 or step == steps - 1:
            los = rng.random((4, 2)) * 0.7
            his = los + rng.uniform(0.05, 0.3)
            ref = engines[0].window(los, his)
            for e in engines[1:]:
                got = e.window(los, his)
                for i, (a, b) in enumerate(zip(got, ref)):
                    assert np.array_equal(np.sort(a), b), (e.name, step, i)
            qs = rng.random((4, 2)).astype(np.float32).astype(np.float64)
            kref = engines[0].knn(qs, 8)
            for e in engines[1:]:
                got = e.knn(qs, 8)
                for i, (a, b) in enumerate(zip(got, kref)):
                    assert np.array_equal(a, b), (e.name, step, i)
    return n_ids


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_host_interleaving_matches_rebuild_oracle(seed):
    pts = f32_points(2500, 2, seed=seed)
    host = StreamingHostEngine(pts)
    _drive_interleaved([RebuildOracle(pts), host], seed, steps=22)
    s = host.stream
    # the schedule actually crossed the LSM machinery, not just the memtable
    assert s.flushes >= 2 and s.merges >= 1 and s.deleted > 0
    assert s.tiers, "no live tier survived"


def test_engine_matrix_interleaving():
    """The acceptance gate: one interleaved schedule, id-identical answers
    on all four paths — host, single-device server, sharded server, and the
    adaptive server with the streaming overlay."""
    pts = f32_points(3000, 2, seed=7)
    engines = ingest_suite(pts, ms=(3,))
    _drive_interleaved(engines, seed=7, steps=14)
    sharded = next(e for e in engines if e.name == "stream-server[m=3]")
    assert sharded.srv.stats.stream_reshards == 0


def test_tombstones_never_resurface():
    """Ids deleted early must be absent from every later answer while the
    stream flushes, merges and retires the tiers that physically held them;
    merges eventually drop the tombstoned rows (shadow shrinks)."""
    pts = f32_points(2000, 2, seed=4)
    s = StreamingIndex(pts, delta_threshold=256, delta_index_every=64,
                       size_ratio=2)
    rng = np.random.default_rng(4)
    doomed = np.unique(rng.integers(0, 2000, size=120))
    assert s.delete(doomed) == len(doomed)
    peak_shadow = s.shadow
    lo = np.zeros((1, 2))
    hi = np.ones((1, 2))
    for _ in range(20):
        s.insert(rng.random((200, 2)).astype(np.float32).astype(np.float64))
        everything = s.window(lo, hi)[0]
        assert not np.intersect1d(everything, doomed).size
        near = s.knn(pts[doomed[:4]], 4)
        for r in near:
            assert not np.intersect1d(r, doomed).size
    # clean merges fuse; the cascade that reaches the tombstone-bearing
    # boot tier rebuilds and physically drops the doomed rows
    assert s.fusions >= 1 and s.merges >= 1
    assert s.shadow < peak_shadow, "no merge ever dropped a tombstoned row"


if HAVE_HYPOTHESIS:

    @given(
        st.integers(0, 2**31 - 1),
        st.lists(
            st.tuples(st.integers(1, 120), st.integers(0, 25)),
            min_size=4, max_size=9,
        ),
    )
    @settings(max_examples=8, deadline=None)
    def test_hypothesis_interleavings(seed, script):
        """Arbitrary (insert-count, delete-count) scripts: the host stream
        stays id-identical to the from-scratch rebuild."""
        rng = np.random.default_rng(seed)
        pts = rng.random((600, 2)).astype(np.float32).astype(np.float64)
        oracle = RebuildOracle(pts)
        host = StreamingHostEngine(
            pts, delta_threshold=256, delta_index_every=64, size_ratio=2
        )
        n_ids = 600
        for n_ins, n_del in script:
            ins = rng.random((n_ins, 2)).astype(np.float32).astype(np.float64)
            np.testing.assert_array_equal(host.insert(ins), oracle.insert(ins))
            n_ids += n_ins
            if n_del:
                dels = rng.integers(0, n_ids, size=n_del)
                assert host.delete(dels) == oracle.delete(dels)
            los = rng.random((2, 2)) * 0.7
            his = los + 0.25
            for a, b in zip(host.window(los, his), oracle.window(los, his)):
                np.testing.assert_array_equal(np.sort(a), b)
            qs = rng.random((2, 2)).astype(np.float32).astype(np.float64)
            for a, b in zip(host.knn(qs, 6), oracle.knn(qs, 6)):
                np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------------------
# device refresh: delta-only uploads, shard surgery, no page leaks
# --------------------------------------------------------------------------
def test_single_device_uploads_are_delta_only():
    """The upload-counter proof: after boot, sustained ingest never triggers
    a full re-export — every device refresh goes through ``apply_delta``."""
    eng = StreamingServerEngine(f32_points(3000, 2, seed=2))
    srv = eng.srv
    boot_full = srv.upload_stats.full_exports
    rng = np.random.default_rng(2)
    n_ids = 3000
    for _ in range(16):
        n_ids += len(eng.insert(
            rng.random((180, 2)).astype(np.float32).astype(np.float64)
        ))
        eng.delete(rng.integers(0, n_ids, size=10))
    assert eng.stream.flushes >= 4 and eng.stream.merges >= 1
    assert srv.upload_stats.full_exports == boot_full
    assert srv.upload_stats.delta_refreshes >= eng.stream.flushes
    # and the mirrored answers are still exact
    oracle = RebuildOracle(f32_points(3000, 2, seed=2))
    rng2 = np.random.default_rng(2)
    for _ in range(16):
        oracle.insert(rng2.random((180, 2)).astype(np.float32).astype(np.float64))
        oracle.delete(rng2.integers(0, len(oracle.pts), size=10))
    los = np.array([[0.1, 0.1], [0.5, 0.4]])
    his = los + 0.3
    for a, b in zip(eng.window(los, his), oracle.window(los, his)):
        np.testing.assert_array_equal(np.sort(a), b)


def test_sharded_refresh_avoids_full_reshard():
    """Shard surgery absorbs tier attach/fuse/retire without ever falling
    back to a full re-shard; only the shards whose plan rows changed get
    re-exported."""
    eng = StreamingServerEngine(f32_points(4000, 2, seed=9), shards=3)
    rng = np.random.default_rng(9)
    n_ids = 4000
    for step in range(20):
        n_ids += len(eng.insert(
            rng.random((150, 2)).astype(np.float32).astype(np.float64)
        ))
        if step % 3 == 0:
            eng.delete(rng.integers(0, n_ids, size=25))
    st_ = eng.srv.stats
    assert st_.stream_syncs >= 3
    assert st_.stream_reshards == 0
    assert st_.shard_refreshes > 0
    # per-shard refreshes beat re-exporting all m shards on every sync
    assert st_.shard_refreshes < 3 * st_.stream_syncs


def test_tier_retirement_recycles_pages():
    """Satellite regression: retired tiers hand their pages back to the
    store's free list, so the allocator high-water mark stays bounded under
    sustained churn instead of leaking one tier's pages per merge."""
    pts = f32_points(2000, 2, seed=1)
    s = StreamingIndex(pts, delta_threshold=256, delta_index_every=64,
                       size_ratio=2)
    rng = np.random.default_rng(1)
    live = list(range(2000))
    peak = s.store.allocated_pages
    for _ in range(40):
        ids = s.insert(rng.random((256, 2)).astype(np.float32).astype(np.float64))
        live.extend(int(i) for i in ids)
        rng.shuffle(live)
        dead, live = live[:256], live[256:]
        s.delete(dead)
        peak = max(peak, s.store.allocated_pages)
    assert s.merges >= 5
    assert s.store.free_page_count > 0
    # live set is ~constant => bounded pages, despite 40 rebuild/merge cycles
    need = -(-s.n_live // 341) * 4  # leaves plus generous tree overhead
    assert peak < need + 120, (peak, need)


def test_mirror_rows_partition_live_tiers():
    """DeviceMirror invariant: the BFS-reachable leaf rows of the mirror
    table cover every live tier row exactly once — retired spans are
    neutralized, never resurrected, and fusions adopt both children."""
    pts = f32_points(1500, 2, seed=6)
    s = StreamingIndex(pts, delta_threshold=256, delta_index_every=64,
                       size_ratio=2)
    mirror = DeviceMirror(s)
    rng = np.random.default_rng(6)
    for step in range(12):
        s.insert(rng.random((200, 2)).astype(np.float32).astype(np.float64))
        s.delete(rng.integers(0, s.n_ids, size=20))
        mirror.sync()
        t = mirror.table
        seen = []
        frontier = [0]
        while frontier:
            r = frontier.pop()
            if t.child_count[r] > 0:
                frontier.extend(
                    range(t.first_child[r], t.first_child[r] + t.child_count[r])
                )
            elif t.leaf_count[r] > 0:
                seen.append(t.perm[t.leaf_start[r]:t.leaf_start[r] + t.leaf_count[r]])
        got = np.concatenate(seen)
        want = (np.concatenate([tier.rows for tier in s.tiers])
                if s.tiers else np.empty(0, np.int64))
        assert len(got) == len(np.unique(got)), "duplicate ids in mirror"
        np.testing.assert_array_equal(np.sort(got), np.sort(want))


# --------------------------------------------------------------------------
# races: ingest concurrent with query threads (the compaction-race fix)
# --------------------------------------------------------------------------
def _raced(engine_factory):
    pts = f32_points(3000, 2, seed=13)
    n_base = len(pts)
    eng = engine_factory(pts)
    rng0 = np.random.default_rng(13)
    pre_deleted = np.unique(rng0.integers(0, n_base, size=80))
    eng.delete(pre_deleted)
    pre_set = set(int(i) for i in pre_deleted)

    stop = threading.Event()
    errors = []

    def ingest():
        rng = np.random.default_rng(99)
        mine = []
        try:
            for _ in range(30):
                ids = eng.insert(
                    rng.random((64, 2)).astype(np.float32).astype(np.float64)
                )
                mine.extend(int(i) for i in ids)
                if len(mine) > 128:  # only ever deletes its own inserts
                    rng.shuffle(mine)
                    eng.delete(mine[:32])
                    mine = mine[32:]
        except Exception as e:  # noqa: BLE001 - recorded for the main thread
            errors.append(("ingest", e))
        finally:
            stop.set()

    def query(tseed):
        rng = np.random.default_rng(tseed)
        try:
            while not stop.is_set():
                lo = rng.random(2) * 0.6
                hi = lo + 0.3
                got = eng.window(lo, hi)[0]
                assert len(got) == len(np.unique(got))
                in_box = ((pts >= lo) & (pts <= hi)).all(axis=1)
                want_base = set(
                    int(i) for i in np.flatnonzero(in_box)
                ) - pre_set
                got_base = set(int(i) for i in got if i < n_base)
                assert got_base == want_base, "raced base-id window drift"
                r = eng.knn(rng.random(2), 8)[0]
                assert len(r) == len(np.unique(r)) and len(r) <= 8
                assert not set(int(i) for i in r) & pre_set
        except Exception as e:  # noqa: BLE001
            errors.append((f"query-{tseed}", e))

    threads = [threading.Thread(target=ingest)] + [
        threading.Thread(target=query, args=(t,)) for t in (1, 2, 3)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors

    # quiesced: full parity against a rebuild oracle replaying the same ops
    oracle = RebuildOracle(pts)
    oracle.delete(pre_deleted)
    rng = np.random.default_rng(99)
    mine = []
    for _ in range(30):
        ids = oracle.insert(
            rng.random((64, 2)).astype(np.float32).astype(np.float64)
        )
        mine.extend(int(i) for i in ids)
        if len(mine) > 128:
            rng.shuffle(mine)
            oracle.delete(mine[:32])
            mine = mine[32:]
    los = np.array([[0.05, 0.1], [0.4, 0.4], [0.0, 0.0]])
    his = los + np.array([[0.3, 0.3], [0.35, 0.3], [1.0, 1.0]])
    for a, b in zip(eng.window(los, his), oracle.window(los, his)):
        np.testing.assert_array_equal(np.sort(a), b)
    qs = f32_points(4, 2, seed=77)
    for a, b in zip(eng.knn(qs, 10), oracle.knn(qs, 10)):
        np.testing.assert_array_equal(a, b)


def test_raced_ingest_streaming_server_sharded():
    _raced(lambda pts: StreamingServerEngine(pts, shards=3))


def test_raced_ingest_adaptive_overlay_compaction():
    """The satellite-3 regression: query threads drive adaptive refinement
    (and frequent compaction — tiny ``compact_slack``) while the ingest
    thread mutates the overlay.  The compactor runs strictly inside the
    TableLock writer section and bumps the table version, so refinement
    writers recompute their row sets instead of grafting stale rows."""

    def make(pts):
        eng = OverlayServerEngine(pts)
        eng.srv.compact_slack = 0.02  # compact nearly every graft
        return eng

    _raced(make)


# --------------------------------------------------------------------------
# durability: checkpoint + journal replay on both streaming paths
# --------------------------------------------------------------------------
def _ingest_script(eng, seed, rounds):
    rng = np.random.default_rng(seed)
    n = 0
    for _ in range(rounds):
        ids = eng.insert(
            rng.random((90, 2)).astype(np.float32).astype(np.float64)
        )
        n = int(ids[-1]) + 1
        eng.delete(rng.integers(0, n, size=12))
    return n


def test_streaming_server_recover_replays_ingest(tmp_path):
    pts = f32_points(2000, 2, seed=8)
    live = StreamingServerEngine(
        pts,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
    )
    _ingest_script(live, seed=8, rounds=4)
    live.srv.checkpoint()
    _ingest_script(live, seed=88, rounds=3)  # post-checkpoint: replayed

    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal", microbatch=32
    )
    assert rec.stream is not None
    assert rec.stats.replayed_records > 0
    assert rec.journal.seq == live.srv.journal.seq
    # identical ingest state and identical answers
    assert rec.stream.n_ids == live.stream.n_ids
    assert rec.stream.shadow == live.stream.shadow
    np.testing.assert_array_equal(
        rec.stream.live_ids(), live.stream.live_ids()
    )
    los = np.array([[0.1, 0.2], [0.0, 0.0]])
    his = np.array([[0.45, 0.55], [1.0, 1.0]])
    for a, b in zip(rec.window(los, his), live.window(los, his)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))
    qs = f32_points(3, 2, seed=5)
    for a, b in zip(rec.knn(qs, 9), live.knn(qs, 9)):
        np.testing.assert_array_equal(a, b)


def test_adaptive_overlay_recover(tmp_path):
    """Kill the adaptive server after checkpoint: graft records AND overlay
    ingest records replay, and the overlay sidecar restores tiers written at
    checkpoint time."""
    pts = f32_points(2500, 2, seed=14)
    live = OverlayServerEngine(
        pts,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
    )
    rng = np.random.default_rng(14)
    for _ in range(3):  # cold queries first: graft journal records
        c = rng.random(2)
        live.window(c - 0.08, c + 0.08)
    _ingest_script(live, seed=14, rounds=8)  # crosses the overlay threshold
    assert live.srv.stream is not None and live.srv.stream.tiers
    live.srv.checkpoint()
    assert (tmp_path / "snap.stream.npz").exists()
    for _ in range(2):
        c = rng.random(2)
        live.window(c - 0.08, c + 0.08)
    _ingest_script(live, seed=15, rounds=2)

    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal", microbatch=32
    )
    rec.OVERLAY_KW = dict(STREAM_KW)
    assert rec.stream is not None
    assert rec.stream.n_ids == live.srv.stream.n_ids
    np.testing.assert_array_equal(
        rec.stream.live_ids(), live.srv.stream.live_ids()
    )
    los = np.array([[0.15, 0.15], [0.0, 0.0]])
    his = np.array([[0.5, 0.6], [1.0, 1.0]])
    for a, b in zip(rec.window(los, his), live.window(los, his)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))
    qs = f32_points(3, 2, seed=15)
    for a, b in zip(rec.knn(qs, 7), live.knn(qs, 7)):
        np.testing.assert_array_equal(a, b)


def test_journal_order_matches_application_order_under_races(tmp_path):
    """Racing inserts must journal in the exact order they are applied:
    replaying the journal has to reproduce the same id -> point mapping
    the live server acknowledged to clients."""
    pts = f32_points(800, 2, seed=31)
    live = StreamingServerEngine(
        pts,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
    )
    live.srv.checkpoint()  # empty barrier so recover() has a snapshot

    def writer(t):
        rng = np.random.default_rng(100 + t)
        for _ in range(20):
            batch = rng.random((25, 2))
            batch[:, 0] = (batch[:, 0] + t) / 2.0  # thread-distinct coords
            live.insert(batch)

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal", microbatch=32
    )
    n = live.srv.stream.n_ids
    assert rec.stream.n_ids == n
    np.testing.assert_array_equal(
        rec.stream.points[:n], live.srv.stream.points[:n]
    )


def test_out_of_range_delete_rejected_before_journaling(tmp_path):
    """A delete with ids outside the stream's range must fail *before* a
    journal record lands — a durable record that deterministically raises
    would make every subsequent recover() fail."""
    from repro.serve.journal import GraftJournal

    pts = f32_points(900, 2, seed=7)
    live = StreamingServerEngine(
        pts,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
    )
    live.srv.checkpoint()
    _ingest_script(live, seed=7, rounds=2)
    bad = live.srv.stream.n_ids + 1000
    with pytest.raises(IndexError):
        live.delete([bad])
    _ingest_script(live, seed=77, rounds=1)  # server keeps ingesting

    for rec_ in GraftJournal.read_records(tmp_path / "ops.journal"):
        if rec_["op"] == "delete":
            assert bad not in rec_["ids"]
    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal", microbatch=32
    )
    np.testing.assert_array_equal(
        rec.stream.live_ids(), live.srv.stream.live_ids()
    )


def test_single_device_stale_upload_serves_exact_then_converges():
    """When the single-device tier upload exhausts its retries, queries
    fall back to the authoritative host stream (exact answers, intact
    certificates) and the upload is re-attempted on the next sync even
    when that sync carries no new structural events."""
    from repro.serve.faults import FaultPlan, FaultRule
    from repro.serve.resilience import RetryPolicy

    pts = f32_points(1500, 2, seed=21)
    plan = FaultPlan([FaultRule("apply_delta", rate=1.0, max_fires=2)],
                     seed=0)
    eng = StreamingServerEngine(
        pts, fault_plan=plan,
        retry=RetryPolicy(max_attempts=2, sleep=lambda s: None),
    )
    oracle = StreamingHostEngine(pts)
    rng = np.random.default_rng(21)
    batch = rng.random((600, 2))  # crosses delta_threshold: flush + upload
    eng.insert(batch)
    oracle.insert(batch)
    assert eng.srv._stream_device_stale  # both attempts faulted

    los = np.array([[0.1, 0.1], [0.0, 0.0]])
    his = np.array([[0.6, 0.7], [1.0, 1.0]])
    res, certs = eng.srv.window(los, his, return_certs=True)
    assert all(c.complete for c in certs)
    for a, b in zip(res, oracle.window(los, his)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))
    qs = rng.random((3, 2))
    for a, b in zip(eng.knn(qs, 8), oracle.knn(qs, 8)):
        np.testing.assert_array_equal(a, b)

    small = rng.random((10, 2))  # no flush, but the stale flag re-uploads
    eng.insert(small)
    oracle.insert(small)
    assert not eng.srv._stream_device_stale
    for a, b in zip(eng.window(los, his), oracle.window(los, his)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))


def test_streaming_sharded_outage_returns_degraded_certificates():
    """A shard outage on the streaming sharded path must surface through
    the completeness certificates (degraded, naming the dead shard's
    subspaces) instead of raising through window(return_certs=True)."""
    from repro.serve.faults import FaultPlan, FaultRule
    from repro.serve.resilience import RetryPolicy

    pts = f32_points(2000, 2, seed=11)
    plan = FaultPlan(
        [FaultRule("shard_dispatch", rate=1.0, match={"shard": 1})], seed=0
    )
    eng = StreamingServerEngine(
        pts, shards=3, fault_plan=plan,
        retry=RetryPolicy(max_attempts=2, sleep=lambda s: None),
    )
    oracle = StreamingHostEngine(pts)
    los = np.array([[0.0, 0.0], [0.2, 0.1]])
    his = np.array([[1.0, 1.0], [0.8, 0.9]])
    res, certs = eng.srv.window(los, his, return_certs=True)
    assert any(not c.complete for c in certs)
    for a, b in zip(res, oracle.window(los, his)):
        assert np.isin(a, b).all()  # degraded: subset of the true answer
    # k-NN must also serve under the outage instead of raising (its
    # certificate may still be certified_exact if pruning clears the
    # dead shard's subspaces — that is the protocol's contract)
    qs = f32_points(2, 2, seed=12)
    res, certs = eng.srv.knn(qs, 5, return_certs=True)
    assert len(res) == len(certs) == len(qs)


def test_sidecar_crash_between_saves_loses_no_ingest(tmp_path, monkeypatch):
    """The adaptive barrier writes base .npz then the overlay sidecar; a
    crash in between leaves the *previous* sidecar next to the new base.
    Recovery must replay ingest from the sidecar's own seq, so the ops
    between the two barriers (still in the journal) are not lost."""
    from repro.serve.faults import FaultError
    from repro.serve.resilience import RetryExhausted, RetryPolicy

    pts = f32_points(2500, 2, seed=14)
    live = OverlayServerEngine(
        pts,
        journal_path=tmp_path / "ops.journal",
        snapshot_path=tmp_path / "snap.npz",
        retry=RetryPolicy(max_attempts=2, sleep=lambda s: None),
    )
    _ingest_script(live, seed=14, rounds=8)
    assert live.srv.stream is not None
    live.srv.checkpoint()  # barrier 1: base + sidecar at the same seq
    _ingest_script(live, seed=15, rounds=2)  # must survive the torn barrier

    real_save = StreamingIndex.save

    def torn_save(self, path, extra=None):
        raise FaultError("crash between base snapshot and sidecar save")

    monkeypatch.setattr(StreamingIndex, "save", torn_save)
    with pytest.raises(RetryExhausted):
        live.srv.checkpoint()  # base lands at the new seq, sidecar stays old
    monkeypatch.setattr(StreamingIndex, "save", real_save)

    rec = DeviceQueryServer.recover(
        tmp_path / "snap.npz", tmp_path / "ops.journal", microbatch=32
    )
    assert rec.stream is not None
    assert rec.stream.n_ids == live.srv.stream.n_ids
    np.testing.assert_array_equal(
        rec.stream.live_ids(), live.srv.stream.live_ids()
    )
    los = np.array([[0.15, 0.15], [0.0, 0.0]])
    his = np.array([[0.5, 0.6], [1.0, 1.0]])
    for a, b in zip(rec.window(los, his), live.window(los, his)):
        np.testing.assert_array_equal(np.sort(a), np.sort(b))


def test_stream_snapshot_roundtrip(tmp_path):
    """Host-level save/load: points, tombstones, tiers, delta and the page
    store round-trip; the reloaded stream keeps answering and ingesting."""
    pts = f32_points(1800, 2, seed=3)
    s = StreamingIndex(pts, **STREAM_KW)
    rng = np.random.default_rng(3)
    for _ in range(6):
        s.insert(rng.random((150, 2)).astype(np.float32).astype(np.float64))
        s.delete(rng.integers(0, s.n_ids, size=15))
    s.save(tmp_path / "stream.npz", extra={"journal_seq": 41})
    assert StreamingIndex.is_stream_snapshot(tmp_path / "stream.npz")
    idx = bulk_load(pts, 250, PageStore(250))
    idx.save(tmp_path / "static.npz")
    assert not StreamingIndex.is_stream_snapshot(tmp_path / "static.npz")

    s2, meta = StreamingIndex.load(tmp_path / "stream.npz")
    assert meta["journal_seq"] == 41
    assert s2.n_ids == s.n_ids and s2.shadow == s.shadow
    los = rng.random((3, 2)) * 0.6
    his = los + 0.25
    for a, b in zip(s.window(los, his), s2.window(los, his)):
        np.testing.assert_array_equal(a, b)
    qs = rng.random((3, 2)).astype(np.float32).astype(np.float64)
    for a, b in zip(s.knn(qs, 6), s2.knn(qs, 6)):
        np.testing.assert_array_equal(a, b)
    # both copies continue ingesting identically
    more = rng.random((600, 2)).astype(np.float32).astype(np.float64)
    np.testing.assert_array_equal(s.insert(more), s2.insert(more))
    for a, b in zip(s.window(los, his), s2.window(los, his)):
        np.testing.assert_array_equal(a, b)
