"""Serving: LM generation engine + FMBI retrieval server."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.datasets import osm_like
from repro.launch.train import reduced_config
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import model as M
from repro.serve.engine import LMServer, RetrievalServer


def test_lm_server_greedy_generation():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = ModelConfig(
        name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=100, dtype="float32", chunk_q=16,
    )
    with use_mesh(mesh):
        params = M.init_params(cfg, jax.random.key(0))
        server = LMServer(cfg, params)
        prompts = np.random.default_rng(0).integers(0, 100, (2, 12))
        out = server.generate(prompts, max_new=5)
    assert out.shape == (2, 5)
    assert out.dtype.kind in "iu"
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()


def test_retrieval_server_exact_and_kernel_paths_agree():
    pts = osm_like(4096, seed=1)
    srv = RetrievalServer(pts, levels=5)
    qs = np.random.default_rng(2).random((8, 2)).astype(np.float32)
    rows, d2, exact = srv.knn(qs, 8, n_candidate_leaves=12)
    _, d2k = srv.knn_kernel(qs, 8)
    for i, q in enumerate(qs):
        od = np.sort(np.sum((pts - q) ** 2, axis=1))[:8]
        if exact[i]:
            np.testing.assert_allclose(np.sort(d2[i]), od, rtol=1e-3,
                                       atol=1e-6)
        np.testing.assert_allclose(np.sort(d2k[i]), od, rtol=1e-3,
                                   atol=1e-6)


def test_adaptive_residency_hit_rate_improves_for_focused_stream():
    """AMBI's residency policy: a focused query stream converges onto a hot
    leaf set (high hit rate); a uniform stream keeps missing."""
    pts = osm_like(20_000, seed=3)
    rng = np.random.default_rng(4)

    focused = RetrievalServer(pts, levels=6, adaptive=True, hot_capacity=8)
    for _ in range(30):
        qs = (rng.random((16, 2)) * 0.05 + 0.6).astype(np.float32)
        focused.knn(qs, 4)

    uniform = RetrievalServer(pts, levels=6, adaptive=True, hot_capacity=8)
    for _ in range(30):
        qs = rng.random((16, 2)).astype(np.float32)
        uniform.knn(qs, 4)

    assert focused.stats.hit_rate > uniform.stats.hit_rate + 0.2


def test_retrieval_server_boots_from_nodetable_snapshot(tmp_path):
    """Bulk load on CPU, snapshot the flat table, and boot the serving path
    from the snapshot without rebuilding: exact answers, adaptive residency
    via nearest_leaf."""
    from repro.core import PageStore, bulk_load

    pts = osm_like(8_000, seed=7)
    idx = bulk_load(pts, 250, PageStore(250))
    snap = tmp_path / "index.npz"
    idx.save(snap)

    srv = RetrievalServer.from_snapshot(snap, adaptive=True, hot_capacity=16)
    assert not srv._routed
    qs = np.random.default_rng(5).random((16, 2)).astype(np.float32)
    rows, d2, exact = srv.knn(qs, 8, n_candidate_leaves=24)
    for i, q in enumerate(qs):
        if exact[i]:
            od = np.sort(np.sum((pts - q) ** 2, axis=1))[:8]
            np.testing.assert_allclose(np.sort(d2[i]), od, rtol=1e-3,
                                       atol=1e-6)
    assert srv.stats.queries == 16  # adaptive residency ran via nearest_leaf

    # bridged leaf grid matches the table: window counts stay exact
    from repro.core import jax_index as JI
    import jax.numpy as jnp

    los = qs[:4] - 0.05
    his = qs[:4] + 0.05
    counts = JI.window_count(srv.index, jnp.asarray(los), jnp.asarray(his))
    for i in range(4):
        ref = int(np.sum(np.all((pts >= los[i]) & (pts <= his[i]), axis=1)))
        assert int(counts[i]) == ref
