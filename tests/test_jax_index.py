"""JAX-native index: build/route/query parity with oracles + shard_map."""
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jax_index
from repro.core.datasets import gaussian, osm_like


@pytest.mark.parametrize("d,levels", [(2, 4), (3, 6), (5, 5)])
def test_build_partitions_equally(d, levels):
    pts = gaussian(4096, d, seed=d).astype(np.float32)
    padded, ids = jax_index.pad_points(pts, levels)
    idx = jax_index.build(jnp.asarray(padded), levels,
                          jnp.asarray(ids, jnp.int32))
    assert idx.n_leaves == 1 << levels
    assert idx.points_sorted.shape[0] == padded.shape[0]
    # each point is inside its leaf's box
    g = jax_index.route(idx, jnp.asarray(pts))
    lo, hi = idx.leaf_lo[g], idx.leaf_hi[g]
    assert bool(jnp.all((pts >= lo - 1e-6) & (pts <= hi + 1e-6)))


def test_window_counts_match_oracle():
    pts = osm_like(8192, seed=2).astype(np.float32)
    padded, ids = jax_index.pad_points(pts, 6)
    idx = jax_index.build(jnp.asarray(padded), 6, jnp.asarray(ids, jnp.int32))
    rng = np.random.default_rng(0)
    los = (rng.random((32, 2)) * 0.8).astype(np.float32)
    his = los + 0.1
    got = np.asarray(jax_index.window_count(idx, jnp.asarray(los),
                                            jnp.asarray(his)))
    want = np.array(
        [np.sum(np.all((pts >= l) & (pts <= h), axis=1))
         for l, h in zip(los, his)]
    )
    np.testing.assert_array_equal(got, want)


def test_window_count_candidate_budget_and_certificate():
    """Candidate-leaf counting: contained leaves are counted without a scan,
    straddling leaves within the budget are scanned exactly, and the
    certificate flags an insufficient budget instead of lying."""
    pts = osm_like(16_384, seed=4).astype(np.float32)
    padded, ids = jax_index.pad_points(pts, 7)
    idx = jax_index.build(jnp.asarray(padded), 7, jnp.asarray(ids, jnp.int32))
    rng = np.random.default_rng(1)
    los = (rng.random((16, 2)) * 0.7).astype(np.float32)
    his = los + 0.25  # wide windows: many contained + several straddling
    want = np.array(
        [np.sum(np.all((pts >= l) & (pts <= h), axis=1))
         for l, h in zip(los, his)]
    )
    # generous budget: exact everywhere, certificate holds
    cnt, exact = jax_index.window_count_candidates(
        idx, jnp.asarray(los), jnp.asarray(his), idx.n_leaves
    )
    assert bool(jnp.all(exact))
    np.testing.assert_array_equal(np.asarray(cnt), want)
    # starved budget: never overcounts, and the certificate is withdrawn
    cnt1, exact1 = jax_index.window_count_candidates(
        idx, jnp.asarray(los), jnp.asarray(his), 1
    )
    assert np.all(np.asarray(cnt1) <= want)
    assert not bool(jnp.all(exact1))
    # the auto-budget wrapper is always exact, with or without the kernel
    for use_kernel in (False, True):
        got = jax_index.window_count(
            idx, jnp.asarray(los), jnp.asarray(his), use_kernel=use_kernel
        )
        np.testing.assert_array_equal(np.asarray(got), want)
    # an explicit starved budget escalates until certified, staying exact
    got = jax_index.window_count(
        idx, jnp.asarray(los), jnp.asarray(his), n_candidate_leaves=1
    )
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("k", [1, 8, 32])
def test_knn_exact_with_certificate(k):
    pts = gaussian(4096, 3, seed=9).astype(np.float32)
    padded, ids = jax_index.pad_points(pts, 5)
    idx = jax_index.build(jnp.asarray(padded), 5, jnp.asarray(ids, jnp.int32))
    qs = np.random.default_rng(1).random((16, 3)).astype(np.float32)
    rows, d2, exact = jax_index.knn(idx, jnp.asarray(qs), k,
                                    n_candidate_leaves=12)
    for i, q in enumerate(qs):
        if not bool(exact[i]):
            continue  # certificate withheld: no exactness claim
        od = np.sort(np.sum((pts - q) ** 2, axis=1))[:k]
        np.testing.assert_allclose(np.sort(np.asarray(d2[i])), od, rtol=1e-4)
    assert np.mean(np.asarray(exact)) > 0.8  # certificate usually holds


def test_window_count_compile_cache_bounded():
    """Recompiles are bounded: budgets are bucketed to powers of two, so a
    workload whose straddle widths grow across calls reuses the warm
    variants — a repeated sweep adds zero retraces of the counting core."""
    pts = osm_like(16_384, seed=6).astype(np.float32)
    padded, ids = jax_index.pad_points(pts, 7)
    idx = jax_index.build(jnp.asarray(padded), 7, jnp.asarray(ids, jnp.int32))
    rng = np.random.default_rng(3)
    los = (rng.random((16, 2)) * 0.5).astype(np.float32)

    def sweep():
        for w in (0.02, 0.05, 0.1, 0.2, 0.35, 0.5):  # growing straddle
            jax_index.window_count(idx, jnp.asarray(los),
                                   jnp.asarray(los + w))
        # explicit non-pow2 budgets land in the same pow2 bucket
        for budget in (5, 6, 7, 8):
            jax_index.window_count(idx, jnp.asarray(los),
                                   jnp.asarray(los + 0.1),
                                   n_candidate_leaves=budget)

    sweep()  # warm every bucket this workload can reach
    before = jax_index.window_count_traces()
    sweep()
    sweep()
    assert jax_index.window_count_traces() == before


DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed
from repro.core.datasets import gaussian
if len(jax.devices()) < 8:
    print(f"DIST-SKIP: only {len(jax.devices())} devices"); sys.exit(0)
try:
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
except (AttributeError, TypeError):  # older jax: no axis_types kwarg
    mesh = jax.make_mesh((8,), ("data",))
pts = gaussian(8192, 2, seed=5).astype(np.float32)
out = distributed.shard_build(jnp.asarray(pts), mesh, levels_local=4)
nm = np.asarray(out[6]).ravel()
assert nm.sum() == 8192, f"lost points: {nm}"
assert nm.max() / nm.mean() < 1.3, f"unbalanced: {nm}"
qs = np.random.default_rng(1).random((8, 2)).astype(np.float32)
d2, rows, shards = distributed.shard_knn(out, jnp.asarray(qs), 8, mesh,
                                         levels_local=4,
                                         n_candidate_leaves=16)
for i, q in enumerate(qs):
    od = np.sort(np.sum((pts - q) ** 2, axis=1))[:8]
    got = np.sort(np.asarray(d2[i]))
    assert np.allclose(got, od, rtol=1e-4), (i, got, od)
print("DIST-OK")
"""


def test_shard_map_distributed_build_and_knn_8dev():
    """Section-5 distributed path on 8 simulated devices (subprocess so the
    forced device count never leaks into this process)."""
    res = subprocess.run(
        [sys.executable, "-c", DIST_SCRIPT], capture_output=True, text=True,
        cwd=str(__import__("pathlib").Path(__file__).resolve().parents[1]),
        timeout=300,
    )
    if "DIST-SKIP" in res.stdout:
        pytest.skip(
            "needs 8 (virtual) devices; host could not provision them: "
            + res.stdout.strip()
        )
    assert "DIST-OK" in res.stdout, res.stdout + res.stderr
