import numpy as np
import pytest

from repro.core import (
    PageStore,
    bulk_load,
    knn_oracle,
    knn_query,
    leaf_stats,
    window_oracle,
    window_query,
)
from repro.core.datasets import gaussian, osm_like, uniform


@pytest.fixture(scope="module")
def built():
    pts = osm_like(250_000, seed=3)  # 734 pages >> 250-page buffer
    store = PageStore(250)
    idx = bulk_load(pts, 250, store)
    return pts, idx, store


def _all_leaf_rows(idx):
    rows = []
    for leaf in idx.root.iter_leaves():
        rows.append(leaf.point_idx)
    return np.concatenate(rows)


def test_every_point_indexed_exactly_once(built):
    pts, idx, _ = built
    rows = _all_leaf_rows(idx)
    assert len(rows) == len(pts)
    assert len(np.unique(rows)) == len(pts)


def test_leaf_mbbs_contain_points(built):
    pts, idx, _ = built
    for leaf in idx.root.iter_leaves():
        sub = pts[leaf.point_idx]
        assert np.all(sub >= leaf.mbb[0] - 1e-12)
        assert np.all(sub <= leaf.mbb[1] + 1e-12)
        assert len(leaf.point_idx) <= idx.leaf_cap


def test_branch_fanout_within_capacity(built):
    _, idx, _ = built
    stack = [idx.root]
    while stack:
        n = stack.pop()
        if not n.is_leaf:
            assert 1 <= len(n.children) <= idx.branch_cap
            stack.extend(n.children)


def test_zero_sibling_leaf_overlap_2d():
    """FMBI's median splits produce zero overlap between leaves."""
    pts = uniform(20_000, 2, seed=1)
    idx = bulk_load(pts, 250)
    from repro.core.metrics import overlap_area_2d

    assert overlap_area_2d(idx) < 1e-9


def test_construction_io_beats_sort_based(built):
    pts, _, store = built
    from repro.core.baselines import bulk_load_str

    st2 = PageStore(250)
    bulk_load_str(pts, 250, st2)
    assert store.stats.total < st2.stats.total


def test_window_queries_match_oracle(built):
    pts, idx, _ = built
    rng = np.random.default_rng(0)
    for _ in range(25):
        c = rng.random(2)
        w = rng.uniform(0.005, 0.08)
        res, io = window_query(idx, c - w, c + w)
        ref = window_oracle(pts, c - w, c + w)
        assert sorted(res.tolist()) == sorted(ref.tolist())
        assert io.total >= 0


def test_knn_queries_match_oracle(built):
    pts, idx, _ = built
    rng = np.random.default_rng(1)
    for k in (1, 16, 64):
        q = rng.random(2)
        res, _ = knn_query(idx, q, k)
        ref = knn_oracle(pts, q, k)
        d_res = np.sort(np.sum((pts[res] - q) ** 2, axis=1))
        d_ref = np.sort(np.sum((pts[ref] - q) ** 2, axis=1))
        assert np.allclose(d_res, d_ref)


def test_dense_subspace_recursion_tiny_buffer():
    """A tiny buffer forces Step-5 dense recursion; index stays exact."""
    pts = gaussian(120_000, 2, seed=5)
    idx = bulk_load(pts, 230)  # barely above C_B=204
    rows = _all_leaf_rows(idx)
    assert len(np.unique(rows)) == len(pts)
    rng = np.random.default_rng(2)
    for _ in range(5):
        c = rng.random(2)
        res, _ = window_query(idx, c - 0.03, c + 0.03)
        ref = window_oracle(pts, c - 0.03, c + 0.03)
        assert sorted(res.tolist()) == sorted(ref.tolist())


def test_balance_close_to_paper(built):
    """Paper Fig 4a: subspace max/mean cardinality ~= 1.06 at scale; allow
    slack at our reduced N."""
    _, idx, _ = built
    ls = leaf_stats(idx)
    assert ls.max_over_mean < 1.6
    assert ls.min_over_mean > 0.4


def test_higher_dims():
    from repro.core.datasets import nycyt_like

    for d in (3, 4, 5):
        pts = nycyt_like(30_000, d=d, seed=7)
        idx = bulk_load(pts, 300)
        rows = _all_leaf_rows(idx)
        assert len(np.unique(rows)) == len(pts)
        rng = np.random.default_rng(3)
        q = rng.random(d)
        res, _ = knn_query(idx, q, 8)
        ref = knn_oracle(pts, q, 8)
        assert np.allclose(
            np.sort(np.sum((pts[res] - q) ** 2, axis=1)),
            np.sort(np.sum((pts[ref] - q) ** 2, axis=1)),
        )
