import numpy as np
import pytest

from repro.core import ALL_LOADERS, PageStore, leaf_stats, window_oracle, window_query
from repro.core.datasets import osm_like

N = 250_000  # 734 pages >> M: the buffer must spill
M = 250


@pytest.fixture(scope="module")
def data():
    return osm_like(N, seed=21)


@pytest.fixture(scope="module")
def all_built(data):
    out = {}
    for name, loader in ALL_LOADERS.items():
        store = PageStore(M)
        out[name] = (loader(data, M, store), store)
    return out


@pytest.mark.parametrize("name", sorted(ALL_LOADERS))
def test_loader_indexes_every_point(all_built, data, name):
    idx, _ = all_built[name]
    rows = np.concatenate(
        [l.point_idx for l in idx.root.iter_leaves()]
    )
    assert len(np.unique(rows)) == len(data)


@pytest.mark.parametrize("name", sorted(ALL_LOADERS))
def test_loader_queries_match_oracle(all_built, data, name):
    idx, _ = all_built[name]
    rng = np.random.default_rng(5)
    for _ in range(8):
        c = rng.random(2)
        w = rng.uniform(0.01, 0.06)
        res, _ = window_query(idx, c - w, c + w)
        ref = window_oracle(data, c - w, c + w)
        assert sorted(res.tolist()) == sorted(ref.tolist()), name


def test_packed_loaders_are_full(all_built):
    for name in ("hilbert", "str", "omt", "waffle"):
        ls = leaf_stats(all_built[name][0])
        assert ls.avg_fill > 0.98, name


def test_kdb_leaves_not_packed(all_built):
    packed = leaf_stats(all_built["str"][0]).count
    kdb = leaf_stats(all_built["kdb"][0]).count
    assert kdb > packed  # paper Table 1: KDB has the highest leaf count


def test_paper_construction_cost_ordering(all_built):
    """Fig 7 top-left qualitative ordering: FMBI < Hilbert < STR < top-down
    methods (OMT / KDB / Waffle)."""
    cost = {n: s.stats.total for n, (_, s) in all_built.items()}
    assert cost["fmbi"] < cost["hilbert"] < cost["str"]
    for heavy in ("omt", "kdb", "waffle"):
        assert cost["str"] < cost[heavy]


def test_fmbi_area_is_competitive(all_built):
    """Paper Table 1 / Fig 4: FMBI total leaf area at or below the packed
    R-tree variants; Hilbert worst (overlap)."""
    area = {n: leaf_stats(i).total_area for n, (i, _) in all_built.items()}
    assert area["fmbi"] <= min(area["str"], area["omt"]) * 1.1
    assert area["hilbert"] > area["fmbi"]
